"""Benchmark harness — one function per paper table/figure.

    concordance   — Fig. 2 left: engine vs per-trait OLS (Pearson of -log10 p)
    throughput    — Fig. 2 right / §3.2: wall time vs panel width P, panel
                    engine vs per-trait loop (the fastGWA-usage analogue)
    engines       — dense (paper-faithful) vs fused 2-bit path, equal stats
    kernels       — us/call of the association GEMM across batch geometries
    scaling_n     — runtime vs cohort size N (linear, §2.2)

Prints ``name,us_per_call,derived`` CSV rows.  CPU numbers contextualize the
*shape* of the paper's claims (sub-linear P scaling, engine equivalence);
absolute TPU throughput comes from the dry-run roofline (EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from scipy import stats as sps

from repro.core import association as A
from repro.core import residualize as Rz
from repro.core.screening import GenomeScan, ScanConfig
from repro.io import plink, synth

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _timeit(fn, *args, repeats=3):
    out = fn(*args)  # compile / warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6, out


def bench_concordance() -> None:
    """Paper Fig. 2 left: near-perfect agreement with per-trait OLS."""
    co = synth.make_cohort(n_samples=500, n_markers=300, n_traits=8,
                           n_causal=6, effect_size=0.5, seed=1)
    n, q = 500, co.covariates.shape[1]
    qb = Rz.covariate_basis(jnp.asarray(co.covariates), n)
    panel = Rz.residualize_and_standardize(jnp.asarray(co.phenotypes), qb)
    res, _ = A.assoc_batch(
        jnp.asarray(co.dosages.astype(np.float32)), panel.y,
        n_samples=n, n_covariates=q,
    )
    g_std, _ = A.standardize_genotype_batch(jnp.asarray(co.dosages.astype(np.float32)))
    g_std = np.asarray(g_std)
    yr = np.asarray(panel.y)
    ref = np.empty((300, 8), np.float64)
    for m in range(300):
        for p in range(8):
            ref[m, p] = sps.linregress(g_std[m], yr[:, p]).rvalue
    r_pearson = np.corrcoef(np.asarray(res.r).ravel(), ref.ravel())[0, 1]
    emit("concordance_fig2_left", 0.0, f"pearson_r={r_pearson:.6f}")


def bench_throughput() -> None:
    """Paper Fig. 2 right: runtime vs phenotype count, panel vs per-trait.

    Two pipelines are timed: the scan core (GEMM + t statistics — on the
    paper's GPU/our TPU target this is the whole cost) and the full pipeline
    including -log10 p.  On this single CPU core the special-function
    epilogue (128-trip continued fraction per cell) dominates and scales
    linearly in P, masking the amortization; the core rows reproduce the
    paper's sub-linear claim, and the full rows document the artifact
    honestly (on TPU the epilogue is <0.1 % of the GEMM — §Roofline)."""
    n, m = 2_000, 4_096
    rng = np.random.default_rng(0)
    g = rng.binomial(2, 0.3, size=(m, n)).astype(np.float32)
    g_dev, _ = A.standardize_genotype_batch(jnp.asarray(g))
    g_dev = jax.block_until_ready(g_dev)

    core_opts = A.AssocOptions(compute_neglog10p=False)

    @jax.jit
    def core_scan(g_std, y_std):
        return A.assoc_from_standardized(
            g_std, y_std, n_samples=n, n_covariates=0, options=core_opts
        )

    @jax.jit
    def full_scan(g_std, y_std):
        return A.assoc_from_standardized(g_std, y_std, n_samples=n, n_covariates=0)

    qb = Rz.covariate_basis(None, n)
    base_us = base_p = None
    us_core = 0.0
    for p in [64, 256, 1024, 2048]:
        y = rng.normal(size=(n, p)).astype(np.float32)
        panel = Rz.residualize_and_standardize(jnp.asarray(y), qb)
        us_core, _ = _timeit(core_scan, g_dev, panel.y)
        us_full, _ = _timeit(full_scan, g_dev, panel.y, repeats=1)
        if base_us is None:
            base_us, base_p = us_core, p
        emit(f"throughput_core_P{p}", us_core, f"us_per_phenotype={us_core / p:.2f}")
        emit(f"throughput_full_P{p}", us_full, f"pvalue_epilogue_share={1 - us_core / max(us_full, 1):.2f}")
    emit("throughput_sublinearity_core", 0.0,
         f"grew_{us_core / base_us:.1f}x_for_{2048 // base_p}x_phenotypes")

    # per-trait loop (fastGWA usage pattern): one trait per scan
    y1 = rng.normal(size=(n, 1)).astype(np.float32)
    panel1 = Rz.residualize_and_standardize(jnp.asarray(y1), qb)
    us1, _ = _timeit(core_scan, g_dev, panel1.y)
    emit("per_trait_loop_core", us1,
         f"panel_speedup_at_P2048={us1 * 2048 / us_core:.0f}x")


def bench_engines() -> None:
    """dense vs fused engine on the same cohort: identical statistics.
    (CPU wall-time of the fused path runs the Pallas interpreter and is not
    indicative of TPU perf — see EXPERIMENTS.md §Roofline for the real
    comparison; here we verify equivalence and report timings for record.)"""
    import os
    import tempfile

    co = synth.make_cohort(n_samples=512, n_markers=1024, n_traits=64, seed=3)
    d = tempfile.mkdtemp()
    paths = synth.write_cohort_files(co, os.path.join(d, "bench"))
    src = plink.PlinkBed(paths["bed"])
    results = {}
    for engine in ("dense", "fused"):
        cfg = ScanConfig(batch_markers=512, engine=engine,
                         block_m=64, block_n=128, block_p=64)
        t0 = time.perf_counter()
        res = GenomeScan(src, co.phenotypes, co.covariates, config=cfg).run()
        dt = time.perf_counter() - t0
        results[engine] = res
        emit(f"engine_{engine}_scan", dt * 1e6,
             f"markers_per_s={co.dosages.shape[0] / dt:.0f}")
    agree = np.abs(results["dense"].best_nlp - results["fused"].best_nlp).max()
    emit("engine_agreement", 0.0, f"max_abs_dnlp={agree:.2e}")


def bench_kernels() -> None:
    """Association GEMM across geometries (us/call + achieved GFLOP/s)."""
    rng = np.random.default_rng(0)
    n = 2_000
    for m, p in [(1024, 256), (4096, 256), (1024, 2048)]:
        g = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))

        @jax.jit
        def corr(g, y):
            return A.correlation(g, y, n)

        us, _ = _timeit(corr, g, y)
        gflops = 2.0 * m * n * p / (us * 1e-6) / 1e9
        emit(f"gemm_M{m}_P{p}", us, f"gflops={gflops:.1f}")


def bench_scaling_n() -> None:
    rng = np.random.default_rng(0)
    m, p = 2048, 256
    core_opts = A.AssocOptions(compute_neglog10p=False)
    for n in [500, 1000, 2000, 4000]:
        g = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))

        def step(g, y, n=n):
            return A.assoc_from_standardized(
                g, y, n_samples=n, n_covariates=0, options=core_opts
            )

        step_j = jax.jit(step)
        us, _ = _timeit(step_j, g, y)
        emit(f"scaling_N{n}", us, f"us_per_sample={us / n:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_concordance()
    bench_throughput()
    bench_engines()
    bench_kernels()
    bench_scaling_n()


if __name__ == "__main__":
    main()
