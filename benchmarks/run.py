"""Benchmark harness — one function per paper table/figure.

    concordance   — Fig. 2 left: engine vs per-trait OLS (Pearson of -log10 p)
    throughput    — Fig. 2 right / §3.2: wall time vs panel width P, panel
                    engine vs per-trait loop (the fastGWA-usage analogue)
    engines       — dense (paper-faithful) vs fused 2-bit path, equal stats
    lmm           — mixed-model wing: GRM/eigen/REML setup amortization vs
                    the per-marker rotation overhead (the fastGWA analogue)
    trait_block   — 2-D scan grid sweep: wall time + peak panel residency
                    vs trait-block width (device memory bounded by the
                    block, not the panel; statistics bitwise-identical;
                    warm-measured — see the §10 compile-time note)
    executor      — multi-device grid executor sweep (fake CPU devices in a
                    subprocess): device count x placement, per-device
                    utilization from the session metrics, bitwise identity
    pipeline      — per-slot pipelining before/after (§15): unpipelined vs
                    prefetched/double-buffered workers at 2 and 4 devices,
                    decode/stage shares of step time
    serve         — scan-as-a-service (§16): warm window-query latency
                    p50/p95/p99 through the full request path (admission,
                    fair-share queue, resident-state reuse), cold-query
                    cost, and 2-client concurrent panel throughput
    kernels       — us/call of the association GEMM across batch geometries
    scaling_n     — runtime vs cohort size N (linear, §2.2)

Run with ``--sections serve,kernels`` to re-measure a subset; rows for the
other sections are carried over from the existing ``BENCH_scan.json``.

Prints ``name,us_per_call,derived`` CSV rows and writes the same data as
``BENCH_scan.json`` (per-section us/call + derived metrics) so the perf
trajectory is machine-diffable across PRs.  CPU numbers contextualize the
*shape* of the paper's claims (sub-linear P scaling, engine equivalence);
absolute TPU throughput comes from the dry-run roofline (EXPERIMENTS.md).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from scipy import stats as sps

from repro.core import association as A
from repro.core import residualize as Rz
from repro.core import stats as S
from repro.core.screening import GenomeScan, ScanConfig
from repro.io import plink, synth

ROWS: list[dict] = []
_SECTION = "misc"


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append(
        {"section": _SECTION, "name": name, "us_per_call": round(us_per_call, 1),
         "derived": derived}
    )
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _timeit(fn, *args, repeats=3):
    out = fn(*args)  # compile / warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6, out


def bench_concordance() -> None:
    """Paper Fig. 2 left: near-perfect agreement with per-trait OLS."""
    co = synth.make_cohort(n_samples=500, n_markers=300, n_traits=8,
                           n_causal=6, effect_size=0.5, seed=1)
    n, q = 500, co.covariates.shape[1]
    qb = Rz.covariate_basis(jnp.asarray(co.covariates), n)
    panel = Rz.residualize_and_standardize(jnp.asarray(co.phenotypes), qb)
    res, _ = A.assoc_batch(
        jnp.asarray(co.dosages.astype(np.float32)), panel.y,
        n_samples=n, n_covariates=q,
    )
    g_std, _ = A.standardize_genotype_batch(jnp.asarray(co.dosages.astype(np.float32)))
    g_std = np.asarray(g_std)
    yr = np.asarray(panel.y)
    ref = np.empty((300, 8), np.float64)
    for m in range(300):
        for p in range(8):
            ref[m, p] = sps.linregress(g_std[m], yr[:, p]).rvalue
    r_pearson = np.corrcoef(np.asarray(res.r).ravel(), ref.ravel())[0, 1]
    emit("concordance_fig2_left", 0.0, f"pearson_r={r_pearson:.6f}")


def bench_throughput() -> None:
    """Paper Fig. 2 right: runtime vs phenotype count, panel vs per-trait.

    Two pipelines are timed: the scan core (GEMM + t statistics — on the
    paper's GPU/our TPU target this is the whole cost) and the full
    default pipeline including -log10 p, which since §13 screens every
    lane on t^2, compacts the rare survivors, and refines only those
    through the canonical host-side executables.  The dense full-tile CF
    that used to put the epilogue at 94-99 % of wall time is measured in
    the ``epilogue`` section for the before/after record."""
    n, m = 2_000, 4_096
    rng = np.random.default_rng(0)
    g = rng.binomial(2, 0.3, size=(m, n)).astype(np.float32)
    g_dev, _ = A.standardize_genotype_batch(jnp.asarray(g))
    g_dev = jax.block_until_ready(g_dev)

    core_opts = A.AssocOptions(compute_neglog10p=False)
    dof = A.AssocOptions().dof(n, 0)
    plan = A.plan_sparse_epilogue(7.301, dof)

    @jax.jit
    def core_scan(g_std, y_std):
        return A.assoc_from_standardized(
            g_std, y_std, n_samples=n, n_covariates=0, options=core_opts
        )

    @jax.jit
    def sparse_step(g_std, y_std):
        res = A.assoc_from_standardized(
            g_std, y_std, n_samples=n, n_covariates=0, options=core_opts
        )
        return A.sparse_epilogue_outputs(res.r, res.t, dof, plan)

    def full_scan(g_std, y_std):
        # The default scan pipeline: core + t^2 screen/compact on device +
        # the canonical exact-tail refine host-side (DESIGN.md §13).
        out = sparse_step(g_std, y_std)
        hit_nlp = S.refine_neglog10p(np.asarray(out["hit_t"]), dof)
        best_nlp = S.refine_neglog10p(np.asarray(out["batch_best_t"]), dof)
        return hit_nlp, best_nlp

    qb = Rz.covariate_basis(None, n)
    base_us = base_p = None
    us_core = 0.0
    for p in [64, 256, 1024, 2048]:
        y = rng.normal(size=(n, p)).astype(np.float32)
        panel = Rz.residualize_and_standardize(jnp.asarray(y), qb)
        us_core, _ = _timeit(core_scan, g_dev, panel.y)
        us_full, _ = _timeit(full_scan, g_dev, panel.y)
        if base_us is None:
            base_us, base_p = us_core, p
        emit(f"throughput_core_P{p}", us_core, f"us_per_phenotype={us_core / p:.2f}")
        emit(f"throughput_full_P{p}", us_full, f"pvalue_epilogue_share={1 - us_core / max(us_full, 1):.2f}")
    emit("throughput_sublinearity_core", 0.0,
         f"grew_{us_core / base_us:.1f}x_for_{2048 // base_p}x_phenotypes")

    # per-trait loop (fastGWA usage pattern): one trait per scan
    y1 = rng.normal(size=(n, 1)).astype(np.float32)
    panel1 = Rz.residualize_and_standardize(jnp.asarray(y1), qb)
    us1, _ = _timeit(core_scan, g_dev, panel1.y)
    emit("per_trait_loop_core", us1,
         f"panel_speedup_at_P2048={us1 * 2048 / us_core:.0f}x")


def bench_engines() -> None:
    """dense vs fused engine on the same cohort: identical statistics.
    (CPU wall-time of the fused path runs the Pallas interpreter and is not
    indicative of TPU perf — see EXPERIMENTS.md §Roofline for the real
    comparison; here we verify equivalence and report timings for record.)"""
    import os
    import tempfile

    co = synth.make_cohort(n_samples=512, n_markers=1024, n_traits=64, seed=3)
    d = tempfile.mkdtemp()
    paths = synth.write_cohort_files(co, os.path.join(d, "bench"))
    src = plink.PlinkBed(paths["bed"])
    results = {}
    for engine in ("dense", "fused"):
        cfg = ScanConfig(batch_markers=512, engine=engine,
                         block_m=64, block_n=128, block_p=64)
        t0 = time.perf_counter()
        res = GenomeScan(src, co.phenotypes, co.covariates, config=cfg).run()
        dt = time.perf_counter() - t0
        results[engine] = res
        emit(f"engine_{engine}_scan", dt * 1e6,
             f"markers_per_s={co.dosages.shape[0] / dt:.0f}")
    agree = np.abs(results["dense"].best_nlp - results["fused"].best_nlp).max()
    emit("engine_agreement", 0.0, f"max_abs_dnlp={agree:.2e}")


def bench_lmm() -> None:
    """Mixed-model wing: one-time setup (GRM stream + eigendecomposition +
    REML) vs the steady-state scan.  The derived columns are the ones that
    matter for capacity planning: setup amortizes over the whole genome, the
    rotation GEMM is the per-marker overhead vs the OLS scan."""
    import os
    import tempfile

    co = synth.make_structured_cohort(
        n_samples=512, n_markers=2048, n_traits=32, n_pops=3, fst=0.1,
        h2=0.4, n_causal=4, seed=7,
    )
    d = tempfile.mkdtemp()
    synth.write_split_plink(co, os.path.join(d, "bench"), n_shards=4)
    from repro.io import open_genotypes

    src = open_genotypes(os.path.join(d, "bench_chr*.bed"))
    m = co.dosages.shape[0]

    base = dict(batch_markers=512, block_m=64, block_n=128, block_p=64)
    ols = GenomeScan(src, co.phenotypes, co.covariates,
                     config=ScanConfig(engine="dense", **base))
    t0 = time.perf_counter()                     # scan only: comparable to
    res_ols = ols.run()                          # the lmm_*_scan rows below
    dt_ols = time.perf_counter() - t0
    emit("lmm_baseline_ols_scan", dt_ols * 1e6, f"lambda_gc={res_ols.lambda_gc:.3f}")

    for loco in (False, True):
        tag = "loco" if loco else "global"
        t0 = time.perf_counter()
        scan = GenomeScan(src, co.phenotypes, co.covariates,
                          config=ScanConfig(engine="lmm", loco=loco, **base))
        dt_setup = time.perf_counter() - t0          # GRM + eigh + REML + rotation
        t0 = time.perf_counter()
        res = scan.run()
        dt_scan = time.perf_counter() - t0
        emit(f"lmm_{tag}_setup", dt_setup * 1e6,
             f"scopes={res.lmm_info['scopes']}")
        emit(f"lmm_{tag}_scan", dt_scan * 1e6,
             f"markers_per_s={m / dt_scan:.0f}")
        emit(f"lmm_{tag}_overhead_vs_ols", 0.0,
             f"scan_slowdown={dt_scan / dt_ols:.2f}x,lambda_gc={res.lambda_gc:.3f}")


def bench_trait_blocks() -> None:
    """The 2-D (marker x trait-block) scan grid: wall time and panel
    residency across block widths.  The derived column that matters for
    capacity planning is ``resident_panel_mib`` — the peak device bytes the
    panel can pin (LRU capacity x N x block width x 4), which is bounded by
    the block size rather than the panel width P; ``panel_mib`` is what the
    unblocked scan pins.  Statistics are bitwise-identical across rows
    (asserted here, property-tested in tests/test_traitblocks.py).

    Each width is scanned twice and the WARM run reported: every block
    width compiles its own step (the epilogue tile shape changes), and
    that one-time XLA compile grows with the tile — timing the first run
    made wider blocks look slower at equal grid area when their steady
    state is identical (the historical trait_block_128 "regression"; see
    DESIGN.md §10).  ``cold_extra_ms`` keeps the compile cost visible."""
    import os
    import tempfile

    co = synth.make_cohort(n_samples=512, n_markers=1024, n_traits=256,
                           n_causal=6, seed=5)
    d = tempfile.mkdtemp()
    paths = synth.write_cohort_files(co, os.path.join(d, "bench_tb"))
    src = plink.PlinkBed(paths["bed"])
    n, p = co.phenotypes.shape
    resident_cap = 4
    base = dict(batch_markers=256, block_m=64, block_n=128, block_p=32,
                panel_resident_blocks=resident_cap)
    ref = None
    for tb in (0, 32, 64, 128):
        cfg = ScanConfig(trait_block=tb, **base)
        t0 = time.perf_counter()
        GenomeScan(src, co.phenotypes, co.covariates, config=cfg).run()
        dt_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        scan = GenomeScan(src, co.phenotypes, co.covariates, config=cfg)
        res = scan.run()
        dt = time.perf_counter() - t0
        if ref is None:
            ref = res
        else:
            assert np.array_equal(ref.best_nlp, res.best_nlp), "grid changed stats"
        width = max(b.n_traits for b in scan.trait_blocks)
        resident = min(resident_cap, scan.n_trait_blocks) * n * width * 4
        emit(
            f"trait_block_{tb or 'off'}", dt * 1e6,
            f"grid={scan.n_batches}x{scan.n_trait_blocks},"
            f"resident_panel_mib={resident / 2**20:.2f},"
            f"panel_mib={n * p * 4 / 2**20:.2f},"
            f"cold_extra_ms={max(dt_cold - dt, 0.0) * 1e3:.0f}",
        )


_EXECUTOR_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json, tempfile, time
import os.path as osp
import numpy as np
import jax
# Persistent compile cache: each executor slot jits its own step (the
# prolog memo is keyed per device), so fake devices 1..3 would recompile
# the identical HLO (~0.4 s each).  The cache deserializes device 0's
# executable instead — the sweep measures scheduling and pipelining, not
# XLA compile times.
jax.config.update("jax_compilation_cache_dir", tempfile.mkdtemp())
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
from repro.api import ExecSpec, GridSpec, Study
from repro.core.sinks import BestTraitSink
from repro.io import plink, synth

co = synth.make_cohort(n_samples=512, n_markers=2048, n_traits=64,
                       n_causal=6, seed=5)
d = tempfile.mkdtemp()
paths = synth.write_cohort_files(co, osp.join(d, "bench_md"))
study = Study.from_arrays(plink.PlinkBed(paths["bed"]),
                          co.phenotypes, co.covariates)
grid = GridSpec(batch_markers=256, trait_block=16,
                block_m=64, block_n=128, block_p=16)

def run(devices, placement, slot_prefetch, autotune):
    session = study.plan(
        grid=grid, hit_threshold_nlp=2.0,
        executor=ExecSpec(devices=devices, placement=placement,
                          slot_prefetch=slot_prefetch,
                          autotune_lease=autotune),
    ).run()
    sink = BestTraitSink(study.n_traits)
    t0 = time.perf_counter()
    for cell in session.events():
        sink.on_cell(cell)
    dt = time.perf_counter() - t0
    key = sink.best_nlp.tobytes() + sink.best_marker.tobytes()
    return dt, key, session.metrics.summary(), session.executor_info

rows, ref = {"executor": [], "pipeline": []}, None
for devices, placement in [(1, "marker-major"), (2, "marker-major"),
                           (4, "marker-major"), (4, "trait-major")]:
    run(devices, placement, 1, True)   # warm page + compile caches
    dt, key, m, info = run(devices, placement, 1, True)
    ref = key if ref is None else ref
    # Two utilization views: the scheduler's busy/(busy+wait) accounting
    # (time holding >=1 claimed item vs empty-handed — DESIGN.md §15) and
    # the per-cell busy_s/wall from the metrics block.  On fake devices
    # timesharing one core the latter is distorted (concurrent steps
    # inflate each other's wall, so it can exceed 1); the scheduler view
    # is the meaningful one here.
    workers = info.get("workers") or {}
    shares = [
        w["busy_s"] / max(w["busy_s"] + w["wait_s"], 1e-9)
        for w in workers.values()
    ]
    rows["executor"].append({
        "devices": devices, "placement": placement, "wall_s": round(dt, 3),
        "markers_per_s": m["markers_per_s"],
        "trait_markers_per_s": m["trait_markers_per_s"],
        "mean_utilization": round(sum(shares) / len(shares), 3) if shares
        else round(
            sum(v["utilization"] for v in m["per_device"].values())
            / max(len(m["per_device"]), 1), 3),
        "cell_util": round(
            sum(v["utilization"] for v in m["per_device"].values())
            / max(len(m["per_device"]), 1), 3),
        "final_lease": (info.get("autotune") or {}).get("final_lease"),
        "identical_to_serial": key == ref,
    })
for devices in (2, 4):
    for piped in (0, 1):
        dt, key, m, info = run(devices, "marker-major", piped, bool(piped))
        rows["pipeline"].append({
            "devices": devices, "slot_prefetch": piped,
            "wall_s": round(dt, 3),
            "trait_markers_per_s": m["trait_markers_per_s"],
            "decode_s": m["decode_s"], "stage_s": m["stage_s"],
            "step_s": m["step_s"],
            "identical_to_serial": key == ref,
        })
print(json.dumps(rows))
"""

_MD_ROWS: dict | None = None


def _executor_child_rows() -> dict:
    """Run the 4-fake-device subprocess once; both the ``executor`` and
    ``pipeline`` sections read from its output."""
    global _MD_ROWS
    if _MD_ROWS is not None:
        return _MD_ROWS
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _EXECUTOR_CHILD],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    if proc.returncode != 0:
        emit("executor_sweep_failed", 0.0, proc.stderr.strip()[-120:].replace(",", ";"))
        _MD_ROWS = {"executor": [], "pipeline": []}
    else:
        _MD_ROWS = json.loads(proc.stdout.strip().splitlines()[-1])
    return _MD_ROWS


def bench_executor() -> None:
    """Multi-device grid executor sweep (DESIGN.md §12), on 4 fake CPU
    devices in a subprocess (the device count is fixed at process start).
    Fake devices timeshare ONE physical CPU, so wall time here measures
    scheduling/staging overhead, not speedup — the rows that matter are
    per-device utilization (the executor keeps slots busy), the session
    metrics throughput, and ``identical=True`` (bitwise identity across
    device counts and placements, the §12 contract).  Each config is run
    twice and the warm run reported (first-touch page-cache and compile-
    cache costs are not scheduling overhead)."""
    for row in _executor_child_rows()["executor"]:
        emit(
            f"executor_d{row['devices']}_{row['placement'].replace('-', '_')}",
            row["wall_s"] * 1e6,
            f"trait_markers_per_s={row['trait_markers_per_s']:.0f},"
            f"mean_util={row['mean_utilization']},"
            f"final_lease={row['final_lease']},"
            f"identical={row['identical_to_serial']}",
        )


def bench_pipeline() -> None:
    """Per-slot pipelining before/after (DESIGN.md §15): the same grid
    drained with ``slot_prefetch=0`` (the historical one-staged-batch
    worker, autotune off) vs the pipelined default at 2 and 4 devices.
    ``decode_share``/``stage_share`` are host decode and H2D staging time
    as fractions of total device step time — the pipelined rows overlap
    them with compute, the unpipelined rows pay them on the critical
    path.  Outputs are bitwise-identical across all rows."""
    for row in _executor_child_rows()["pipeline"]:
        step = max(row["step_s"], 1e-9)
        tag = "piped" if row["slot_prefetch"] else "unpiped"
        emit(
            f"pipeline_d{row['devices']}_{tag}",
            row["wall_s"] * 1e6,
            f"trait_markers_per_s={row['trait_markers_per_s']:.0f},"
            f"decode_share={row['decode_s'] / step:.3f},"
            f"stage_share={row['stage_s'] / step:.3f},"
            f"identical={row['identical_to_serial']}",
        )


def bench_epilogue() -> None:
    """§13 before/after on one statistic tile (M=4096, P=2048): the dense
    128-trip CF over every lane (the historical default, 94-99 % of scan
    wall time on CPU) vs the t^2 screen + compact + canonical refine the
    scan now runs.  ``share_of_full`` is each epilogue's fraction of a
    (core + epilogue) step — the sparse row is the acceptance number."""
    n, m, p = 2_000, 4_096, 2_048
    rng = np.random.default_rng(0)
    g = rng.binomial(2, 0.3, size=(m, n)).astype(np.float32)
    g_dev, _ = A.standardize_genotype_batch(jnp.asarray(g))
    y = rng.normal(size=(n, p)).astype(np.float32)
    panel = Rz.residualize_and_standardize(
        jnp.asarray(y), Rz.covariate_basis(None, n)
    )
    core_opts = A.AssocOptions(compute_neglog10p=False)
    dof = A.AssocOptions().dof(n, 0)

    @jax.jit
    def core(g_std, y_std):
        return A.assoc_from_standardized(
            g_std, y_std, n_samples=n, n_covariates=0, options=core_opts
        )

    us_core, res = _timeit(core, g_dev, panel.y)
    r_tile = jax.block_until_ready(res.r)
    t_tile = jax.block_until_ready(res.t)

    @jax.jit
    def dense_cf(t):
        return S.neglog10_p_from_t(t, dof)

    us_dense, _ = _timeit(dense_cf, t_tile, repeats=1)

    plan = A.plan_sparse_epilogue(7.301, dof)

    @jax.jit
    def screen(r, t):
        return A.sparse_epilogue_outputs(r, t, dof, plan)

    def sparse_ep(r, t):
        out = screen(r, t)
        hit_nlp = S.refine_neglog10p(np.asarray(out["hit_t"]), dof)
        best_nlp = S.refine_neglog10p(np.asarray(out["batch_best_t"]), dof)
        return out, hit_nlp, best_nlp

    us_sparse, (out, _, _) = _timeit(sparse_ep, r_tile, t_tile)
    emit("epilogue_dense_cf", us_dense,
         f"share_of_full={us_dense / (us_core + us_dense):.2f}")
    emit("epilogue_sparse", us_sparse,
         f"share_of_full={us_sparse / (us_core + us_sparse):.2f},"
         f"speedup_vs_dense={us_dense / max(us_sparse, 1):.0f}x")
    emit("epilogue_compaction", 0.0,
         f"screen_count={int(out['screen_count'])},capacity={plan.capacity},"
         f"lanes={m * p}")


def bench_io() -> None:
    """Packed genotype staging (DESIGN.md §17): the same scan drained with
    dense float32 staging vs 2-bit packed bytes as the H2D currency.  Wall
    time on CPU is not the point (fake-device H2D is a memcpy); the rows
    that matter are ``h2d_bytes_per_marker`` — ceil(N/4) packed vs 4N
    dense, the ~16x reduction the acceptance gate checks — ``decode_s``
    (host prep collapses to a slab memcpy + stat LUTs), and
    ``identical=True`` (packed staging is bitwise-neutral).  The cache row
    re-runs the packed scan against a warm ``PackedSlabCache``: every slab
    is a hit, so host prep pays zero disk reads."""
    import os
    import tempfile

    from repro.api import GridSpec, IOSpec, Study, TsvWriter
    from repro.io import open_genotypes
    from repro.io.packed_cache import default_cache

    co = synth.make_cohort(
        n_samples=1003, n_markers=2048, n_traits=32, missing_rate=0.02, seed=5
    )
    d = tempfile.mkdtemp()
    beds = synth.write_split_plink(co, os.path.join(d, "bench"), n_shards=3)
    src = open_genotypes(",".join(beds))
    study = Study.from_arrays(src, co.phenotypes, co.covariates)
    grid = GridSpec(batch_markers=512, block_m=64, block_n=128, block_p=64)

    def scan(tag, staging):
        default_cache().clear()
        plan = study.plan(grid=grid, io=IOSpec(genotype_staging=staging),
                          hit_threshold_nlp=2.0)
        t0 = time.perf_counter()
        session = plan.run()
        out = os.path.join(d, tag)
        session.stream_to(TsvWriter(out))
        dt = time.perf_counter() - t0
        files = {
            f: open(os.path.join(out, f)).read()
            for f in ("hits.tsv", "per_trait_best.tsv", "qc.tsv")
        }
        return dt, session.metrics.summary(), files

    dt_d, m_d, files_d = scan("stage_dense", "dense")
    dt_p, m_p, files_p = scan("stage_packed", "packed")
    emit("io_dense_staging", dt_d * 1e6,
         f"h2d_bytes_per_marker={m_d['h2d_bytes_per_marker']:.0f},"
         f"decode_s={m_d['decode_s']:.3f}")
    emit("io_packed_staging", dt_p * 1e6,
         f"h2d_bytes_per_marker={m_p['h2d_bytes_per_marker']:.0f},"
         f"decode_s={m_p['decode_s']:.3f},"
         f"identical={files_p == files_d}")
    emit("io_h2d_reduction", 0.0,
         f"bytes_ratio={m_d['h2d_bytes_per_marker'] / m_p['h2d_bytes_per_marker']:.1f}x,"
         f"n_samples={co.phenotypes.shape[0]}")

    # Warm-cache rerun: the whole genotype stream is slab-cache hits.
    plan = study.plan(grid=grid, io=IOSpec(genotype_staging="packed"),
                      hit_threshold_nlp=2.0)
    t0 = time.perf_counter()
    session = plan.run()
    session.stream_to(TsvWriter(os.path.join(d, "stage_packed_warm")))
    dt_w = time.perf_counter() - t0
    cs = default_cache().stats()
    emit("io_packed_warm_cache", dt_w * 1e6,
         f"cache_hits={cs['hits']},cache_misses={cs['misses']},"
         f"decode_s={session.metrics.summary()['decode_s']:.3f}")


def bench_serve() -> None:
    """Scan-as-a-service (DESIGN.md §16): request latency through the full
    serve path — admission, fair-share queueing on the persistent
    WorkQueue, resident-state reuse, request-scoped TSV writers.  The row
    that matters for an interactive service is the WARM window-query
    latency: the resident study already holds the residualized panel,
    compiled step, and device slots, so a query pays only decode + step +
    epilogue + write.  ``serve_window_cold`` keeps the one-time cost
    (first decode/compile for the window shape) visible, and
    ``serve_concurrent_panels`` measures two interleaved panel uploads
    sharing the executor — the multi-tenant case."""
    import os
    import tempfile

    from repro.api import GridSpec, Study
    from repro.serve import ServeHost

    co = synth.make_cohort(n_samples=512, n_markers=2048, n_traits=64,
                           n_causal=6, seed=9)
    d = tempfile.mkdtemp()
    paths = synth.write_cohort_files(co, os.path.join(d, "bench_serve"))
    study = Study.from_files(paths["bed"], paths["pheno"], paths["cov"])
    host = ServeHost(devices=1, max_resident_slots=4,
                     out_root=os.path.join(d, "serve_out"))
    try:
        host.admit_study(
            "bench", study,
            grid=GridSpec(batch_markers=256, trait_block=16,
                          block_m=64, block_n=128, block_p=16),
            hit_threshold_nlp=2.0,
        )
        warm = host.warm_study("bench")
        emit("serve_warm_study", warm["prepare_s"] * 1e6,
             "one_time=source_scan+residualize+compile")

        def window(lo: int, hi: int) -> float:
            t0 = time.perf_counter()
            info = host.wait(host.submit_window("bench", lo, hi), timeout=600)
            assert info["status"] == "done", info
            return time.perf_counter() - t0

        cold_s = window(0, 256)  # first query still pays step compile
        lats = []
        m_total = co.dosages.shape[0]
        for i in range(15):
            lo = (i * 256) % m_total
            lats.append(window(lo, lo + 256))
        p50, p95, p99 = (float(np.percentile(lats, q)) for q in (50, 95, 99))
        emit("serve_window_cold", cold_s * 1e6,
             f"first_query_extra_vs_warm_p50={cold_s / max(p50, 1e-9):.1f}x")
        emit("serve_window_warm", float(np.mean(lats)) * 1e6,
             f"n=15,p50_ms={p50 * 1e3:.0f},p95_ms={p95 * 1e3:.0f},"
             f"p99_ms={p99 * 1e3:.0f}")

        import threading

        rng = np.random.default_rng(11)
        errs: list[str] = []

        def panel_client(seed_off: int) -> None:
            panel = np.asarray(co.phenotypes) + rng.normal(
                scale=1e-3, size=co.phenotypes.shape
            ).astype(np.float32) * seed_off
            info = host.wait(
                host.submit_panel("bench", panel), timeout=600
            )
            if info["status"] != "done":
                errs.append(str(info))

        t0 = time.perf_counter()
        ts = [threading.Thread(target=panel_client, args=(i,))
              for i in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        dt = time.perf_counter() - t0
        assert not errs, errs
        summary = host.metrics_summary()
        lat = summary["serve"]["latency"]
        cache = summary["serve"]["caches"]["device_state"]
        tm = 2 * m_total * co.phenotypes.shape[1]
        emit("serve_concurrent_panels", dt * 1e6,
             f"requests=2,trait_markers_per_s={tm / dt:.0f},"
             f"device_state_hit_rate={cache['hit_rate']}")
        emit("serve_latency_all", 0.0,
             f"n={lat['n']},p50_s={lat['p50_s']},p95_s={lat['p95_s']},"
             f"p99_s={lat['p99_s']}")
    finally:
        host.shutdown()


def bench_kernels() -> None:
    """Association GEMM across geometries (us/call + achieved GFLOP/s)."""
    rng = np.random.default_rng(0)
    n = 2_000
    for m, p in [(1024, 256), (4096, 256), (1024, 2048)]:
        g = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))

        @jax.jit
        def corr(g, y):
            return A.correlation(g, y, n)

        us, _ = _timeit(corr, g, y)
        gflops = 2.0 * m * n * p / (us * 1e-6) / 1e9
        emit(f"gemm_M{m}_P{p}", us, f"gflops={gflops:.1f}")


def bench_scaling_n() -> None:
    rng = np.random.default_rng(0)
    m, p = 2048, 256
    core_opts = A.AssocOptions(compute_neglog10p=False)
    for n in [500, 1000, 2000, 4000]:
        g = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))

        def step(g, y, n=n):
            return A.assoc_from_standardized(
                g, y, n_samples=n, n_covariates=0, options=core_opts
            )

        step_j = jax.jit(step)
        us, _ = _timeit(step_j, g, y)
        emit(f"scaling_N{n}", us, f"us_per_sample={us / n:.2f}")


def main(argv: list[str] | None = None) -> None:
    global _SECTION
    import argparse

    sections = [
        ("concordance", bench_concordance),
        ("throughput", bench_throughput),
        ("engines", bench_engines),
        ("lmm", bench_lmm),
        ("trait_block", bench_trait_blocks),
        ("executor", bench_executor),
        ("pipeline", bench_pipeline),
        ("epilogue", bench_epilogue),
        ("io", bench_io),
        ("serve", bench_serve),
        ("kernels", bench_kernels),
        ("scaling_n", bench_scaling_n),
    ]
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--sections", default=None, metavar="A,B,...",
        help="run only these sections and merge the rest from the existing "
             f"BENCH_scan.json (default: all of {','.join(n for n, _ in sections)})",
    )
    args = ap.parse_args(argv)
    wanted = None if args.sections is None else set(args.sections.split(","))
    if wanted:
        unknown = wanted - {n for n, _ in sections}
        if unknown:
            ap.error(f"unknown sections: {sorted(unknown)}")

    print("name,us_per_call,derived")
    for name, fn in sections:
        if wanted is not None and name not in wanted:
            continue
        _SECTION = name
        fn()
    rows = list(ROWS)
    if wanted is not None:
        # Partial run: keep every row of sections we did not re-run, in the
        # canonical section order, so the JSON stays a full snapshot.
        try:
            with open("BENCH_scan.json") as f:
                kept = [r for r in json.load(f)["rows"]
                        if r["section"] not in wanted]
        except (OSError, KeyError, ValueError):
            kept = []
        order = {n: i for i, (n, _) in enumerate(sections)}
        rows = sorted(kept + rows,
                      key=lambda r: order.get(r["section"], len(order)))
    payload = {
        "schema": 1,
        "device": jax.devices()[0].platform,
        "jax": jax.__version__,
        "sections": sorted({r["section"] for r in rows}),
        "rows": rows,
    }
    with open("BENCH_scan.json", "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote BENCH_scan.json ({len(rows)} rows)")


if __name__ == "__main__":
    main()
