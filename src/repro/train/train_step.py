"""Sharded training step: loss -> microbatched grads -> AdamW.

Distribution contract: params/optimizer state sharded by
``train.partition`` (FSDP over data, TP/EP over model); batch sharded over
the data axes; gradient accumulation over microbatches via ``lax.scan``
(activation memory / n_micro); remat policy per arch; optional int8
gradient-compression collective for the data-parallel all-reduce
(``runtime.compression``) through the manual shard_map path.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import api as M
from repro.models.sharding_ctx import activation_sharding_scope
from repro.runtime.sharding import DEFAULT_RULES, batch_axes
from repro.train import partition
from repro.train.optimizer import AdamWConfig, OptState, adamw_init, adamw_update

__all__ = ["TrainStepConfig", "softmax_xent", "build_train_step", "batch_shardings"]

_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


@dataclass(frozen=True)
class TrainStepConfig:
    n_microbatches: int = 1
    remat: str = "none"             # none | full | dots
    moe_aux_weight: float = 0.01
    z_loss_weight: float = 1e-4
    accum_dtype: str = "float32"    # gradient-accumulation dtype (bf16 halves
                                    # grad HBM for the 480B cells)
    loss_chunk: int = 0             # >0: chunked cross-entropy over sequence
                                    # chunks of this size — the (B,S,V) f32
                                    # logits tensor is never materialized
                                    # (decisive for 256k-vocab train cells)
    optimizer: AdamWConfig = AdamWConfig()


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross entropy; logits (B,S,V) f32, labels (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold), jnp.mean(jnp.square(logz))


def _chunked_xent(cfg, tcfg, params, hidden, labels):
    """Cross entropy + z-loss over sequence chunks: the head GEMM and the
    f32 logits exist only one chunk at a time (forward AND backward — the
    scan re-runs the chunk head in its own backward)."""
    b, s, d = hidden.shape
    c = min(tcfg.loss_chunk, s)
    while s % c:
        c -= 1  # largest divisor <= requested chunk
    n_chunks = s // c
    h_chunks = jnp.moveaxis(hidden.reshape(b, n_chunks, c, d), 1, 0)
    y_chunks = jnp.moveaxis(labels.reshape(b, n_chunks, c), 1, 0)

    def body(carry, sl):
        xent_sum, z_sum = carry
        h, y = sl
        logits = M.apply_head(cfg, params, h)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return (xent_sum + jnp.sum(logz - gold), z_sum + jnp.sum(jnp.square(logz))), None

    (xent_sum, z_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (h_chunks, y_chunks)
    )
    denom = b * s
    return xent_sum / denom, z_sum / denom


def _loss_fn(cfg: ModelConfig, tcfg: TrainStepConfig, params, batch, remat_policy):
    if tcfg.loss_chunk:
        hidden, aux = M.train_hidden(cfg, params, batch, remat_policy=remat_policy)
        if "vision_embeds" in batch:
            hidden = hidden[:, batch["vision_embeds"].shape[1] :]
        xent, z = _chunked_xent(cfg, tcfg, params, hidden, batch["labels"])
    else:
        logits, aux = M.train_logits(cfg, params, batch, remat_policy=remat_policy)
        if "vision_embeds" in batch:
            # Loss on the text positions only; the stub patches carry no labels.
            logits = logits[:, batch["vision_embeds"].shape[1] :]
        xent, z = softmax_xent(logits, batch["labels"])
    loss = xent + tcfg.moe_aux_weight * aux + tcfg.z_loss_weight * z
    return loss, {"xent": xent, "moe_aux": aux}


def batch_shardings(specs: dict, mesh: Mesh) -> dict:
    """Batch dim over the data axes; positions (3,B,S) has batch second.
    Non-divisible batch dims (e.g. long_500k's batch=1) replicate."""
    dp = batch_axes(mesh)

    def shard(name, spec):
        if name == "positions" and len(spec.shape) == 3 and spec.shape[0] == 3:
            want = P(None, dp, None)
        else:
            want = P(*([dp] + [None] * (len(spec.shape) - 1)))
        return partition.divisible_sharding(mesh, want, spec.shape)

    return {k: shard(k, v) for k, v in specs.items()}


def build_train_step(
    cfg: ModelConfig,
    *,
    tcfg: TrainStepConfig = TrainStepConfig(),
    mesh: Mesh | None = None,
    rules=DEFAULT_RULES,
    donate: bool = True,
) -> Callable:
    """Returns ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)``, jitted (and sharded when ``mesh`` is given)."""
    remat_policy = _POLICIES[tcfg.remat]

    def grads_of(params, batch):
        if tcfg.n_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: _loss_fn(cfg, tcfg, p, batch, remat_policy), has_aux=True
            )(params)
            return loss, metrics, grads

        n = tcfg.n_microbatches

        def micro_slices(x):
            b = x.shape[0]
            if x.ndim >= 2 and x.shape[0] == 3:  # vlm positions (3, B, S)
                return x.reshape(3, n, x.shape[1] // n, *x.shape[2:]).swapaxes(0, 1)
            return x.reshape(n, b // n, *x.shape[1:])

        micro = jax.tree.map(micro_slices, batch)

        def body(carry, mb):
            loss_sum, grads_sum = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: _loss_fn(cfg, tcfg, p, mb, remat_policy), has_aux=True
            )(params)
            # Accumulate in the accumulator's dtype so the scan carry stays
            # stable (fp32 leaves keep fp32 even when accum_dtype=bf16).
            grads_sum = jax.tree.map(lambda s, g: s + g.astype(s.dtype), grads_sum, grads)
            return (loss_sum + loss, grads_sum), metrics

        accum_dt = jnp.bfloat16 if tcfg.accum_dtype == "bfloat16" else jnp.float32

        def zero_like(p):
            dt = accum_dt if p.dtype == jnp.bfloat16 else jnp.promote_types(p.dtype, jnp.float32)
            return jnp.zeros(p.shape, dt)

        zero_grads = jax.tree.map(zero_like, params)
        (loss_sum, grads), metrics = jax.lax.scan(body, (0.0, zero_grads), micro)
        grads = jax.tree.map(lambda g: g / n, grads)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / n, last_metrics, grads

    def step(params, opt_state: OptState, batch):
        with activation_sharding_scope(mesh, rules):
            loss, metrics, grads = grads_of(params, batch)
            new_params, new_opt, opt_metrics = adamw_update(
                tcfg.optimizer, grads, opt_state, params
            )
        return new_params, new_opt, {"loss": loss, **metrics, **opt_metrics}

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    p_abs, p_logical = param_axes_for(cfg)
    p_shard = partition.tree_shardings(p_logical, mesh, rules, abstract_tree=p_abs)
    opt_shard = OptState(m=p_shard, v=p_shard, count=NamedSharding(mesh, P()))
    metrics_shard = None  # let GSPMD pick for scalars
    return jax.jit(
        step,
        in_shardings=(p_shard, opt_shard, None),
        out_shardings=(p_shard, opt_shard, metrics_shard),
        donate_argnums=(0, 1) if donate else (),
    )


@functools.lru_cache(maxsize=32)
def param_axes_for(cfg: ModelConfig):
    """(abstract params, logical axes) — cached per config."""
    params_abs = M.abstract_params(cfg)
    return params_abs, partition.param_logical_axes(params_abs)


def init_train_state(
    cfg: ModelConfig,
    tcfg: TrainStepConfig,
    key: jax.Array,
    *,
    max_positions: int = 4096,
):
    params = M.init_model(cfg, key, max_positions=max_positions)
    return params, adamw_init(tcfg.optimizer, params)
