from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_step import TrainStepConfig, build_train_step, init_train_state
from repro.train.serve_step import build_decode_step, build_prefill_step

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "TrainStepConfig",
    "build_train_step",
    "init_train_state",
    "build_decode_step",
    "build_prefill_step",
]
