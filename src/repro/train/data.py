"""Synthetic token pipeline for the LM wing's examples and tests.

Zipf-distributed token ids with a deterministic per-step seed so data is
reproducible across restarts (the checkpoint records only the step number).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["TokenStream", "make_batch"]


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int, *, seed: int = 0) -> dict:
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    b, s = shape.global_batch, shape.seq_len
    zipf = rng.zipf(1.3, size=(b, s + 1))
    tokens = np.minimum(zipf, cfg.vocab - 1).astype(np.int32)
    batch = {
        "tokens": tokens[:, :s],
        "labels": tokens[:, 1:],
        "positions": np.broadcast_to(np.arange(s, dtype=np.int32), (b, s)).copy(),
    }
    if cfg.family == "encdec":
        batch["frames"] = rng.normal(0, 0.02, (b, cfg.encoder_len, cfg.d_model)).astype(np.float32)
        batch.pop("positions")
    if cfg.family == "vlm":
        patches = min(cfg.vision_stub_patches, max(s // 2, 1))
        batch["vision_embeds"] = rng.normal(0, 0.02, (b, patches, cfg.d_model)).astype(np.float32)
        batch["tokens"] = batch["tokens"][:, : s - patches]
        batch["labels"] = batch["labels"][:, : s - patches]
        batch["positions"] = np.broadcast_to(np.arange(s, dtype=np.int32), (3, b, s)).copy()
    return batch


class TokenStream:
    """Stateless iterable over steps (resume = start at step N)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0, start_step: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = make_batch(self.cfg, self.shape, self.step, seed=self.seed)
        self.step += 1
        return batch
