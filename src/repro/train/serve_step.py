"""Sharded serving steps: prefill (build caches) and decode (one token).

The decode step is the latency path: caches shard batch over the data axes
and heads/state over model; the token inputs are tiny and replicate-safe.
"""
from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api as M
from repro.models.sharding_ctx import activation_sharding_scope
from repro.runtime.sharding import DEFAULT_RULES, batch_axes
from repro.train import partition
from repro.train.train_step import batch_shardings, param_axes_for

__all__ = ["build_prefill_step", "build_decode_step"]


def build_prefill_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    mesh: Mesh | None = None,
    rules=DEFAULT_RULES,
) -> Callable:
    def step(params, batch):
        with activation_sharding_scope(mesh, rules):
            return M.serve_prefill(cfg, params, batch, cache_capacity=shape.seq_len)

    if mesh is None:
        return jax.jit(step)
    p_abs, p_logical = param_axes_for(cfg)
    p_shard = partition.tree_shardings(p_logical, mesh, rules, abstract_tree=p_abs)
    specs = M.input_specs(cfg, shape)
    b_shard = batch_shardings(specs, mesh)
    caches_abs = M.abstract_caches(cfg, shape)
    c_shard = partition.tree_shardings(
        partition.cache_logical_axes(caches_abs), mesh, rules, abstract_tree=caches_abs
    )
    dp = batch_axes(mesh)
    logits_shard = partition.divisible_sharding(
        mesh, P(dp, "model"), (shape.global_batch, cfg.vocab)
    )
    return jax.jit(step, in_shardings=(p_shard, b_shard), out_shardings=(logits_shard, c_shard))


def build_decode_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    mesh: Mesh | None = None,
    rules=DEFAULT_RULES,
) -> Callable:
    def step(params, token, pos, caches):
        with activation_sharding_scope(mesh, rules):
            return M.serve_decode(cfg, params, token, pos, caches)

    if mesh is None:
        return jax.jit(step, donate_argnums=(3,))
    p_abs, p_logical = param_axes_for(cfg)
    p_shard = partition.tree_shardings(p_logical, mesh, rules, abstract_tree=p_abs)
    caches_abs = M.abstract_caches(cfg, shape)
    c_shard = partition.tree_shardings(
        partition.cache_logical_axes(caches_abs), mesh, rules, abstract_tree=caches_abs
    )
    dp = batch_axes(mesh)
    tok_shard = partition.divisible_sharding(mesh, P(dp), (shape.global_batch,))
    logits_shard = partition.divisible_sharding(
        mesh, P(dp, "model"), (shape.global_batch, cfg.vocab)
    )
    return jax.jit(
        step,
        in_shardings=(p_shard, tok_shard, tok_shard, c_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(3,),
    )
