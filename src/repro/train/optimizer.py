"""AdamW from scratch (no optax in the container — and the optimizer is a
first-class part of the framework: its state dtype and sharding are what
make the 480B train cells fit).

State is a pytree mirroring params: ``{m, v}`` per leaf plus a scalar count.
``state_dtype`` controls m/v precision — bf16 halves optimizer HBM, which is
the difference between fitting and not fitting arctic-480b on 256 chips
(EXPERIMENTS.md §Dry-run); fp32 is default elsewhere.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "global_norm", "cosine_schedule"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"     # "float32" | "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def _state_dt(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32


def adamw_init(cfg: AdamWConfig, params: Any) -> OptState:
    dt = _state_dt(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def adamw_update(
    cfg: AdamWConfig, grads: Any, state: OptState, params: Any
) -> tuple[Any, OptState, dict[str, jax.Array]]:
    """One AdamW step with global-norm clipping and decoupled weight decay.
    Returns (new_params, new_state, metrics)."""
    dt = _state_dt(cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = cosine_schedule(cfg, count)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        step_dir = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step_dir + decay)
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_m, new_v, count), {"grad_norm": gnorm, "lr": lr}
