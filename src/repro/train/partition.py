"""Logical-axis inference for parameter and cache pytrees.

Leaf names carry the semantics (``wq``, ``w_in``, ``router``, ...); this
module maps each leaf to its logical axes, which ``runtime.sharding`` then
resolves to physical mesh axes.  Stacked leaves (under the layer-scan
``pattern`` stacks / encdec ``encoder``/``decoder`` stacks) get a leading
``layers`` axis (unsharded).

This is the FSDP/TP heart of the LM wing: "embed" -> data axis (FSDP),
"heads"/"mlp"/"vocab"/"experts"/"state" -> model axis (TP/EP).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime.sharding import DEFAULT_RULES, LogicalAxisRules

__all__ = ["param_logical_axes", "tree_shardings", "cache_logical_axes"]

# leaf-name -> logical axes, keyed by (name, ndim-without-stacking).
_PARAM_TABLE: dict[tuple[str, int], tuple] = {
    ("embed", 2): ("vocab", "embed"),
    ("lm_head", 2): ("embed", "vocab"),
    ("enc_pos", 2): (None, "embed"),
    ("dec_pos", 2): (None, "embed"),
    ("wq", 3): ("embed", "heads", None),
    ("wk", 3): ("embed", "kv_heads", None),
    ("wv", 3): ("embed", "kv_heads", None),
    ("wo", 3): ("heads", None, "embed"),
    ("bq", 2): ("heads", None),
    ("bk", 2): ("kv_heads", None),
    ("bv", 2): ("kv_heads", None),
    ("w_in", 2): ("embed", "mlp"),
    ("w_gate", 2): ("embed", "mlp"),
    ("w_out", 2): ("mlp", "embed"),
    # rwkv
    ("w_r", 3): ("embed", "heads", None),
    ("w_k", 3): ("embed", "heads", None),
    ("w_v", 3): ("embed", "heads", None),
    ("w_g", 3): ("embed", "heads", None),
    ("w_o", 3): ("heads", None, "embed"),
    ("mix_a", 2): ("embed", None),
    ("mix_b", 3): (None, None, "embed"),
    ("decay_a", 2): ("embed", None),
    ("decay_b", 3): (None, "heads", None),
    ("cm_k", 2): ("embed", "mlp"),
    ("cm_v", 2): ("mlp", "embed"),
    ("cm_r", 2): ("embed", None),
    # rg-lru
    ("w_branch", 2): ("embed", "state"),
    ("w_a", 2): ("state", None),
    ("w_i", 2): ("state", None),
    ("conv", 2): (None, "state"),
    ("conv_bias", 1): ("state",),
    ("lam", 1): ("state",),
    ("b_a", 1): ("state",),
    ("b_i", 1): ("state",),
    # rg-lru's (w, d) output projection shares the "w_out" name at ndim 2 —
    # ("mlp","embed") would be wrong logically but "state" and "mlp" both map
    # to the model axis, so the physical sharding is identical.
}

# Expert-parallel leaves live under a "moe" parent (its "dense" residual
# sub-dict keeps the dense table) — same leaf names, different rank/axes.
_MOE_TABLE: dict[tuple[str, int], tuple] = {
    ("router", 2): ("embed", "experts"),
    ("w_in", 3): ("experts", "embed", None),
    ("w_gate", 3): ("experts", "embed", None),
    ("w_out", 3): ("experts", None, "embed"),
}

_CACHE_TABLE: dict[str, tuple] = {
    # KV caches prefer head sharding (no softmax collectives); when the head
    # count does not divide the model axis (kv=4..12 vs 16-way TP — most of
    # the zoo), the priority resolver falls back to sharding the *sequence*
    # dim instead (flash-decoding style; GSPMD inserts the partial-softmax
    # reductions).  Without this, 32k-deep caches replicate — measured up to
    # 68x HBM on qwen1.5-32b decode (EXPERIMENTS.md §Perf).
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "k_scale": ("batch", "kv_seq", "kv_heads"),
    "v_scale": ("batch", "kv_seq", "kv_heads"),
    "positions": ("batch", "kv_seq"),
    "cross_k": ("batch", "kv_seq", "kv_heads", None),
    "cross_v": ("batch", "kv_seq", "kv_heads", None),
    "wkv": ("batch", "heads", None, None),
    "shift_tm": ("batch", None),
    "shift_cm": ("batch", None),
    "h": ("batch", "state"),
    "conv": ("batch", None, "state"),
}

# Dim-assignment priority for shape-aware resolution: contracting/model dims
# claim their axes first; fallbacks (kv_seq) only take what remains.
_PRIORITY = {
    "vocab": 0, "heads": 0, "kv_heads": 0, "mlp": 0, "experts": 0, "state": 0,
    "embed": 1, "batch": 1, "seq": 2, "kv_seq": 3,
}


def _path_keys(path) -> list[str]:
    keys = []
    for entry in path:
        if hasattr(entry, "key"):
            keys.append(str(entry.key))
        elif hasattr(entry, "name"):
            keys.append(str(entry.name))
    return keys


def _leaf_name(path) -> str:
    keys = _path_keys(path)
    return keys[-1] if keys else ""


def param_logical_axes(params: Any) -> Any:
    """Pytree of logical-axis tuples matching ``params``."""

    def infer(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        in_moe = "moe" in keys and "dense" not in keys
        table = _MOE_TABLE if in_moe else _PARAM_TABLE
        for extra in (0, 1):  # 0 = unstacked, 1 = one leading scan axis
            key = (name, leaf.ndim - extra)
            if key in table:
                return (None,) * extra + table[key]
        return (None,) * leaf.ndim  # norms, scalars, small LoRA bits: replicate

    return jax.tree_util.tree_map_with_path(infer, params)


def cache_logical_axes(caches: Any) -> Any:
    def infer(path, leaf):
        name = _leaf_name(path)
        # NamedTuple fields (LayerCache) appear as .name via GetAttrKey.
        base = _CACHE_TABLE.get(name)
        if base is None:
            return (None,) * leaf.ndim
        extra = leaf.ndim - len(base)
        return (None,) * max(extra, 0) + base

    return jax.tree_util.tree_map_with_path(infer, caches)


def divisible_sharding(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> NamedSharding:
    """NamedSharding with any non-divisible dim degraded to replicated."""
    fixed = []
    for dim, axes in enumerate(spec):
        if axes is None or dim >= len(shape):
            fixed.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        ways = 1
        for a in ax_tuple:
            ways *= mesh.shape[a]
        fixed.append(axes if ways and shape[dim] % ways == 0 else None)
    return NamedSharding(mesh, P(*fixed))


def _is_logical(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_shardings(
    logical_tree: Any,
    mesh: Mesh,
    rules: LogicalAxisRules = DEFAULT_RULES,
    *,
    abstract_tree: Any = None,
) -> Any:
    """Resolve logical axes to NamedShardings.

    When ``abstract_tree`` (matching ShapeDtypeStructs) is given, any dim
    whose size is not divisible by its assigned mesh axes degrades to
    replicated — e.g. 40 query heads cannot split 16-way TP, so that dim
    stays unsharded rather than failing the lower (the dry-run records the
    resulting memory cost; fixing the head/mesh mismatch is a §Perf lever).
    """

    def resolve(logical, leaf=None):
        if leaf is None:
            return NamedSharding(mesh, rules.physical(logical, mesh))
        # Shape-aware resolution: dims claim axes in priority order and an
        # axis skipped for divisibility stays available for later dims
        # (e.g. kv_heads=8 cannot take model=16, so kv_seq gets it).
        table = dict(rules.rules)
        available = set(mesh.axis_names)
        assign: list = [None] * len(logical)
        order = sorted(
            (i for i in range(len(logical)) if logical[i] is not None),
            key=lambda i: _PRIORITY.get(logical[i], 4),
        )
        for i in order:
            mapped = table.get(logical[i])
            if mapped is None:
                continue
            cands = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            picked: list[str] = []
            ways = 1
            for c in cands:
                if c in available and leaf.shape[i] % (ways * mesh.shape[c]) == 0:
                    picked.append(c)
                    ways *= mesh.shape[c]
            if picked:
                available.difference_update(picked)
                assign[i] = picked[0] if len(picked) == 1 else tuple(picked)
        return NamedSharding(mesh, P(*assign))

    if abstract_tree is None:
        return jax.tree.map(resolve, logical_tree, is_leaf=_is_logical)
    return jax.tree.map(
        lambda logical, leaf: resolve(logical, leaf),
        logical_tree,
        abstract_tree,
        is_leaf=_is_logical,
    )
