"""LM-wing training driver.

    python -m repro.launch.train --arch gemma-7b --shape train_4k \
        [--steps 200] [--reduced] [--checkpoint-dir ckpt/] [--mesh pod|multipod|none]

With ``--reduced`` the family-preserving smoke config runs on one CPU device
(CI / laptop); without it, the full config expects a real TPU slice whose
topology matches ``launch.mesh.make_production_mesh`` (on multi-host, run one
process per host under the same arguments — jax.distributed picks up the
cluster env).  Checkpoints restore-by-step; data is a deterministic
function of step, so restarts are exactly resumable.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.models import api as M
from repro.runtime.checkpoint import TrainCheckpoint
from repro.train.data import make_batch
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainStepConfig, build_train_step, init_train_state


def flatten_state(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(e, "key", getattr(e, "name", e))) for e in path)
        flat[key] = np.asarray(leaf)
    return flat


def unflatten_like(tree, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(e, "key", getattr(e, "name", e))) for e in path)
        leaves.append(jnp.asarray(flat[key], leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), leaves)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "pod", "multipod"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="dots", choices=["none", "dots", "full"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = cfg.reduced()
        shape = ShapeConfig(shape.name, seq_len=64, global_batch=4, kind="train")

    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    tcfg = TrainStepConfig(
        n_microbatches=args.microbatches,
        remat=args.remat,
        optimizer=AdamWConfig(lr=args.lr, total_steps=max(args.steps, 100)),
    )
    params, opt = init_train_state(
        cfg, tcfg, jax.random.PRNGKey(0), max_positions=shape.seq_len
    )
    step_fn = build_train_step(cfg, tcfg=tcfg, mesh=mesh, donate=True)

    start = 0
    ckpt = TrainCheckpoint(args.checkpoint_dir) if args.checkpoint_dir else None
    if ckpt and ckpt.latest_step() is not None:
        start, flat = ckpt.restore()
        params = unflatten_like(params, {k[2:]: v for k, v in flat.items() if k.startswith("p/")})
        opt = unflatten_like(opt, {k[2:]: v for k, v in flat.items() if k.startswith("o/")})
        print(f"resumed from step {start}")

    t_last, tok_count = time.time(), 0
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        tok_count += shape.global_batch * shape.seq_len
        if (step + 1) % args.log_every == 0:
            dt = time.time() - t_last
            print(
                f"step {step + 1:5d}  loss {float(metrics['loss']):.4f}  "
                f"gnorm {float(metrics['grad_norm']):.2f}  "
                f"lr {float(metrics['lr']):.2e}  tok/s {tok_count / dt:,.0f}",
                flush=True,
            )
            t_last, tok_count = time.time(), 0
        if ckpt and (step + 1) % args.checkpoint_every == 0:
            flat = {**{f"p/{k}": v for k, v in flatten_state(params).items()},
                    **{f"o/{k}": v for k, v in flatten_state(opt).items()}}
            ckpt.save(step + 1, flat)
    print("done.")


if __name__ == "__main__":
    main()
