"""Render EXPERIMENTS.md tables from the dry-run record directory.

    python -m repro.launch.report [--dir experiments/dryrun]

Emits the §Dry-run and §Roofline markdown tables to stdout; EXPERIMENTS.md
includes the generated blocks verbatim.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


def fmt_bytes(n) -> str:
    if n is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_s(x) -> str:
    if x is None:
        return "—"
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1.0:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def dryrun_table(recs: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | per-dev HBM | fits 16G | compile | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        mesh = {"pod": "16x16", "multipod": "2x16x16"}.get(r.get("mesh_kind", ""), "?")
        if r.get("status") == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | **skip** | — | — | — | "
                f"{r['skip_reason'][:70]}… |"
            )
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | {r['status']} | — | — | — | — |")
            continue
        mem = r.get("memory") or {}
        peak = mem.get("peak_bytes")
        colls = r.get("collectives_by_kind") or {}
        coll_str = (
            ", ".join(f"{k.split('-')[-1][:6]}:{fmt_bytes(v)}" for k, v in sorted(colls.items()))
            or "none"
        )
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | {fmt_bytes(peak)} "
            f"({r.get('hbm_util', 0):.2f}x) | {'yes' if r.get('fits_hbm') else 'NO'} | "
            f"{r.get('compile_s', 0):.0f}s | {coll_str} |"
        )
    return "\n".join(rows)


def roofline_table(recs: list[dict], mesh_kind: str = "pod") -> str:
    rows = [
        "| arch | shape | compute | memory(floor) | collective | dominant | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh_kind") != mesh_kind:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r.get('memory_floor_s'))} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{100 * (r.get('useful_flops_ratio') or 0):.0f}% | "
            f"**{100 * (r.get('roofline_fraction') or 0):.1f}%** |"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    ok = sum(1 for r in recs if r.get("status") == "ok")
    skip = sum(1 for r in recs if r.get("status") == "skip")
    print(f"### §Dry-run ({ok} compiled cells, {skip} assigned skips)\n")
    print(dryrun_table(recs))
    print("\n### §Roofline — single pod (16x16 = 256 chips)\n")
    print(roofline_table(recs, "pod"))
    print("\n### §Roofline — multi-pod (2x16x16 = 512 chips)\n")
    print(roofline_table(recs, "multipod"))


if __name__ == "__main__":
    main()
