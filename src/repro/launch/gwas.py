"""TorchGWAS-equivalent command line: a thin subcommand shell over
``repro.api`` (the paper's §2.1 packaged workflow).

    python -m repro.launch.gwas scan \
        --genotypes cohort.bed --pheno panel.tsv --covar covars.tsv \
        --out results/ [--engine fused] [--writer tsv,npz] [--resume ...]

    python -m repro.launch.gwas grm \
        --genotypes 'cohort_chr*.bed' --out results/grm.npz [--loco]

    python -m repro.launch.gwas merge \
        --checkpoint-dir ck/ --out results/ [--genotypes ... --pheno ...]

    python -m repro.launch.gwas report --out results/ [--top 20]

    python -m repro.launch.gwas serve \
        --genotypes cohort.bed --pheno panel.tsv [--covar covars.tsv] \
        [--port 8763] [--devices 2] [--ready-file serve.addr]

``scan`` binds a Study, plans the grid, and streams the session's events
through result writers — hits land in sorted ``hits.tsv`` batch by batch
(never held as a dense table in RAM), per-trait best and per-marker QC
follow at close, and ``summary.json`` records the run.  ``grm`` runs the
streamed GRM pass standalone; ``merge`` turns a committed checkpoint
directory into final outputs without recomputing anything; ``report``
pretty-prints a results directory.

The historical flags-only invocation (no subcommand) still works and means
``scan``:

    python -m repro.launch.gwas --genotypes cohort.bed --pheno panel.tsv \
        --covar covars.tsv --out results/

Accepts PLINK (.bed), BGEN (.bgen) and NumPy (.npy/.npz) genotype
containers — one file, a glob (quote it!), or a comma-separated list opened
as one contiguous multi-file source; aligns tables by sample id.
``--checkpoint-dir`` makes the scan restartable at grid-cell granularity.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core.association import AssocOptions
from repro.core.engines import available_engines
from repro.runtime.workqueue import available_backends

SUBCOMMANDS = ("scan", "grm", "merge", "report", "serve")


# ------------------------------------------------------------------- scan


def build_scan_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch.gwas scan", description=__doc__)
    ap.add_argument("--genotypes", required=True,
                    help=".bed / .bgen / .npy / .npz — one file, a glob "
                         "('cohort_chr*.bed'), or a comma-separated list")
    ap.add_argument("--pheno", required=True, help="phenotype table (FID IID trait...)")
    ap.add_argument("--covar", default=None, help="covariate table")
    ap.add_argument("--out", required=True, help="output directory")
    ap.add_argument("--writer", default="tsv",
                    help="comma list of result writers (see "
                         "repro.api.available_writers()); default tsv")
    ap.add_argument("--engine", default="dense", choices=available_engines())
    ap.add_argument("--mode", default="mp", choices=["mp", "sample"])
    ap.add_argument("--dof-mode", default="paper", choices=["paper", "exact"])
    ap.add_argument("--precision", default="fp32", choices=["fp32", "bf16"])
    ap.add_argument("--input-dtype", default="fp32", choices=["fp32", "bf16"],
                    help="fused kernel GEMM input dtype (the epilogue stays "
                         "fp32 either way)")
    ap.add_argument("--batch-markers", type=int, default=8192)
    ap.add_argument("--trait-block", type=int, default=0,
                    help="tile the trait axis into blocks of this width "
                         "(2-D scan grid; 0 = unblocked; rounded up to a "
                         "multiple of the block-p compute tile).  Peak "
                         "device memory then scales with the block, not "
                         "the panel; results are bitwise-identical either "
                         "way")
    ap.add_argument("--block-p", type=int, default=256,
                    help="panel-axis compute tile: the fused kernel's p-tile "
                         "and the dense/lmm GEMM chunk; trait blocks align "
                         "to it")
    ap.add_argument("--panel-resident-blocks", type=int, default=4,
                    help="how many panel blocks the device LRU keeps staged")
    ap.add_argument("--hit-spill-rows", type=int, default=2_000_000,
                    help="spill buffered hit rows to npz parts under --out "
                         "once this many are resident in RAM")
    ex = ap.add_argument_group("multi-device executor")
    ex.add_argument("--devices", type=int, default=1,
                    help="executor slots draining the scan grid (0 = every "
                         "visible device; 1 = the serial walk).  Results "
                         "are bitwise-identical to a single-device scan")
    ex.add_argument("--placement", default="marker-major",
                    choices=["marker-major", "trait-major"],
                    help="cell placement: marker-major reuses each staged "
                         "genotype batch across its trait blocks, "
                         "trait-major keeps one panel block resident per "
                         "device while re-reading the genotype stream")
    ex.add_argument("--lease-batches", type=int, default=2,
                    help="work items leased per scheduler claim (work "
                         "stealing splits at marker-batch granularity)")
    ex.add_argument("--exec-backend", default="threads",
                    choices=sorted(available_backends()),
                    help="scheduler backend, one of: "
                         f"{', '.join(sorted(available_backends()))}.  "
                         "threads keeps the lease table in-process; "
                         "shared-fs puts it on the filesystem next to "
                         "--checkpoint-dir so N independent processes "
                         "(across hosts) drain one grid — run the same "
                         "command on each host")
    ex.add_argument("--host-id", default=None,
                    help="this process's identity in the shared-fs lease "
                         "table (default hostname-pid); must be unique per "
                         "live process")
    ex.add_argument("--slot-prefetch", type=int, default=1,
                    help="per-device look-ahead depth: claim and decode the "
                         "next marker batch while the current one computes "
                         "(0 = unpipelined worker; output is bitwise-"
                         "identical either way)")
    ex.add_argument("--autotune-lease", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink --lease-batches at runtime as the grid "
                         "drains (guided self-scheduling) and when workers "
                         "report high wait share; chosen values land in "
                         "summary.json under executor.autotune")
    ex.add_argument("--lease-ttl", type=float, default=60.0,
                    help="shared-fs heartbeat expiry in seconds: a lease "
                         "not refreshed for this long counts as a dead "
                         "host's and is stolen (safe either way — cells "
                         "are idempotent; this only tunes reclaim latency)")
    ap.add_argument("--progress", action="store_true",
                    help="live per-cell progress line on stderr (auto when "
                         "stderr is a tty)")
    lmm = ap.add_argument_group("mixed model (--engine lmm)")
    lmm.add_argument("--loco", action="store_true",
                     help="leave-one-chromosome-out GRM (needs a multi-file fileset)")
    lmm.add_argument("--grm-method", default="std", choices=["std", "centered"])
    lmm.add_argument("--grm-batch-markers", type=int, default=4096)
    lmm.add_argument("--lmm-delta", type=float, default=None,
                     help="pin the variance ratio se^2/sg^2 (skip the REML fit)")
    lmm.add_argument("--lmm-epilogue", default="dense", choices=["dense", "fused"])
    ap.add_argument("--maf-min", type=float, default=0.0)
    ap.add_argument("--hit-threshold", type=float, default=7.301,
                    help="-log10 p threshold (default genome-wide 5e-8)")
    ap.add_argument("--no-sparse-epilogue", action="store_true",
                    help="compute the full dense -log10 p tile per cell "
                         "instead of the threshold-compacted sparse epilogue "
                         "(identical output, slower; for audits)")
    ap.add_argument("--hit-capacity", type=int, default=4096,
                    help="per-cell compacted hit-buffer slots; overflow "
                         "falls back to the dense pull for that cell")
    ap.add_argument("--exclude-related", action="store_true")
    ap.add_argument("--multivariate", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--io-workers", type=int, default=2)
    ap.add_argument("--genotype-staging", default="auto",
                    choices=["auto", "packed", "dense"],
                    help="H2D staging currency (DESIGN.md §17): 'packed' "
                         "stages raw 2-bit PLINK bytes with device-side "
                         "decode (~16x less transfer, bitwise-identical "
                         "output), 'dense' stages decoded float32; 'auto' "
                         "picks packed whenever the source supports it")
    ap.add_argument("--packed-cache-mb", type=int, default=256,
                    help="shared packed-slab host cache budget (scan, GRM, "
                         "and serve warm windows share one read per batch)")
    return ap


# Historical entry point compatibility: the flags-only invocation parses
# with the scan parser.
build_parser = build_scan_parser


def cmd_scan(argv) -> None:
    from repro.api import ExecSpec, GridSpec, IOSpec, LmmSpec, Study, get_writer

    args = build_scan_parser().parse_args(argv)
    if args.exec_backend != "threads" and not args.checkpoint_dir:
        raise SystemExit(
            f"--exec-backend {args.exec_backend} coordinates processes "
            "through the checkpoint directory (lease table + manifest); "
            "pass --checkpoint-dir (the SAME path on every host)"
        )
    os.makedirs(args.out, exist_ok=True)

    try:
        study = Study.from_files(
            args.genotypes, args.pheno, args.covar,
            exclude_related=args.exclude_related,
        )
    except ValueError as e:
        if "missing from the tables" in str(e):
            raise SystemExit(str(e)) from None
        raise
    plan = study.plan(
        engine=args.engine,
        grid=GridSpec(
            batch_markers=args.batch_markers,
            trait_block=args.trait_block,
            block_p=args.block_p,
            panel_resident_blocks=args.panel_resident_blocks,
        ),
        lmm=(
            LmmSpec(
                loco=args.loco,
                grm_method=args.grm_method,
                grm_batch_markers=args.grm_batch_markers,
                delta=args.lmm_delta,
                epilogue=args.lmm_epilogue,
            )
            if args.engine == "lmm" else None
        ),
        io=IOSpec(io_workers=args.io_workers, spill_dir=args.out,
                  hit_spill_rows=args.hit_spill_rows,
                  genotype_staging=args.genotype_staging,
                  packed_cache_mb=args.packed_cache_mb),
        executor=ExecSpec(devices=args.devices, placement=args.placement,
                          lease_batches=args.lease_batches,
                          slot_prefetch=args.slot_prefetch,
                          autotune_lease=args.autotune_lease,
                          backend=args.exec_backend, host_id=args.host_id,
                          lease_ttl=args.lease_ttl),
        options=AssocOptions(dof_mode=args.dof_mode, precision=args.precision),
        mode=args.mode,
        hit_threshold_nlp=args.hit_threshold,
        maf_min=args.maf_min,
        multivariate=args.multivariate,
        checkpoint_dir=args.checkpoint_dir,
        input_dtype=args.input_dtype,
        sparse_epilogue=not args.no_sparse_epilogue,
        hit_capacity=args.hit_capacity,
    )
    # Writers resolve BEFORE the expensive amortized prepare (GRM/REML for
    # lmm can take hours at scale; a typo'd --writer must fail in
    # milliseconds, not after it).
    writers = [
        get_writer(name)(args.out, spill_rows=args.hit_spill_rows)
        for name in args.writer.split(",") if name
    ]
    session = plan.run(resume=not args.no_resume)
    if args.progress or sys.stderr.isatty():
        # Live progress off the session metrics hook: cells done, markers/s,
        # device count — one line, rewritten in place.
        session.progress = lambda m: print(
            f"\r{m.progress_line()}", end="", file=sys.stderr, flush=True
        )
    # wall_s covers the scan itself, not the amortized setup — the same
    # accounting the historical CLI reported.
    t0 = time.time()
    wsum = session.stream_to(*writers)
    wall = time.time() - t0
    if session.progress is not None:
        print(file=sys.stderr)  # finish the \r progress line

    summary = {
        "markers": session.n_markers,
        "samples": session.n_samples,
        "traits": session.n_traits,
        "excluded_related": study.excluded_samples,
        "dof": session.dof,
        "hits": int(wsum.get("hits", 0)),
        "lambda_gc": wsum.get("lambda_gc"),
        "wall_s": wall,
        "markers_per_s": session.n_markers / wall,
        "engine": args.engine,
        "sparse_epilogue": not args.no_sparse_epilogue,
        # The *resolved* staging currency ("auto" negotiates per source)
        "genotype_staging": session.prepared.ctx.genotype_staging,
        "writers": [w.name for w in writers],
        "genotype_shards": getattr(study.source, "n_shards", 1),
        "trait_block": args.trait_block,
        "trait_blocks": session.n_trait_blocks,
        "grid_cells": session.n_batches * session.n_trait_blocks,
        "executor": session.executor_info,
        "metrics": session.metrics.summary(),
    }
    if session.lmm_info:
        info = session.lmm_info
        summary["lmm"] = {
            "grm_method": info["grm_method"],
            "loco": info["loco"],
            "scopes": info["scopes"],
            "spectrum_hash": info["spectrum_hash"],
            "delta": (
                {str(k): float(v) for k, v in info["delta"].items()}
                if isinstance(info["delta"], dict) else float(info["delta"])
            ),
            **(
                {"h2_per_trait": np.asarray(info["h2"]).round(4).tolist()}
                if "h2" in info else {}
            ),
        }
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(json.dumps(summary, indent=1))
    if "hits_tsv" in wsum:
        print(f"hits: {wsum['hits_tsv']}")


# -------------------------------------------------------------------- grm


def cmd_grm(argv) -> None:
    from repro.core.grm import grm_spectrum, spectrum_fingerprint, stream_grm
    from repro.io import open_genotypes

    ap = argparse.ArgumentParser(
        prog="repro.launch.gwas grm",
        description="Streamed GRM pass, standalone: one pass over the "
                    "genotype stream, never materializing dosages.",
    )
    ap.add_argument("--genotypes", required=True)
    ap.add_argument("--out", required=True, help="output .npz path")
    ap.add_argument("--method", default="std", choices=["std", "centered"])
    ap.add_argument("--batch-markers", type=int, default=4096)
    ap.add_argument("--maf-min", type=float, default=0.0)
    ap.add_argument("--io-workers", type=int, default=2)
    ap.add_argument("--loco", action="store_true",
                    help="also store each leave-one-chromosome-out GRM "
                         "(needs a multi-file fileset)")
    ap.add_argument("--spectrum", action="store_true",
                    help="also eigendecompose and store (s, u)")
    ap.add_argument("--genotype-staging", default="auto",
                    choices=["auto", "packed", "dense"],
                    help="H2D currency of the GRM pass (see scan --help)")
    args = ap.parse_args(argv)

    source = open_genotypes(args.genotypes)
    t0 = time.time()
    grm = stream_grm(
        source, batch_markers=args.batch_markers, method=args.method,
        maf_min=args.maf_min, io_workers=args.io_workers,
        staging=args.genotype_staging,
    )
    k = grm.full()
    arrays: dict[str, np.ndarray] = {
        "k": k,
        "shard_boundaries": np.asarray(
            getattr(source, "shard_boundaries", (0, source.n_markers))
        ),
    }
    if args.loco:
        if grm.n_shards < 2:
            raise SystemExit("--loco needs a per-chromosome fileset (>= 2 shards)")
        for sid in range(grm.n_shards):
            arrays[f"loco_{sid}"] = grm.loco(sid)
    spec_hash = None
    if args.spectrum:
        s, u = grm_spectrum(k)
        arrays["s"], arrays["u"] = s, u
        spec_hash = spectrum_fingerprint({-1: s})
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    tmp = args.out + ".tmp.npz"
    np.savez_compressed(tmp, **arrays)
    os.replace(tmp, args.out)
    summary = {
        "samples": int(k.shape[0]),
        "markers": source.n_markers,
        "method": args.method,
        "loco_scopes": grm.n_shards if args.loco else 0,
        **({"spectrum_hash": spec_hash} if spec_hash else {}),
        "wall_s": time.time() - t0,
        "out": args.out,
    }
    print(json.dumps(summary, indent=1))


# ------------------------------------------------------------------ merge


def cmd_merge(argv) -> None:
    from repro.api import get_writer
    from repro.api.session import CheckpointReplay

    ap = argparse.ArgumentParser(
        prog="repro.launch.gwas merge",
        description="Fold a committed checkpoint directory into final "
                    "outputs without recomputing any grid cell.",
    )
    ap.add_argument("--checkpoint-dir", required=True)
    ap.add_argument("--out", required=True, help="output directory")
    ap.add_argument("--writer", default="tsv")
    ap.add_argument("--genotypes", default=None,
                    help="optional: resolve marker names for the TSVs")
    ap.add_argument("--pheno", default=None,
                    help="optional: resolve trait names for the TSVs")
    args = ap.parse_args(argv)

    marker_ids = trait_names = None
    if args.genotypes:
        from repro.io import open_genotypes

        marker_ids = open_genotypes(args.genotypes).marker_ids
    if args.pheno:
        from repro.io import read_table

        trait_names = tuple(read_table(args.pheno).names)
    replay = CheckpointReplay(
        args.checkpoint_dir, marker_ids=marker_ids, trait_names=trait_names
    )
    if not replay.complete:
        done = len(list(replay.checkpoint.completed_cells()))
        total = replay.n_batches * replay.n_trait_blocks
        print(f"warning: checkpoint is partial ({done}/{total} cells); "
              "merging what is committed", file=sys.stderr)
    os.makedirs(args.out, exist_ok=True)
    writers = [get_writer(n)(args.out) for n in args.writer.split(",") if n]
    wsum = replay.stream_to(*writers)
    summary = {
        "markers": replay.n_markers,
        "traits": replay.n_traits,
        "grid_cells": replay.n_batches * replay.n_trait_blocks,
        "merged_cells": len(list(replay.checkpoint.completed_cells())),
        "complete": replay.complete,
        "hits": int(wsum.get("hits", 0)),
        "lambda_gc": wsum.get("lambda_gc"),
        "writers": [w.name for w in writers],
    }
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(json.dumps(summary, indent=1))


# ----------------------------------------------------------------- report


def cmd_report(argv) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.launch.gwas report",
        description="Pretty-print a results directory (summary + top hits).",
    )
    ap.add_argument("--out", required=True, help="results directory to read")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args(argv)

    spath = os.path.join(args.out, "summary.json")
    if os.path.exists(spath):
        with open(spath) as f:
            summary = json.load(f)
        print("== scan summary ==")
        for k in ("markers", "samples", "traits", "hits", "lambda_gc",
                  "engine", "dof", "wall_s"):
            if k in summary and summary[k] is not None:
                v = summary[k]
                print(f"  {k:<12} {v:.4g}" if isinstance(v, float) else f"  {k:<12} {v}")
        if "lmm" in summary:
            print(f"  lmm          scopes={summary['lmm'].get('scopes')} "
                  f"loco={summary['lmm'].get('loco')}")
    hits_path = os.path.join(args.out, "hits.tsv")
    if not os.path.exists(hits_path):
        raise SystemExit(f"no hits.tsv under {args.out}")
    rows = []
    with open(hits_path) as f:
        header = f.readline().rstrip("\n").split("\t")
        for line in f:
            rows.append(line.rstrip("\n").split("\t"))
    rows.sort(key=lambda r: -float(r[4]))
    print(f"\n== top {min(args.top, len(rows))} of {len(rows)} hits ==")
    print(f"  {'marker':<14} {'trait':<12} {'r':>8} {'t':>9} {'-log10p':>9}")
    for r in rows[: args.top]:
        print(f"  {r[0]:<14} {r[1]:<12} {r[2]:>8} {r[3]:>9} {r[4]:>9}")


# ------------------------------------------------------------------ serve


def build_serve_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.gwas serve",
        description="Persistent multi-tenant scan service (DESIGN.md §16): "
                    "keep a cohort resident — open source, residualized "
                    "panel, GRM spectrum, warm device slots — and serve "
                    "phenotype-panel scans and marker-window queries over "
                    "HTTP, byte-identical to the offline `scan` subcommand.",
    )
    ap.add_argument("--genotypes", required=True,
                    help="resident study genotypes (.bed/.bgen/.npy/.npz, "
                         "glob, or comma list)")
    ap.add_argument("--pheno", required=True, help="resident phenotype table")
    ap.add_argument("--covar", default=None, help="covariate table")
    ap.add_argument("--study-id", default="default",
                    help="name the resident study registers under")
    ap.add_argument("--engine", default="dense", choices=available_engines())
    ap.add_argument("--batch-markers", type=int, default=8192)
    ap.add_argument("--trait-block", type=int, default=0)
    ap.add_argument("--block-p", type=int, default=256)
    ap.add_argument("--hit-threshold", type=float, default=7.301)
    ap.add_argument("--maf-min", type=float, default=0.0)
    sv = ap.add_argument_group("service")
    sv.add_argument("--host", default="127.0.0.1")
    sv.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral; the bound port is "
                         "printed and written to --ready-file)")
    sv.add_argument("--devices", type=int, default=1,
                    help="serve worker slots (0 = every visible device)")
    sv.add_argument("--max-resident-slots", type=int, default=8,
                    help="warm device-state cache capacity (LRU-evicted "
                         "beyond this; pinned slots never evict)")
    sv.add_argument("--lease-size", type=int, default=1,
                    help="cells leased per worker claim from the fair-share "
                         "queue (1 = finest-grained interleaving)")
    sv.add_argument("--drr-quantum", type=float, default=2.0,
                    help="deficit-round-robin quantum: cells credited per "
                         "request queue per scheduling round, scaled by "
                         "study weight")
    sv.add_argument("--weight", type=float, default=1.0,
                    help="fair-share weight of the resident study")
    sv.add_argument("--out-root", default=None,
                    help="directory for per-request result bundles "
                         "(default: a fresh temp dir)")
    sv.add_argument("--ready-file", default=None,
                    help="write '<host> <port>' here once listening "
                         "(atomic; lets scripts wait for boot)")
    sv.add_argument("--no-warm", action="store_true",
                    help="skip the eager resident-panel prepare at boot "
                         "(first window query pays it instead)")
    sv.add_argument("--verbose", action="store_true",
                    help="log HTTP requests to stderr")
    return ap


def cmd_serve(argv) -> None:
    import signal

    from repro.api import GridSpec, ServeSpec, Study
    from repro.serve import ServeHost, ServeServer

    args = build_serve_parser().parse_args(argv)
    spec = ServeSpec(
        host=args.host, port=args.port, devices=args.devices,
        max_resident_slots=args.max_resident_slots,
        lease_size=args.lease_size, drr_quantum=args.drr_quantum,
        default_weight=args.weight,
    )
    spec.validate()
    study = Study.from_files(args.genotypes, args.pheno, args.covar)
    host = ServeHost(
        devices=spec.devices,
        max_resident_slots=spec.max_resident_slots,
        lease_size=spec.lease_size,
        drr_quantum=spec.drr_quantum,
        default_weight=spec.default_weight,
        out_root=args.out_root,
    )
    host.admit_study(
        args.study_id, study,
        engine=args.engine,
        grid=GridSpec(batch_markers=args.batch_markers,
                      trait_block=args.trait_block, block_p=args.block_p),
        hit_threshold_nlp=args.hit_threshold,
        maf_min=args.maf_min,
    )
    boot: dict = {"study": args.study_id, "warm": not args.no_warm}
    if not args.no_warm:
        boot["prepare_s"] = host.warm_study(args.study_id)["prepare_s"]
    server = ServeServer(
        host, bind=spec.host, port=spec.port, verbose=args.verbose
    ).start()
    bound_host, bound_port = server.address
    boot.update({"host": bound_host, "port": bound_port,
                 "out_root": host.out_root})
    print(json.dumps({"serving": boot}), flush=True)
    if args.ready_file:
        tmp = args.ready_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{bound_host} {bound_port}\n")
        os.replace(tmp, args.ready_file)

    def _stop(signum, frame):  # noqa: ARG001 — signal signature
        server.shutdown_async()

    signal.signal(signal.SIGTERM, _stop)
    signal.signal(signal.SIGINT, _stop)
    server.wait()
    print(json.dumps({"stopped": {"requests": host.metrics_summary()["requests"]}}),
          flush=True)


# ------------------------------------------------------------------- main


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    try:
        if argv and argv[0] in SUBCOMMANDS:
            cmd, rest = argv[0], argv[1:]
            return {
                "scan": cmd_scan,
                "grm": cmd_grm,
                "merge": cmd_merge,
                "report": cmd_report,
                "serve": cmd_serve,
            }[cmd](rest)
        # Historical flags-only invocation == `scan` (kept until the
        # GenomeScan shim is removed).
        return cmd_scan(argv)
    except BrokenPipeError:
        # stdout went away (e.g. `... report | head`); not an error.  Point
        # the fd at devnull so the interpreter's exit flush stays quiet.
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return


if __name__ == "__main__":
    main()
