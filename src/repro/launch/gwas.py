"""TorchGWAS-equivalent command line (the paper's §2.1 packaged workflow).

    python -m repro.launch.gwas \
        --genotypes cohort.bed --pheno panel.tsv --covar covars.tsv \
        --out results/ [--engine fused] [--exclude-related] [--multivariate] \
        [--batch-markers 8192] [--maf-min 0.01] [--resume]

    # per-chromosome fileset: glob (quote it!) or comma list
    python -m repro.launch.gwas --genotypes 'cohort_chr*.bed' ...

    # paper-scale trait panels: tile the trait axis (2-D scan grid with
    # out-of-core panel blocks; bitwise-identical results, device memory
    # bounded by the block width instead of the panel width)
    python -m repro.launch.gwas --genotypes 'cohort_chr*.bed' \
        --trait-block 2048 ...

    # mixed model (population structure / relatedness): streamed GRM +
    # one-time rotation; --loco subtracts each chromosome's GRM share
    python -m repro.launch.gwas --genotypes 'cohort_chr*.bed' \
        --engine lmm --loco ...

Accepts PLINK (.bed), BGEN (.bgen) and NumPy (.npy/.npz) genotype
containers — one file, a glob, or a comma-separated list opened as one
contiguous multi-file source; aligns tables by sample id; writes a hits
TSV + per-trait best TSV + a JSON run summary.  ``--checkpoint-dir`` makes
the scan restartable at marker-batch granularity.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.association import AssocOptions
from repro.core.engines import available_engines
from repro.core.screening import GenomeScan, ScanConfig
from repro.io import align_tables, open_genotypes, read_table


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch.gwas", description=__doc__)
    ap.add_argument("--genotypes", required=True,
                    help=".bed / .bgen / .npy / .npz — one file, a glob "
                         "('cohort_chr*.bed'), or a comma-separated list")
    ap.add_argument("--pheno", required=True, help="phenotype table (FID IID trait...)")
    ap.add_argument("--covar", default=None, help="covariate table")
    ap.add_argument("--out", required=True, help="output directory")
    ap.add_argument("--engine", default="dense", choices=available_engines())
    ap.add_argument("--mode", default="mp", choices=["mp", "sample"])
    ap.add_argument("--dof-mode", default="paper", choices=["paper", "exact"])
    ap.add_argument("--precision", default="fp32", choices=["fp32", "bf16"])
    ap.add_argument("--batch-markers", type=int, default=8192)
    ap.add_argument("--trait-block", type=int, default=0,
                    help="tile the trait axis into blocks of this width "
                         "(2-D scan grid; 0 = unblocked; rounded up to a "
                         "multiple of the block-p compute tile).  Peak "
                         "device memory then scales with the block, not "
                         "the panel; results are bitwise-identical either "
                         "way")
    ap.add_argument("--block-p", type=int, default=256,
                    help="panel-axis compute tile: the fused kernel's p-tile "
                         "and the dense/lmm GEMM chunk; trait blocks align "
                         "to it")
    ap.add_argument("--panel-resident-blocks", type=int, default=4,
                    help="how many panel blocks the device LRU keeps staged")
    ap.add_argument("--hit-spill-rows", type=int, default=2_000_000,
                    help="spill collected hits to npz parts under --out "
                         "once this many rows are resident in RAM")
    lmm = ap.add_argument_group("mixed model (--engine lmm)")
    lmm.add_argument("--loco", action="store_true",
                     help="leave-one-chromosome-out GRM (needs a multi-file fileset)")
    lmm.add_argument("--grm-method", default="std", choices=["std", "centered"])
    lmm.add_argument("--grm-batch-markers", type=int, default=4096)
    lmm.add_argument("--lmm-delta", type=float, default=None,
                     help="pin the variance ratio se^2/sg^2 (skip the REML fit)")
    lmm.add_argument("--lmm-epilogue", default="dense", choices=["dense", "fused"])
    ap.add_argument("--maf-min", type=float, default=0.0)
    ap.add_argument("--hit-threshold", type=float, default=7.301,
                    help="-log10 p threshold (default genome-wide 5e-8)")
    ap.add_argument("--exclude-related", action="store_true")
    ap.add_argument("--multivariate", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--io-workers", type=int, default=2)
    return ap


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    source = open_genotypes(args.genotypes)
    pheno = read_table(args.pheno)
    covar = read_table(args.covar) if args.covar else None
    y, c, keep = align_tables(source.sample_ids, pheno, covar)
    if not keep.all():
        raise SystemExit(
            f"{(~keep).sum()} genotype samples missing from the tables; "
            "subset the genotype container first (alignment is strict by design)"
        )
    y = np.where(np.isnan(y), np.nanmean(y, axis=0, keepdims=True), y)

    config = ScanConfig(
        batch_markers=args.batch_markers,
        trait_block=args.trait_block,
        engine=args.engine,
        mode=args.mode,
        options=AssocOptions(dof_mode=args.dof_mode, precision=args.precision),
        hit_threshold_nlp=args.hit_threshold,
        maf_min=args.maf_min,
        exclude_related=args.exclude_related,
        multivariate=args.multivariate,
        checkpoint_dir=args.checkpoint_dir,
        io_workers=args.io_workers,
        block_p=args.block_p,
        panel_resident_blocks=args.panel_resident_blocks,
        spill_dir=args.out,
        hit_spill_rows=args.hit_spill_rows,
        loco=args.loco,
        grm_method=args.grm_method,
        grm_batch_markers=args.grm_batch_markers,
        lmm_delta=args.lmm_delta,
        lmm_epilogue=args.lmm_epilogue,
    )
    scan = GenomeScan(source, y, c, config=config)
    t0 = time.time()
    result = scan.run(resume=not args.no_resume)
    wall = time.time() - t0

    hits_path = os.path.join(args.out, "hits.tsv")
    with open(hits_path, "w") as f:
        f.write("marker\ttrait\tr\tt\tneglog10p\n")
        for (m, t), (r, tt, nlp) in zip(result.hits, result.hit_stats):
            f.write(f"{source.marker_ids[m]}\t{pheno.names[t]}\t{r:.5f}\t{tt:.4f}\t{nlp:.3f}\n")
    best_path = os.path.join(args.out, "per_trait_best.tsv")
    with open(best_path, "w") as f:
        f.write("trait\tbest_marker\tneglog10p\n")
        for t, name in enumerate(pheno.names):
            m = int(result.best_marker[t])
            mid = source.marker_ids[m] if m >= 0 else "NA"
            f.write(f"{name}\t{mid}\t{result.best_nlp[t]:.3f}\n")
    summary = {
        "markers": result.n_markers,
        "samples": result.n_samples,
        "traits": result.n_traits,
        "excluded_related": result.excluded_samples,
        "dof": result.dof,
        "hits": int(len(result.hits)),
        "lambda_gc": result.lambda_gc,
        "wall_s": wall,
        "markers_per_s": result.n_markers / wall,
        "engine": args.engine,
        "genotype_shards": getattr(source, "n_shards", 1),
        "trait_block": args.trait_block,
        "trait_blocks": scan.n_trait_blocks,
        "grid_cells": scan.n_batches * scan.n_trait_blocks,
    }
    if result.lmm_info:
        info = result.lmm_info
        summary["lmm"] = {
            "grm_method": info["grm_method"],
            "loco": info["loco"],
            "scopes": info["scopes"],
            "spectrum_hash": info["spectrum_hash"],
            "delta": (
                {str(k): float(v) for k, v in info["delta"].items()}
                if isinstance(info["delta"], dict) else float(info["delta"])
            ),
            **(
                {"h2_per_trait": np.asarray(info["h2"]).round(4).tolist()}
                if "h2" in info else {}
            ),
        }
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(json.dumps(summary, indent=1))
    print(f"hits: {hits_path}")


if __name__ == "__main__":
    main()
