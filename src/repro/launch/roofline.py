"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = sum over collectives of wire_bytes / link_bandwidth

``cost_analysis()`` is per-device on an SPMD-partitioned module (calibrated
in tests/test_roofline.py), so no division by chip count is applied.
Collective wire bytes use ring formulas on the participating group size k:

    all-reduce        2 (k-1)/k * bytes
    all-gather        (k-1)/k   * bytes   (bytes = full output buffer)
    reduce-scatter    (k-1)/k   * bytes   (bytes = full input buffer)
    all-to-all        (k-1)/k   * bytes
    collective-permute            bytes

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
The pod axis crosses DCN; we model it at 6.25 GB/s/host-link and flag any
cell whose collective term is DCN-dominated.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.configs.base import GwasWorkloadConfig, ModelConfig, ShapeConfig

__all__ = [
    "HW",
    "parse_collectives",
    "roofline_from_compiled",
    "model_flops",
    "param_count",
]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 per chip
    peak_flops_f32: float = 98.5e12   # fp32 ~ half MXU rate
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link (intra-pod)
    dcn_bw: float = 6.25e9            # bytes/s per host (pod axis)


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# Real XLA text carries layout annotations: ``f32[512,64]{1,0} all-reduce(...``
_SHAPE_ITEM = r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?"
_COLL_RE = re.compile(
    r"=\s*\(?\s*((?:" + _SHAPE_ITEM + r"(?:,\s*)?)+)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class Collective:
    kind: str
    out_bytes: int
    group_size: int
    wire_bytes: float = 0.0


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> list[Collective]:
    """Scan optimized HLO for collective ops with their buffer sizes and
    participating group sizes."""
    out: list[Collective] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shapes_str)
        k = 1
        gm = _GROUPS_LIST_RE.search(line)
        if gm:
            k = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                k = int(gi.group(2))  # [groups, group_size]
        if kind == "all-reduce":
            wire = 2.0 * nbytes * (k - 1) / max(k, 1)
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = nbytes * (k - 1) / max(k, 1)
        else:  # collective-permute
            wire = float(nbytes)
        out.append(Collective(kind=kind, out_bytes=nbytes, group_size=k, wire_bytes=wire))
    return out


def roofline_from_compiled(compiled, *, n_devices: int, hw: HW = HW()) -> dict:
    """All three terms + provenance from one compiled executable."""
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    coll_bytes = sum(c.wire_bytes for c in colls)
    by_kind: dict[str, float] = {}
    for c in colls:
        by_kind[c.kind] = by_kind.get(c.kind, 0.0) + c.wire_bytes

    terms = {
        "compute_s": flops / hw.peak_flops,
        "memory_s": bytes_accessed / hw.hbm_bw,
        "collective_s": coll_bytes / hw.ici_bw,
    }
    dominant = max(terms, key=terms.get)
    mem = None
    try:
        ms = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ms.argument_size_in_bytes),
            "output_bytes": int(ms.output_size_in_bytes),
            "temp_bytes": int(ms.temp_size_in_bytes),
            "alias_bytes": int(ms.alias_size_in_bytes),
        }
        mem["peak_bytes"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"] - mem["alias_bytes"]
        )
    except Exception:  # noqa: BLE001 — backend without memory analysis
        pass
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_wire_bytes": coll_bytes,
        "collectives_by_kind": by_kind,
        "n_collectives": len(colls),
        **terms,
        "dominant": dominant,
        "memory": mem,
    }


# ------------------------------------------------------- analytic model FLOPs

def param_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts from the config (no allocation)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    mlp = (3 if cfg.activation in ("silu", "geglu") else 2) * d * cfg.d_ff
    embed = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)

    if cfg.family == "encdec":
        enc = cfg.encoder_layers * (attn + mlp)
        dec = cfg.n_layers * (2 * attn + mlp)   # self + cross attention
        total = enc + dec + embed
        return total, total

    total = active = 0
    for kind in _kinds(cfg):
        if kind in ("attn", "local"):
            if cfg.moe is not None:
                e = cfg.moe
                moe_p = e.n_experts * 3 * d * e.d_ff_expert + d * e.n_experts
                moe_a = e.top_k * 3 * d * e.d_ff_expert + d * e.n_experts
                dense = 3 * d * e.dense_d_ff if e.dense_d_ff else 0
                total += attn + moe_p + dense
                active += attn + moe_a + dense
            else:
                total += attn + mlp
                active += attn + mlp
        elif kind == "rwkv":
            layer = 5 * d * d + (2 * d * cfg.d_ff + d * d)  # time-mix + channel-mix
            total += layer
            active += layer
        elif kind == "rec":
            w = cfg.lru_width
            layer = (2 * d * w + 2 * w * w + w * d) + mlp
            total += layer
            active += layer
    return total + embed, active + embed


def _kinds(cfg: ModelConfig) -> list[str]:
    k = len(cfg.block_pattern)
    reps, tail = cfg.n_layers // k, cfg.n_layers % k
    return list(cfg.block_pattern) * reps + list(cfg.block_pattern[:tail])


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs: 6 N_active D for train, 2 N_active per served token,
    plus the quadratic attention term where applicable, plus the intrinsic
    recurrence state work for SSM/hybrid families (the WKV outer-product
    updates are the architecture's compute, not overhead)."""
    _, active = param_count(cfg)
    b, s = shape.global_batch, shape.seq_len
    attn_flops = 0.0
    for kind in _kinds(cfg):
        if kind == "attn":
            attn_flops += 2 * 2 * b * cfg.n_heads * cfg.resolved_head_dim * s * s / 2
        elif kind == "local":
            w = min(cfg.local_window, s)
            attn_flops += 2 * 2 * b * cfg.n_heads * cfg.resolved_head_dim * s * w
    rec = recurrence_flops(cfg, shape)
    if shape.kind == "train":
        return 6.0 * active * b * s + 3.0 * attn_flops + rec
    if shape.kind == "prefill":
        return 2.0 * active * b * s + attn_flops + rec
    # decode: one token against a seq_len-deep cache
    per_tok_attn = 0.0
    for kind in _kinds(cfg):
        if kind == "attn":
            per_tok_attn += 2 * 2 * cfg.n_heads * cfg.resolved_head_dim * s
        elif kind == "local":
            per_tok_attn += 2 * 2 * cfg.n_heads * cfg.resolved_head_dim * min(cfg.local_window, s)
    return 2.0 * active * b + per_tok_attn * b + rec


def gwas_flops(g: GwasWorkloadConfig, *, batch_only: bool = True) -> float:
    """Useful FLOPs of one marker-batch step: 2 M N P (Eq. 2's GEMM)."""
    m = g.batch_markers if batch_only else g.n_markers
    return 2.0 * m * g.n_samples * g.n_traits


def memory_floor_bytes(
    cfg: ModelConfig, shape: ShapeConfig, n_devices: int, *,
    state_dtype_bytes: int = 4, kv_bytes: int = 2,
) -> float:
    """Analytic per-device HBM-traffic floor for one step.

    The CPU backend's ``bytes accessed`` is an upper bound (its fusion is far
    weaker than TPU's), so the roofline memory term is bracketed:
    ``floor <= true <= hlo``.  The floor counts only unavoidable traffic:

      train:   params read fwd+bwd + grads written/read + opt state r/w
               + ~6 activation-sized transfers per layer (bf16)
      prefill: params once + ~4 activation transfers per layer + KV write
      decode:  params once + full KV/state read + cache write
    """
    total, _ = param_count(cfg)
    p_bytes = 2 * total / n_devices               # bf16 params, fully sharded
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    dp = max(n_devices / 16, 1)                   # data-parallel ways
    act_unit = (b / dp) * s * d * 2               # one bf16 activation pass
    if shape.kind == "train":
        # params fwd + bwd + grads w/r + opt m,v r/w (state dtype)
        params_io = 3 * p_bytes + 2 * (4 * total / n_devices) + 4 * (
            state_dtype_bytes * total / n_devices
        )
        act_io = 6.0 * act_unit * cfg.n_layers
        return params_io + act_io
    if shape.kind == "prefill":
        return p_bytes + 4.0 * act_unit * cfg.n_layers
    # decode: params once + full cache/state read (+ small write).
    kv_bytes_total = 0.0
    for kind in _kinds(cfg):
        if kind == "attn":
            kv_bytes_total += 2 * b * s * cfg.n_kv_heads * cfg.resolved_head_dim * kv_bytes
        elif kind == "local":
            kv_bytes_total += 2 * b * min(cfg.local_window, s) * cfg.n_kv_heads * cfg.resolved_head_dim * kv_bytes
        elif kind == "rwkv":
            h = cfg.d_model // cfg.rwkv_head_dim
            kv_bytes_total += b * h * cfg.rwkv_head_dim**2 * 4
        elif kind == "rec":
            kv_bytes_total += b * cfg.lru_width * 4
    return p_bytes + kv_bytes_total / n_devices


def recurrence_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic FLOPs of the *time-scan* inner loops (WKV / RG-LRU), which
    XLA's cost analysis counts only once per while body.  Added to HLO FLOPs
    as ``corrected`` in the dry-run records (the multiplier is the scan trip
    count minus the one counted body)."""
    b = shape.global_batch
    steps = 1 if shape.kind == "decode" else shape.seq_len
    fwd_mult = 3.0 if shape.kind == "train" else 1.0
    per_step = 0.0
    for kind in _kinds(cfg):
        if kind == "rwkv":
            h = cfg.d_model // cfg.rwkv_head_dim
            per_step += 7.0 * b * h * cfg.rwkv_head_dim**2
        elif kind == "rec":
            per_step += 3.0 * b * cfg.lru_width
    return per_step * max(steps - 1, 0) * fwd_mult
