"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with abstract inputs, print memory/cost analysis, derive roofline
terms.  THE proof that the distribution config is coherent without hardware.

Usage:
    python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all [--mesh both] [--out-dir experiments/dryrun]

Orchestrator mode (--all) runs each cell in a subprocess (isolation: one
cell's OOM/compile bug cannot take down the sweep) and skips cells whose
JSON record already exists.
"""
# The 512 placeholder devices MUST be configured before jax initializes —
# keep these two lines first, before any other import.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import LM_ARCHS, SHAPES, get_config, supported_shapes
from repro.configs.base import GwasWorkloadConfig, ModelConfig, ShapeConfig
from repro.launch import roofline as RL
from repro.launch.mesh import describe, make_production_mesh
from repro.models import api as M
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.serve_step import build_decode_step, build_prefill_step
from repro.train.train_step import TrainStepConfig, build_train_step

HBM_PER_CHIP = 16 * 1024**3  # v5e

# Per-arch training memory knobs (microbatching + remat + optimizer dtype).
# Chosen against the 16 GB/chip budget; EXPERIMENTS.md §Dry-run records the
# resulting hbm_util per cell.  arctic-480b genuinely cannot train on one
# 256-chip pod (params+grads+opt state > 4 TB aggregate) — that cell records
# fits_hbm=False by design and fits on the 512-chip multi-pod mesh.
TRAIN_OVERRIDES: dict[str, dict] = {
    "arctic-480b": dict(n_microbatches=16, remat="full", state_dtype="bfloat16",
                        accum_dtype="bfloat16", loss_chunk=512),
    "deepseek-coder-33b": dict(n_microbatches=8, remat="full"),
    "qwen1.5-32b": dict(n_microbatches=8, remat="full", loss_chunk=512),
    "gemma-7b": dict(n_microbatches=4, remat="full", loss_chunk=512),
    "gemma2-9b": dict(n_microbatches=4, remat="full", loss_chunk=512),
    "qwen2-vl-7b": dict(n_microbatches=4, remat="full", loss_chunk=512),
    "rwkv6-3b": dict(n_microbatches=4, remat="full", loss_chunk=512),
    "recurrentgemma-2b": dict(n_microbatches=4, remat="full", loss_chunk=512),
    "granite-moe-1b-a400m": dict(loss_chunk=512),
    "whisper-small": dict(n_microbatches=4, loss_chunk=512),
}


def _tcfg_for(arch: str, *, accounting: bool = False) -> TrainStepConfig:
    ov = TRAIN_OVERRIDES.get(arch, {})
    return TrainStepConfig(
        n_microbatches=1 if accounting else ov.get("n_microbatches", 1),
        # chunked loss runs inside a lax.scan; accounting lowers disable it
        # (identical math, exact flop counting)
        loss_chunk=0 if accounting else ov.get("loss_chunk", 0),
        remat=ov.get("remat", "dots"),
        accum_dtype=ov.get("accum_dtype", "float32"),
        optimizer=AdamWConfig(state_dtype=ov.get("state_dtype", "float32")),
    )


# Hillclimb variants: "--arch <base>+<flag>" applies a config patch on top of
# the registered architecture (records land beside the baselines for §Perf).
VARIANT_FLAGS = {
    "kvint8": dict(kv_cache_dtype="int8"),
    "attnchunk": dict(attn_chunk=1024),
    "moea2a": dict(moe_impl="manual"),
}


def _resolve_arch(arch: str):
    base, *flags = arch.split("+")
    cfg = get_config(base)
    for f in flags:
        cfg = dataclasses.replace(cfg, **VARIANT_FLAGS[f])
    return base, cfg, flags


def lower_lm_cell(arch: str, shape_name: str, mesh, *, accounting_reps: int | None = None):
    """Lower one LM cell.

    ``accounting_reps=None`` -> the production config (layers scanned): the
    record of truth for memory analysis, collectives and compile time.
    ``accounting_reps=r`` -> an UNROLLED variant with ``r`` pattern repeats
    (and microbatching off): XLA's cost analysis counts loop bodies once, so
    exact FLOPs/bytes come from differencing two small unrolled lowers and
    extrapolating to the full depth (see run_cell).
    """
    base, cfg, _flags = _resolve_arch(arch)
    shape = SHAPES[shape_name]
    if accounting_reps is not None:
        k = len(cfg.block_pattern)
        # chunked attention runs in a lax.scan; accounting lowers use the
        # dense-equivalent math for exact flop counting
        overrides = dict(scan_layers=False, n_layers=accounting_reps * k, attn_chunk=0)
        if cfg.family == "encdec":
            overrides["encoder_layers"] = accounting_reps
            overrides["n_layers"] = accounting_reps
        cfg = dataclasses.replace(cfg, **overrides)
    max_pos = shape.seq_len if cfg.family == "encdec" else 4096
    params_abs = M.abstract_params(cfg, max_positions=max_pos)
    specs = M.input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = _tcfg_for(base, accounting=accounting_reps is not None)
        step = build_train_step(cfg, tcfg=tcfg, mesh=mesh, donate=True)
        opt_abs = jax.eval_shape(lambda p: adamw_init(tcfg.optimizer, p), params_abs)
        lowered = step.lower(params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        step = build_prefill_step(cfg, shape, mesh=mesh)
        lowered = step.lower(params_abs, specs)
    else:  # decode
        step = build_decode_step(cfg, shape, mesh=mesh)
        caches_abs = M.abstract_caches(cfg, shape)
        lowered = step.lower(params_abs, specs["token"], specs["pos"], caches_abs)
    return lowered


def lower_gwas_cell(engine: str, mesh) -> tuple:
    from repro.core.association import AssocOptions
    from repro.core.screening import build_dense_step, build_fused_step

    g: GwasWorkloadConfig = get_config("gwas_ukb")
    if engine.endswith("_p2k"):
        # The paper's second benchmark point: 2,048 phenotypes.
        g = dataclasses.replace(g, n_traits=2_048)
        engine = engine[: -len("_p2k")]
    n_pad = -(-g.n_samples // g.block_n) * g.block_n
    mf = RL.gwas_flops(g)
    if engine.startswith("fused"):
        precision = "bf16" if engine == "fused_bf16" else "fp32"
        # bf16 engine also stores the phenotype panel replica in bf16 —
        # halving the one HBM stream that survives the 2-bit genotype fusion
        # (§Perf A4).
        y_dtype = jnp.bfloat16 if precision == "bf16" else jnp.float32
        block_p = min(g.block_p, g.n_traits // 16)  # per-device tile must divide
        step = build_fused_step(
            n_samples=g.n_samples, n_covariates=12,
            options=AssocOptions(precision=precision),
            mesh=mesh, block_m=g.block_m, block_n=g.block_n, block_p=block_p,
        )
        args = (
            jax.ShapeDtypeStruct((g.batch_markers, n_pad // 4), jnp.uint8),
            jax.ShapeDtypeStruct((g.batch_markers, 1), jnp.float32),
            jax.ShapeDtypeStruct((g.batch_markers, 1), jnp.float32),
            jax.ShapeDtypeStruct((g.batch_markers,), jnp.bool_),
            jax.ShapeDtypeStruct((g.n_samples, g.n_traits), y_dtype),
        )
    else:
        step = build_dense_step(
            n_samples=g.n_samples, n_covariates=12, options=AssocOptions(),
            mesh=mesh, mode=g.mode,
        )
        args = (
            jax.ShapeDtypeStruct((g.batch_markers, g.n_samples), jnp.float32),
            jax.ShapeDtypeStruct((g.n_samples, g.n_traits), jnp.float32),
        )
    lowered = step.lower(*args)
    # The fused kernel's grid loop bodies are counted once by cost analysis;
    # its true math equals the dense engine's GEMM.
    corr = mf if engine.startswith("fused") else 0.0
    return lowered, mf, 0, 0, {"recurrence_flops_correction": corr}


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": describe(mesh),
        "mesh_kind": mesh_kind,
        "n_devices": mesh.size,
    }
    hw = RL.HW()

    # ---- pass 1: production (scanned) config — memory, collectives, compile.
    t0 = time.time()
    if arch == "gwas_ukb":
        lowered, mf, total, active, extras = lower_gwas_cell(shape_name, mesh)
    else:
        _, cfg, _fl = _resolve_arch(arch)
        shape = SHAPES[shape_name]
        total, active = RL.param_count(cfg)
        mf = RL.model_flops(cfg, shape)
        extras = {"recurrence_flops_correction": RL.recurrence_flops(cfg, shape)}
        lowered = lower_lm_cell(arch, shape_name, mesh)
    record["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 1)
    roof = RL.roofline_from_compiled(compiled, n_devices=mesh.size)
    record.update(roof)

    # ---- pass 2: FLOP/byte accounting (XLA counts loop bodies once, so the
    # scanned numbers undercount by the trip count).  Two small UNROLLED
    # lowers give exact per-repeat costs; extrapolate to full depth.
    if arch == "gwas_ukb":
        flops_exact = roof["flops_per_device"] + extras["recurrence_flops_correction"] / mesh.size
        bytes_exact = roof["bytes_per_device"]
        if shape_name.startswith("fused"):
            # interpret-mode grid loop: bytes dominated by the packed stream;
            # account analytically (2-bit genotypes + Y replica + R/T out).
            g: GwasWorkloadConfig = get_config("gwas_ukb")
            dp = mesh.size // 16
            bytes_exact = (
                g.batch_markers * g.n_samples / 4 / dp
                + g.n_samples * g.n_traits * 4 / 16
                + 2 * g.batch_markers * g.n_traits * 4 / mesh.size
            )
    else:
        _, cfg, _fl = _resolve_arch(arch)
        k = len(cfg.block_pattern)
        equiv_reps = cfg.n_layers / k
        accounting = []
        for reps in (1, 2):
            t0 = time.time()
            small = lower_lm_cell(arch, shape_name, mesh, accounting_reps=reps).compile()
            ca = small.cost_analysis()
            accounting.append(
                (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)))
            )
            del small
        (f1, b1), (f2, b2) = accounting
        flops_exact = f1 + (equiv_reps - 1.0) * (f2 - f1)
        bytes_exact = b1 + (equiv_reps - 1.0) * (b2 - b1)
        flops_exact += extras["recurrence_flops_correction"] / mesh.size
        record["accounting"] = {
            "reps1": {"flops": f1, "bytes": b1},
            "reps2": {"flops": f2, "bytes": b2},
            "equiv_repeats": equiv_reps,
        }

    # GWAS runs fp32 GEMMs unless the bf16 variant is selected; the MXU's
    # fp32 rate is half its bf16 rate.  LM cells are bf16 throughout.
    peak = hw.peak_flops
    if arch == "gwas_ukb" and shape_name != "fused_bf16":
        peak = hw.peak_flops_f32
    record["peak_flops_used"] = peak
    record["flops_per_device_exact"] = flops_exact
    record["bytes_per_device_exact"] = bytes_exact
    record["compute_s"] = flops_exact / peak
    record["memory_s"] = bytes_exact / hw.hbm_bw  # CPU-fusion upper bound
    if arch == "gwas_ukb":
        g = get_config("gwas_ukb")
        dp = mesh.size // 16
        floor = (
            g.batch_markers * g.n_samples * (0.25 if shape_name.startswith("fused") else 4.0) / dp
            + g.n_samples * g.n_traits * 4 / 16
            + 2 * g.batch_markers * g.n_traits * 4 / mesh.size
        )
    else:
        base_name, vcfg, vflags = _resolve_arch(arch)
        ov = TRAIN_OVERRIDES.get(base_name, {})
        sd = 2 if ov.get("state_dtype") == "bfloat16" else 4
        floor = RL.memory_floor_bytes(
            vcfg, SHAPES[shape_name], mesh.size, state_dtype_bytes=sd,
            kv_bytes=1 if vcfg.kv_cache_dtype == "int8" else 2,
        )
    record["memory_floor_bytes"] = floor
    record["memory_floor_s"] = floor / hw.hbm_bw
    record["dominant"] = max(
        ("compute_s", "memory_floor_s", "collective_s"), key=lambda kk: record[kk]
    )
    record["model_flops_global"] = mf
    record["model_flops_per_device"] = mf / mesh.size
    record["useful_flops_ratio"] = (mf / mesh.size) / flops_exact if flops_exact else None
    # Headline score: useful compute time over the dominant bound.
    useful_s = (mf / mesh.size) / peak
    record["roofline_fraction"] = useful_s / max(
        record["compute_s"], record["memory_floor_s"], record["collective_s"], 1e-30
    )
    record["params_total"] = total
    record["params_active"] = active
    if roof.get("memory"):
        peak = roof["memory"]["peak_bytes"]
        record["fits_hbm"] = bool(peak <= HBM_PER_CHIP)
        record["hbm_util"] = round(peak / HBM_PER_CHIP, 3)
    record["status"] = "ok"
    # The console proof the assignment asks for:
    print(f"[{arch} x {shape_name} x {describe(mesh)}]")
    try:
        print(compiled.memory_analysis())
    except Exception as e:  # noqa: BLE001
        print("memory_analysis unavailable:", e)
    ca = compiled.cost_analysis()
    print({kk: ca[kk] for kk in sorted(ca) if kk in ("flops", "bytes accessed")})
    return record


def cell_inventory() -> list[tuple[str, str, str | None]]:
    """All (arch, shape, skip_reason) cells, GWAS engines included."""
    cells: list[tuple[str, str, str | None]] = []
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        for name, shape in supported_shapes(cfg).items():
            if shape is None:
                reason = (
                    "long_500k needs sub-quadratic attention; "
                    f"{arch} has unbounded-context layers (DESIGN.md §Arch-applicability)"
                )
                cells.append((arch, name, reason))
            else:
                cells.append((arch, name, None))
    cells.append(("gwas_ukb", "dense", None))       # paper-faithful fp32 baseline
    cells.append(("gwas_ukb", "fused", None))       # beyond-paper 2-bit Pallas, fp32 GEMM
    cells.append(("gwas_ukb", "fused_bf16", None))  # + bf16 MXU inputs (fp32 accum)
    # the paper's second benchmark point (2,048 phenotypes)
    cells.append(("gwas_ukb", "dense_p2k", None))
    cells.append(("gwas_ukb", "fused_p2k", None))
    cells.append(("gwas_ukb", "fused_bf16_p2k", None))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    if not args.all:
        for mesh_kind in meshes:
            record = run_cell(args.arch, args.shape, mesh_kind)
            path = os.path.join(
                args.out_dir, f"{args.arch}__{args.shape}__{mesh_kind}.json"
            )
            with open(path, "w") as f:
                json.dump(record, f, indent=1)
            print("->", path)
        return

    # Orchestrator: subprocess per cell, resumable, failures recorded.
    todo = []
    for arch, shape, skip in cell_inventory():
        for mesh_kind in meshes:
            path = os.path.join(args.out_dir, f"{arch}__{shape}__{mesh_kind}.json")
            if os.path.exists(path):
                continue
            if skip is not None:
                with open(path, "w") as f:
                    json.dump(
                        {"arch": arch, "shape": shape, "mesh_kind": mesh_kind,
                         "status": "skip", "skip_reason": skip},
                        f, indent=1,
                    )
                continue
            todo.append((arch, shape, mesh_kind, path))

    print(f"{len(todo)} cells to run")
    for i, (arch, shape, mesh_kind, path) in enumerate(todo):
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
            "--out-dir", args.out_dir,
        ]
        print(f"[{i + 1}/{len(todo)}] {arch} x {shape} x {mesh_kind}", flush=True)
        try:
            proc = subprocess.run(
                cmd, timeout=args.timeout, capture_output=True, text=True
            )
            if proc.returncode != 0:
                with open(path, "w") as f:
                    json.dump(
                        {"arch": arch, "shape": shape, "mesh_kind": mesh_kind,
                         "status": "error",
                         "error": (proc.stderr or "")[-3000:]},
                        f, indent=1,
                    )
                print("   ERROR (recorded)")
        except subprocess.TimeoutExpired:
            with open(path, "w") as f:
                json.dump(
                    {"arch": arch, "shape": shape, "mesh_kind": mesh_kind,
                     "status": "timeout", "timeout_s": args.timeout},
                    f, indent=1,
                )
            print("   TIMEOUT (recorded)")


if __name__ == "__main__":
    main()
