"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only the dry-run
process sets ``xla_force_host_platform_device_count``).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "POD_CHIPS", "describe"]

POD_CHIPS = 256  # 16 x 16 TPU v5e pod slice


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod; (pod=2, data=16, model=16) for two
    pods — 512 chips.  The 'pod' axis carries only data parallelism (DCN
    between pods is too slow for TP), which the sharding rules encode."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def describe(mesh) -> str:
    return "x".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
