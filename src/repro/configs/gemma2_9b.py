"""Gemma-2 9B [arXiv:2408.00118]: alternating local(4096)/global attention,
attention + final logit softcaps, post-norms, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    norm_plus_one=True,
    block_pattern=("local", "attn"),
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
)
