"""Granite-3.0 1B-a400m [hf:ibm-granite]: 32-expert top-8 MoE, GQA kv=8."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    activation="silu",
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
)
