"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]: 128-expert
top-2 MoE with a parallel dense-FFN residual path."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    activation="silu",
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864, dense_d_ff=4864),
)
