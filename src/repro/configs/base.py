"""Config dataclasses for the architecture zoo and the GWAS workload.

Every assigned architecture is a frozen ``ModelConfig``; shapes are the four
assigned input geometries.  ``reduced()`` produces the family-preserving
small config the smoke tests instantiate on CPU (full configs are only ever
lowered abstractly by the dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    dense_d_ff: int = 0            # arctic: parallel dense-FFN residual width
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    activation: str = "silu"       # silu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl (t, h, w) rotary split
    block_pattern: tuple[str, ...] = ("attn",)      # layer kinds, cycled
    local_window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    post_norms: bool = False       # gemma2: norm after attn/mlp too
    embed_scale: bool = False      # gemma family: embeddings * sqrt(d_model)
    norm_plus_one: bool = False    # gemma family RMSNorm (1 + w) convention
    moe: MoEConfig | None = None
    # ssm / hybrid
    rwkv_head_dim: int = 64
    lru_width: int = 0             # recurrentgemma RG-LRU state width
    conv_width: int = 4
    # enc-dec
    encoder_layers: int = 0
    encoder_len: int = 1500        # whisper frame positions after conv stub
    # vlm
    vision_stub_patches: int = 0   # patches supplied by the frontend stub
    dtype: str = "bfloat16"
    # scan_layers=True: lax.scan over layer repeats (fast compile, small HLO).
    # The dry-run flips it off so cost_analysis sees every layer (XLA counts
    # loop bodies once); numerics are identical either way (tested).
    scan_layers: bool = True
    kv_cache_dtype: str = "bfloat16"   # "int8": quantized KV cache (serve)
    # >0: flash-style online-softmax attention over KV chunks of this size —
    # the (S, T) logits tensor is never materialized (prefill_32k would
    # otherwise hold S^2 = 4 GB f32 score tiles per head group).
    attn_chunk: int = 0
    moe_impl: str = "gspmd"            # "manual": shard_map all-to-all dispatch

    # ------------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables padded to a 256 multiple so the vocab dim
        shards cleanly on any mesh axis (49155 % 16 != 0 would otherwise
        force the model's largest GEMM to replicate — measured 5x waste,
        EXPERIMENTS.md §Perf).  Logits beyond ``vocab`` are masked to -inf."""
        return -(-self.vocab // 256) * 256

    @property
    def attention_free(self) -> bool:
        return all(k in ("rwkv", "rec") for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer kind attends over unbounded context."""
        return all(k in ("rwkv", "rec", "local") for k in self.block_pattern)

    def reduced(self) -> "ModelConfig":
        """Family-preserving smoke-test size: every structural feature kept,
        every dimension shrunk."""
        changes: dict = dict(
            n_layers=max(len(self.block_pattern), 2 if self.n_layers > 1 else 1),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=512,
            local_window=16,
        )
        if self.family == "hybrid":
            changes["n_layers"] = len(self.block_pattern) + 2  # pattern + tail coverage
        if self.moe is not None:
            changes["moe"] = MoEConfig(
                n_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                dense_d_ff=64 if self.moe.dense_d_ff else 0,
                capacity_factor=self.moe.capacity_factor,
            )
        if self.lru_width:
            changes["lru_width"] = 64
        if self.encoder_layers:
            changes["encoder_layers"] = 2
            changes["encoder_len"] = 32
        if self.mrope_sections is not None:
            changes["mrope_sections"] = (4, 2, 2)  # sums to head_dim//2 = 8
        if self.vision_stub_patches:
            changes["vision_stub_patches"] = 8
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return ShapeConfig(self.name, seq_len=32, global_batch=2, kind=self.kind)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def supported_shapes(cfg: ModelConfig) -> dict[str, ShapeConfig | None]:
    """The assigned 4-cell row for an arch; None marks an assigned skip
    (recorded, never silently dropped).  Rules from the assignment:
    ``long_500k`` needs sub-quadratic attention; encoder-only archs would
    skip decode (none of ours are encoder-only)."""
    out: dict[str, ShapeConfig | None] = {}
    for name, shape in SHAPES.items():
        if name == "long_500k" and not cfg.sub_quadratic:
            out[name] = None
            continue
        out[name] = shape
    return out


@dataclass(frozen=True)
class GwasWorkloadConfig:
    """The paper's own benchmark workload (§3.1) as a dry-runnable config."""

    arch: str = "gwas_ukb"
    n_markers: int = 8_900_000
    n_samples: int = 23_000
    n_traits: int = 20_480
    n_covariates: int = 12
    batch_markers: int = 8_192
    engine: str = "fused"
    mode: str = "mp"
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256

    def reduced(self) -> "GwasWorkloadConfig":
        return dataclasses.replace(
            self,
            n_markers=2_048,
            n_samples=512,
            n_traits=64,
            batch_markers=512,
            block_m=64,
            block_n=128,
            block_p=64,
        )
