"""The paper's own benchmark workload (§3.1): 8.9M markers x 23k samples x
20,480 phenotypes, fused 2-bit engine, marker x phenotype sharding."""
from repro.configs.base import GwasWorkloadConfig

CONFIG = GwasWorkloadConfig()
