"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    GwasWorkloadConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SHAPES,
    supported_shapes,
)

_MODULES = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-small": "whisper_small",
    "rwkv6-3b": "rwkv6_3b",
    "gemma-7b": "gemma_7b",
    "gemma2-9b": "gemma2_9b",
    "qwen1.5-32b": "qwen15_32b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "arctic-480b": "arctic_480b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "gwas_ukb": "gwas_ukb",
}

LM_ARCHS = tuple(a for a in _MODULES if a != "gwas_ukb")


def list_archs() -> tuple[str, ...]:
    return tuple(_MODULES)


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {', '.join(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


__all__ = [
    "GwasWorkloadConfig",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SHAPES",
    "supported_shapes",
    "get_config",
    "list_archs",
    "LM_ARCHS",
]
