"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: RG-LRU recurrence + local
attention in a (rec, rec, local) pattern, MQA kv=1, GeGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    norm_plus_one=True,
    block_pattern=("rec", "rec", "local"),
    local_window=2048,
    lru_width=2560,
    conv_width=4,
)
