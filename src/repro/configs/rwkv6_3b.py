"""RWKV-6 'Finch' 3B [arXiv:2404.05892]: attention-free, data-dependent
decay time-mix; 40 heads x 64 head_dim."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                    # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    activation="silu",
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
)
