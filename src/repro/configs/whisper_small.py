"""Whisper-small [arXiv:2212.04356]: enc-dec, conv frontend stubbed to
precomputed frame embeddings (1500 positions), learned positions, GELU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-small",
    family="encdec",
    n_layers=12,                   # decoder layers
    encoder_layers=12,
    encoder_len=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    activation="gelu",
    rope_theta=0.0,                # learned absolute positions, no rope
)
