"""Qwen2-VL-7B backbone [arXiv:2409.12191]: GQA kv=4, M-RoPE, vision stub."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    activation="silu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # (t, h, w) halves of the 64 rotary pairs
    vision_stub_patches=1024,      # frontend stub supplies patch embeddings
)
