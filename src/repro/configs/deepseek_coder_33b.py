"""DeepSeek-Coder 33B [arXiv:2401.14196]: llama-arch, deep-narrow, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    activation="silu",
)
