"""Gemma-7B [arXiv:2403.08295]: GeGLU, head_dim=256 (q dim 4096 != d_model
3072), RMSNorm(1+w), embedding scaling, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    norm_plus_one=True,
)
