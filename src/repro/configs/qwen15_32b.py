"""Qwen1.5-32B [hf:Qwen/Qwen1.5-*]: llama-style with QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab=152064,
    activation="silu",
    qkv_bias=True,
)
