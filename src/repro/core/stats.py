"""Statistical epilogue for the association engine.

Everything here is pure ``jax.numpy`` so it can run inside the jitted scan
step on device, sharded along both the marker and the phenotype axis with no
collectives (all ops are elementwise over the ``(M, P)`` statistic tile).

Numerical notes
---------------
* Two-sided p-value of a t statistic with ``nu`` degrees of freedom is the
  regularized incomplete beta ``I_x(nu/2, 1/2)`` at ``x = nu / (nu + t^2)``.
* ``betainc`` underflows around ``p ~ 1e-35`` in float32.  GWAS hits routinely
  reach ``p < 1e-100``, so we always report ``-log10 p`` through a dedicated
  log-space branch:

  - tail (``t^2 > 6``): modified-Lentz continued fraction for
    ``I_x(a, b)`` evaluated as ``log I = a log x + b log1p(-x) - betaln(a,b)
    - log a + log(cf)``.  The CF converges for ``x < (a+1)/(a+b+2)``, which
    at ``t^2 > 6`` holds for every dof (see tests).
  - bulk (``t^2 <= 6``): the complement identity
    ``p = 1 - I_z(b, a)`` with ``z = t^2/(nu + t^2)`` computed directly —
    ``z`` is small and well conditioned in float32, unlike ``x = 1 - z``.

  Validated against ``scipy.stats.t.logsf`` across dof in {2..1e6} and
  t in [0, 1e3] in ``tests/test_stats.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import betainc, betaln, erfc, gammaincc, gammaln

__all__ = [
    "t_from_r",
    "chi2_from_r",
    "neglog10_p_from_t",
    "neglog10_p_from_r",
    "neglog10_sf_chi2",
    "t2_screen_threshold",
    "refine_neglog10p",
    "REFINE_WIDTH",
    "bh_qvalues",
    "genomic_control_lambda",
    "LOG10E",
]

LOG10E = 0.4342944819032518  # log10(e)

_CF_ITERS = 128     # fixed Lentz trips; ample inside the convergence region
_T2_SWITCH = 6.0    # t^2 above this -> log-space tail; below -> complement form
_FPMIN = 1e-30


def t_from_r(r: jax.Array, dof: jax.Array | float, *, eps: float = 1e-12) -> jax.Array:
    """Paper Eq. (3): ``T = R * sqrt(dof / (1 - R^2))``.

    ``dof`` is ``N - 2`` in the paper-faithful mode and ``N - 2 - q`` in the
    exact covariate mode.  ``1 - r^2`` is clamped at ``eps`` so monomorphic /
    perfectly-collinear columns produce large-but-finite statistics instead of
    inf (they are masked upstream anyway).
    """
    r = jnp.asarray(r)
    denom = jnp.maximum(1.0 - jnp.square(r), eps)
    return r * jnp.sqrt(jnp.asarray(dof, r.dtype) / denom)


def chi2_from_r(r: jax.Array, n_eff: jax.Array | float) -> jax.Array:
    """Large-sample score statistic ``N * r^2 ~ chi^2_1`` (used by the
    multivariate omnibus screen where per-trait dof corrections wash out)."""
    r = jnp.asarray(r)
    return jnp.asarray(n_eff, r.dtype) * jnp.square(r)


def _betacf(a: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    """Modified-Lentz continued fraction for the incomplete beta
    (Numerical Recipes betacf), elementwise, fixed ``_CF_ITERS`` trips.

    Converges for ``x < (a+1)/(a+b+2)``; callers clamp x into that region
    for lanes routed to the other branch.
    """
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = jnp.ones_like(x)
    d = 1.0 - qab * x / qap
    d = jnp.where(jnp.abs(d) < _FPMIN, _FPMIN, d)
    d = 1.0 / d
    h = d

    def body(m, carry):
        c, d, h = carry
        mf = jnp.asarray(m, x.dtype) + 1.0
        m2 = 2.0 * mf
        # even step
        aa = mf * (b - mf) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        d = jnp.where(jnp.abs(d) < _FPMIN, _FPMIN, d)
        c = 1.0 + aa / c
        c = jnp.where(jnp.abs(c) < _FPMIN, _FPMIN, c)
        d = 1.0 / d
        h = h * d * c
        # odd step
        aa = -(a + mf) * (qab + mf) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        d = jnp.where(jnp.abs(d) < _FPMIN, _FPMIN, d)
        c = 1.0 + aa / c
        c = jnp.where(jnp.abs(c) < _FPMIN, _FPMIN, c)
        d = 1.0 / d
        h = h * d * c
        return c, d, h

    _, _, h = jax.lax.fori_loop(0, _CF_ITERS, body, (c, d, h))
    return h


_LGAMMA_HALF = 0.5723649429247001  # lgamma(1/2) = log(sqrt(pi))


def _betaln_half(a: jax.Array) -> jax.Array:
    """``betaln(a, 1/2)`` stable for huge ``a``.

    Direct lgamma differencing cancels catastrophically in f32 for
    ``a > ~1e4``; use ``Gamma(a+1/2)/Gamma(a) ~ sqrt(a)(1 - 1/(8a) +
    1/(128a^2))`` above a switch point (error O(a^-3)).
    """
    direct = betaln(a, jnp.asarray(0.5, a.dtype))
    inv = 1.0 / jnp.maximum(a, 1.0)
    asymptotic = _LGAMMA_HALF - 0.5 * jnp.log(jnp.maximum(a, 1.0)) - jnp.log1p(
        -0.125 * inv + (1.0 / 128.0) * inv * inv
    )
    return jnp.where(a > 200.0, asymptotic, direct)


def _log_p_tail(nu: jax.Array, t2: jax.Array) -> jax.Array:
    """``log I_x(nu/2, 1/2)`` at ``x = nu/(nu+t^2)`` — the two-sided t tail —
    with every term computed from the well-conditioned ratio ``t^2/nu``:

        a log x   = -a log1p(t^2/nu)
        b log(1-x)=  0.5 (log t^2 - log(nu + t^2))
    """
    a = nu * 0.5
    b = jnp.asarray(0.5, nu.dtype)
    x_cf = jnp.minimum(nu / (nu + t2), nu / (nu + _T2_SWITCH))
    cf = _betacf(a, b, x_cf)
    t2s = jnp.maximum(t2, _T2_SWITCH)  # bulk lanes are discarded by the caller
    log_x_term = -a * jnp.log1p(t2s / nu)
    log_1mx_term = 0.5 * (jnp.log(t2s) - jnp.log(nu + t2s))
    return (
        log_x_term
        + log_1mx_term
        - _betaln_half(a)
        - jnp.log(a)
        + jnp.log(jnp.maximum(cf, _FPMIN))
    )


_SQRT_HALF = 0.7071067811865476
_INV_SQRT_2PI = 0.3989422804014327
_NU_BETAINC = 4096.0   # below this dof the f32 betainc complement is accurate
_T2_ERFC_MAX = 144.0   # erfc underflows in f32 past |t| ~ 12


def neglog10_p_from_t(t: jax.Array, dof: jax.Array | float) -> jax.Array:
    """Two-sided ``-log10 p`` for a t statistic, stable to ``p ~ 1e-10000``.

    Three lanes, selected elementwise by an adaptive switch
    ``t2* = clip(nu/2000, 6, 144)`` (chosen from a measured f32 error map;
    see EXPERIMENTS.md):

      * tail (``t^2 > t2*``): log-space continued fraction — never
        underflows, f32 cancellation error <= ~1e-4 rel on -log10 p for
        ``nu <= 2e6`` (i.e. cohorts up to ~2M samples; beyond that the tail
        lane degrades gracefully to ~1e-3 — documented envelope);
      * bulk, ``nu <= 4096``: complement identity ``p = 1 - I_z(1/2, nu/2)``
        on the well-conditioned small variable ``z = t^2/(nu+t^2)``;
      * bulk, ``nu > 4096``: Edgeworth-corrected normal
        ``P(T>t) = Q(t) + (t^3+t) phi(t)/(4 nu) + O(nu^-2)`` — jax's f32
        ``betainc`` loses accuracy for ``a = nu/2 > ~1e4``.
    """
    t = jnp.asarray(t, jnp.float32)
    nu = jnp.asarray(dof, jnp.float32) * jnp.ones_like(t)
    t2 = jnp.square(t)
    z = t2 / (nu + t2)
    a = nu * 0.5
    b = jnp.asarray(0.5, jnp.float32)
    t2_switch = jnp.clip(nu / 2000.0, _T2_SWITCH, _T2_ERFC_MAX)

    log_p_tail = _log_p_tail(nu, jnp.maximum(t2, t2_switch))

    p_beta = 1.0 - betainc(b, a, jnp.clip(z, 0.0, 1.0))
    abs_t = jnp.abs(t)
    q_norm = 0.5 * erfc(abs_t * _SQRT_HALF)
    phi = _INV_SQRT_2PI * jnp.exp(-0.5 * jnp.minimum(t2, 160.0))
    p_norm = 2.0 * (q_norm + (abs_t * t2 + abs_t) * phi / (4.0 * nu))
    p_bulk = jnp.where(nu > _NU_BETAINC, p_norm, p_beta)
    log_p_bulk = jnp.log(jnp.clip(p_bulk, 1e-38, 1.0))

    log_p = jnp.where(t2 > t2_switch, log_p_tail, log_p_bulk)
    return jnp.maximum(-LOG10E * log_p, 0.0)


def neglog10_p_from_r(r: jax.Array, dof: jax.Array | float) -> jax.Array:
    """Fused convenience path ``r -> t -> -log10 p``."""
    return neglog10_p_from_t(t_from_r(r, dof), dof)


# ------------------------------------------------- sparse-epilogue screening
#
# The monotonicity contract (DESIGN.md §13): for fixed dof, the exact
# two-sided tail is strictly decreasing in t^2, so -log10 p is strictly
# increasing in t^2.  ``neglog10_p_from_t`` evaluates that function in f32
# with bounded error (<= ~5e-3 relative, tests/test_stats.py) and bounded
# local non-monotonic jitter (<= 1e-3, ``test_neglog10_p_deep_tail_monotone``).
# Inverting the hit threshold through the device function itself therefore
# yields a t^2 bound that — once padded by a margin dwarfing both error
# terms — soundly *underestimates* the true boundary: every lane the device
# would report as a hit passes the screen, and only near-threshold misses
# are screened in spuriously (the exact CF then rejects them).

_T2_SCREEN_MAX = 1e37  # f32-finite cap for the bracket search


@functools.lru_cache(maxsize=1024)
def t2_screen_threshold(threshold_nlp: float, dof: float) -> float | None:
    """Invert the hit threshold to a conservative per-dof t^2 screen bound.

    Returns ``t2*`` such that ``neglog10_p_from_t(t, dof) >= threshold_nlp``
    implies ``t^2 >= t2*`` — the admission test of the sparse p-value
    epilogue.  Host-side bisection on the f32 device function (so the bound
    is consistent with the code that later refines survivors), against a
    reduced target ``threshold - (0.05 + 0.02*threshold)`` whose margin
    covers both the f32 evaluation error (<= ~5e-3 relative, twice — once
    at the boundary probe, once on the screened lane) and the
    non-monotonic jitter.
    Cached per (threshold, dof): one inversion per scan, reused by every
    grid cell.

    ``None`` means no useful bound exists (threshold at or below the
    margin floor): callers must fall back to the dense epilogue.
    """
    threshold_nlp = float(threshold_nlp)
    dof = float(dof)
    target = threshold_nlp - (0.05 + 0.02 * threshold_nlp)
    if not (target > 0.0) or not (dof > 0.0):
        return None

    f = jax.jit(lambda t2: neglog10_p_from_t(jnp.sqrt(t2), dof))

    def nlp32(t2: float) -> float:
        return float(f(jnp.float32(t2)))

    hi = 1.0
    while nlp32(hi) < target:
        hi *= 4.0
        if hi > _T2_SCREEN_MAX:
            # Even the largest representable statistic stays below the
            # target, so no lane can ever reach the threshold: a screen at
            # the cap soundly rejects everything.
            return float(_T2_SCREEN_MAX)
    lo = 0.0
    for _ in range(96):
        mid = 0.5 * (lo + hi)
        if mid <= lo or mid >= hi:
            break
        if nlp32(mid) < target:
            lo = mid
        else:
            hi = mid
    # ``lo`` is the largest probe still below the reduced target; one ulp
    # down (in f32, the comparison precision on device) for strictness.
    return float(np.nextafter(np.float32(lo), np.float32(0.0)))


# Canonical chunk width for refining hit buffers (DESIGN.md §13).  Every
# hit-valued refine — compact buffer, overflow fallback, dense audit,
# tile reconstruction — evaluates in fixed (REFINE_WIDTH,) chunks so the
# emitted bits cannot depend on the configured buffer capacity.  A full
# SIMD multiple, so no scalar remainder lanes exist whose position could
# change a bit.
REFINE_WIDTH = 64


@functools.lru_cache(maxsize=None)
def _refine_exe(length: int, dof: float):
    """One cached executable per (shape, dof).  XLA's codegen for the CF
    loop is context-sensitive — the same values evaluated at a different
    shape or inside a differently-fused program can differ in the last
    f32 bit — so every emitted -log10 p must come out of *one* compiled
    program.  This cache is that program."""
    return jax.jit(lambda t: neglog10_p_from_t(t, dof))


def refine_neglog10p(
    t_values: np.ndarray, dof: float, *, width: int | None = None
) -> np.ndarray:
    """Canonical exact-tail refine (DESIGN.md §13).

    Evaluates the exact 128-trip CF on a 1-D t buffer through the cached
    per-(shape, dof) executable.  With ``width``, the buffer is zero-padded
    and evaluated in fixed ``(width,)`` chunks; hit-valued callers always
    pass ``width=REFINE_WIDTH``, so the sparse compact path, the overflow
    fallback, the dense audit mode, and the full-tile reconstruction all
    feed slot-identical chunks to one executable and produce bit-identical
    values for the same t.  Padding lanes (t=0) map to nlp=0 and are
    sliced off.
    """
    flat = np.ascontiguousarray(np.asarray(t_values, np.float32).ravel())
    dof = float(dof)
    if width is None:
        exe = _refine_exe(int(flat.shape[0]), dof)
        return np.asarray(exe(jnp.asarray(flat)))
    width = int(width)
    k = int(flat.shape[0])
    n_chunks = max(1, -(-k // width))
    buf = np.zeros(n_chunks * width, np.float32)
    buf[:k] = flat
    exe = _refine_exe(width, dof)
    out = np.concatenate(
        [np.asarray(exe(jnp.asarray(buf[i * width:(i + 1) * width])))
         for i in range(n_chunks)]
    )
    return out[:k]


def _log_gammaincc_cf(a: jax.Array, z: jax.Array) -> jax.Array:
    """``log( Gamma(a, z) / Gamma(a) )`` via the NR ``gcf`` continued
    fraction, valid (and fast) for ``z > a + 1``.  Log-space: never
    underflows."""
    b0 = z + 1.0 - a
    c = jnp.full_like(z, 1.0 / _FPMIN)
    d = 1.0 / jnp.where(jnp.abs(b0) < _FPMIN, _FPMIN, b0)
    h = d

    def body(i, carry):
        c, d, h, b0 = carry
        i_f = jnp.asarray(i, z.dtype) + 1.0
        an = -i_f * (i_f - a)
        b0 = b0 + 2.0
        d = an * d + b0
        d = jnp.where(jnp.abs(d) < _FPMIN, _FPMIN, d)
        c = b0 + an / c
        c = jnp.where(jnp.abs(c) < _FPMIN, _FPMIN, c)
        d = 1.0 / d
        h = h * d * c
        return c, d, h, b0

    _, _, h, _ = jax.lax.fori_loop(0, _CF_ITERS, body, (c, d, h, b0))
    return -z + a * jnp.log(jnp.maximum(z, 1e-38)) - gammaln(a) + jnp.log(
        jnp.maximum(h, _FPMIN)
    )


def neglog10_sf_chi2(stat: jax.Array, k: jax.Array | float) -> jax.Array:
    """``-log10 P(chi^2_k >= stat)``, stable into the deep tail.

    Bulk lanes (sf not near underflow) use ``gammaincc`` directly; tail lanes
    (``z > a+1`` and sf tiny) use the log-space ``gcf`` continued fraction.
    """
    s = jnp.asarray(stat, jnp.float32)
    a = jnp.asarray(k, jnp.float32) * 0.5 * jnp.ones_like(s)
    half = s * 0.5
    direct = gammaincc(a, jnp.maximum(half, 0.0))
    log_direct = jnp.log(jnp.maximum(direct, 1e-38))
    z_cf = jnp.maximum(half, a + 1.001)  # clamp unused lanes into validity
    log_tail = _log_gammaincc_cf(a, z_cf)
    use_tail = (half > a + 1.0) & (direct < 1e-6)
    log_sf = jnp.where(use_tail, log_tail, log_direct)
    return jnp.maximum(-LOG10E * log_sf, 0.0)


def bh_qvalues(neglog10p: jax.Array) -> jax.Array:
    """Benjamini-Hochberg q-values from a flat vector of ``-log10 p``.

    Monotone step-up in log space: sort ascending by p (descending by
    ``-log10 p``), apply ``q_i = min_{j >= i} p_j * m / j``.
    Returns q as ``-log10 q`` in the original order.
    """
    nlp = jnp.ravel(neglog10p)
    m = nlp.shape[0]
    order = jnp.argsort(-nlp)  # most significant first
    nlp_sorted = nlp[order]
    ranks = jnp.arange(1, m + 1, dtype=nlp.dtype)
    # -log10(p * m / rank) = nlp - log10(m) + log10(rank)
    nlq_raw = nlp_sorted - jnp.log10(jnp.asarray(m, nlp.dtype)) + jnp.log10(ranks)
    # enforce monotone non-increasing significance via reverse cummax
    nlq_sorted = jax.lax.cummax(nlq_raw[::-1])[::-1]
    nlq_sorted = jnp.maximum(nlq_sorted, 0.0)
    inv = jnp.argsort(order)
    return nlq_sorted[inv].reshape(neglog10p.shape)


def genomic_control_lambda(t_stats: jax.Array) -> jax.Array:
    """Genomic-control lambda: median(t^2) / qchisq(0.5, 1).

    ``qchisq(0.5, 1) = 0.45493642``.  Values near 1 indicate a calibrated
    scan; inflation (relatedness/stratification) pushes it above 1.  Used by
    tests to check calibration on null panels.
    """
    chi2 = jnp.square(jnp.asarray(t_stats, jnp.float32))
    return jnp.median(chi2) / 0.45493642311957184
