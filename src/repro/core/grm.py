"""Streamed genetic relationship matrix (GRM) accumulation.

The mixed-model wing needs ``K = (1/M) sum_m z_m z_m^T`` over all (valid)
markers, where ``z_m`` is the standardized dosage vector of marker ``m``.
Like ``core.kinship`` this reduces to one GEMM per marker batch, so the
estimator rides the same streaming discipline as the scan itself: batches
come from ``runtime.prefetch.BatchPlanner`` (boundary-respecting for
multi-file sources), decode runs on ``Prefetcher`` worker threads, and the
(N, N) accumulator is the only resident state — the genotype matrix never
is.

Per-shard partial sums are kept separately so leave-one-chromosome-out
(LOCO) GRMs are a subtraction, not a second pass:

    K_full    = (sum_s S_s) / (sum_s c_s)
    K_loco(s) = (sum_{s' != s} S_s') / (sum_{s' != s} c_s')

Two estimators ship (``method``):

    "std"       GCTA-style: z standardized to unit variance; the
                normalizer is the valid-marker count (diag(K) ~ 1).
    "centered"  centered-only dosages normalized by ``sum_m 2 p_m (1-p_m)``
                (the EPACTS/EMMAX convention).

Memory note: partial sums are (n_shards, N, N) float64 on the host.  For
biobank N this is the term that matters; production deployments stream into
a sharded device accumulator instead — the per-shard *interface* here is
what LOCO relies on, and is sized for the cohorts the test/bench tier runs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.association import standardize_genotype_batch
from repro.runtime.prefetch import BatchPlanner, Prefetcher

__all__ = ["StreamedGRM", "stream_grm", "grm_spectrum", "spectrum_fingerprint"]

GRM_METHODS = ("std", "centered")


@jax.jit
def _grm_block_std(g_raw: jax.Array, maf_min: jax.Array):
    """One marker block ``(M, N)`` -> ``(S, c)``: ``S = Z^T Z`` over rows
    that are valid and pass the MAF gate, ``c`` the rows folded in.  The
    gate lives inside the jitted block so the pass standardizes once and
    never syncs the host between stats and GEMM."""
    g_std, ms = standardize_genotype_batch(g_raw)
    keep = ms.valid & (ms.maf >= maf_min)
    g_std = g_std * keep[:, None]
    s = jax.lax.dot_general(
        g_std, g_std, (((0,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )
    return s, jnp.sum(keep.astype(jnp.float32))


@jax.jit
def _grm_block_centered(g_raw: jax.Array, maf_min: jax.Array):
    """Centered-only estimator: ``S = Gc^T Gc``, normalizer ``sum 2p(1-p)``."""
    g_std, ms = standardize_genotype_batch(g_raw)  # reuse imputation/mean path
    g = jnp.asarray(g_raw, jnp.float32)
    missing = jnp.isnan(g) | (g == -9.0)
    g_imp = jnp.where(missing, ms.mean[:, None], g)
    keep = ms.valid & (ms.maf >= maf_min)
    gc = (g_imp - ms.mean[:, None]) * keep[:, None]
    s = jax.lax.dot_general(
        gc, gc, (((0,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )
    af = ms.mean / 2.0
    norm = jnp.sum(jnp.where(keep, 2.0 * af * (1.0 - af), 0.0))
    return s, norm


@dataclass
class StreamedGRM:
    """Per-shard GRM partial sums + normalizers (see module docstring)."""

    shard_sums: np.ndarray     # (S, N, N) float64 unnormalized sums
    shard_norms: np.ndarray    # (S,) float64 per-shard normalizer
    n_samples: int
    method: str

    @property
    def n_shards(self) -> int:
        return self.shard_sums.shape[0]

    @staticmethod
    def _checked_norm(norm: float, what: str) -> float:
        if norm <= 1e-9:
            raise ValueError(
                f"{what} normalizer is ~0 — no markers survived the "
                "validity/MAF filters; loosen maf_min or check the input"
            )
        return norm

    def full(self) -> np.ndarray:
        """The all-markers GRM."""
        norm = self._checked_norm(float(self.shard_norms.sum()), "GRM")
        return self.shard_sums.sum(axis=0) / norm

    def loco(self, shard_id: int) -> np.ndarray:
        """Leave-one-chromosome-out GRM: everything but ``shard_id``."""
        if not 0 <= shard_id < self.n_shards:
            raise IndexError(f"shard {shard_id} outside [0, {self.n_shards})")
        if self.n_shards < 2:
            raise ValueError("LOCO needs >= 2 shards (per-chromosome fileset)")
        mask = np.ones(self.n_shards, bool)
        mask[shard_id] = False
        norm = self._checked_norm(
            float(self.shard_norms[mask].sum()), f"LOCO({shard_id}) GRM"
        )
        return self.shard_sums[mask].sum(axis=0) / norm


def stream_grm(
    source,
    *,
    keep: np.ndarray | None = None,
    batch_markers: int = 4096,
    method: str = "std",
    maf_min: float = 0.0,
    io_workers: int = 2,
    prefetch_depth: int = 3,
    staging: str = "auto",
) -> StreamedGRM:
    """Accumulate the GRM in one streamed pass over ``source``.

    ``keep`` subselects the sample axis (relatedness exclusion mask).
    Batches follow the same plan the scan itself uses, so multi-file
    sources stream per-chromosome shards concurrently and the partial sums
    land in per-shard slots for LOCO.

    ``staging`` selects the H2D currency like the scan's
    ``--genotype-staging`` (DESIGN.md §17): under "packed" the worker
    threads fetch raw 2-bit slabs through the shared ``PackedSlabCache``
    (so the GRM pass and the scan share one read per batch) and the device
    decode front-end expands them *in front of* the unchanged jitted block
    accumulator — same compiled GEMM program, bit-identical partial sums.
    "auto" falls back to the decoded path when the source has no native
    packed layout or ``keep`` actually drops samples.
    """
    if method not in GRM_METHODS:
        raise ValueError(f"unknown grm method {method!r}; expected one of {GRM_METHODS}")
    from repro.core.engines import resolve_genotype_staging

    # keep=None or an all-true mask never subsets, so packed staging stays
    # eligible; an excluding mask forces the host-side decoded path.
    excluding = int(keep is not None and not bool(np.asarray(keep).all()))
    staging = resolve_genotype_staging(
        staging, source, excluded_samples=excluding, mesh=None
    )
    plan = BatchPlanner(batch_markers).plan(source)
    n_shards = max((b.source_id for b in plan), default=0) + 1
    n = int(keep.sum()) if keep is not None else source.n_samples

    sums = np.zeros((n_shards, n, n), np.float64)
    norms = np.zeros(n_shards, np.float64)

    if staging == "packed":
        from repro.io.packed_cache import read_packed_cached
        from repro.kernels.gwas_dot import ops as kops

        def read(batch):
            return batch, read_packed_cached(source, batch.lo, batch.hi)

        def to_device(slab):
            return kops.decode_packed_device(slab, n_samples=n)
    else:
        def read(batch):
            d = source.read_dosages(batch.lo, batch.hi)
            if keep is not None and not keep.all():
                d = d[:, keep]
            return batch, np.asarray(d, np.float32)

        def to_device(dosages):
            return dosages

    block = _grm_block_centered if method == "centered" else _grm_block_std
    gate = jnp.float32(maf_min)
    prefetched = Prefetcher(plan, read, depth=prefetch_depth, num_workers=io_workers)
    for batch, payload in prefetched:
        s, c = block(to_device(payload), gate)
        sums[batch.source_id] += np.asarray(s, np.float64)
        norms[batch.source_id] += float(c)
    return StreamedGRM(shard_sums=sums, shard_norms=norms, n_samples=n, method=method)


def grm_spectrum(k: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Eigendecomposition ``K = U diag(s) U^T`` with tiny negative
    eigenvalues (float roundoff on a PSD-by-construction matrix) clipped to
    zero.  Returned in ascending eigenvalue order (numpy's convention)."""
    s, u = np.linalg.eigh(np.asarray(k, np.float64))
    return np.maximum(s, 0.0), u


def spectrum_fingerprint(spectra: dict[int, np.ndarray]) -> str:
    """Stable short hash of the GRM eigenvalue spectra (one per LOCO scope).

    Goes into the scan checkpoint fingerprint: resuming a mixed-model scan
    against a *different* GRM (new markers, new exclusion mask) would
    silently mix incompatible statistics, exactly like resuming against a
    re-sharded fileset.  Eigenvalues are rounded to 6 significant decimals
    so the hash is stable across BLAS minor-version jitter.
    """
    import hashlib

    h = hashlib.sha256()
    for scope in sorted(spectra):
        h.update(str(scope).encode())
        vals = np.asarray(spectra[scope], np.float64)
        scale = np.power(10.0, 5 - np.floor(np.log10(np.maximum(vals, 1e-30))))
        rounded = np.where(vals > 1e-12, np.rint(vals * scale) / scale, 0.0)
        h.update(rounded.astype(np.float64).tobytes())
    return h.hexdigest()[:16]
