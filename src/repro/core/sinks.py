"""Composable result sinks for the genome scan (DESIGN.md §4).

``GenomeScan.run`` used to interleave five accumulation concerns (per-trait
best, hit collection, QC arrays, lambda-GC probe, checkpoint commits) in one
loop body.  Each is now a ``ResultSink``:

    on_batch(view, payload)   consume one computed batch; add the arrays this
                              sink wants persisted to the checkpoint shard
                              ``payload``
    merge_shard(shard, lo, hi) replay a previously committed shard (resume)
    result()                  contribute fields to the final ``ScanResult``

Sinks read device outputs through a shared ``BatchView`` that pulls each
tile across PCIe at most once, lazily — the "hit-driven host pull" invariant
(the full (M, P) nlp/r/t tiles only cross when a batch actually contains
hits, no matter how many sinks are attached).  The checkpoint committer is
itself just the last sink in the chain, so crash-resume is one line of
composition instead of special cases in the driver.

Since the scan became a 2-D (marker-batch x trait-block) grid (DESIGN.md
§10), one ``BatchView`` covers one grid *cell*: a marker range crossed with
a trait range ``[t_lo, t_lo + n_traits)``.  Sinks fold cells — trait-indexed
accumulators offset by the cell's block origin, marker-indexed accumulators
written once per marker batch (the ``t_lo == 0`` cell carries them).  An
unblocked scan is the degenerate single-block grid, so nothing changes for
it.
"""
from __future__ import annotations

import os
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import stats as _stats
from repro.core.engines import HostBatch
from repro.runtime.checkpoint import ScanCheckpoint
from repro.runtime.prefetch import MarkerBatch

_T2MAX_PROBE = None  # lazy jit; jax caches per input shape


def _screen_any(t_tile, t2_screen: float) -> bool:
    """Scalar device probe: does any lane pass the t^2 screen?  max is an
    exact selection, so ``max(t^2) >= thr`` iff some lane passes — only one
    float crosses PCIe, preserving the hit-driven-pull invariant for
    dense-mode cells under a sparse-capable config."""
    global _T2MAX_PROBE
    if _T2MAX_PROBE is None:
        import jax

        _T2MAX_PROBE = jax.jit(lambda t: jnp.max(jnp.square(t)))
    return bool(np.asarray(_T2MAX_PROBE(t_tile)) >= np.float32(t2_screen))


__all__ = [
    "BatchView",
    "ResultSink",
    "BestTraitSink",
    "HitSink",
    "QCSink",
    "LambdaGCSink",
    "CheckpointSink",
    "extract_hits",
]


def extract_hits(view: "BatchView", threshold: float) -> tuple[np.ndarray, np.ndarray]:
    """Collect one cell's (marker, trait) entries at or above ``threshold``.

    Returns globalized ``(H, 2)`` int32 indices and ``(H, 3)`` float32
    (r, t, -log10 p) stats.  The hit-driven-pull invariant lives here: the
    full per-cell tiles only cross PCIe when the device-side hit counter is
    non-zero.  Shared by ``HitSink`` (the ScanResult path) and
    ``api.session.CellResult`` (the streaming path) so both extract
    bit-identical rows.
    """
    hits = np.zeros((0, 2), np.int32)
    stats = np.zeros((0, 3), np.float32)
    if view.is_sparse and not view.overflowed:
        # Sparse epilogue (DESIGN.md §13): the device already compacted the
        # screened lanes; only the tiny fixed-capacity buffers cross PCIe,
        # and the exact CF runs host-side through the canonical
        # (capacity, dof) executable.  The screen admits a sub-threshold
        # margin — the exact nlp filter here rejects it, leaving precisely
        # the dense path's hit set in the dense path's row-major order
        # (first-K compaction preserves it).
        if view.screen_count == 0:
            return hits, stats
        idx = view.hit_idx
        hit_nlp = view.hit_nlp
        keep = (idx >= 0) & (hit_nlp >= threshold)
        if keep.any():
            flat = idx[keep].astype(np.int64)
            rows = flat // view.n_traits
            cols = flat % view.n_traits
            hits = np.stack(
                [
                    rows.astype(np.int32) + view.batch.lo,
                    cols.astype(np.int32) + view.t_lo,
                ],
                1,
            )
            stats = np.stack(
                [view.hit_r[keep], view.hit_t[keep], hit_nlp[keep]], 1
            ).astype(np.float32)
        return hits, stats
    if view.t2_screen is not None and view.dof is not None:
        # Dense-mode extraction under a sparse-capable config — also the
        # sparse overflow fallback.  Screen the pulled t tile on the host
        # with the identical f32 square-and-compare the device screen uses
        # (same t bits -> same survivor set), gather survivors in flat
        # row-major order (the compaction order), and refine them through
        # the same (capacity,)-shaped executable the compact path uses —
        # chunk 0 of the zero-padded buffer is elementwise identical to a
        # non-overflowed compact buffer, so every emitted bit matches.
        if "t" not in view._cache and not _screen_any(
            view._out["t"], view.t2_screen
        ):
            return hits, stats
        t_np = view.t
        flat_t = np.ascontiguousarray(t_np, np.float32).ravel()
        survivors = np.nonzero(np.square(flat_t) >= np.float32(view.t2_screen))[0]
        if survivors.size == 0:
            return hits, stats
        nlp_vals = _stats.refine_neglog10p(
            flat_t[survivors], view.dof, width=_stats.REFINE_WIDTH
        ).astype(np.float32)
        keep = nlp_vals >= threshold
        if keep.any():
            flat = survivors[keep].astype(np.int64)
            rows = flat // view.n_traits
            cols = flat % view.n_traits
            r_np = view.r
            hits = np.stack(
                [
                    rows.astype(np.int32) + view.batch.lo,
                    cols.astype(np.int32) + view.t_lo,
                ],
                1,
            )
            stats = np.stack(
                [r_np[rows, cols], t_np[rows, cols], nlp_vals[keep]], 1
            ).astype(np.float32)
        return hits, stats
    # Historical dense tile path (no screen plan — e.g. the GenomeScan shim
    # fed a raw step dict): gate the full-tile pull on the device-side hit
    # counter.
    if view.hit_count > 0:
        nlp = view.nlp
        rows, cols = np.nonzero(nlp >= threshold)
        r_np, t_np = view.r, view.t
        hits = np.stack(
            [
                rows.astype(np.int32) + view.batch.lo,
                cols.astype(np.int32) + view.t_lo,
            ],
            1,
        )
        stats = np.stack(
            [r_np[rows, cols], t_np[rows, cols], nlp[rows, cols]], 1
        ).astype(np.float32)
    return hits, stats


class BatchView:
    """Lazy, cached host view over one device step output — one grid cell.

    Every ``np.asarray`` on a device output is a host pull; multiple sinks
    share one view so each tile crosses at most once.  ``t_probe`` slices on
    the device *before* pulling, so the calibration probe never forces the
    full t tile across.

    ``n_traits`` is the cell's trait-block width (the full panel width for
    an unblocked scan); ``t_lo``/``block_index`` locate the block on the
    global trait axis so sinks can offset their folds.

    A *sparse* cell (DESIGN.md §13) carries compacted
    ``hit_idx``/``hit_r``/``hit_t`` buffers instead of the dense nlp
    tile.  All *emitted* -log10 p values — ``hit_nlp``, ``best_nlp``, and
    the reconstructed ``nlp`` tile — are evaluated host-side through the
    canonical per-(shape, dof) executables (``stats.refine_neglog10p``):
    XLA's CF codegen is fusion-context-sensitive, so the only way sparse
    and dense cells agree bitwise is for both to route p-values through
    one compiled program per shape.  Hit buffers always refine in fixed
    ``stats.REFINE_WIDTH`` chunks, so the emitted bits cannot depend on
    the configured capacity.  ``t2_screen`` carries the scan's screen
    threshold so dense-mode extraction can mirror the sparse screen
    exactly.
    """

    def __init__(
        self,
        host: HostBatch,
        out: dict,
        n_traits: int,
        *,
        t_lo: int = 0,
        block_index: int = 0,
        dof: float | None = None,
        t2_screen: float | None = None,
    ):
        self.batch: MarkerBatch = host.batch
        self.host = host
        self._out = out
        self.n_traits = n_traits
        self.t_lo = t_lo
        self.t_hi = t_lo + n_traits
        self.block_index = block_index
        self.dof = dof
        self.t2_screen = t2_screen
        self.m_batch = host.batch.n_markers
        self._cache: dict[str, np.ndarray] = {}

    def _pull(self, key: str) -> np.ndarray:
        if key not in self._cache:
            self._cache[key] = np.asarray(self._out[key])
        return self._cache[key]

    @property
    def is_sparse(self) -> bool:
        return "hit_idx" in self._out

    @property
    def hit_capacity(self) -> int:
        return int(self._out["hit_idx"].shape[0])

    @property
    def screen_count(self) -> int:
        """Exact count of lanes past the t^2 screen (sparse cells only)."""
        return int(self._pull("screen_count"))

    @property
    def overflowed(self) -> bool:
        """True when the screen found more lanes than the compacted buffer
        holds — the compacted arrays are then truncated and the host must
        fall back to the reconstructed dense tile."""
        return self.is_sparse and self.screen_count > self.hit_capacity

    @property
    def hit_idx(self) -> np.ndarray:
        """Compacted flat (row-major over the cell tile) screened-lane
        indices, ``-1``-padded to capacity."""
        return self._pull("hit_idx")

    @property
    def hit_r(self) -> np.ndarray:
        return self._pull("hit_r")

    @property
    def hit_t(self) -> np.ndarray:
        return self._pull("hit_t")

    @property
    def hit_nlp(self) -> np.ndarray:
        """Exact -log10 p on the compacted lanes, refined host-side
        through the canonical (capacity, dof) executable.  Padding slots
        hold refine(0) — callers mask on ``hit_idx >= 0``."""
        if "hit_nlp" not in self._cache:
            if "hit_nlp" in self._out:  # synthetic/raw step dicts
                self._cache["hit_nlp"] = np.asarray(self._out["hit_nlp"])
            else:
                self._cache["hit_nlp"] = _stats.refine_neglog10p(
                    self.hit_t, float(self.dof), width=_stats.REFINE_WIDTH
                ).astype(np.float32)
        return self._cache["hit_nlp"]

    @property
    def hit_count(self) -> int:
        return int(self._pull("hit_count"))

    @property
    def best_nlp(self) -> np.ndarray:
        """Per-trait winner -log10 p.  When the step emitted the winner t
        (``batch_best_t``), the value is refined host-side through the
        canonical (P, dof) executable — identical bits whether the cell ran
        the sparse or the dense epilogue.  Raw step dicts without it fall
        back to the in-step tile value."""
        if "batch_best_t" in self._out and self.dof is not None:
            if "best_nlp" not in self._cache:
                self._cache["best_nlp"] = _stats.refine_neglog10p(
                    self._pull("batch_best_t")[: self.n_traits], float(self.dof)
                ).astype(np.float32)
            return self._cache["best_nlp"]
        return self._pull("batch_best_nlp")[: self.n_traits]

    @property
    def best_row(self) -> np.ndarray:
        return self._pull("batch_best_row")[: self.n_traits]

    @property
    def nlp(self) -> np.ndarray:
        if "nlp" not in self._out:
            # Sparse cell: the dense tile never existed on device.
            # Reconstruct it on the host from the pulled t through the
            # canonical fixed-width refine executable (full-tile QC /
            # report paths only — extraction never reads this).
            if "nlp" not in self._cache:
                if self.dof is None:
                    raise RuntimeError(
                        "sparse cell without dof: BatchView cannot "
                        "reconstruct the nlp tile"
                    )
                t_np = self.t
                self._cache["nlp"] = (
                    _stats.refine_neglog10p(
                        t_np.ravel(), float(self.dof),
                        width=_stats.REFINE_WIDTH,
                    )
                    .astype(np.float32)
                    .reshape(t_np.shape)
                )
            return self._cache["nlp"]
        return self._pull("nlp")[: self.m_batch]

    @property
    def r(self) -> np.ndarray:
        return self._pull("r")[: self.m_batch]

    @property
    def t(self) -> np.ndarray:
        return self._pull("t")[: self.m_batch]

    @property
    def maf(self) -> np.ndarray:
        if self.host.host_maf is not None:
            return self.host.host_maf[: self.m_batch]
        return self._pull("maf")[: self.m_batch]

    @property
    def valid(self) -> np.ndarray:
        if self.host.host_valid is not None:
            return self.host.host_valid[: self.m_batch]
        return self._pull("valid")[: self.m_batch]

    @property
    def omnibus_nlp(self) -> np.ndarray | None:
        if "omnibus_nlp" not in self._out:
            return None
        return self._pull("omnibus_nlp")[: self.m_batch]

    def t_probe(self, rows: int) -> np.ndarray:
        if "t" in self._cache:  # tile already on host (a hit pulled it)
            return self._cache["t"][: min(self.m_batch, rows), 0]
        return np.asarray(self._out["t"][: min(self.m_batch, rows), 0])


class ResultSink:
    """One accumulation concern of the scan; see module docstring."""

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        raise NotImplementedError

    def on_cell(self, cell: Any) -> None:
        """Fold one streamed ``api.session.CellResult`` (the event path the
        result writers drive; the ``GenomeScan`` shim uses the historical
        ``on_batch``/``merge_shard`` chain directly).  The default routes
        live cells through the legacy ``on_batch`` hook — so sink
        subclasses written against that interface keep working — and
        replayed cells through ``merge_shard``.  Built-in sinks override
        this to fold from the cell's cached payload directly (same arrays,
        extracted once)."""
        if cell.view is not None:
            self.on_batch(cell.view, {})
        else:
            self.merge_shard(cell.payload(), cell.lo, cell.hi)

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        """Fold a previously committed checkpoint shard in (resume path)."""

    def result(self) -> dict[str, Any]:
        return {}


class BestTraitSink(ResultSink):
    """Per-trait running best -log10 p and the global marker achieving it.

    Accumulators span the full panel; each grid cell folds into the trait
    slice its block covers.  The fold is *order-normalized*: the winner is
    the max by (nlp, then LOWER global marker), which is associative and
    commutative — so any cell completion order (the serial grid walk, a
    multi-device executor's work-stealing order, a resume's replayed-last
    order) lands on the identical (best_nlp, best_marker) pair.  In-order
    folding with a strict ``>`` picked the earlier batch on exact nlp ties,
    i.e. the lower marker — the normalized rule reproduces that serial
    result exactly, it just no longer depends on arrival order.
    """

    def __init__(self, n_traits: int):
        self.best_nlp = np.zeros(n_traits, np.float32)
        self.best_marker = np.full(n_traits, -1, np.int64)

    def _fold(self, b_best: np.ndarray, b_row: np.ndarray, lo: int, t_lo: int) -> None:
        sl = slice(t_lo, t_lo + b_best.shape[0])
        cur_nlp = self.best_nlp[sl]
        cur_marker = self.best_marker[sl]
        cand_marker = lo + b_row.astype(np.int64)
        # Ties on nlp go to the lower global marker; the virgin accumulator
        # (0.0, -1) only loses to a strictly positive nlp, so all-masked
        # cells leave traits at marker -1 no matter when they arrive.
        improved = (b_best > cur_nlp) | (
            (b_best == cur_nlp) & (cur_marker >= 0) & (cand_marker < cur_marker)
        )
        self.best_nlp[sl] = np.where(improved, b_best, cur_nlp)
        self.best_marker[sl] = np.where(improved, cand_marker, cur_marker)

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        payload["best_nlp"] = view.best_nlp
        payload["best_row"] = view.best_row
        self._fold(view.best_nlp, view.best_row, view.batch.lo, view.t_lo)

    def on_cell(self, cell: Any) -> None:
        self._fold(cell.best_nlp, cell.best_row, cell.lo, cell.t_lo)

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        self._fold(shard["best_nlp"], shard["best_row"], lo, int(shard.get("t_lo", 0)))

    def result(self) -> dict[str, Any]:
        return {"best_nlp": self.best_nlp, "best_marker": self.best_marker}


class HitSink(ResultSink):
    """Collect (marker, trait) cells above the genome-wide line, pulling the
    full tiles only for cells whose device-side hit counter is non-zero.

    Trait columns are globalized with the cell's block origin at collection
    time, so committed shards and the final result always carry global trait
    indices.

    Scan-time host RAM is bounded: once more than ``spill_rows`` hit rows
    accumulate (dense hit regions on a wide panel are unbounded over a
    whole scan), the in-RAM buffers are flushed to appendable ``.npz`` part
    files under ``spill_dir`` and the RAM is released.  ``result()``
    re-reads the parts in order (then unlinks them), so spilling never
    changes the returned arrays — append order is preserved exactly.  Note
    the bound covers the *scan*: ``result()`` still materializes the full
    hit set once, for the final ``ScanResult`` — replacing that with
    streaming summary-stat writers is a ROADMAP item.  ``spill_dir=None``
    (the default) disables spilling and keeps the historical
    everything-in-RAM behavior.
    """

    def __init__(
        self,
        threshold_nlp: float,
        *,
        spill_dir: str | None = None,
        spill_rows: int = 2_000_000,
    ):
        self.threshold = threshold_nlp
        self.spill_dir = spill_dir
        self.spill_rows = max(1, spill_rows)
        self._hits: list[np.ndarray] = []
        self._stats: list[np.ndarray] = []
        self._rows_in_ram = 0
        self._spill_paths: list[str] = []
        self.spilled_rows = 0
        if spill_dir is not None and os.path.isdir(spill_dir):
            # The spill dir is per-run scratch (the CLI points it at --out):
            # parts a crashed previous run left behind would collide by
            # index with ours and masquerade as results — clear them.
            for stale in os.listdir(spill_dir):
                if stale.startswith("hits_spill_") and stale.endswith(".npz"):
                    os.unlink(os.path.join(spill_dir, stale))

    def _append(self, hits: np.ndarray, stats: np.ndarray) -> None:
        self._hits.append(hits)
        self._stats.append(stats)
        self._rows_in_ram += len(hits)
        if self.spill_dir is not None and self._rows_in_ram >= self.spill_rows:
            self._flush()

    def _flush(self) -> None:
        os.makedirs(self.spill_dir, exist_ok=True)
        part = os.path.join(
            self.spill_dir, f"hits_spill_{len(self._spill_paths):05d}.npz"
        )
        tmp = part + ".tmp.npz"
        np.savez(tmp, hits=np.concatenate(self._hits), hit_stats=np.concatenate(self._stats))
        os.replace(tmp, part)
        self._spill_paths.append(part)
        self.spilled_rows += self._rows_in_ram
        self._hits.clear()
        self._stats.clear()
        self._rows_in_ram = 0

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        batch_hits, batch_stats = extract_hits(view, self.threshold)
        payload["hits"] = batch_hits
        payload["hit_stats"] = batch_stats
        self._append(batch_hits, batch_stats)

    def on_cell(self, cell: Any) -> None:
        self._append(cell.hits, cell.hit_stats)

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        self._append(shard["hits"], shard["hit_stats"])

    def result(self) -> dict[str, Any]:
        hits = [np.zeros((0, 2), np.int32)]
        stats = [np.zeros((0, 3), np.float32)]
        for part in self._spill_paths:
            with np.load(part) as z:
                hits.append(z["hits"])
                stats.append(z["hit_stats"])
        hits.extend(self._hits)
        stats.extend(self._stats)
        out = {"hits": np.concatenate(hits), "hit_stats": np.concatenate(stats)}
        # Fold everything back into the RAM buffers BEFORE unlinking the
        # consumed parts: result() stays repeatable (a second call returns
        # the same arrays), and parts — intermediate state, not run
        # artifacts — don't pile up next to hits.tsv across reruns.
        self._hits = [out["hits"]]
        self._stats = [out["hit_stats"]]
        self._rows_in_ram = len(out["hits"])
        for part in self._spill_paths:
            if os.path.exists(part):
                os.unlink(part)
        self._spill_paths.clear()
        return out


class QCSink(ResultSink):
    """Dense per-marker QC arrays: observed MAF, validity mask, and (when
    the multivariate screen is on) the omnibus -log10 p track."""

    def __init__(self, n_markers: int, *, multivariate: bool = False):
        self.maf = np.zeros(n_markers, np.float32)
        self.valid = np.zeros(n_markers, bool)
        self.omnibus_nlp = np.zeros(n_markers, np.float32) if multivariate else None

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        # Marker-level tracks are identical across trait blocks; the t_lo==0
        # cell carries them (one device pull and one persisted copy per
        # marker batch, not one per grid cell).
        if view.t_lo != 0:
            return
        lo, hi = view.batch.lo, view.batch.hi
        self.maf[lo:hi] = view.maf
        self.valid[lo:hi] = view.valid
        payload["maf"] = self.maf[lo:hi]
        payload["valid"] = self.valid[lo:hi]
        if self.omnibus_nlp is not None and view.omnibus_nlp is not None:
            self.omnibus_nlp[lo:hi] = view.omnibus_nlp
            payload["omnibus_nlp"] = self.omnibus_nlp[lo:hi]

    def on_cell(self, cell: Any) -> None:
        if cell.maf is None:  # a t_lo > 0 cell: no marker-level tracks
            return
        lo, hi = cell.lo, cell.hi
        self.maf[lo:hi] = cell.maf
        self.valid[lo:hi] = cell.valid
        if self.omnibus_nlp is not None and cell.omnibus_nlp is not None:
            self.omnibus_nlp[lo:hi] = cell.omnibus_nlp

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        if "maf" not in shard:  # a t_lo > 0 cell: no marker-level tracks
            return
        self.maf[lo:hi] = shard["maf"]
        self.valid[lo:hi] = shard["valid"]
        if self.omnibus_nlp is not None and "omnibus_nlp" in shard:
            self.omnibus_nlp[lo:hi] = shard["omnibus_nlp"]

    def result(self) -> dict[str, Any]:
        return {"maf": self.maf, "valid": self.valid, "omnibus_nlp": self.omnibus_nlp}


class LambdaGCSink(ResultSink):
    """Genomic-control calibration probe: a small t-statistic sample of the
    first trait per batch.  The probe is persisted in every checkpoint shard
    so a resumed scan merges the probes of already-committed batches instead
    of estimating lambda from whatever little it recomputed."""

    def __init__(self, rows: int = 64):
        self.rows = rows
        self._samples: list[np.ndarray] = []

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        # The probe samples the *global* first trait, which lives in the
        # t_lo==0 block; other cells contribute nothing, so a blocked scan
        # estimates lambda from exactly the same sample as an unblocked one.
        if view.t_lo != 0:
            return
        probe = np.asarray(view.t_probe(self.rows), np.float32)
        payload["t_probe"] = probe
        self._samples.append(probe)

    def on_cell(self, cell: Any) -> None:
        if cell.t_probe is not None:
            self._samples.append(np.asarray(cell.t_probe, np.float32))

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        # Shards written before the probe was persisted simply contribute
        # nothing (lambda then rests on the recomputed batches, as before).
        if "t_probe" in shard:
            self._samples.append(np.asarray(shard["t_probe"], np.float32))

    def result(self) -> dict[str, Any]:
        probe = np.concatenate(self._samples) if self._samples else np.zeros(1, np.float32)
        lam = float(_stats.genomic_control_lambda(jnp.asarray(probe))) if probe.size else 1.0
        return {"lambda_gc": lam}


class CheckpointSink(ResultSink):
    """Commit each grid cell's accumulated payload as an atomic shard.  Must
    be the LAST sink in the chain: it persists whatever the sinks before it
    put into ``payload``.  Shards carry the cell's trait extent so resume
    folds land at the right block origin.

    Since the api redesign the ``ScanSession`` executor commits every live
    cell natively (from ``CellResult.payload()`` — the built-in sinks'
    exact payload), so this sink is no longer composed by default.  Append
    it explicitly after custom sinks whose ``payload`` contributions must
    be persisted; re-committing a cell is an idempotent overwrite."""

    def __init__(self, ckpt: ScanCheckpoint):
        self.ckpt = ckpt

    def on_cell(self, cell: Any) -> None:
        # The api's ScanSession commits cells natively; when this sink is
        # nevertheless composed into an event-driven chain, re-committing
        # the same payload is an idempotent overwrite, never a truncation.
        if cell.view is not None:
            self.ckpt.commit_cell(cell.batch_index, cell.block_index, cell.payload())

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        shard = {
            "lo": np.asarray(view.batch.lo),
            "hi": np.asarray(view.batch.hi),
            "t_lo": np.asarray(view.t_lo),
            "t_hi": np.asarray(view.t_hi),
            **payload,
        }
        self.ckpt.commit_cell(view.batch.index, view.block_index, shard)
