"""Composable result sinks for the genome scan (DESIGN.md §4).

``GenomeScan.run`` used to interleave five accumulation concerns (per-trait
best, hit collection, QC arrays, lambda-GC probe, checkpoint commits) in one
loop body.  Each is now a ``ResultSink``:

    on_batch(view, payload)   consume one computed batch; add the arrays this
                              sink wants persisted to the checkpoint shard
                              ``payload``
    merge_shard(shard, lo, hi) replay a previously committed shard (resume)
    result()                  contribute fields to the final ``ScanResult``

Sinks read device outputs through a shared ``BatchView`` that pulls each
tile across PCIe at most once, lazily — the "hit-driven host pull" invariant
(the full (M, P) nlp/r/t tiles only cross when a batch actually contains
hits, no matter how many sinks are attached).  The checkpoint committer is
itself just the last sink in the chain, so crash-resume is one line of
composition instead of special cases in the driver.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import stats as _stats
from repro.core.engines import HostBatch
from repro.runtime.checkpoint import ScanCheckpoint
from repro.runtime.prefetch import MarkerBatch

__all__ = [
    "BatchView",
    "ResultSink",
    "BestTraitSink",
    "HitSink",
    "QCSink",
    "LambdaGCSink",
    "CheckpointSink",
]


class BatchView:
    """Lazy, cached host view over one device step output.

    Every ``np.asarray`` on a device output is a host pull; multiple sinks
    share one view so each tile crosses at most once.  ``t_probe`` slices on
    the device *before* pulling, so the calibration probe never forces the
    full t tile across.
    """

    def __init__(self, host: HostBatch, out: dict, n_traits: int):
        self.batch: MarkerBatch = host.batch
        self.host = host
        self._out = out
        self.n_traits = n_traits
        self.m_batch = host.batch.n_markers
        self._cache: dict[str, np.ndarray] = {}

    def _pull(self, key: str) -> np.ndarray:
        if key not in self._cache:
            self._cache[key] = np.asarray(self._out[key])
        return self._cache[key]

    @property
    def hit_count(self) -> int:
        return int(self._pull("hit_count"))

    @property
    def best_nlp(self) -> np.ndarray:
        return self._pull("batch_best_nlp")[: self.n_traits]

    @property
    def best_row(self) -> np.ndarray:
        return self._pull("batch_best_row")[: self.n_traits]

    @property
    def nlp(self) -> np.ndarray:
        return self._pull("nlp")[: self.m_batch]

    @property
    def r(self) -> np.ndarray:
        return self._pull("r")[: self.m_batch]

    @property
    def t(self) -> np.ndarray:
        return self._pull("t")[: self.m_batch]

    @property
    def maf(self) -> np.ndarray:
        if self.host.host_maf is not None:
            return self.host.host_maf[: self.m_batch]
        return self._pull("maf")[: self.m_batch]

    @property
    def valid(self) -> np.ndarray:
        if self.host.host_valid is not None:
            return self.host.host_valid[: self.m_batch]
        return self._pull("valid")[: self.m_batch]

    @property
    def omnibus_nlp(self) -> np.ndarray | None:
        if "omnibus_nlp" not in self._out:
            return None
        return self._pull("omnibus_nlp")[: self.m_batch]

    def t_probe(self, rows: int) -> np.ndarray:
        if "t" in self._cache:  # tile already on host (a hit pulled it)
            return self._cache["t"][: min(self.m_batch, rows), 0]
        return np.asarray(self._out["t"][: min(self.m_batch, rows), 0])


class ResultSink:
    """One accumulation concern of the scan; see module docstring."""

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        raise NotImplementedError

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        """Fold a previously committed checkpoint shard in (resume path)."""

    def result(self) -> dict[str, Any]:
        return {}


class BestTraitSink(ResultSink):
    """Per-trait running best -log10 p and the global marker achieving it."""

    def __init__(self, n_traits: int):
        self.best_nlp = np.zeros(n_traits, np.float32)
        self.best_marker = np.full(n_traits, -1, np.int64)

    def _fold(self, b_best: np.ndarray, b_row: np.ndarray, lo: int) -> None:
        improved = b_best > self.best_nlp
        self.best_nlp = np.where(improved, b_best, self.best_nlp)
        self.best_marker = np.where(
            improved, lo + b_row.astype(np.int64), self.best_marker
        )

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        payload["best_nlp"] = view.best_nlp
        payload["best_row"] = view.best_row
        self._fold(view.best_nlp, view.best_row, view.batch.lo)

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        self._fold(shard["best_nlp"], shard["best_row"], lo)

    def result(self) -> dict[str, Any]:
        return {"best_nlp": self.best_nlp, "best_marker": self.best_marker}


class HitSink(ResultSink):
    """Collect (marker, trait) cells above the genome-wide line, pulling the
    full tiles only for batches whose device-side hit counter is non-zero."""

    def __init__(self, threshold_nlp: float):
        self.threshold = threshold_nlp
        self._hits: list[np.ndarray] = []
        self._stats: list[np.ndarray] = []

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        batch_hits = np.zeros((0, 2), np.int32)
        batch_stats = np.zeros((0, 3), np.float32)
        if view.hit_count > 0:
            nlp = view.nlp
            rows, cols = np.nonzero(nlp >= self.threshold)
            r_np, t_np = view.r, view.t
            batch_hits = np.stack(
                [rows.astype(np.int32) + view.batch.lo, cols.astype(np.int32)], 1
            )
            batch_stats = np.stack(
                [r_np[rows, cols], t_np[rows, cols], nlp[rows, cols]], 1
            ).astype(np.float32)
        payload["hits"] = batch_hits
        payload["hit_stats"] = batch_stats
        self._hits.append(batch_hits)
        self._stats.append(batch_stats)

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        self._hits.append(shard["hits"])
        self._stats.append(shard["hit_stats"])

    def result(self) -> dict[str, Any]:
        return {
            "hits": np.concatenate(self._hits) if self._hits else np.zeros((0, 2), np.int32),
            "hit_stats": (
                np.concatenate(self._stats) if self._stats else np.zeros((0, 3), np.float32)
            ),
        }


class QCSink(ResultSink):
    """Dense per-marker QC arrays: observed MAF, validity mask, and (when
    the multivariate screen is on) the omnibus -log10 p track."""

    def __init__(self, n_markers: int, *, multivariate: bool = False):
        self.maf = np.zeros(n_markers, np.float32)
        self.valid = np.zeros(n_markers, bool)
        self.omnibus_nlp = np.zeros(n_markers, np.float32) if multivariate else None

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        lo, hi = view.batch.lo, view.batch.hi
        self.maf[lo:hi] = view.maf
        self.valid[lo:hi] = view.valid
        payload["maf"] = self.maf[lo:hi]
        payload["valid"] = self.valid[lo:hi]
        if self.omnibus_nlp is not None and view.omnibus_nlp is not None:
            self.omnibus_nlp[lo:hi] = view.omnibus_nlp
            payload["omnibus_nlp"] = self.omnibus_nlp[lo:hi]

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        self.maf[lo:hi] = shard["maf"]
        self.valid[lo:hi] = shard["valid"]
        if self.omnibus_nlp is not None and "omnibus_nlp" in shard:
            self.omnibus_nlp[lo:hi] = shard["omnibus_nlp"]

    def result(self) -> dict[str, Any]:
        return {"maf": self.maf, "valid": self.valid, "omnibus_nlp": self.omnibus_nlp}


class LambdaGCSink(ResultSink):
    """Genomic-control calibration probe: a small t-statistic sample of the
    first trait per batch.  The probe is persisted in every checkpoint shard
    so a resumed scan merges the probes of already-committed batches instead
    of estimating lambda from whatever little it recomputed."""

    def __init__(self, rows: int = 64):
        self.rows = rows
        self._samples: list[np.ndarray] = []

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        probe = np.asarray(view.t_probe(self.rows), np.float32)
        payload["t_probe"] = probe
        self._samples.append(probe)

    def merge_shard(self, shard: dict[str, np.ndarray], lo: int, hi: int) -> None:
        # Shards written before the probe was persisted simply contribute
        # nothing (lambda then rests on the recomputed batches, as before).
        if "t_probe" in shard:
            self._samples.append(np.asarray(shard["t_probe"], np.float32))

    def result(self) -> dict[str, Any]:
        probe = np.concatenate(self._samples) if self._samples else np.zeros(1, np.float32)
        lam = float(_stats.genomic_control_lambda(jnp.asarray(probe))) if probe.size else 1.0
        return {"lambda_gc": lam}


class CheckpointSink(ResultSink):
    """Commit each batch's accumulated payload as an atomic shard.  Must be
    the LAST sink in the chain: it persists whatever the sinks before it
    put into ``payload``."""

    def __init__(self, ckpt: ScanCheckpoint):
        self.ckpt = ckpt

    def on_batch(self, view: BatchView, payload: dict[str, np.ndarray]) -> None:
        shard = {
            "lo": np.asarray(view.batch.lo),
            "hi": np.asarray(view.batch.hi),
            **payload,
        }
        self.ckpt.commit_batch(view.batch.index, shard)
