"""The streaming genome-scan driver: the paper's workflow end to end.

    panel setup (once)                      Eq. 1, amortized across the scan
      -> relatedness exclusion (optional)   core.kinship
      -> covariate basis + residualize      core.residualize
      -> engine setup (optional)            engine.setup_scan — the lmm
         (streamed GRM, eigh, REML,         engine's amortized work lives
          one-time panel rotation)          here (core.grm / core.lmm, §9)
    marker stream (planned + batched)       runtime.prefetch.BatchPlanner
      -> host: decode / repack + stats      engine.prepare_batch (prefetch threads)
      -> staging: async host->device copy   runtime.prefetch.double_buffer
      -> device: GEMM + epilogue            engine step (dense XLA or fused Pallas)
      -> sinks: best / hits / QC / lambda   core.sinks (hit-driven host pull)
      -> sink: commit shard + manifest      runtime.checkpoint (atomic, resumable)

The driver is engine-agnostic: ``core.engines`` resolves ``cfg.engine``
through a registry, and each engine owns both its host-side batch
preparation and its device step, so new engines require no driver changes
(DESIGN.md §1-§4).  Genotype input may be one container or a per-chromosome
fileset (``io.MultiFileSource``); the planner keeps every batch within one
shard so different files stream and prefetch concurrently.

Distribution: the step builders accept a Mesh and return pjit'd (dense) or
shard_map'd (fused) steps obeying ``runtime.sharding.gwas_shardings``.
CPU tests run the identical code with mesh=None.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.association import AssocOptions
from repro.core.engines import (
    EngineContext,
    ScanEngine,
    build_dense_step,
    build_fused_step,
    build_lmm_step,
    get_engine,
)
from repro.core.residualize import covariate_basis, residualize_and_standardize
from repro.core.sinks import (
    BatchView,
    BestTraitSink,
    CheckpointSink,
    HitSink,
    LambdaGCSink,
    QCSink,
    ResultSink,
)
from repro.runtime.checkpoint import ScanCheckpoint, config_fingerprint
from repro.runtime.prefetch import BatchPlanner, Prefetcher, double_buffer

__all__ = [
    "ScanConfig",
    "ScanResult",
    "GenomeScan",
    "build_dense_step",
    "build_fused_step",
    "build_lmm_step",
]


@dataclass(frozen=True)
class ScanConfig:
    batch_markers: int = 4096
    options: AssocOptions = AssocOptions()
    engine: str = "dense"          # registry name: core.engines.available_engines()
    mode: str = "mp"               # sharding mode; "sample" implies engine="dense"
    hit_threshold_nlp: float = 7.301  # 5e-8, the GWAS genome-wide line
    maf_min: float = 0.0
    exclude_related: bool = False
    multivariate: bool = False
    checkpoint_dir: str | None = None
    prefetch_depth: int = 3
    io_workers: int = 2
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256
    input_dtype: str = "fp32"      # fused engine GEMM input: "fp32" | "bf16"
    # mixed-model wing (engine="lmm"; DESIGN.md §9)
    loco: bool = False             # leave-one-chromosome-out GRM per shard
    grm_method: str = "std"        # "std" (GCTA) | "centered" (EMMAX)
    grm_batch_markers: int = 4096  # marker batch of the streamed GRM pass
    lmm_delta: float | None = None # pin se^2/sg^2 (skips the REML fit)
    lmm_epilogue: str = "dense"    # t/p epilogue: "dense" XLA | "fused" Pallas

    def fingerprint_payload(self) -> dict:
        d = dataclasses.asdict(self)
        d["options"] = dataclasses.asdict(self.options)
        # Mesh topology and host counts never enter the fingerprint (elastic).
        d.pop("prefetch_depth"), d.pop("io_workers"), d.pop("checkpoint_dir")
        return d


@dataclass
class ScanResult:
    n_markers: int
    n_samples: int
    n_traits: int
    dof: int
    best_nlp: np.ndarray       # (P,) per-trait best -log10 p
    best_marker: np.ndarray    # (P,) global marker index of the best hit
    hits: np.ndarray           # (H, 2) int32 (marker, trait) above threshold
    hit_stats: np.ndarray      # (H, 3) float32 (r, t, nlp)
    maf: np.ndarray            # (M,)
    valid: np.ndarray          # (M,) bool
    lambda_gc: float           # genomic control on a null-trait subsample
    omnibus_nlp: np.ndarray | None = None   # (M,) multivariate screen
    excluded_samples: int = 0
    lmm_info: dict | None = None  # mixed-model diagnostics (delta, h2, ...)


class GenomeScan:
    """Orchestrates one full scan over a genotype source."""

    def __init__(
        self,
        source: Any,                     # GenotypeSource protocol (repro.io)
        phenotypes: np.ndarray,          # (N, P) aligned to source samples
        covariates: np.ndarray | None = None,
        *,
        config: ScanConfig = ScanConfig(),
        mesh: Mesh | None = None,
    ):
        self.source = source
        self.config = config
        self.mesh = mesh
        n = source.n_samples
        if phenotypes.shape[0] != n:
            raise ValueError(
                f"phenotypes rows ({phenotypes.shape[0]}) != genotype samples ({n}); "
                "align tables first (repro.io.align_tables)"
            )

        self._keep = np.ones(n, bool)
        self.excluded_samples = 0
        if config.exclude_related:
            from repro.core.kinship import exclude_related

            probe = source.read_dosages(0, min(source.n_markers, 4096)).T
            self._keep, _, _ = exclude_related(probe)
            self.excluded_samples = int((~self._keep).sum())
            phenotypes = phenotypes[self._keep]
            covariates = covariates[self._keep] if covariates is not None else None

        self.n_samples = int(self._keep.sum())
        self.n_traits = phenotypes.shape[1]
        self.engine: ScanEngine = get_engine(config.engine)

        self._n_traits_eff = float(self.n_traits)
        self._whitening = None
        if self.engine.uses_global_panel:
            # OLS panel prep (Eq. 1), amortized once.  Engines that build
            # their own panel (lmm: rotated per LOCO scope in setup_scan)
            # skip this entirely — no (N, P) array is kept alive for them.
            self._q = covariate_basis(
                jnp.asarray(covariates) if covariates is not None else None,
                self.n_samples,
            )
            self.panel = residualize_and_standardize(jnp.asarray(phenotypes), self._q)
            self.n_covariates = self.panel.n_covariates
            self._y = self.panel.y
            if config.multivariate:
                from repro.core import multivariate as mv

                self._whitening, eig = mv.whiten_panel(self.panel.y)
                self._n_traits_eff = float(mv.effective_tests(eig))
        else:
            self._q = None
            self.panel = None
            self._y = None
            cov = None if covariates is None else np.asarray(covariates)
            self.n_covariates = 0 if cov is None else (1 if cov.ndim == 1 else cov.shape[1])
        self.dof = config.options.dof(self.n_samples, self.n_covariates)
        self._ctx = EngineContext(
            n_samples=self.n_samples,
            n_covariates=self.n_covariates,
            options=config.options,
            mesh=mesh,
            mode=config.mode,
            hit_threshold=config.hit_threshold_nlp,
            maf_min=config.maf_min,
            block_m=config.block_m,
            block_n=config.block_n,
            block_p=config.block_p,
            q_basis=self._q,
            multivariate=config.multivariate,
            n_traits_eff=self._n_traits_eff,
            whitening=self._whitening,
            keep=self._keep,
            excluded_samples=self.excluded_samples,
            loco=config.loco,
            grm_method=config.grm_method,
            grm_batch_markers=config.grm_batch_markers,
            lmm_delta=config.lmm_delta,
            lmm_epilogue=config.lmm_epilogue,
            io_workers=config.io_workers,
        )
        self.engine.validate(self._ctx)
        # Amortized engine setup (LMM: streamed GRM + eigendecomposition +
        # REML + panel rotation).  Engines may override the scan dof and
        # contribute diagnostics to the result.
        self.lmm_info: dict | None = None
        setup = self.engine.setup_scan(source, np.asarray(phenotypes), covariates, self._ctx)
        if setup:
            self.dof = int(setup.get("dof", self.dof))
            self.lmm_info = setup.get("info")
        self._step = self.engine.build_step(self._ctx)
        self.planner = BatchPlanner(config.batch_markers)
        self.plan = self.planner.plan(source)

    # ---------------------------------------------------------------- batches

    @property
    def n_batches(self) -> int:
        return len(self.plan)

    # ------------------------------------------------------------------- run

    def _make_sinks(self, ckpt: ScanCheckpoint | None) -> list[ResultSink]:
        sinks: list[ResultSink] = [
            BestTraitSink(self.n_traits),
            HitSink(self.config.hit_threshold_nlp),
            QCSink(self.source.n_markers, multivariate=self.config.multivariate),
            LambdaGCSink(),
        ]
        if ckpt is not None:
            sinks.append(CheckpointSink(ckpt))  # last: persists peers' payload
        return sinks

    def run(self, *, resume: bool = True) -> ScanResult:
        cfg = self.config
        m_total = self.source.n_markers
        ckpt: ScanCheckpoint | None = None
        todo = self.plan
        if cfg.checkpoint_dir:
            # Engine state (e.g. the LMM's GRM spectrum hash) is part of the
            # scan identity: resuming against a different GRM or refitted
            # variance components would mix incompatible statistics.
            engine_state = self.engine.state_fingerprint()
            fp = config_fingerprint(
                {
                    **cfg.fingerprint_payload(),
                    "n_markers": m_total,
                    "n_samples": self.n_samples,
                    "n_traits": self.n_traits,
                    # The plan's index->(lo,hi) mapping depends on the shard
                    # layout; resuming against a re-sharded fileset would
                    # silently mix two incompatible batch decompositions.
                    "shard_boundaries": list(
                        getattr(self.source, "shard_boundaries", (0, m_total))
                    ),
                    **({"engine_state": engine_state} if engine_state else {}),
                }
            )
            ckpt = ScanCheckpoint(cfg.checkpoint_dir, fingerprint=fp, n_batches=self.n_batches)
            if resume:
                pending = set(ckpt.pending_batches())
                todo = [b for b in self.plan if b.index in pending]

        sinks = self._make_sinks(ckpt)
        # OLS engines take the driver's residualized panel as the trailing
        # step argument; the lmm engine carries per-scope panels inside
        # device_args instead (they differ per LOCO chromosome).
        extra = (jnp.asarray(self._y),) if self.engine.uses_global_panel else ()

        prefetched = Prefetcher(
            todo,
            lambda b: self.engine.prepare_batch(self.source, b, self._ctx),
            depth=cfg.prefetch_depth,
            num_workers=cfg.io_workers,
        )

        def stage(host_batch):
            # jnp.asarray launches the copy; on accelerators it completes
            # while the device chews on the previous batch (double buffer).
            return host_batch, tuple(jnp.asarray(a) for a in host_batch.device_args)

        for host_batch, dev_args in double_buffer(prefetched, stage):
            out = self._step(*dev_args, *extra)
            view = BatchView(host_batch, out, self.n_traits)
            payload: dict[str, np.ndarray] = {}
            for sink in sinks:
                sink.on_batch(view, payload)

        # Resume path: replay previously committed shards through the sinks.
        if ckpt is not None:
            done_now = {b.index for b in todo}
            for idx in sorted(ckpt.completed - done_now):
                shard = ckpt.load_batch(idx)
                lo, hi = int(shard["lo"]), int(shard["hi"])
                for sink in sinks:
                    sink.merge_shard(shard, lo, hi)

        fields: dict[str, Any] = {}
        for sink in sinks:
            fields.update(sink.result())
        return ScanResult(
            n_markers=m_total,
            n_samples=self.n_samples,
            n_traits=self.n_traits,
            dof=self.dof,
            excluded_samples=self.excluded_samples,
            lmm_info=self.lmm_info,
            **fields,
        )
