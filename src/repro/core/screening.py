"""The streaming genome-scan driver: the paper's workflow end to end.

    panel setup (once)                      Eq. 1, amortized across the scan
      -> relatedness exclusion (optional)   core.kinship
      -> covariate basis + residualize      core.residualize
    marker stream (batched)
      -> host: decode / repack + stats      io.* + kernels.ops (prefetch threads)
      -> device: GEMM + epilogue            assoc step (dense XLA or fused Pallas)
      -> device: per-trait max, hit count   "hit-driven host pull": the full
                                            (M, P) tile crosses PCIe only when
                                            a batch actually contains hits
      -> host: commit shard + manifest      runtime.checkpoint (atomic, resumable)

Distribution: the same step builders accept a Mesh and return pjit'd
(dense) or shard_map'd (fused) steps obeying ``runtime.sharding.gwas_shardings``.
CPU tests run the identical code with mesh=None.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import stats as _stats
from repro.core.association import AssocOptions, assoc_from_standardized, standardize_genotype_batch
from repro.core.residualize import covariate_basis, residualize_and_standardize
from repro.runtime.checkpoint import ScanCheckpoint, config_fingerprint
from repro.runtime.prefetch import Prefetcher
from repro.runtime.sharding import batch_axes, gwas_shardings

__all__ = ["ScanConfig", "ScanResult", "GenomeScan", "build_dense_step", "build_fused_step"]


@dataclass(frozen=True)
class ScanConfig:
    batch_markers: int = 4096
    options: AssocOptions = AssocOptions()
    engine: str = "dense"          # "dense" (XLA, paper-faithful) | "fused" (Pallas 2-bit)
    mode: str = "mp"               # sharding mode; "sample" implies engine="dense"
    hit_threshold_nlp: float = 7.301  # 5e-8, the GWAS genome-wide line
    maf_min: float = 0.0
    exclude_related: bool = False
    multivariate: bool = False
    checkpoint_dir: str | None = None
    prefetch_depth: int = 3
    io_workers: int = 2
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256
    input_dtype: str = "fp32"      # fused engine GEMM input: "fp32" | "bf16"

    def fingerprint_payload(self) -> dict:
        d = dataclasses.asdict(self)
        d["options"] = dataclasses.asdict(self.options)
        # Mesh topology and host counts never enter the fingerprint (elastic).
        d.pop("prefetch_depth"), d.pop("io_workers"), d.pop("checkpoint_dir")
        return d


@dataclass
class ScanResult:
    n_markers: int
    n_samples: int
    n_traits: int
    dof: int
    best_nlp: np.ndarray       # (P,) per-trait best -log10 p
    best_marker: np.ndarray    # (P,) global marker index of the best hit
    hits: np.ndarray           # (H, 2) int32 (marker, trait) above threshold
    hit_stats: np.ndarray      # (H, 3) float32 (r, t, nlp)
    maf: np.ndarray            # (M,)
    valid: np.ndarray          # (M,) bool
    lambda_gc: float           # genomic control on a null-trait subsample
    omnibus_nlp: np.ndarray | None = None   # (M,) multivariate screen
    excluded_samples: int = 0


def build_dense_step(
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions,
    mesh: Mesh | None = None,
    mode: str = "mp",
    hit_threshold: float = 7.301,
    q_basis: jax.Array | None = None,
    multivariate: bool = False,
    n_traits_eff: float = 1.0,
    whitening: jax.Array | None = None,
) -> Callable[..., dict[str, jax.Array]]:
    """Paper-faithful dense step: float dosages in, summary tiles out."""
    dof = options.dof(n_samples, n_covariates)

    def step(g_raw: jax.Array, y_std: jax.Array) -> dict[str, jax.Array]:
        g_std, ms = standardize_genotype_batch(g_raw)
        if options.dof_mode == "exact":
            from repro.core.residualize import residualize_genotypes

            g_std = residualize_genotypes(g_std, q_basis)
        res = assoc_from_standardized(
            g_std, y_std, n_samples=n_samples, n_covariates=n_covariates, options=options
        )
        mask = ms.valid[:, None]
        nlp = jnp.where(mask, res.neglog10p, 0.0)
        out = {
            "r": jnp.where(mask, res.r, 0.0),
            "t": jnp.where(mask, res.t, 0.0),
            "nlp": nlp,
            "maf": ms.maf,
            "valid": ms.valid,
            "batch_best_nlp": jnp.max(nlp, axis=0),
            "batch_best_row": jnp.argmax(nlp, axis=0).astype(jnp.int32),
            "hit_count": jnp.sum(nlp >= hit_threshold).astype(jnp.int32),
        }
        if multivariate:
            from repro.core import multivariate as mv

            omni, omni_nlp = mv.omnibus_chi2(
                out["r"], n_samples, n_traits_eff, whitening=whitening
            )
            out["omnibus"] = omni
            out["omnibus_nlp"] = omni_nlp
        return out

    if mesh is None:
        return jax.jit(step)

    sh = gwas_shardings(mesh, mode=mode)
    mv_spec = {"omnibus": sh["marker_vec"], "omnibus_nlp": sh["marker_vec"]} if multivariate else {}
    rep = NamedSharding(mesh, P())
    model_vec = NamedSharding(mesh, P("model"))
    out_shardings = {
        "r": sh["out"],
        "t": sh["out"],
        "nlp": sh["out"],
        "maf": sh["marker_vec"],
        "valid": sh["marker_vec"],
        "batch_best_nlp": model_vec,
        "batch_best_row": model_vec,
        "hit_count": rep,
        **mv_spec,
    }
    return jax.jit(step, in_shardings=(sh["g"], sh["y"]), out_shardings=out_shardings)


def build_fused_step(
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions,
    mesh: Mesh | None = None,
    hit_threshold: float = 7.301,
    block_m: int = 256,
    block_n: int = 512,
    block_p: int = 256,
    interpret: bool | None = None,
) -> Callable[..., dict[str, jax.Array]]:
    """Beyond-paper fused step: 2-bit packed slabs in (kernel layout),
    summary tiles out.  'mp' sharding only — the in-kernel epilogue requires
    complete sample contractions per device (DESIGN.md §5)."""
    from repro.kernels.gwas_dot.gwas_dot import build_gwas_dot

    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    dof = options.dof(n_samples, n_covariates)
    input_dtype = jnp.bfloat16 if options.precision == "bf16" else jnp.float32

    def kernel_local(packed, mean2d, inv2d, y):
        m_loc = packed.shape[0]
        n_pad = packed.shape[1] * 4
        p_loc = y.shape[1]
        call = build_gwas_dot(
            m_loc, n_pad, p_loc,
            block_m=block_m, block_n=block_n, block_p=block_p,
            n_samples=n_samples, dof=dof,
            input_dtype=input_dtype, interpret=interpret,
        )
        return tuple(call(packed, mean2d, inv2d, y))

    if mesh is not None:
        dp = batch_axes(mesh)
        kernel_fn = jax.shard_map(
            kernel_local,
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None), P(dp, None), P(None, "model")),
            out_specs=(P(dp, "model"), P(dp, "model")),
            # pallas_call out_shapes carry no vma metadata; the kernel is
            # elementwise-independent per shard so the check is vacuous here.
            check_vma=False,
        )
    else:
        kernel_fn = kernel_local

    def step(packed, mean2d, inv2d, valid, y_std):
        p_true = y_std.shape[1]
        pad_p = (-p_true) % block_p
        pad_n = packed.shape[1] * 4 - y_std.shape[0]  # packed samples are tile-padded
        if pad_p or pad_n:
            y_std = jnp.pad(y_std, ((0, pad_n), (0, pad_p)))
        r, t = kernel_fn(packed, mean2d, inv2d, y_std)
        if pad_p:
            r = r[:, :p_true]
            t = t[:, :p_true]
        mask = valid[:, None]
        r = jnp.where(mask, r, 0.0)
        t = jnp.where(mask, t, 0.0)
        nlp = jnp.where(mask, _stats.neglog10_p_from_t(t, dof), 0.0)
        return {
            "r": r,
            "t": t,
            "nlp": nlp,
            "batch_best_nlp": jnp.max(nlp, axis=0),
            "batch_best_row": jnp.argmax(nlp, axis=0).astype(jnp.int32),
            "hit_count": jnp.sum(nlp >= hit_threshold).astype(jnp.int32),
        }

    if mesh is None:
        return jax.jit(step)
    sh = gwas_shardings(mesh, mode="mp")
    model_vec = NamedSharding(mesh, P("model"))
    return jax.jit(
        step,
        in_shardings=(sh["packed"], sh["packed"], sh["packed"], sh["marker_vec"], sh["y"]),
        out_shardings={
            "r": sh["out"],
            "t": sh["out"],
            "nlp": sh["out"],
            "batch_best_nlp": model_vec,
            "batch_best_row": model_vec,
            "hit_count": NamedSharding(mesh, P()),
        },
    )


class GenomeScan:
    """Orchestrates one full scan over a genotype source."""

    def __init__(
        self,
        source: Any,                     # GenotypeSource protocol (repro.io)
        phenotypes: np.ndarray,          # (N, P) aligned to source samples
        covariates: np.ndarray | None = None,
        *,
        config: ScanConfig = ScanConfig(),
        mesh: Mesh | None = None,
    ):
        self.source = source
        self.config = config
        self.mesh = mesh
        n = source.n_samples
        if phenotypes.shape[0] != n:
            raise ValueError(
                f"phenotypes rows ({phenotypes.shape[0]}) != genotype samples ({n}); "
                "align tables first (repro.io.align_tables)"
            )

        self._keep = np.ones(n, bool)
        self.excluded_samples = 0
        if config.exclude_related:
            from repro.core.kinship import exclude_related

            probe = source.read_dosages(0, min(source.n_markers, 4096)).T
            self._keep, _, _ = exclude_related(probe)
            self.excluded_samples = int((~self._keep).sum())
            phenotypes = phenotypes[self._keep]
            covariates = covariates[self._keep] if covariates is not None else None

        self.n_samples = int(self._keep.sum())
        self.n_traits = phenotypes.shape[1]
        self._q = covariate_basis(
            jnp.asarray(covariates) if covariates is not None else None, self.n_samples
        )
        self.panel = residualize_and_standardize(jnp.asarray(phenotypes), self._q)
        self.n_covariates = self.panel.n_covariates
        self.dof = config.options.dof(self.n_samples, self.n_covariates)

        self._n_traits_eff = float(self.n_traits)
        self._y = self.panel.y
        self._whitening = None
        if config.multivariate:
            from repro.core import multivariate as mv

            self._whitening, eig = mv.whiten_panel(self.panel.y)
            self._n_traits_eff = float(mv.effective_tests(eig))
        if config.engine == "fused":
            if config.mode != "mp":
                raise ValueError("fused engine supports marker x phenotype sharding only")
            self._step = build_fused_step(
                n_samples=self.n_samples,
                n_covariates=self.n_covariates,
                options=config.options,
                mesh=mesh,
                hit_threshold=config.hit_threshold_nlp,
                block_m=config.block_m,
                block_n=config.block_n,
                block_p=config.block_p,
            )
        else:
            self._step = build_dense_step(
                n_samples=self.n_samples,
                n_covariates=self.n_covariates,
                options=config.options,
                mesh=mesh,
                mode=config.mode,
                hit_threshold=config.hit_threshold_nlp,
                q_basis=self._q,
                multivariate=config.multivariate,
                n_traits_eff=self._n_traits_eff,
                whitening=self._whitening,
            )

    # ---------------------------------------------------------------- batches

    @property
    def n_batches(self) -> int:
        b = self.config.batch_markers
        return (self.source.n_markers + b - 1) // b

    def _batch_range(self, idx: int) -> tuple[int, int]:
        b = self.config.batch_markers
        return idx * b, min((idx + 1) * b, self.source.n_markers)

    def _load_batch(self, idx: int):
        lo, hi = self._batch_range(idx)
        cfg = self.config
        if cfg.engine == "fused":
            from repro.kernels.gwas_dot import ops as kops

            plink_packed = self.source.read_packed(lo, hi)
            codes = kops.unpack_plink_to_codes(plink_packed, len(self._keep))
            if self.excluded_samples:
                codes = codes[:, self._keep]
            mean, inv_std, valid = kops.marker_stats_from_codes(codes)
            if cfg.maf_min > 0:
                af = mean / 2.0
                maf = np.minimum(af, 1.0 - af)
                valid &= maf >= cfg.maf_min
                inv_std = np.where(valid, inv_std, 0.0).astype(np.float32)
            packed = kops.pack_tiled(codes, cfg.block_n)
            pad_m = (-packed.shape[0]) % cfg.block_m
            if pad_m:
                packed = np.pad(packed, ((0, pad_m), (0, 0)), constant_values=0b01)
                mean = np.pad(mean, (0, pad_m))
                inv_std = np.pad(inv_std, (0, pad_m))
                valid = np.pad(valid, (0, pad_m))
            maf = np.minimum(mean / 2.0, 1.0 - mean / 2.0)
            return idx, (lo, hi), (
                packed,
                mean.reshape(-1, 1),
                inv_std.reshape(-1, 1),
                valid,
            ), maf
        dosages = self.source.read_dosages(lo, hi)
        if self.excluded_samples:
            dosages = dosages[:, self._keep]
        return idx, (lo, hi), (np.asarray(dosages, np.float32),), None

    # ------------------------------------------------------------------- run

    def run(self, *, resume: bool = True) -> ScanResult:
        cfg = self.config
        m_total = self.source.n_markers
        ckpt: ScanCheckpoint | None = None
        if cfg.checkpoint_dir:
            fp = config_fingerprint(
                {
                    **cfg.fingerprint_payload(),
                    "n_markers": m_total,
                    "n_samples": self.n_samples,
                    "n_traits": self.n_traits,
                }
            )
            ckpt = ScanCheckpoint(cfg.checkpoint_dir, fingerprint=fp, n_batches=self.n_batches)
            batch_ids = ckpt.pending_batches() if resume else list(range(self.n_batches))
        else:
            batch_ids = list(range(self.n_batches))

        best_nlp = np.zeros(self.n_traits, np.float32)
        best_marker = np.full(self.n_traits, -1, np.int64)
        hits: list[np.ndarray] = []
        hit_stats: list[np.ndarray] = []
        maf_all = np.zeros(m_total, np.float32)
        valid_all = np.zeros(m_total, bool)
        omni_all = np.zeros(m_total, np.float32) if cfg.multivariate else None
        t_sample: list[np.ndarray] = []

        y_dev = jnp.asarray(self._y)

        for idx, (lo, hi), dev_args, host_maf in Prefetcher(
            batch_ids, self._load_batch, depth=cfg.prefetch_depth, num_workers=cfg.io_workers
        ):
            out = self._step(*[jnp.asarray(a) for a in dev_args], y_dev)
            m_batch = hi - lo
            b_best = np.asarray(out["batch_best_nlp"])[: self.n_traits]
            b_row = np.asarray(out["batch_best_row"])[: self.n_traits]
            improved = b_best > best_nlp
            best_nlp = np.where(improved, b_best, best_nlp)
            best_marker = np.where(improved, lo + b_row.astype(np.int64), best_marker)

            if host_maf is not None:
                maf_all[lo:hi] = host_maf[:m_batch]
                valid_all[lo:hi] = np.asarray(dev_args[3])[:m_batch]
            else:
                maf_all[lo:hi] = np.asarray(out["maf"])[:m_batch]
                valid_all[lo:hi] = np.asarray(out["valid"])[:m_batch]
            if omni_all is not None and "omnibus_nlp" in out:
                omni_all[lo:hi] = np.asarray(out["omnibus_nlp"])[:m_batch]

            # Hit-driven host pull: the full tile crosses to host only when
            # this batch contains at least one genome-wide-significant cell.
            batch_hits = np.zeros((0, 2), np.int32)
            batch_stats = np.zeros((0, 3), np.float32)
            if int(out["hit_count"]) > 0:
                nlp = np.asarray(out["nlp"])[:m_batch]
                rows, cols = np.nonzero(nlp >= cfg.hit_threshold_nlp)
                r_np = np.asarray(out["r"])[:m_batch]
                t_np = np.asarray(out["t"])[:m_batch]
                batch_hits = np.stack([rows.astype(np.int32) + lo, cols.astype(np.int32)], 1)
                batch_stats = np.stack(
                    [r_np[rows, cols], t_np[rows, cols], nlp[rows, cols]], 1
                ).astype(np.float32)
            hits.append(batch_hits)
            hit_stats.append(batch_stats)

            # Calibration probe: first trait's t row sample for lambda_GC.
            t_sample.append(np.asarray(out["t"])[: min(m_batch, 64), 0])

            if ckpt is not None:
                shard = {
                    "lo": np.asarray(lo),
                    "hi": np.asarray(hi),
                    "best_nlp": b_best,
                    "best_row": b_row,
                    "hits": batch_hits,
                    "hit_stats": batch_stats,
                    "maf": maf_all[lo:hi],
                    "valid": valid_all[lo:hi],
                }
                if omni_all is not None:
                    shard["omnibus_nlp"] = omni_all[lo:hi]
                ckpt.commit_batch(idx, shard)

        # Resume path: merge previously committed shards.
        if ckpt is not None and set(batch_ids) != set(range(self.n_batches)):
            for idx in sorted(ckpt.completed - set(batch_ids)):
                shard = ckpt.load_batch(idx)
                lo, hi = int(shard["lo"]), int(shard["hi"])
                improved = shard["best_nlp"] > best_nlp
                best_nlp = np.where(improved, shard["best_nlp"], best_nlp)
                best_marker = np.where(
                    improved, lo + shard["best_row"].astype(np.int64), best_marker
                )
                hits.append(shard["hits"])
                hit_stats.append(shard["hit_stats"])
                maf_all[lo:hi] = shard["maf"]
                valid_all[lo:hi] = shard["valid"]
                if omni_all is not None and "omnibus_nlp" in shard:
                    omni_all[lo:hi] = shard["omnibus_nlp"]

        t_probe = np.concatenate(t_sample) if t_sample else np.zeros(1, np.float32)
        lam = float(_stats.genomic_control_lambda(jnp.asarray(t_probe))) if t_probe.size else 1.0
        return ScanResult(
            n_markers=m_total,
            n_samples=self.n_samples,
            n_traits=self.n_traits,
            dof=self.dof,
            best_nlp=best_nlp,
            best_marker=best_marker,
            hits=np.concatenate(hits) if hits else np.zeros((0, 2), np.int32),
            hit_stats=np.concatenate(hit_stats) if hit_stats else np.zeros((0, 3), np.float32),
            maf=maf_all,
            valid=valid_all,
            lambda_gc=lam,
            omnibus_nlp=omni_all,
            excluded_samples=self.excluded_samples,
        )
