"""Deprecated blocking facade over the layered public API (``repro.api``).

The scan itself now lives behind the bind -> plan -> execute -> emit
layers (DESIGN.md §11):

    bind     ``repro.api.Study``        source opening, alignment, sample QC
    plan     ``Study.plan``             typed specs -> normalized ScanConfig
    execute  ``repro.api.ScanSession``  the streaming grid executor
    emit     ``repro.api.writers``      streaming sorted-TSV / npz shards

``GenomeScan``/``ScanResult`` remain as *shims* for existing callers: a
``GenomeScan`` binds a Study, prepares a plan, and ``run()`` folds the
session's ``CellResult`` event stream through the historical sinks into a
dense ``ScanResult`` — bitwise-identical to the pre-redesign driver (the
sinks, steps, planners, and checkpoint format are the very same objects the
session uses; only the loop moved).  New code should prefer the API: it
streams instead of materializing, and its writers keep host memory bounded
per grid cell no matter how wide the panel is.

``PanelStore`` lives in ``core.panels`` now; ``ScanConfig`` in
``api.specs``; both are re-exported here for compatibility.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np
from jax.sharding import Mesh

from repro.api.session import ScanSession
from repro.api.specs import ScanConfig
from repro.api.study import Study
from repro.core.engines import (
    build_dense_step,
    build_fused_step,
    build_lmm_step,
)
from repro.core.panels import PanelStore
from repro.core.sinks import (
    BestTraitSink,
    HitSink,
    LambdaGCSink,
    QCSink,
    ResultSink,
)
from repro.runtime.checkpoint import ScanCheckpoint

__all__ = [
    "ScanConfig",
    "ScanResult",
    "GenomeScan",
    "PanelStore",
    "build_dense_step",
    "build_fused_step",
    "build_lmm_step",
]


@dataclass
class ScanResult:
    """Dense end-of-scan summary (deprecated collection shape).

    Materializes the full hit table plus per-trait/per-marker tracks on the
    host at scan end.  Prefer streaming ``ScanSession.events()`` through
    result writers for paper-scale panels.
    """

    n_markers: int
    n_samples: int
    n_traits: int
    dof: int
    best_nlp: np.ndarray       # (P,) per-trait best -log10 p
    best_marker: np.ndarray    # (P,) global marker index of the best hit
    hits: np.ndarray           # (H, 2) int32 (marker, trait) above threshold
    hit_stats: np.ndarray      # (H, 3) float32 (r, t, nlp)
    maf: np.ndarray            # (M,)
    valid: np.ndarray          # (M,) bool
    lambda_gc: float           # genomic control on a null-trait subsample
    omnibus_nlp: np.ndarray | None = None   # (M,) multivariate screen
    excluded_samples: int = 0
    lmm_info: dict | None = None  # mixed-model diagnostics (delta, h2, ...)


class GenomeScan:
    """Deprecated: orchestrates one full scan and collects a ``ScanResult``.

    Equivalent API session:

        study = Study.from_arrays(source, phenotypes, covariates,
                                  exclude_related=cfg.exclude_related)
        session = study.plan_config(cfg, mesh=mesh).run()
        for cell in session.events(): ...

    The shim keeps the historical surface (constructor-time validation and
    engine setup, ``run(resume=...)``, ``_make_sinks`` extension hook, a
    swappable ``_step``) so existing tests, benchmarks, and callers run
    unchanged on top of the session executor.
    """

    def __init__(
        self,
        source: Any,                     # GenotypeSource protocol (repro.io)
        phenotypes: np.ndarray,          # (N, P) aligned to source samples
        covariates: np.ndarray | None = None,
        *,
        config: ScanConfig = ScanConfig(),
        mesh: Mesh | None = None,
    ):
        self.source = source
        self.config = config
        self.mesh = mesh
        self.study = Study.from_arrays(
            source, phenotypes, covariates,
            exclude_related=config.exclude_related,
        )
        # Prepare eagerly: the historical constructor validated the
        # (engine, config) combination and ran the amortized engine setup
        # (GRM/REML for lmm), and callers rely on both.
        self._plan = self.study.plan_config(config, mesh=mesh)
        prep = self._plan.prepare()
        self._prepared = prep
        self._step = prep.step           # swappable, as before (tests do)

    # ------------------------------------------------------ mirrored state

    @property
    def excluded_samples(self) -> int:
        return self.study.excluded_samples

    @property
    def n_samples(self) -> int:
        return self.study.n_samples

    @property
    def n_traits(self) -> int:
        return self.study.n_traits

    @property
    def n_covariates(self) -> int:
        return self._prepared.n_covariates

    @property
    def engine(self):
        return self._prepared.engine

    @property
    def trait_blocks(self):
        return self._prepared.trait_blocks

    @property
    def panels(self) -> PanelStore | None:
        return self._prepared.panels

    @property
    def dof(self) -> int:
        return self._prepared.dof

    @property
    def lmm_info(self) -> dict | None:
        return self._prepared.lmm_info

    @property
    def plan(self):
        """The marker-batch decomposition (historical name)."""
        return self._prepared.batches

    @property
    def n_batches(self) -> int:
        return self._prepared.n_batches

    @property
    def n_trait_blocks(self) -> int:
        return self._prepared.n_trait_blocks

    # ------------------------------------------------------------------- run

    def _make_sinks(self, ckpt: ScanCheckpoint | None) -> list[ResultSink]:
        """The ScanResult accumulation chain.  Note the session commits
        checkpoint cells natively now, so no CheckpointSink rides here; the
        ``ckpt`` argument stays for subclass compatibility."""
        return [
            BestTraitSink(self.n_traits),
            HitSink(
                self.config.hit_threshold_nlp,
                spill_dir=self.config.spill_dir,
                spill_rows=self.config.hit_spill_rows,
            ),
            QCSink(self.source.n_markers, multivariate=self.config.multivariate),
            LambdaGCSink(),
        ]

    def run(self, *, resume: bool = True) -> ScanResult:
        session = ScanSession(self._prepared, resume=resume, step=self._step)
        sinks = self._make_sinks(session.checkpoint)
        events = session.events()
        try:
            # The historical fold loop, verbatim: live cells flow through
            # ``on_batch`` with ONE payload dict shared across the chain
            # (so subclass sinks composing through ``_make_sinks`` keep
            # their payload-sharing contract), replayed cells through
            # ``merge_shard``.  Note the session commits checkpoint cells
            # natively from ``CellResult.payload()`` — custom payload keys
            # are only persisted if a ``CheckpointSink`` is explicitly
            # appended after the contributing sinks.
            for cell in events:
                if cell.view is not None:
                    payload: dict[str, np.ndarray] = {}
                    for sink in sinks:
                        sink.on_batch(cell.view, payload)
                else:
                    shard = cell.payload()
                    for sink in sinks:
                        sink.merge_shard(shard, cell.lo, cell.hi)
        finally:
            # Error path included: a raising sink must not leave decode
            # workers alive or the in-flight staged copy pinned — closing
            # the generator runs the session's teardown.
            events.close()

        fields: dict[str, Any] = {}
        for sink in sinks:
            fields.update(sink.result())
        return ScanResult(
            n_markers=self.source.n_markers,
            n_samples=self.n_samples,
            n_traits=self.n_traits,
            dof=self.dof,
            excluded_samples=self.excluded_samples,
            lmm_info=self.lmm_info,
            **fields,
        )
