"""The streaming genome-scan driver: the paper's workflow end to end.

    panel setup (once)                      Eq. 1, amortized across the scan
      -> relatedness exclusion (optional)   core.kinship
      -> covariate basis + residualize      core.residualize (per trait
         (host-side PanelStore,             block; device residency bounded
          block slices on an LRU)           by trait_block, DESIGN.md §10)
      -> engine setup (optional)            engine.setup_scan — the lmm
         (streamed GRM, eigh, REML,         engine's amortized work lives
          one-time panel rotation)          here (core.grm / core.lmm, §9)
    2-D scan grid (marker x trait block)    runtime.prefetch planners
      -> host: decode / repack + stats      engine.prepare_batch (prefetch threads)
      -> staging: async host->device copy   runtime.prefetch.double_buffer
      -> device: GEMM + epilogue            engine step per grid cell — each
         (trait blocks inner loop)          staged genotype batch is reused
                                            across every trait block before
                                            the next H2D copy
      -> sinks: best / hits / QC / lambda   core.sinks (hit-driven host pull,
                                            folds offset by block origin)
      -> sink: commit cell shard+manifest   runtime.checkpoint (atomic,
                                            resumable mid-panel)

The driver is engine-agnostic: ``core.engines`` resolves ``cfg.engine``
through a registry, and each engine owns both its host-side batch
preparation and its device step, so new engines require no driver changes
(DESIGN.md §1-§4).  Genotype input may be one container or a per-chromosome
fileset (``io.MultiFileSource``); the planner keeps every batch within one
shard so different files stream and prefetch concurrently.

``trait_block=0`` (the default) is the unblocked degenerate grid — one
block spanning the panel — and reproduces the classic 1-D scan bitwise.
A blocked scan is *also* bitwise-identical to the unblocked one for every
engine (tests/test_traitblocks.py): every step computes the panel axis in
fixed ``block_p``-wide tiles and scheduling blocks are aligned to them, so
each tile's GEMM is the same shape over the same columns no matter how the
axis is blocked — tiling changes scheduling and memory, never statistics.

Distribution: the step builders accept a Mesh and return pjit'd (dense) or
shard_map'd (fused) steps obeying ``runtime.sharding.gwas_shardings``.
CPU tests run the identical code with mesh=None.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.association import AssocOptions
from repro.core.engines import (
    DeviceLRU,
    EngineContext,
    ScanEngine,
    build_dense_step,
    build_fused_step,
    build_lmm_step,
    get_engine,
)
from repro.core.residualize import covariate_basis, residualize_and_standardize
from repro.core.sinks import (
    BatchView,
    BestTraitSink,
    CheckpointSink,
    HitSink,
    LambdaGCSink,
    QCSink,
    ResultSink,
)
from repro.runtime.checkpoint import ScanCheckpoint, config_fingerprint
from repro.runtime.prefetch import (
    BatchPlanner,
    Prefetcher,
    TraitBlock,
    TraitBlockPlanner,
    double_buffer,
)

__all__ = [
    "ScanConfig",
    "ScanResult",
    "GenomeScan",
    "PanelStore",
    "build_dense_step",
    "build_fused_step",
    "build_lmm_step",
]


class PanelStore:
    """Host-resident residualized phenotype panel, tiled on the trait axis.

    The store residualizes + standardizes the panel in fixed ``quantum``-wide
    column chunks on the device (peak device footprint during setup: one
    ``(N, quantum)`` slice, never ``(N, P)``), keeps the float32 results
    host-side, and serves device-resident block slices through a small LRU —
    panels that fit stay resident, paper-scale panels stream.  The chunk
    decomposition is the same regardless of ``trait_block`` (it is the
    compute quantum, not the scheduling block), so blocked and unblocked
    stores hold bitwise-identical panels.
    """

    def __init__(self, blocks: list[TraitBlock], panel: np.ndarray,
                 *, max_resident: int = 4):
        self.blocks = list(blocks)
        self._panel = panel               # (N, P) float32, host
        self._dev = DeviceLRU(            # block index -> staged device array
            max_resident,
            lambda idx: jnp.asarray(self.host_block(self.blocks[idx])),
        )

    @classmethod
    def residualized(
        cls,
        phenotypes: np.ndarray,
        q_basis: Any,
        blocks: list[TraitBlock],
        *,
        quantum: int,
        max_resident: int = 4,
    ) -> "PanelStore":
        n, p = phenotypes.shape
        panel = np.empty((n, p), np.float32)
        for lo in range(0, p, quantum):
            hi = min(lo + quantum, p)
            chunk = residualize_and_standardize(
                jnp.asarray(phenotypes[:, lo:hi]), q_basis
            )
            panel[:, lo:hi] = np.asarray(chunk.y)
        return cls(blocks, panel, max_resident=max_resident)

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def host_block(self, block: TraitBlock) -> np.ndarray:
        return self._panel[:, block.lo : block.hi]

    def device_block(self, block: TraitBlock) -> Any:
        """Device array for one block; ``jnp.asarray`` launches the copy
        asynchronously, so staging overlaps the previous cell's compute."""
        return self._dev.get(block.index)


@dataclass(frozen=True)
class ScanConfig:
    batch_markers: int = 4096
    trait_block: int = 0           # trait-axis tile width; 0 = unblocked (§10)
    options: AssocOptions = AssocOptions()
    engine: str = "dense"          # registry name: core.engines.available_engines()
    mode: str = "mp"               # sharding mode; "sample" implies engine="dense"
    hit_threshold_nlp: float = 7.301  # 5e-8, the GWAS genome-wide line
    maf_min: float = 0.0
    exclude_related: bool = False
    multivariate: bool = False
    checkpoint_dir: str | None = None
    prefetch_depth: int = 3
    io_workers: int = 2
    panel_resident_blocks: int = 4 # device LRU capacity for panel blocks
    spill_dir: str | None = None   # HitSink spill location (None: all in RAM)
    hit_spill_rows: int = 2_000_000  # spill past this many resident hit rows
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256
    input_dtype: str = "fp32"      # fused engine GEMM input: "fp32" | "bf16"
    # mixed-model wing (engine="lmm"; DESIGN.md §9)
    loco: bool = False             # leave-one-chromosome-out GRM per shard
    grm_method: str = "std"        # "std" (GCTA) | "centered" (EMMAX)
    grm_batch_markers: int = 4096  # marker batch of the streamed GRM pass
    lmm_delta: float | None = None # pin se^2/sg^2 (skips the REML fit)
    lmm_epilogue: str = "dense"    # t/p epilogue: "dense" XLA | "fused" Pallas

    def fingerprint_payload(self) -> dict:
        d = dataclasses.asdict(self)
        d["options"] = dataclasses.asdict(self.options)
        # Mesh topology, host counts, and host-memory/spill knobs never
        # enter the fingerprint (elastic restarts may retune them).
        # trait_block STAYS: it defines the checkpoint grid decomposition.
        for k in ("prefetch_depth", "io_workers", "checkpoint_dir",
                  "panel_resident_blocks", "spill_dir", "hit_spill_rows"):
            d.pop(k)
        return d


@dataclass
class ScanResult:
    n_markers: int
    n_samples: int
    n_traits: int
    dof: int
    best_nlp: np.ndarray       # (P,) per-trait best -log10 p
    best_marker: np.ndarray    # (P,) global marker index of the best hit
    hits: np.ndarray           # (H, 2) int32 (marker, trait) above threshold
    hit_stats: np.ndarray      # (H, 3) float32 (r, t, nlp)
    maf: np.ndarray            # (M,)
    valid: np.ndarray          # (M,) bool
    lambda_gc: float           # genomic control on a null-trait subsample
    omnibus_nlp: np.ndarray | None = None   # (M,) multivariate screen
    excluded_samples: int = 0
    lmm_info: dict | None = None  # mixed-model diagnostics (delta, h2, ...)


class GenomeScan:
    """Orchestrates one full scan over a genotype source."""

    def __init__(
        self,
        source: Any,                     # GenotypeSource protocol (repro.io)
        phenotypes: np.ndarray,          # (N, P) aligned to source samples
        covariates: np.ndarray | None = None,
        *,
        config: ScanConfig = ScanConfig(),
        mesh: Mesh | None = None,
    ):
        self.source = source
        self.config = config
        self.mesh = mesh
        n = source.n_samples
        if phenotypes.shape[0] != n:
            raise ValueError(
                f"phenotypes rows ({phenotypes.shape[0]}) != genotype samples ({n}); "
                "align tables first (repro.io.align_tables)"
            )

        self._keep = np.ones(n, bool)
        self.excluded_samples = 0
        if config.exclude_related:
            from repro.core.kinship import exclude_related

            probe = source.read_dosages(0, min(source.n_markers, 4096)).T
            self._keep, _, _ = exclude_related(probe)
            self.excluded_samples = int((~self._keep).sum())
            phenotypes = phenotypes[self._keep]
            covariates = covariates[self._keep] if covariates is not None else None

        self.n_samples = int(self._keep.sum())
        self.n_traits = phenotypes.shape[1]
        self.engine: ScanEngine = get_engine(config.engine)

        # The trait axis of the 2-D scan grid (DESIGN.md §10).  block_p is
        # the panel-axis compute tile of every engine's step; aligning the
        # scheduling blocks to it is what makes the blocked scan
        # bitwise-identical to the unblocked one.
        self.trait_blocks = TraitBlockPlanner(
            config.trait_block, quantum=config.block_p
        ).plan(self.n_traits)
        if config.multivariate and len(self.trait_blocks) > 1:
            raise ValueError(
                "the multivariate omnibus screen needs the whole panel per "
                "marker (it combines evidence across every trait); run it "
                "unblocked (trait_block=0)"
            )

        self._n_traits_eff = float(self.n_traits)
        self._whitening = None
        self.panels: PanelStore | None = None
        if self.engine.uses_global_panel:
            # OLS panel prep (Eq. 1), amortized once per trait block into a
            # host-side store.  Engines that build their own panel (lmm:
            # rotated per LOCO scope in setup_scan) skip this entirely — no
            # (N, P) device array is ever kept alive.
            self._q = covariate_basis(
                jnp.asarray(covariates) if covariates is not None else None,
                self.n_samples,
            )
            phenotypes = np.asarray(phenotypes)
            self.panels = PanelStore.residualized(
                phenotypes, self._q, self.trait_blocks,
                quantum=config.block_p,
                max_resident=config.panel_resident_blocks,
            )
            self.n_covariates = int(self._q.shape[1]) - 1
            if config.multivariate:
                from repro.core import multivariate as mv

                # unblocked by the check above: block 0 IS the full panel
                y_full = self.panels.device_block(self.trait_blocks[0])
                self._whitening, eig = mv.whiten_panel(y_full)
                self._n_traits_eff = float(mv.effective_tests(eig))
        else:
            self._q = None
            cov = None if covariates is None else np.asarray(covariates)
            self.n_covariates = 0 if cov is None else (1 if cov.ndim == 1 else cov.shape[1])
        self.dof = config.options.dof(self.n_samples, self.n_covariates)
        self._ctx = EngineContext(
            n_samples=self.n_samples,
            n_covariates=self.n_covariates,
            options=config.options,
            mesh=mesh,
            mode=config.mode,
            hit_threshold=config.hit_threshold_nlp,
            maf_min=config.maf_min,
            block_m=config.block_m,
            block_n=config.block_n,
            block_p=config.block_p,
            q_basis=self._q,
            multivariate=config.multivariate,
            n_traits_eff=self._n_traits_eff,
            whitening=self._whitening,
            keep=self._keep,
            excluded_samples=self.excluded_samples,
            trait_blocks=tuple(self.trait_blocks),
            panel_resident_blocks=config.panel_resident_blocks,
            loco=config.loco,
            grm_method=config.grm_method,
            grm_batch_markers=config.grm_batch_markers,
            lmm_delta=config.lmm_delta,
            lmm_epilogue=config.lmm_epilogue,
            io_workers=config.io_workers,
        )
        self.engine.validate(self._ctx)
        # Amortized engine setup (LMM: streamed GRM + eigendecomposition +
        # REML + panel rotation).  Engines may override the scan dof and
        # contribute diagnostics to the result.
        self.lmm_info: dict | None = None
        setup = self.engine.setup_scan(source, np.asarray(phenotypes), covariates, self._ctx)
        if setup:
            self.dof = int(setup.get("dof", self.dof))
            self.lmm_info = setup.get("info")
        self._step = self.engine.build_step(self._ctx)
        self.planner = BatchPlanner(config.batch_markers)
        self.plan = self.planner.plan(source)

    # ------------------------------------------------------------------ grid

    @property
    def n_batches(self) -> int:
        return len(self.plan)

    @property
    def n_trait_blocks(self) -> int:
        return len(self.trait_blocks)

    def _panel_block(self, batch, block: TraitBlock):
        """The trailing step argument for one grid cell: the driver's
        residualized store for OLS engines, the engine's own per-scope
        rotated panel for the rest."""
        if self.engine.uses_global_panel:
            return self.panels.device_block(block)
        return self.engine.panel_block(batch, block)

    # ------------------------------------------------------------------- run

    def _make_sinks(self, ckpt: ScanCheckpoint | None) -> list[ResultSink]:
        sinks: list[ResultSink] = [
            BestTraitSink(self.n_traits),
            HitSink(
                self.config.hit_threshold_nlp,
                spill_dir=self.config.spill_dir,
                spill_rows=self.config.hit_spill_rows,
            ),
            QCSink(self.source.n_markers, multivariate=self.config.multivariate),
            LambdaGCSink(),
        ]
        if ckpt is not None:
            sinks.append(CheckpointSink(ckpt))  # last: persists peers' payload
        return sinks

    def run(self, *, resume: bool = True) -> ScanResult:
        cfg = self.config
        m_total = self.source.n_markers
        blocks = self.trait_blocks
        ckpt: ScanCheckpoint | None = None
        todo = self.plan
        pending: set[tuple[int, int]] | None = None   # (batch, block) cells
        if cfg.checkpoint_dir:
            # Engine state (e.g. the LMM's GRM spectrum hash) is part of the
            # scan identity: resuming against a different GRM or refitted
            # variance components would mix incompatible statistics.
            engine_state = self.engine.state_fingerprint()
            fp = config_fingerprint(
                {
                    **cfg.fingerprint_payload(),
                    "n_markers": m_total,
                    "n_samples": self.n_samples,
                    "n_traits": self.n_traits,
                    # The plan's index->(lo,hi) mapping depends on the shard
                    # layout; resuming against a re-sharded fileset would
                    # silently mix two incompatible batch decompositions.
                    "shard_boundaries": list(
                        getattr(self.source, "shard_boundaries", (0, m_total))
                    ),
                    **({"engine_state": engine_state} if engine_state else {}),
                }
            )
            ckpt = ScanCheckpoint(
                cfg.checkpoint_dir,
                fingerprint=fp,
                n_batches=self.n_batches,
                n_blocks=len(blocks),
            )
            if resume:
                pending = set(ckpt.pending_cells())
                # A marker batch is re-staged iff ANY of its cells is
                # pending; completed cells of a re-staged batch are skipped
                # in the inner loop and replayed from their shards below.
                batches_pending = {b for b, _ in pending}
                todo = [b for b in self.plan if b.index in batches_pending]

        sinks = self._make_sinks(ckpt)
        computed: set[tuple[int, int]] = set()

        prefetched = Prefetcher(
            todo,
            lambda b: self.engine.prepare_batch(self.source, b, self._ctx),
            depth=cfg.prefetch_depth,
            num_workers=cfg.io_workers,
        )

        def stage(host_batch):
            # jnp.asarray launches the copy; on accelerators it completes
            # while the device chews on the previous batch (double buffer).
            return host_batch, tuple(jnp.asarray(a) for a in host_batch.device_args)

        stream = double_buffer(prefetched, stage)
        try:
            for host_batch, dev_args in stream:
                bidx = host_batch.batch.index
                # Trait blocks are the INNER loop: one staged genotype batch
                # feeds every block before the next H2D copy (DESIGN.md §10).
                for blk in blocks:
                    cell = (bidx, blk.index)
                    if pending is not None and cell not in pending:
                        continue
                    out = self._step(*dev_args, self._panel_block(host_batch.batch, blk))
                    view = BatchView(
                        host_batch, out, blk.n_traits,
                        t_lo=blk.lo, block_index=blk.index,
                    )
                    payload: dict[str, np.ndarray] = {}
                    for sink in sinks:
                        sink.on_batch(view, payload)
                    computed.add(cell)
        finally:
            # Error path included: a raising sink or engine step must not
            # leave decode workers alive or the in-flight staged copy pinned.
            stream.close()
            prefetched.shutdown()

        # Resume path: replay committed-but-not-recomputed cells' shards.
        if ckpt is not None:
            for bidx, kidx in sorted(ckpt.completed_cells() - computed):
                shard = ckpt.load_cell(bidx, kidx)
                lo, hi = int(shard["lo"]), int(shard["hi"])
                for sink in sinks:
                    sink.merge_shard(shard, lo, hi)

        fields: dict[str, Any] = {}
        for sink in sinks:
            fields.update(sink.result())
        return ScanResult(
            n_markers=m_total,
            n_samples=self.n_samples,
            n_traits=self.n_traits,
            dof=self.dof,
            excluded_samples=self.excluded_samples,
            lmm_info=self.lmm_info,
            **fields,
        )
