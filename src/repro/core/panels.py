"""Host-resident phenotype panels and trait-axis staging (DESIGN.md §10).

``PanelStore`` owns the residualized panel: host-side float32, tiled on the
trait axis, served as device-resident block slices through a small LRU.
``PanelPrefetcher`` overlaps the *next* trait block's host->device staging
with the current block's device step — the same H2D/compute overlap the
marker axis gets from ``runtime.prefetch.double_buffer``, applied to the
second grid dimension.  Both are engine-agnostic: the lmm engine's
per-(scope, block) rotated panels ride the same prefetcher because its
``DeviceLRU`` is thread-safe too.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.core.engines import DeviceLRU
from repro.core.residualize import residualize_and_standardize
from repro.runtime.prefetch import TraitBlock

__all__ = ["PanelStore", "PanelView", "PanelPrefetcher"]


class PanelView:
    """One device's residency of a shared host panel: a per-executor-slot
    LRU of staged block slices (DESIGN.md §12).

    Each slot of the multi-device executor holds its own view onto the one
    host-side ``PanelStore``, staging blocks with explicit
    ``jax.device_put`` onto its device — the slices themselves are the
    identical host float32 bytes, so every device computes on bit-equal
    panels.  ``device=None`` places on the implicit default device (the
    serial executor's view *is* the store's own LRU, preserving the
    historical single-device behavior exactly).
    """

    def __init__(self, store: "PanelStore", *, device=None, max_resident: int = 4):
        import jax

        self._store = store
        self.device = device
        self._dev = DeviceLRU(            # block index -> staged device array
            max_resident,
            (lambda idx: jnp.asarray(store.host_block(store.blocks[idx])))
            if device is None
            else (lambda idx: jax.device_put(
                store.host_block(store.blocks[idx]), device)),
        )

    def device_block(self, block: TraitBlock):
        """Device array for one block; ``jnp.asarray``/``jax.device_put``
        launch the copy asynchronously, so staging overlaps the previous
        cell's compute."""
        return self._dev.get(block.index)

    def pin_block(self, block: TraitBlock) -> None:
        """Ref-count-pin one staged block against LRU eviction (serve
        keeps a resident study's hot blocks warm across requests)."""
        self._dev.pin(block.index)

    def unpin_block(self, block: TraitBlock) -> None:
        self._dev.unpin(block.index)

    def cache_stats(self) -> dict:
        """Hit/miss/eviction counters of this view's staging LRU — the
        panel-cache observability surfaced in serve metrics."""
        return self._dev.stats()

    def release(self) -> None:
        """Drop every staged block (executor-slot teardown).  The view
        stays usable — the next ``device_block`` restages — but a closed
        scan no longer pins panel blocks on its devices."""
        self._dev.clear()


class PanelStore:
    """Host-resident residualized phenotype panel, tiled on the trait axis.

    The store residualizes + standardizes the panel in fixed ``quantum``-wide
    column chunks on the device (peak device footprint during setup: one
    ``(N, quantum)`` slice, never ``(N, P)``), keeps the float32 results
    host-side, and serves device-resident block slices through a small LRU —
    panels that fit stay resident, paper-scale panels stream.  The chunk
    decomposition is the same regardless of ``trait_block`` (it is the
    compute quantum, not the scheduling block), so blocked and unblocked
    stores hold bitwise-identical panels.  ``device_view`` hands each
    executor slot its own LRU over the same host panel (multi-device
    scans); the store's own ``device_block`` is the default-device view.
    """

    def __init__(self, blocks: list[TraitBlock], panel: np.ndarray,
                 *, max_resident: int = 4):
        self.blocks = list(blocks)
        self._panel = panel               # (N, P) float32, host
        self.max_resident = max_resident
        self._default = PanelView(self, device=None, max_resident=max_resident)
        self._dev = self._default._dev    # block index -> staged device array

    @classmethod
    def residualized(
        cls,
        phenotypes: np.ndarray,
        q_basis: Any,
        blocks: list[TraitBlock],
        *,
        quantum: int,
        max_resident: int = 4,
    ) -> "PanelStore":
        n, p = phenotypes.shape
        panel = np.empty((n, p), np.float32)
        for lo in range(0, p, quantum):
            hi = min(lo + quantum, p)
            chunk = residualize_and_standardize(
                jnp.asarray(phenotypes[:, lo:hi]), q_basis
            )
            panel[:, lo:hi] = np.asarray(chunk.y)
        return cls(blocks, panel, max_resident=max_resident)

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def host_block(self, block: TraitBlock) -> np.ndarray:
        return self._panel[:, block.lo : block.hi]

    def device_block(self, block: TraitBlock) -> Any:
        """Device array for one block on the default device (the serial
        executor's path — see ``PanelView``)."""
        return self._default.device_block(block)

    def cache_stats(self) -> dict:
        """The shared default view's staging-LRU counters."""
        return self._default.cache_stats()

    def device_view(self, device=None, *, max_resident: int | None = None) -> PanelView:
        """A per-executor-slot view staging blocks onto ``device``.

        ``device=None`` returns the store's shared default view (NOT a
        fresh LRU): the serial executor and the trait-axis look-ahead then
        hit one cache, exactly the pre-executor behavior."""
        if device is None:
            return self._default
        return PanelView(
            self, device=device,
            max_resident=self.max_resident if max_resident is None else max_resident,
        )


class PanelPrefetcher:
    """Single-worker look-ahead on the trait axis: stage block b+1 while the
    device chews on block b.

    ``stage`` is whatever serves a grid cell's panel slice (the driver's
    ``PanelStore.device_block`` for OLS engines, the lmm engine's
    ``panel_block``); results land in the underlying thread-safe
    ``DeviceLRU``, so the consumer's own ``stage`` call finds them resident.
    The worker is deliberately best-effort: a staging error is swallowed
    here and surfaces on the consumer's synchronous call for the same
    block.  ``shutdown`` drains and joins — the scan's error path calls it
    from a ``finally`` so a raising sink or step never leaks the thread.
    """

    def __init__(self, stage: Callable[[Any, TraitBlock], Any], *, name: str = "panel-prefetch"):
        self._stage = stage
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._stop = False
        self._worker = threading.Thread(target=self._run, daemon=True, name=name)
        self._worker.start()

    def _run(self) -> None:
        while not self._stop:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is None:
                return
            batch, block = item
            try:
                self._stage(batch, block)
            except Exception:  # noqa: BLE001 — see docstring: best-effort
                pass

    def request(self, batch: Any, block: TraitBlock) -> None:
        """Enqueue one look-ahead staging; drops the request when the worker
        is saturated (falling behind means the device is the bottleneck and
        the synchronous path will stage it anyway)."""
        if self._stop:
            return
        try:
            self._q.put_nowait((batch, block))
        except queue.Full:
            pass

    def shutdown(self, *, join_timeout: float = 5.0) -> None:
        self._stop = True
        try:
            self._q.put_nowait(None)
        except queue.Full:
            pass
        if self._worker.is_alive() and self._worker is not threading.current_thread():
            self._worker.join(timeout=join_timeout)
