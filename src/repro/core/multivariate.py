"""Multivariate phenotype screening (paper abstract: "linear GWAS and
multivariate phenotype screening").

Given the per-batch correlation tile ``R (M, P)`` the engine already
produces, three panel-level screens are provided, all elementwise/reduction
ops over the tile (no extra GEMMs in the scan):

* ``omnibus_chi2``   — ``S_m = N * sum_p r_mp^2``.  If the phenotype panel has
  been *whitened* (decorrelated once, amortized across the scan — the same
  trick the paper uses for residualization), ``S_m ~ chi^2_P`` under the null.
* ``max_abs_t``      — strongest single-trait signal per marker, with a
  Sidak/effective-tests adjusted p-value.
* ``effective_tests``— Li & Ji (2005) eigenvalue-based effective number of
  independent traits, used to calibrate ``max_abs_t``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import stats as _stats

__all__ = [
    "whiten_panel",
    "omnibus_chi2",
    "max_abs_t",
    "effective_tests",
    "MultivariateScreen",
]


class MultivariateScreen(NamedTuple):
    omnibus: jax.Array        # (M,) chi^2_P statistic
    omnibus_nlp: jax.Array    # (M,) -log10 p
    max_t: jax.Array          # (M,) max_p |t|
    max_t_nlp: jax.Array      # (M,) effective-tests-adjusted -log10 p


def whiten_panel(y_std: jax.Array, *, eig_floor: float = 1e-6) -> tuple[jax.Array, jax.Array]:
    """Whitening matrix for a standardized panel: ``W = V diag(lam^-1/2)``
    so that ``Y W`` has identity trait correlation.

    One ``P x P`` eigendecomposition amortized across the whole genome scan
    (the panel is fixed).  Eigenvalues below ``eig_floor * max`` are dropped
    (their directions carry no independent signal).  Returns ``(W,
    eigenvalues)``; the scan keeps per-trait statistics on the *original*
    panel and applies ``W`` to the correlation tile only (``r @ W``), which
    is algebraically identical to correlating against the whitened panel.
    """
    y = jnp.asarray(y_std, jnp.float32)
    n = y.shape[0]
    corr = (y.T @ y) / n
    lam, vec = jnp.linalg.eigh(corr)
    lam = lam[::-1]
    vec = vec[:, ::-1]
    keep = lam > eig_floor * lam[0]
    scale = jnp.where(keep, jax.lax.rsqrt(jnp.maximum(lam, eig_floor)), 0.0)
    w = vec * scale[None, :]
    return w, lam


def omnibus_chi2(
    r_tile: jax.Array,
    n_samples: int,
    n_traits_eff: float,
    whitening: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Panel omnibus: ``S = N * sum_p r_w^2 ~ chi^2_{P_eff}`` where
    ``r_w = r @ W`` decorrelates the traits (pass ``whitening=None`` only if
    the panel was already whitened)."""
    if whitening is not None:
        r_tile = r_tile @ whitening
    s = jnp.asarray(n_samples, jnp.float32) * jnp.sum(jnp.square(r_tile), axis=-1)
    nlp = _stats.neglog10_sf_chi2(s, n_traits_eff)
    return s, nlp


def max_abs_t(
    t_tile: jax.Array, dof: int, n_traits_eff: float
) -> tuple[jax.Array, jax.Array]:
    """Strongest per-marker hit with Sidak correction by the effective test
    count: ``p_adj = 1 - (1 - p_min)^Meff``; in -log10 space use the stable
    ``p_adj ~ Meff * p_min`` for small p (the only regime anyone screens)."""
    tmax = jnp.max(jnp.abs(t_tile), axis=-1)
    nlp = _stats.neglog10_p_from_t(tmax, dof)
    nlp_adj = jnp.maximum(nlp - jnp.log10(jnp.asarray(n_traits_eff, jnp.float32)), 0.0)
    return tmax, nlp_adj


def effective_tests(eigenvalues: jax.Array) -> jax.Array:
    """Li & Ji (2005): ``Meff = sum_i I(lam_i >= 1) + (lam_i - floor(lam_i))``
    over eigenvalues of the trait correlation matrix."""
    lam = jnp.maximum(jnp.asarray(eigenvalues, jnp.float32), 0.0)
    return jnp.sum(jnp.where(lam >= 1.0, 1.0, 0.0) + (lam - jnp.floor(lam)))


def screen(
    r_tile: jax.Array,
    t_tile: jax.Array,
    *,
    n_samples: int,
    dof: int,
    n_traits_eff: float,
) -> MultivariateScreen:
    omni, omni_nlp = omnibus_chi2(r_tile, n_samples, n_traits_eff)
    tmax, tmax_nlp = max_abs_t(t_tile, dof, n_traits_eff)
    return MultivariateScreen(omnibus=omni, omnibus_nlp=omni_nlp, max_t=tmax, max_t_nlp=tmax_nlp)
