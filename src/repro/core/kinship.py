"""Relatedness-aware sample exclusion (paper §4: "the current implementation
already includes relatedness-aware sample exclusion during preprocessing").

KING-robust kinship (Manichaikul et al. 2010):

    phi_ij = (N_AaAa(i,j) - 2 * N_opp(i,j)) / (N_Aa(i) + N_Aa(j))

where ``N_AaAa`` counts markers at which both samples are heterozygous,
``N_opp`` counts opposite homozygotes, and ``N_Aa(i)`` is sample i's
heterozygote count.  All three reduce to indicator GEMMs, so the estimator
shares the framework's batched-GEMM machinery:

    H = [g == 1],  A = [g == 2],  B = [g == 0]          (indicators, N x M)
    N_AaAa = H H^T,   N_opp = A B^T + B A^T             (two GEMMs)

Pruning is the standard greedy maximum-independent-set heuristic on the
relatedness graph (drop the highest-degree sample until no edge remains) —
a small host-side graph problem, device does only the GEMMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["king_kinship", "greedy_unrelated", "exclude_related"]

# KING kinship thresholds: 2^(-d/2 - 1.5) for degree d boundaries.
DEGREE2_THRESHOLD = 0.0884  # exclude pairs closer than 3rd degree


@functools.partial(jax.jit, static_argnames=("batch_markers",))
def _king_accumulate(g: jax.Array, batch_markers: int = 0) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One pass over a genotype block ``(N, M)`` with codes {0,1,2, missing<0}.

    Returns (N_AaAa, N_opp, het_counts).  Missing markers contribute to no
    indicator (their pairwise counts are slightly conservative, matching
    KING's --kinship default behaviour of complete-pair analysis only when
    missingness is low).
    """
    het = (g == 1).astype(jnp.float32)
    hom_alt = (g == 2).astype(jnp.float32)
    hom_ref = (g == 0).astype(jnp.float32)
    n_hh = het @ het.T
    n_opp = hom_alt @ hom_ref.T
    n_opp = n_opp + n_opp.T
    return n_hh, n_opp, jnp.sum(het, axis=1)


def king_kinship(genotypes: np.ndarray, *, block_markers: int = 8192) -> np.ndarray:
    """KING-robust kinship matrix ``(N, N)`` from integer dosages ``(N, M)``.

    Streams marker blocks so the full genotype matrix never needs to be
    resident (same streaming discipline as the GWAS scan).  Missing dosage is
    any value outside {0, 1, 2}.
    """
    g = np.asarray(genotypes)
    n, m = g.shape
    n_hh = np.zeros((n, n), np.float64)
    n_opp = np.zeros((n, n), np.float64)
    het_counts = np.zeros((n,), np.float64)
    for lo in range(0, m, block_markers):
        block = jnp.asarray(g[:, lo : lo + block_markers], jnp.int32)
        hh, opp, het = _king_accumulate(block)
        n_hh += np.asarray(hh, np.float64)
        n_opp += np.asarray(opp, np.float64)
        het_counts += np.asarray(het, np.float64)
    denom = het_counts[:, None] + het_counts[None, :]
    denom = np.maximum(denom, 1.0)
    phi = (n_hh - 2.0 * n_opp) / denom
    np.fill_diagonal(phi, 0.5)
    return phi


def greedy_unrelated(phi: np.ndarray, *, threshold: float = DEGREE2_THRESHOLD) -> np.ndarray:
    """Greedy max-independent-set on the relatedness graph.

    Returns a boolean keep-mask over samples.  Deterministic: ties broken by
    lower index, matching what PLINK's --king-cutoff does in spirit.
    """
    phi = np.asarray(phi)
    n = phi.shape[0]
    adj = (phi > threshold).astype(np.int64)
    np.fill_diagonal(adj, 0)
    keep = np.ones(n, dtype=bool)
    degree = adj.sum(axis=1)
    while True:
        active_deg = np.where(keep, degree, -1)
        worst = int(np.argmax(active_deg))
        if active_deg[worst] <= 0:
            break
        keep[worst] = False
        degree -= adj[worst]
        degree[worst] = 0
    return keep


def exclude_related(
    genotypes: np.ndarray,
    sample_ids: list[str] | None = None,
    *,
    threshold: float = DEGREE2_THRESHOLD,
    block_markers: int = 8192,
) -> tuple[np.ndarray, list[str] | None, np.ndarray]:
    """Preprocessing entry point: estimate kinship, prune related samples.

    Returns ``(keep_mask, kept_ids, phi)``.
    """
    phi = king_kinship(genotypes, block_markers=block_markers)
    keep = greedy_unrelated(phi, threshold=threshold)
    kept_ids = [s for s, k in zip(sample_ids, keep) if k] if sample_ids is not None else None
    return keep, kept_ids, phi
