"""The paper's primary contribution: a phenotype-panel association engine.

Public surface:
    AssocOptions, assoc_batch, assoc_from_standardized  — the kernel (Eq. 2-3)
    covariate_basis, residualize_and_standardize        — Eq. 1
    stats                                               — t/p epilogue, BH, lambda_GC
    multivariate                                        — panel-level screens
    kinship                                             — relatedness exclusion
    grm, lmm                                            — mixed-model wing (streamed GRM,
                                                          REML + one-time rotation)
    screening                                           — the streaming genome-scan driver
"""
from repro.core.association import (
    AssocOptions,
    AssocResult,
    MarkerStats,
    assoc_batch,
    assoc_from_standardized,
    correlation,
    standardize_genotype_batch,
)
from repro.core.residualize import (
    StandardizedPanel,
    covariate_basis,
    residualize_and_standardize,
    residualize_genotypes,
)

__all__ = [
    "AssocOptions",
    "AssocResult",
    "MarkerStats",
    "assoc_batch",
    "assoc_from_standardized",
    "correlation",
    "standardize_genotype_batch",
    "StandardizedPanel",
    "covariate_basis",
    "residualize_and_standardize",
    "residualize_genotypes",
]
