"""Linear mixed model reduction to the panel-correlation epilogue.

Model (per trait):  ``y = X b + g beta + u + e``,  ``u ~ N(0, sg^2 K)``,
``e ~ N(0, se^2 I)``.  With the GRM spectrum ``K = U diag(s) U^T`` and
``delta = se^2 / sg^2``, rotating everything by ``U^T`` diagonalizes the
covariance:  ``Cov(U^T y) = sg^2 diag(s + delta)``.  Scaling rows by
``w^(1/2)``, ``w_i = 1/(s_i + delta)``, then whitens it — after which the
GLS score test for ``beta`` is *exactly* the partial-correlation epilogue
the OLS scan already runs (Eq. 2-3 with ``dof = N - 2 - q``):

    A    = U diag(sqrt(w))                    one-time (N, N) rotation
    Yhat = A^T Y,   Xhat = A^T [1 | C]        amortized once per panel
    Qhat = orth(Xhat)                         whitened covariate basis
    ghat = g_std A  ->  project out Qhat  ->  unit-RMS rows
    r    = ghat Yres / N,  t = r sqrt(dof / (1 - r^2))

This is the same amortize-once trick as residualization/whitening
(Fabregat-Traver & Aulchenko; Peise et al.): the per-marker cost is one
extra (M, N) x (N, N) GEMM, and every downstream stage — epilogue, sinks,
checkpointing — is untouched.

Variance components come from a FaST-LMM-style REML profile over ``delta``
on the rotated null model: for fixed ``delta`` the GLS fit is closed-form
(diagonal weights), so the 1-D profile is a vectorized grid over all traits
at once plus an optional per-trait Brent refine.  One *pooled* ``delta``
(geometric mean over traits) drives the scan rotation so the genotype GEMM
stays shared across the panel; per-trait ``h2`` estimates are reported as
diagnostics.  Exactness therefore holds per trait when traits share their
variance ratio; heterogeneous panels get a calibrated approximation (the
standard panel-LMM trade, see DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "REMLResult",
    "RotatedPanel",
    "reml_grid",
    "fit_variance_components",
    "rotate_panel",
    "whiten_project_standardize",
    "default_delta_grid",
]

_RANK_TOL = 1e-8


def _reduced_design(covariates: np.ndarray | None, n: int) -> np.ndarray:
    """Full-rank design ``[1 | C]`` (float64): collinear covariate columns
    are dropped via pivoted QR so every scope sees the same column set.

    Rank detection runs on *centered, unit-scaled* columns (mirroring
    ``covariate_basis``): otherwise a legitimately independent covariate on
    a tiny absolute scale would fall under a relative threshold set by the
    intercept's norm and be dropped silently.  The returned design keeps
    the original (unscaled) columns — scaling is for detection only.
    """
    from scipy.linalg import qr as _qr

    ones = np.ones((n, 1))
    if covariates is None:
        return ones
    c = np.asarray(covariates, np.float64)
    if c.ndim == 1:
        c = c[:, None]
    x = np.concatenate([ones, c], axis=1)
    c_scaled = c - c.mean(axis=0, keepdims=True)
    c_scaled /= np.maximum(c_scaled.std(axis=0, keepdims=True), 1e-12)
    probe = np.concatenate([ones, c_scaled], axis=1)
    _, r, piv = _qr(probe, mode="economic", pivoting=True)
    diag = np.abs(np.diagonal(r))
    rank = int(np.sum(diag > diag[0] * 1e-6))
    keep = np.sort(piv[:rank])
    return x[:, keep]


def default_delta_grid(n_points: int = 64) -> np.ndarray:
    """Log-spaced ``delta`` grid covering h2 from ~0.999 to ~0.001."""
    return np.logspace(-3.0, 3.0, n_points)


def reml_grid(
    y_rot: np.ndarray,
    x_rot: np.ndarray,
    s: np.ndarray,
    deltas: np.ndarray,
) -> np.ndarray:
    """Restricted log-likelihood profile ``(len(deltas), P)``.

    All traits are evaluated together per grid point: the weighted normal
    matrix ``X^T W X`` and its Cholesky are shared across the panel, so one
    grid point costs O(N k^2 + N k P) regardless of P.
    """
    y = np.asarray(y_rot, np.float64)
    x = np.asarray(x_rot, np.float64)
    s = np.asarray(s, np.float64)
    n, p = y.shape
    k = x.shape[1]
    nk = n - k
    ll = np.empty((len(deltas), p))
    for i, d in enumerate(np.asarray(deltas, np.float64)):
        w = 1.0 / (s + d)
        xw = x * w[:, None]
        xtx = x.T @ xw
        _, logdet_xtx = np.linalg.slogdet(xtx)
        beta = np.linalg.solve(xtx, xw.T @ y)
        resid = y - x @ beta
        rss = np.einsum("np,n,np->p", resid, w, resid)
        rss = np.maximum(rss, 1e-300)
        ll[i] = -0.5 * (
            nk * (np.log(2.0 * np.pi * rss / nk) + 1.0)
            + np.sum(np.log(s + d))
            + logdet_xtx
        )
    return ll


@dataclass
class REMLResult:
    delta: np.ndarray          # (P,) per-trait REML variance ratio se^2/sg^2
    h2: np.ndarray             # (P,) narrow-sense heritability 1/(1+delta)
    sigma_g2: np.ndarray       # (P,) genetic variance at the optimum
    loglik: np.ndarray         # (P,) restricted log-likelihood at the optimum
    delta_pooled: float        # geometric mean of per-trait deltas


def fit_variance_components(
    y_rot: np.ndarray,
    x_rot: np.ndarray,
    s: np.ndarray,
    *,
    deltas: np.ndarray | None = None,
    refine: bool = True,
) -> REMLResult:
    """Per-trait REML over ``delta`` (grid + optional bounded Brent refine),
    all on the rotated null model.  ``s`` is the GRM spectrum."""
    from scipy.optimize import minimize_scalar

    grid = default_delta_grid() if deltas is None else np.asarray(deltas, np.float64)
    y = np.asarray(y_rot, np.float64)
    x = np.asarray(x_rot, np.float64)
    ll = reml_grid(y, x, s, grid)
    best = np.argmax(ll, axis=0)
    p = y.shape[1]
    delta = grid[best].astype(np.float64)
    loglik = ll[best, np.arange(p)]
    if refine:
        log_grid = np.log(grid)
        for t in range(p):
            b = int(best[t])
            lo = log_grid[max(b - 1, 0)]
            hi = log_grid[min(b + 1, len(grid) - 1)]
            if hi - lo < 1e-12:
                continue
            yt = y[:, t : t + 1]
            res = minimize_scalar(
                lambda ld, yt=yt: -reml_grid(yt, x, s, np.exp([ld]))[0, 0],
                bounds=(lo, hi),
                method="bounded",
                options={"xatol": 1e-4},
            )
            if -res.fun > loglik[t]:
                delta[t] = float(np.exp(res.x))
                loglik[t] = -res.fun
    # sigma_g^2 at the optimum (per trait, GLS closed form)
    n, k = x.shape
    sigma_g2 = np.empty(p)
    for t in range(p):
        w = 1.0 / (s + delta[t])
        xw = x * w[:, None]
        beta = np.linalg.solve(x.T @ xw, xw.T @ y[:, t])
        resid = y[:, t] - x @ beta
        sigma_g2[t] = float(np.sum(w * resid * resid) / (n - k))
    return REMLResult(
        delta=delta,
        h2=1.0 / (1.0 + delta),
        sigma_g2=sigma_g2,
        loglik=loglik,
        delta_pooled=float(np.exp(np.mean(np.log(np.clip(delta, 1e-6, 1e6))))),
    )


@dataclass
class RotatedPanel:
    """Everything the scan needs for one LMM scope (global or one LOCO
    chromosome), amortized once.

    The whitened panel ``y`` lives host-side in float32; the blocked scan
    (DESIGN.md §10) ships ``y_block`` slices to the device on demand, so
    device residency is bounded by the trait-block width, not the panel.
    The float64 whitening itself runs panel-wide at setup: the global REML
    fit materializes the rotated panel anyway, and BLAS float64 GEMMs are
    not column-partition-invariant, so re-deriving blocks independently
    would break the blocked == unblocked bitwise contract.
    """

    rotation: np.ndarray       # (N, N) float32  A = U diag(sqrt(w))
    qhat: np.ndarray           # (N, k) float32 orthonormal whitened design basis
    y: np.ndarray              # (N, P) float32 projected, unit-RMS panel
    trait_valid: np.ndarray    # (P,) bool — residual variance survived
    n_covariates: int          # k - 1 (intercept excluded, matching ScanConfig)
    dof: int                   # N - 2 - n_covariates
    delta: float               # pooled variance ratio driving the rotation
    reml: REMLResult | None    # per-trait fits (None when delta was pinned)

    def y_block(self, lo: int, hi: int) -> np.ndarray:
        """The whitened panel restricted to one trait block ``[lo, hi)`` —
        what a grid cell's device step consumes."""
        return self.y[:, lo:hi]


def whiten_project_standardize(
    y_rot: np.ndarray,
    w_sqrt: np.ndarray,
    qhat: np.ndarray,
    *,
    var_tol: float = 1e-10,
) -> tuple[np.ndarray, np.ndarray]:
    """The whitening stage of the rotation, on an already-rotated panel (or
    a trait block of one): scale rows by ``w^(1/2)``, project the whitened
    design basis out, rescale columns to unit RMS.  Returns ``(y_std,
    trait_valid)``.  The scaling and standardization are column-wise; the
    projection is one small GEMM against ``qhat``."""
    y_hat = y_rot * w_sqrt[:, None]
    y_res = y_hat - qhat @ (qhat.T @ y_hat)
    var = np.mean(np.square(y_res), axis=0)
    trait_valid = var > var_tol
    inv = np.where(trait_valid, 1.0 / np.sqrt(np.maximum(var, var_tol)), 0.0)
    return y_res * inv[None, :], trait_valid


def _orthonormal_basis(mat: np.ndarray, *, rank_tol: float = 1e-7) -> np.ndarray:
    """Orthonormal basis of span(mat) with rank detection; zero columns for
    dropped directions (harmless in the projector, mirrors covariate_basis)."""
    m = np.asarray(mat, np.float64)
    norms = np.maximum(np.linalg.norm(m, axis=0), 1e-30)
    q, r = np.linalg.qr(m / norms)
    diag = np.abs(np.diagonal(r))
    keep = diag > rank_tol * max(float(diag.max()), 1e-30)
    return q * keep[None, :]


def rotate_panel(
    phenotypes: np.ndarray,
    covariates: np.ndarray | None,
    s: np.ndarray,
    u: np.ndarray,
    *,
    delta: float | None = None,
    reml_deltas: np.ndarray | None = None,
    refine: bool = True,
    var_tol: float = 1e-10,
) -> RotatedPanel:
    """One-time panel preparation for an LMM scope.

    Rotates phenotypes and the ``[1 | C]`` design into the GRM eigenbasis,
    fits (or accepts) the variance ratio, whitens by ``diag(sqrt(w))``,
    projects the whitened design out of the panel, and rescales columns to
    unit RMS — leaving ``Y`` in exactly the shape the correlation epilogue
    expects.  ``delta`` pins the variance ratio (skips REML).
    """
    y = np.asarray(phenotypes, np.float64)
    n, p = y.shape
    if u.shape != (n, n):
        raise ValueError(f"eigenvector matrix {u.shape} != ({n}, {n})")
    x = _reduced_design(covariates, n)
    k = x.shape[1]

    y_rot = u.T @ y
    x_rot = u.T @ x

    reml: REMLResult | None = None
    if delta is None:
        reml = fit_variance_components(
            y_rot, x_rot, s, deltas=reml_deltas, refine=refine
        )
        delta_used = reml.delta_pooled
    else:
        delta_used = float(delta)

    w_sqrt = 1.0 / np.sqrt(np.asarray(s, np.float64) + delta_used)
    rotation = u * w_sqrt[None, :]            # A = U diag(sqrt(w)); ghat = g_std @ A
    x_hat = x_rot * w_sqrt[:, None]
    qhat = _orthonormal_basis(x_hat)
    y_std, trait_valid = whiten_project_standardize(
        y_rot, w_sqrt, qhat, var_tol=var_tol
    )

    return RotatedPanel(
        rotation=rotation.astype(np.float32),
        qhat=qhat.astype(np.float32),
        y=y_std.astype(np.float32),
        trait_valid=trait_valid,
        n_covariates=k - 1,
        dof=n - 1 - k,
        delta=delta_used,
        reml=reml,
    )
