"""The TorchGWAS association kernel (paper §2.2) as a composable JAX module.

The hot path is one GEMM per genotype batch:

    R = G_std @ Y_std / N          (Eq. 2)   G_std: (M, N), Y_std: (N, P)
    T = R * sqrt(dof / (1 - R^2))  (Eq. 3)
    p = two-sided t tail           (core.stats, log-space)

Everything is a pure function of arrays so it jits/shards cleanly.  The
distribution contract (see launch/mesh.py):

    marker-sharded mode ("mp"):   G: P(('pod','data'), None)   Y: P(None, 'model')
                                  R/T/p: P(('pod','data'), 'model')  — no collectives
    sample-sharded mode ("sample"): G: P(None, ('pod','data'))  Y: P(('pod','data'), 'model')
                                  R: psum over 'data' (XLA inserts the all-reduce)

Precision ladder (paper-faithful first):
    "fp32"  — float32 inputs, HIGHEST precision dot (paper: cuBLAS fp32)
    "bf16"  — bfloat16 inputs, float32 accumulation (TPU MXU native; beyond-paper)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import stats as _stats

__all__ = [
    "AssocOptions",
    "MarkerStats",
    "AssocResult",
    "SparseEpilogue",
    "standardize_genotype_batch",
    "correlation",
    "assoc_from_standardized",
    "assoc_batch",
    "plan_sparse_epilogue",
    "sparse_epilogue_outputs",
]


@dataclasses.dataclass(frozen=True)
class AssocOptions:
    """Options for the association engine.

    dof_mode: "paper" uses N-2 (Eq. 3 as published); "exact" uses N-2-q and
        implies genotype residualization (Frisch-Waugh-Lovell) so the result
        equals full covariate-adjusted OLS.
    precision: "fp32" | "bf16" (see module docstring).
    eps: clamp for 1 - r^2.
    compute_neglog10p: skip the (elementwise but special-function-heavy)
        p-value epilogue when only |T| ranking is needed.
    sparse_epilogue: sparse p-value mode (DESIGN.md §13): skip the full
        (M, P) -log10 p tile — the caller screens on t^2 and refines only
        past-threshold lanes through ``sparse_epilogue_outputs``.  Implies
        the nlp tile of ``AssocResult`` is zeros, like
        ``compute_neglog10p=False``.
    """

    dof_mode: str = "paper"
    precision: str = "fp32"
    eps: float = 1e-12
    compute_neglog10p: bool = True
    sparse_epilogue: bool = False

    def __post_init__(self) -> None:
        if self.dof_mode not in ("paper", "exact"):
            raise ValueError(f"unknown dof_mode: {self.dof_mode!r}")
        if self.precision not in ("fp32", "bf16"):
            raise ValueError(f"unknown precision: {self.precision!r}")

    def dof(self, n_samples: int, n_covariates: int) -> int:
        if self.dof_mode == "paper":
            return n_samples - 2
        return n_samples - 2 - n_covariates


class MarkerStats(NamedTuple):
    """Per-marker summary statistics from standardization."""

    mean: jax.Array       # (M,) dosage mean over non-missing samples
    inv_std: jax.Array    # (M,) 1/population-std of the imputed dosage; 0 if monomorphic
    maf: jax.Array        # (M,) minor-allele frequency
    n_missing: jax.Array  # (M,) int32
    valid: jax.Array      # (M,) bool — polymorphic and not all-missing


class AssocResult(NamedTuple):
    r: jax.Array            # (M, P) correlation
    t: jax.Array            # (M, P) t statistic
    neglog10p: jax.Array    # (M, P) two-sided -log10 p (zeros if disabled)


def standardize_genotype_batch(
    g_raw: jax.Array,
    *,
    missing_value: float = -9.0,
    var_tol: float = 1e-10,
) -> tuple[jax.Array, MarkerStats]:
    """Standardize a dosage batch ``(M, N)``; missing entries are mean-imputed.

    ``missing_value`` marks missing dosages (NaN also works).  The imputed
    value is the per-marker mean, which becomes exactly 0 after
    standardization — this is what lets the fused 2-bit kernel map the
    missing code straight to 0.
    """
    g = jnp.asarray(g_raw, jnp.float32)
    missing = jnp.isnan(g) | (g == missing_value)
    present = ~missing
    n_present = jnp.maximum(jnp.sum(present, axis=1), 1)
    mean = jnp.sum(jnp.where(present, g, 0.0), axis=1) / n_present
    g_imp = jnp.where(present, g, mean[:, None])
    var = jnp.mean(jnp.square(g_imp - mean[:, None]), axis=1)
    valid = (var > var_tol) & (jnp.sum(present, axis=1) > 0)
    inv_std = jnp.where(valid, jax.lax.rsqrt(jnp.maximum(var, var_tol)), 0.0)
    g_std = (g_imp - mean[:, None]) * inv_std[:, None]
    af = mean / 2.0
    maf = jnp.minimum(af, 1.0 - af)
    return g_std, MarkerStats(
        mean=mean,
        inv_std=inv_std,
        maf=maf,
        n_missing=jnp.sum(missing, axis=1).astype(jnp.int32),
        valid=valid,
    )


def correlation(
    g_std: jax.Array,
    y_std: jax.Array,
    n_samples: int | jax.Array,
    *,
    precision: str = "fp32",
    trait_tile: int | None = None,
) -> jax.Array:
    """Paper Eq. (2): ``R = G Y / N`` with an explicit precision contract.

    ``trait_tile`` fixes the panel-axis compute tile: the GEMM is evaluated
    in ``trait_tile``-wide column chunks (last chunk ragged) instead of one
    panel-wide dot.  This is the same discipline the fused Pallas kernel
    applies with ``block_p``, and it is what makes the blocked 2-D scan grid
    bitwise-identical to the unblocked scan (DESIGN.md §10): BLAS/XLA GEMM
    micro-kernels group accumulators differently per output width, so the
    only way two decompositions of the trait axis agree bitwise is to run
    the *same* fixed-width tiles in both.  ``None`` keeps the single-dot
    behavior (standalone use; the scan always passes its ``block_p``).
    """
    if precision == "bf16":
        g_std = g_std.astype(jnp.bfloat16)
        y_std = y_std.astype(jnp.bfloat16)
        dot_precision = jax.lax.Precision.DEFAULT
    else:
        dot_precision = jax.lax.Precision.HIGHEST

    def dot(y_cols: jax.Array) -> jax.Array:
        return jax.lax.dot_general(
            g_std,
            y_cols,
            (((1,), (0,)), ((), ())),
            precision=dot_precision,
            preferred_element_type=jnp.float32,
        )

    p = y_std.shape[1]
    if trait_tile is not None and 0 < trait_tile < p:
        r = jnp.concatenate(
            [dot(y_std[:, i : i + trait_tile]) for i in range(0, p, trait_tile)],
            axis=1,
        )
    else:
        r = dot(y_std)
    return r / jnp.asarray(n_samples, jnp.float32)


def assoc_from_standardized(
    g_std: jax.Array,
    y_std: jax.Array,
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions = AssocOptions(),
    trait_tile: int | None = None,
) -> AssocResult:
    """Association statistics from pre-standardized inputs (both zero-mean,
    unit population variance).  This is the function the distributed scan
    jits; shapes ``(M, N) x (N, P) -> (M, P)``.  ``trait_tile`` — see
    ``correlation``."""
    r = correlation(
        g_std, y_std, n_samples, precision=options.precision, trait_tile=trait_tile
    )
    # Guard: standardization guarantees |r| <= 1 up to rounding; clamp so the
    # epilogue stays finite even for degenerate columns.
    r = jnp.clip(r, -1.0, 1.0)
    dof = options.dof(n_samples, n_covariates)
    t = _stats.t_from_r(r, dof, eps=options.eps)
    if options.compute_neglog10p and not options.sparse_epilogue:
        nlp = _stats.neglog10_p_from_t(t, dof)
    else:
        nlp = jnp.zeros_like(t)
    return AssocResult(r=r, t=t, neglog10p=nlp)


def assoc_batch(
    g_raw: jax.Array,
    y_std: jax.Array,
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions = AssocOptions(),
    q_basis: jax.Array | None = None,
    missing_value: float = -9.0,
) -> tuple[AssocResult, MarkerStats]:
    """End-to-end batch path from raw dosages: standardize -> (optionally
    FWL-residualize) -> correlate -> epilogue.

    ``q_basis`` is required when ``options.dof_mode == "exact"``.
    """
    g_std, marker_stats = standardize_genotype_batch(g_raw, missing_value=missing_value)
    if options.dof_mode == "exact":
        if q_basis is None:
            raise ValueError("exact mode requires the covariate basis q_basis")
        from repro.core.residualize import residualize_genotypes

        g_std = residualize_genotypes(g_std, q_basis)
    res = assoc_from_standardized(
        g_std,
        y_std,
        n_samples=n_samples,
        n_covariates=n_covariates,
        options=options,
    )
    # Invalid (monomorphic / all-missing) markers: r=t=0, p=1.
    mask = marker_stats.valid[:, None]
    res = AssocResult(
        r=jnp.where(mask, res.r, 0.0),
        t=jnp.where(mask, res.t, 0.0),
        neglog10p=jnp.where(mask, res.neglog10p, 0.0),
    )
    return res, marker_stats


# ----------------------------------------------------- sparse p-value epilogue
#
# DESIGN.md §13.  The 128-trip Lentz continued fraction in
# ``stats.neglog10_p_from_t`` dominated the full scan (BENCH_scan.json
# measured a 0.94-0.99 epilogue share) because it ran over every lane of
# every (M, P) tile.  For fixed dof, -log10 p is strictly monotone in t^2,
# so the epilogue only needs the CF on (a) the per-trait t^2 winner and
# (b) the lanes past a conservative t^2 screen — O(P + hits) evaluations
# instead of O(M*P), with bitwise-identical results.


@dataclasses.dataclass(frozen=True)
class SparseEpilogue:
    """Per-scan compile-time constants of the sparse p-value epilogue.

    ``t2_screen`` is the conservative inverse of the hit threshold
    (``stats.t2_screen_threshold``); ``capacity`` the static size of the
    compacted device buffer (jit shapes stay fixed — past-capacity cells
    overflow to the host fallback in ``core.sinks.extract_hits``).
    """

    threshold_nlp: float
    t2_screen: float
    capacity: int


def plan_sparse_epilogue(
    threshold_nlp: float,
    dof: float,
    *,
    capacity: int = 4096,
    cell_area: int | None = None,
) -> SparseEpilogue | None:
    """Resolve the sparse-epilogue constants for one scan, or ``None`` when
    screening cannot help (threshold at/below the inversion margin, or a
    non-positive dof).  ``cell_area`` clamps the compacted buffer at the
    grid-cell extent — a buffer wider than the tile it compacts is waste.
    """
    t2 = _stats.t2_screen_threshold(float(threshold_nlp), float(dof))
    if t2 is None or not (t2 > 0.0):
        return None
    cap = int(capacity)
    if cell_area is not None:
        cap = min(cap, int(cell_area))
    # Round up to a multiple of the canonical refine chunk width so the
    # compacted buffer's slot layout chunks evenly — a survivor then lands
    # in the same (REFINE_WIDTH,) chunk slot whether it came off the
    # device compact buffer or the host survivor gather (DESIGN.md §13).
    w = _stats.REFINE_WIDTH
    cap = max(w, -(-cap // w) * w)
    return SparseEpilogue(float(threshold_nlp), float(t2), cap)


def sparse_epilogue_outputs(
    r: jax.Array,
    t: jax.Array,
    dof: float,
    plan: SparseEpilogue,
    *,
    screen: tuple[jax.Array, jax.Array] | None = None,
) -> dict[str, jax.Array]:
    """Screen one masked (M, P) statistic tile on t^2 and compact survivors.

    Inputs must be the *masked* r/t tiles (invalid lanes zeroed) so masked
    lanes never pass the screen.  No CF runs here at all: the exact-tail
    refine happens host-side through the canonical per-(shape, dof)
    executables (``stats.refine_neglog10p``) so the sparse, dense-audit,
    and overflow paths all evaluate -log10 p in one compiled program —
    in-step CF bits are fusion-context-sensitive and would break the
    bitwise contract (DESIGN.md §13).  Returns the sparse step outputs:

        batch_best_row   (P,) int32 — argmax over t^2 (first index on ties;
                         identical to argmax over the nlp tile because nlp
                         is a monotone function of t^2 — the §13 contract)
        batch_best_t     (P,) f32 — winner t, refined host-side
        hit_idx          (capacity,) int32 — row-major flat indices of
                         screened lanes in first-K order (matches the dense
                         path's np.nonzero order), -1 padded
        hit_r/hit_t      (capacity,) f32 — gathered stats; 0 in padding
        screen_count     () int32 — total screened lanes; > capacity means
                         the buffer overflowed (host fallback)

    ``screen`` optionally supplies ``(hit_idx, screen_count)`` from a fused
    kernel (``kernels.tstat.screen_compact``) instead of the XLA
    nonzero-gather; the compaction layout is identical either way.
    """
    del dof  # the refine is host-side now; kept for call-site symmetry
    t2 = jnp.square(t)
    # argmax over the transposed tile: per-trait reductions then run along
    # contiguous memory (~1.7x faster on XLA CPU) and the result is the same
    # int32 — argmax keeps first-occurrence ties along the marker axis in
    # either layout.
    best_row = jnp.argmax(t2.T, axis=1).astype(jnp.int32)
    best_t = jnp.take_along_axis(t, best_row[None, :], axis=0)[0]
    if screen is None:
        keep = t2.ravel() >= plan.t2_screen
        screen_count = jnp.sum(keep).astype(jnp.int32)
        # nonzero lowers to a full-length serial cumsum on XLA CPU — by far
        # the most expensive op in the epilogue.  Almost every tile of a
        # genome scan has zero survivors, so gate the compaction on the cheap
        # reduction: the empty branch emits exactly what nonzero(fill_value=-1)
        # would (all -1), so emitted bits are unchanged in every case.
        idx = jax.lax.cond(
            screen_count > 0,
            lambda: jnp.nonzero(keep, size=plan.capacity, fill_value=-1)[0].astype(
                jnp.int32
            ),
            lambda: jnp.full((plan.capacity,), -1, jnp.int32),
        )
    else:
        idx, screen_count = screen
    slot = idx >= 0
    safe = jnp.maximum(idx, 0)
    hit_t = jnp.where(slot, t.ravel()[safe], 0.0)
    hit_r = jnp.where(slot, r.ravel()[safe], 0.0)
    return {
        "batch_best_row": best_row,
        "batch_best_t": best_t,
        "hit_idx": idx,
        "hit_r": hit_r,
        "hit_t": hit_t,
        "screen_count": screen_count,
    }
