"""Covariate handling: orthonormal basis construction and panel residualization.

Implements paper Eq. (1):  ``Y_res = (I - Q Q^T)(Y - Ybar)`` with ``Q`` an
orthonormal basis spanning the covariate space, followed by column-wise
standardization to unit (population) variance.

Design choices (documented in DESIGN.md §8):

* ``Q`` always includes the intercept column, so mean-centering and
  residualization are a single projection.  ``Q`` comes from a reduced QR of
  the ``[1 | C]`` matrix with rank detection (collinear covariates are
  dropped, matching what LAPACK-based tools do silently).
* Standardization uses the population variance (``ddof=0``) so that the
  downstream ``R = G Y / N`` is *exactly* the Pearson correlation of the
  residualized data.
* ``exact`` mode residualizes the genotype batch with the same ``Q``
  (Frisch-Waugh-Lovell), making the t statistic identical to the full
  per-trait OLS with covariates.  The paper's release residualizes Y only;
  both modes ship, the paper's is the default.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "covariate_basis",
    "residualize_and_standardize",
    "residualize_genotypes",
    "StandardizedPanel",
]


class StandardizedPanel(NamedTuple):
    """Residualized + standardized phenotype panel ready for the scan."""

    y: jax.Array          # (N, P) float32, zero mean, unit population variance
    valid: jax.Array      # (P,) bool — False where the residual variance was ~0
    n_samples: int
    n_covariates: int     # columns of Q *excluding* the intercept


def covariate_basis(
    covariates: jax.Array | None,
    n_samples: int,
    *,
    rank_tol: float = 1e-5,
) -> jax.Array:
    """Orthonormal basis ``Q (N, q+1)`` of ``span([1 | C])``.

    Covariates are centered and scaled to unit variance first (the span is
    unchanged once the intercept is present, and the QR diagonal becomes a
    meaningful relative rank signal in float32).  Rank-deficient (collinear)
    columns are zeroed out of the basis: zero columns in Q are harmless in
    the projection ``Q Q^T``.  ``rank_tol=1e-5`` matches f32 QR roundoff for
    exactly-collinear inputs.
    """
    ones = jnp.ones((n_samples, 1), jnp.float32)
    if covariates is None:
        mat = ones
    else:
        cov = jnp.asarray(covariates, jnp.float32)
        if cov.ndim == 1:
            cov = cov[:, None]
        cov = cov - jnp.mean(cov, axis=0, keepdims=True)
        std = jnp.std(cov, axis=0, keepdims=True)
        cov = cov / jnp.maximum(std, 1e-12)
        mat = jnp.concatenate([ones, cov], axis=1)
    q, r = jnp.linalg.qr(mat, mode="reduced")
    diag = jnp.abs(jnp.diagonal(r))
    keep = diag > rank_tol * jnp.max(diag)
    return q * keep[None, :].astype(q.dtype)


def _project_out(x: jax.Array, q: jax.Array) -> jax.Array:
    """``(I - Q Q^T) x`` without materializing the N x N projector."""
    return x - q @ (q.T @ x)


def residualize_and_standardize(
    y: jax.Array,
    q: jax.Array,
    *,
    var_tol: float = 1e-10,
) -> StandardizedPanel:
    """Paper Eq. (1) + column standardization.

    Returns the standardized panel and a validity mask for phenotypes whose
    residual variance collapsed (constant columns, or columns exactly in the
    covariate span).  Invalid columns are zeroed so they contribute r = 0.
    """
    y = jnp.asarray(y, jnp.float32)
    n = y.shape[0]
    y_res = _project_out(y, q)
    # Population variance of the residuals (they are mean-zero by construction
    # because Q contains the intercept).
    var = jnp.mean(jnp.square(y_res), axis=0)
    valid = var > var_tol
    inv_std = jnp.where(valid, jax.lax.rsqrt(jnp.maximum(var, var_tol)), 0.0)
    y_std = y_res * inv_std[None, :]
    return StandardizedPanel(
        y=y_std,
        valid=valid,
        n_samples=n,
        n_covariates=int(q.shape[1]) - 1,
    )


def residualize_genotypes(g_std: jax.Array, q: jax.Array, *, var_tol: float = 1e-10) -> jax.Array:
    """FWL 'exact' mode: project covariates out of a standardized genotype
    batch ``(M, N)`` and re-standardize rows.

    After this, ``R = G Y / N`` with the exact dof ``N - 2 - q`` reproduces
    full covariate-adjusted OLS t statistics (validated in
    ``tests/test_residualize.py`` against a direct lstsq fit).
    """
    g = jnp.asarray(g_std, jnp.float32)
    g_res = (g - (g @ q) @ q.T)
    var = jnp.mean(jnp.square(g_res), axis=1)
    valid = var > var_tol
    inv_std = jnp.where(valid, jax.lax.rsqrt(jnp.maximum(var, var_tol)), 0.0)
    return g_res * inv_std[:, None]
