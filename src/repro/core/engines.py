"""Pluggable scan engines: device step construction + host batch preparation.

An engine owns both halves of one batch's journey (DESIGN.md §2):

    host side    ``prepare_batch``  — read from the genotype source, decode /
                 repack / compute marker stats on a prefetch worker thread,
                 returning a ``HostBatch`` of device-ready ndarrays
    device side  ``build_step``     — a jit'd (optionally sharded) callable
                 mapping those arrays + the trait panel to summary tiles

``GenomeScan`` resolves engines by name through the registry and never
branches on engine identity — new engines (e.g. an int8 dequant GEMM or a
mixed-precision screen) plug in with ``@register_engine`` and a config
string, touching no driver code.

``build_dense_step`` / ``build_fused_step`` remain importable (also re-
exported from ``core.screening``) for tests and external harnesses.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import stats as _stats
from repro.core.association import (
    AssocOptions,
    assoc_from_standardized,
    plan_sparse_epilogue,
    sparse_epilogue_outputs,
    standardize_genotype_batch,
)
from repro.runtime.compat import shard_map
from repro.runtime.prefetch import MarkerBatch, TraitBlock
from repro.runtime.sharding import batch_axes, gwas_shardings

__all__ = [
    "EngineContext",
    "EngineDeviceState",
    "HostBatch",
    "ScanEngine",
    "DeviceLRU",
    "DenseEngine",
    "FusedEngine",
    "LMMEngine",
    "register_engine",
    "get_engine",
    "available_engines",
    "build_dense_step",
    "build_fused_step",
    "build_lmm_step",
]


class DeviceLRU:
    """Small keyed cache of device-staged arrays with LRU eviction.

    One idiom, four users (the driver's ``PanelStore`` blocks, the lmm
    engine's per-(scope, block) panels and per-scope rotation pairs, the
    serve registry's warm executor slots): stage through ``loader`` on
    miss, refresh recency on hit, evict the least recently used entry past
    ``capacity``.  ``on_evict`` lets dependent caches cascade (a LOCO
    scope's panel blocks die with its rotation).  Thread-safe: loaders may
    be reached from prefetch workers.

    ``pin``/``unpin`` hold a ref-count per key: pinned entries are never
    chosen for eviction (capacity may be transiently exceeded while every
    resident entry is pinned), which is what lets a long-lived serve
    request keep its device state resident while other requests churn the
    cache.  Hit/miss/eviction counters feed the serve cache-hit-rate
    observability and cost nothing on the scan hot path.
    """

    def __init__(self, capacity: int, loader: Callable[[Any], Any],
                 *, on_evict: Callable[[Any], None] | None = None):
        self.capacity = max(1, capacity)
        self._loader = loader
        self._on_evict = on_evict
        self._data: dict[Any, Any] = {}
        self._pins: dict[Any, int] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Any) -> Any:
        with self._lock:
            if key in self._data:
                self.hits += 1
                self._data[key] = self._data.pop(key)  # refresh recency
            else:
                self.misses += 1
                while len(self._data) >= self.capacity:
                    gone = next(
                        (k for k in self._data if k not in self._pins), None
                    )
                    if gone is None:
                        break  # everything resident is pinned: overshoot
                    self._data.pop(gone)
                    self.evictions += 1
                    if self._on_evict is not None:
                        self._on_evict(gone)
                self._data[key] = self._loader(key)
            return self._data[key]

    def pin(self, key: Any) -> None:
        """Hold ``key`` resident (ref-counted): eviction skips it until the
        matching ``unpin``.  Pinning a not-yet-loaded key is allowed — the
        pin protects the entry the next ``get`` stages."""
        with self._lock:
            self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key: Any) -> None:
        with self._lock:
            if key not in self._pins:
                raise KeyError(f"unpin of {key!r} without a matching pin")
            n = self._pins[key] - 1
            if n <= 0:
                del self._pins[key]
            else:
                self._pins[key] = n

    def pinned(self, key: Any) -> bool:
        with self._lock:
            return key in self._pins

    @property
    def n_pinned(self) -> int:
        return len(self._pins)

    def stats(self) -> dict:
        """Counter snapshot for cache observability (serve metrics)."""
        with self._lock:
            total = self.hits + self.misses
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "resident": len(self._data),
                "pinned": len(self._pins),
                "hit_rate": round(self.hits / total, 4) if total else None,
            }

    def drop_if(self, pred: Callable[[Any], bool]) -> None:
        with self._lock:
            for key in [k for k in self._data if pred(k)]:
                self._data.pop(key)

    def clear(self) -> None:
        """Drop every staged entry (cascading through ``on_evict``) —
        executor-slot teardown, so a closed scan pins no device blocks.
        Deliberately ignores pins: teardown outranks residency, and the
        pin table is cleared with the data."""
        with self._lock:
            for key in list(self._data):
                self._data.pop(key)
                if self._on_evict is not None:
                    self._on_evict(key)
            self._pins.clear()

    def __len__(self) -> int:
        return len(self._data)


@dataclass
class EngineContext:
    """Everything an engine needs, assembled once per scan by the driver."""

    n_samples: int                     # after relatedness exclusion
    n_covariates: int
    options: AssocOptions
    mesh: Mesh | None = None
    mode: str = "mp"
    hit_threshold: float = 7.301
    maf_min: float = 0.0
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256
    q_basis: jax.Array | None = None
    multivariate: bool = False
    n_traits_eff: float = 1.0
    whitening: jax.Array | None = None
    keep: np.ndarray | None = None     # host-side sample mask (None: keep all)
    excluded_samples: int = 0
    # the trait axis of the 2-D scan grid (DESIGN.md §10): the planned
    # blocks, and how many panel blocks an engine may keep device-resident
    trait_blocks: tuple[TraitBlock, ...] = ()
    panel_resident_blocks: int = 4
    # fused kernel GEMM input dtype ("fp32" | "bf16"); the epilogue (t,
    # -log10 p, argmax) always runs fp32 regardless (tests/test_oracle.py
    # bf16 audit)
    input_dtype: str = "fp32"
    # mixed-model knobs (consumed by the lmm engine only)
    loco: bool = False
    grm_method: str = "std"
    grm_batch_markers: int = 4096
    lmm_delta: float | None = None
    lmm_epilogue: str = "dense"
    io_workers: int = 2
    # sparse p-value epilogue (DESIGN.md §13): screen on t^2, exact-CF
    # refine only winners + past-threshold lanes.  Results are bitwise-
    # identical to the dense CF path; engines silently fall back to dense
    # under a sharding mesh (data-dependent gathers don't shard).
    sparse_epilogue: bool = False
    hit_capacity: int = 4096
    # H2D staging currency (DESIGN.md §17): "dense" stages decoded float32
    # (the historical path), "packed" stages raw PLINK 2-bit bytes and
    # decodes on device — ~16x less H2D traffic, bitwise-identical results.
    # Drivers resolve "auto"/"packed" via ``resolve_genotype_staging``
    # before building the context; engines trust the resolved value.
    genotype_staging: str = "dense"


GENOTYPE_STAGINGS = ("auto", "packed", "dense")


def resolve_genotype_staging(
    requested: str,
    source: Any,
    *,
    excluded_samples: int = 0,
    mesh: Mesh | None = None,
) -> str:
    """Negotiate the staging currency per source (DESIGN.md §17).

    "auto" picks packed whenever it is exactly equivalent and actually
    cheaper: the source speaks native 2-bit bytes (PlinkBed, MultiFileSource
    of beds — numpy/BGEN fall back decoded, unchanged), no host-side sample
    subsetting (relatedness exclusion slices the decoded matrix before
    staging), and no sharding mesh (staged shardings are declared over the
    decoded layout).  Explicit "packed" raises instead of silently falling
    back; "dense" is always honored.
    """
    if requested not in GENOTYPE_STAGINGS:
        raise ValueError(
            f"unknown genotype staging {requested!r}; expected one of {GENOTYPE_STAGINGS}"
        )
    if requested == "dense":
        return "dense"
    blockers = []
    if not getattr(source, "supports_packed", False):
        blockers.append(
            f"{type(source).__name__} has no native 2-bit layout"
        )
    if excluded_samples:
        blockers.append("relatedness exclusion subsets samples on host")
    if mesh is not None:
        blockers.append("sharding mesh stages the decoded layout")
    if not blockers:
        return "packed"
    if requested == "packed":
        raise ValueError(
            "genotype_staging='packed' unavailable: " + "; ".join(blockers)
        )
    return "dense"


@dataclass
class HostBatch:
    """Host-prepared batch: positional device args for the engine's step,
    plus any marker stats already known on the host (fused path) so sinks
    need not pull them back from the device."""

    batch: MarkerBatch
    device_args: tuple[np.ndarray, ...]
    host_maf: np.ndarray | None = None     # (m_batch,) observed MAF
    host_valid: np.ndarray | None = None   # (m_batch,) bool


class EngineDeviceState:
    """Everything an engine stages onto ONE device — an executor slot.

    The multi-device grid executor (DESIGN.md §12) gives every device its
    own slot: a compiled step, the H2D placement of each claimed batch's
    arrays, and whatever device caches the engine keeps (the lmm engine's
    per-scope rotation pair and per-(scope, block) rotated panels live in
    its subclass).  The serial executor is the degenerate single slot with
    ``device=None`` — placement then falls back to ``jnp.asarray`` on the
    implicit default device, the historical behavior bit for bit.

    Host-side amortized state (the residualized panel, GRM/REML results,
    rotated panels in float32) stays on the *engine* and is shared by every
    slot; only staged device arrays and the step's prolog memo are
    per-slot.  ``put`` is the one placement primitive: explicit
    ``jax.device_put`` onto the slot's device, so no slot ever leans on the
    process-global default device.
    """

    def __init__(self, engine: "ScanEngine", ctx: "EngineContext",
                 *, device: Any = None, step: Callable[..., dict] | None = None):
        self.engine = engine
        self.device = device
        if device is not None:
            # Steps close over context arrays (the covariate basis, the
            # multivariate whitening); a jitted computation whose constants
            # are committed to another device would be rejected — re-place
            # them on this slot's device before the step is built.  Bitwise
            # copies: placement moves bytes, never values.
            ctx = dataclasses.replace(
                ctx,
                q_basis=None if ctx.q_basis is None
                else jax.device_put(ctx.q_basis, device),
                whitening=None if ctx.whitening is None
                else jax.device_put(ctx.whitening, device),
            )
        self.ctx = ctx
        # A fresh step per slot: the one-slot prolog memo inside keys on the
        # staged array's identity, which is per-device — sharing a step
        # across slots would thrash the memo (and race it across worker
        # threads).  Same closure, same jaxpr, same compiled math.
        self.step = step if step is not None else engine.build_step(ctx)

    def put(self, arr: Any) -> jax.Array:
        """Stage one array onto this slot's device (async on accelerators)."""
        if self.device is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, self.device)

    def stage(self, host_batch: "HostBatch") -> tuple:
        """Device-resident positional step args for one claimed batch."""
        return tuple(self.put(a) for a in host_batch.device_args)

    def panel_block(self, batch: MarkerBatch, block: TraitBlock) -> jax.Array:
        """Device panel slice for one grid cell (engines with
        ``uses_global_panel = False`` only; the driver's per-slot panel view
        serves global-panel engines)."""
        raise NotImplementedError(
            f"engine {self.engine.name!r} uses the driver's panel store"
        )

    def reset(self) -> None:
        """Drop per-slot pinned device state (the step memo's last batch)."""
        getattr(self.step, "reset", lambda: None)()


class ScanEngine:
    """Engine interface; subclasses register with ``@register_engine``.

    Every engine's step takes the cell's trait-block panel slice as its
    trailing argument.  ``uses_global_panel`` tells the driver who serves
    that slice: the driver's own residualized ``PanelStore`` (OLS engines),
    or the engine's device state's ``panel_block`` hook (the lmm engine,
    whose panels vary per LOCO scope as well as per block).  Device-staged
    state lives in per-executor-slot ``EngineDeviceState`` objects built by
    ``make_device_state`` — one per device, so a multi-device scan never
    shares staged arrays or prolog memos across devices.
    """

    name: str = "?"
    uses_global_panel: bool = True

    def validate(self, ctx: EngineContext) -> None:
        """Raise ValueError for unsupported (engine, context) combinations."""

    def setup_scan(
        self,
        source: Any,
        phenotypes: np.ndarray,
        covariates: np.ndarray | None,
        ctx: EngineContext,
    ) -> dict[str, Any] | None:
        """Optional amortized per-scan setup (after ``validate``, before
        ``build_step``).  May return overrides for the driver:
        ``{"dof": int, "info": dict}``.  Default: nothing to do."""
        return None

    def state_fingerprint(self) -> str | None:
        """Hashable summary of engine state a resume must match (e.g. the
        GRM spectrum); folded into the checkpoint fingerprint when set."""
        return None

    def build_step(self, ctx: EngineContext) -> Callable[..., dict[str, jax.Array]]:
        raise NotImplementedError

    def prepare_batch(self, source: Any, batch: MarkerBatch, ctx: EngineContext) -> HostBatch:
        raise NotImplementedError

    def make_device_state(
        self, ctx: EngineContext, *, device: Any = None,
        step: Callable[..., dict] | None = None,
    ) -> EngineDeviceState:
        """One executor slot's device residency; see ``EngineDeviceState``.
        ``step`` reuses an already-built step for the slot (the serial
        executor passes the plan's — keeping the shim's swappable ``_step``
        contract); by default the slot builds its own."""
        return EngineDeviceState(self, ctx, device=device, step=step)


_REGISTRY: dict[str, type[ScanEngine]] = {}


def register_engine(name: str) -> Callable[[type[ScanEngine]], type[ScanEngine]]:
    def deco(cls: type[ScanEngine]) -> type[ScanEngine]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_engine(name: str) -> ScanEngine:
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scan engine {name!r}; available: {available_engines()}"
        ) from None
    return cls()


def available_engines() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------- steps


def _dense_best_and_hits(nlp: jax.Array, t: jax.Array, hit_threshold: float) -> dict:
    """Reference-path summary outputs from a full masked nlp tile.

    The winner is the argmax over t^2 (first index on ties) with its nlp
    read from the tile — the same winner rule the sparse epilogue refines,
    so both paths agree bitwise even where the f32 nlp tile plateaus
    (distinct t^2 mapping to one nlp value) — the §13 monotonicity
    contract.
    """
    best_row = jnp.argmax(jnp.square(t), axis=0).astype(jnp.int32)
    return {
        "batch_best_nlp": jnp.take_along_axis(nlp, best_row[None, :], axis=0)[0],
        "batch_best_row": best_row,
        "batch_best_t": jnp.take_along_axis(t, best_row[None, :], axis=0)[0],
        "hit_count": jnp.sum(nlp >= hit_threshold).astype(jnp.int32),
    }


def _resolve_sparse(
    sparse_epilogue, mesh, options, hit_threshold, dof, hit_capacity,
    multivariate=False,
):
    """One gate for all three builders: the sparse epilogue needs a
    meaningful threshold (plan may refuse), an nlp-producing scan, no
    sharding mesh (the compaction gather is data-dependent — it does not
    shard; the multi-device grid executor, which jits per device, is the
    scaling path that does support it), and no multivariate omnibus (that
    screen consumes the full r tile in-step; keep its program identical to
    the audited dense one)."""
    if (
        not sparse_epilogue
        or mesh is not None
        or multivariate
        or not options.compute_neglog10p
    ):
        return None
    return plan_sparse_epilogue(hit_threshold, dof, capacity=hit_capacity)


def build_dense_step(
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions,
    mesh: Mesh | None = None,
    mode: str = "mp",
    hit_threshold: float = 7.301,
    maf_min: float = 0.0,
    q_basis: jax.Array | None = None,
    multivariate: bool = False,
    n_traits_eff: float = 1.0,
    whitening: jax.Array | None = None,
    trait_tile: int | None = None,
    split_prolog: bool = True,
    sparse_epilogue: bool = False,
    hit_capacity: int = 4096,
    packed_input: bool = False,
) -> Callable[..., dict[str, jax.Array]]:
    """Paper-faithful dense step: float dosages in, summary tiles out.

    ``packed_input`` accepts raw PLINK 2-bit bytes ``(M, ceil(N/4)) uint8``
    instead of float dosages and decodes them on device (DESIGN.md §17).
    The decode runs as its *own* jitted executable in front of the
    unchanged prolog/cell programs, so every downstream compiled artifact —
    and therefore every emitted bit — is identical to dense staging.
    ``trait_tile`` fixes the panel-axis GEMM tile (the scan passes its
    ``block_p``) so every trait-block decomposition computes identical
    tiles — the §10 bitwise contract.

    ``sparse_epilogue`` switches the p-value epilogue to the threshold-
    compacted sparse form (DESIGN.md §13): no full nlp tile; instead
    ``hit_idx``/``hit_r``/``hit_t`` compacted buffers of static
    ``hit_capacity`` plus ``screen_count`` (> capacity signals the host
    overflow fallback).  Hits, best-trait tables, and every persisted
    array are bitwise-identical to the dense path; mesh mode ignores the
    flag (the compaction gather does not shard).

    Like the lmm step, the computation splits into a once-per-marker-batch
    *prolog* (standardize + the exact-mode FWL residualization — everything
    trait-independent) and a per-cell *epilogue* (the panel GEMM + t/p).
    With ``split_prolog`` (the default) the prolog is jitted separately and
    memoized on the staged batch's array identity, so a blocked scan's
    inner trait-block loop pays the O(MN) standardization once per marker
    batch instead of once per grid cell (the ROADMAP "dense/fused prolog
    split" item).  ``split_prolog=False`` keeps the historical single-jit
    shape — same numbers bitwise (tests/test_screening.py asserts it): the
    cell GEMM consumes the identical float32 ``g_std`` either way, and
    standardization is elementwise/per-marker, so materializing it at the
    jit boundary cannot change a bit.
    """
    if packed_input and mesh is not None:
        raise ValueError("packed_input requires mesh=None (see resolve_genotype_staging)")
    if packed_input:
        from repro.kernels.gwas_dot import ops as kops

        decode = functools.partial(kops.decode_packed_device, n_samples=n_samples)
    dof = options.dof(n_samples, n_covariates)
    sparse = _resolve_sparse(
        sparse_epilogue, mesh, options, hit_threshold, dof, hit_capacity,
        multivariate=multivariate,
    )
    cell_options = (
        dataclasses.replace(options, sparse_epilogue=True) if sparse is not None
        else options
    )

    def prolog(g_raw: jax.Array):
        g_std, ms = standardize_genotype_batch(g_raw)
        if options.dof_mode == "exact":
            from repro.core.residualize import residualize_genotypes

            g_std = residualize_genotypes(g_std, q_basis)
        valid = ms.valid & (ms.maf >= maf_min) if maf_min > 0 else ms.valid
        return g_std, ms.maf, valid

    def cell(g_std, maf, valid, y_std) -> dict[str, jax.Array]:
        res = assoc_from_standardized(
            g_std, y_std, n_samples=n_samples, n_covariates=n_covariates,
            options=cell_options, trait_tile=trait_tile,
        )
        mask = valid[:, None]
        r = jnp.where(mask, res.r, 0.0)
        t = jnp.where(mask, res.t, 0.0)
        out = {"r": r, "t": t, "maf": maf, "valid": valid}
        if sparse is not None:
            out.update(sparse_epilogue_outputs(r, t, dof, sparse))
        else:
            nlp = jnp.where(mask, res.neglog10p, 0.0)
            out["nlp"] = nlp
            out.update(_dense_best_and_hits(nlp, t, hit_threshold))
        if multivariate:
            from repro.core import multivariate as mv

            omni, omni_nlp = mv.omnibus_chi2(
                out["r"], n_samples, n_traits_eff, whitening=whitening
            )
            out["omnibus"] = omni
            out["omnibus_nlp"] = omni_nlp
        return out

    def step_monolithic(g_raw: jax.Array, y_std: jax.Array) -> dict[str, jax.Array]:
        return cell(*prolog(g_raw), y_std)

    if mesh is None:
        if not split_prolog:
            mono_j = jax.jit(step_monolithic)
            if not packed_input:
                return mono_j
            # Decode-then-mono as two executables: the mono program is the
            # exact compiled artifact dense staging runs.
            return lambda g_raw, y_std: mono_j(decode(g_raw), y_std)
        prolog_j = jax.jit(prolog)
        cell_j = jax.jit(cell)
    else:
        sh = gwas_shardings(mesh, mode=mode)
        mv_spec = {"omnibus": sh["marker_vec"], "omnibus_nlp": sh["marker_vec"]} if multivariate else {}
        rep = NamedSharding(mesh, P())
        model_vec = NamedSharding(mesh, P("model"))
        out_shardings = {
            "r": sh["out"],
            "t": sh["out"],
            "nlp": sh["out"],
            "maf": sh["marker_vec"],
            "valid": sh["marker_vec"],
            "batch_best_nlp": model_vec,
            "batch_best_row": model_vec,
            "batch_best_t": model_vec,
            "hit_count": rep,
            **mv_spec,
        }
        if not split_prolog:
            return jax.jit(
                step_monolithic, in_shardings=(sh["g"], sh["y"]), out_shardings=out_shardings
            )
        prolog_j = jax.jit(
            prolog,
            in_shardings=(sh["g"],),
            out_shardings=(sh["g"], sh["marker_vec"], sh["marker_vec"]),
        )
        cell_j = jax.jit(
            cell,
            in_shardings=(sh["g"], sh["marker_vec"], sh["marker_vec"], sh["y"]),
            out_shardings=out_shardings,
        )

    # One-slot memo keyed on the staged genotype array's identity: the
    # driver passes the same device array for every trait block of a batch,
    # and a fresh one per batch.  Holding the reference pins the id.
    memo: dict[str, Any] = {"g": None, "out": None}

    def step(g_raw: jax.Array, y_std: jax.Array) -> dict[str, jax.Array]:
        if memo["g"] is not g_raw:
            # Packed staging: the device decode (its own executable) feeds
            # the identical prolog program — the decoded f32 never exists
            # on host and lives on device only for this batch's prolog.
            memo["out"] = prolog_j(decode(g_raw) if packed_input else g_raw)
            memo["g"] = g_raw
        return cell_j(*memo["out"], y_std)

    # The executor calls this at teardown so the last batch's staged raw +
    # standardized arrays don't stay pinned on device for the lifetime of a
    # cached plan.
    step.reset = lambda: memo.update(g=None, out=None)
    return step


def build_fused_step(
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions,
    mesh: Mesh | None = None,
    hit_threshold: float = 7.301,
    block_m: int = 256,
    block_n: int = 512,
    block_p: int = 256,
    interpret: bool | None = None,
    input_dtype: str | None = None,
    sparse_epilogue: bool = False,
    hit_capacity: int = 4096,
    packed_input: bool = False,
) -> Callable[..., dict[str, jax.Array]]:
    """Beyond-paper fused step: 2-bit packed slabs in (kernel layout),
    summary tiles out.  'mp' sharding only — the in-kernel epilogue requires
    complete sample contractions per device (DESIGN.md §5).

    ``input_dtype`` selects the kernel's GEMM input dtype ("fp32" | "bf16");
    the in-kernel accumulation and the epilogue (t, -log10 p, argmax) stay
    float32 either way — the GEMM-bf16 / epilogue-fp32 split audited by the
    oracle suite.  ``None`` defers to ``options.precision`` (the historical
    plumbing).  ``sparse_epilogue`` — see ``build_dense_step``; the kernel
    still emits the full r/t tiles, only the p-value work is compacted.

    ``packed_input`` takes raw PLINK bytes ``(M, ceil(N/4))`` instead of the
    kernel's tile-local layout and performs the tile repack *on device* as
    its own jitted byte shuffle (DESIGN.md §17) — killing the host
    ``unpack_plink_to_codes`` + ``pack_tiled`` round trip, so host prep is
    a memcpy plus the LUT marker-stat pass.  The kernel step itself is the
    unchanged compiled program; output bits are identical."""
    from repro.kernels.gwas_dot.gwas_dot import build_gwas_dot

    if packed_input and mesh is not None:
        raise ValueError("packed_input requires mesh=None (see resolve_genotype_staging)")
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    dof = options.dof(n_samples, n_covariates)
    sparse = _resolve_sparse(
        sparse_epilogue, mesh, options, hit_threshold, dof, hit_capacity
    )
    use_bf16 = input_dtype == "bf16" or (input_dtype is None and options.precision == "bf16")
    input_dtype = jnp.bfloat16 if use_bf16 else jnp.float32

    def kernel_local(packed, mean2d, inv2d, y):
        m_loc = packed.shape[0]
        n_pad = packed.shape[1] * 4
        p_loc = y.shape[1]
        call = build_gwas_dot(
            m_loc, n_pad, p_loc,
            block_m=block_m, block_n=block_n, block_p=block_p,
            n_samples=n_samples, dof=dof,
            input_dtype=input_dtype, interpret=interpret,
        )
        return tuple(call(packed, mean2d, inv2d, y))

    if mesh is not None:
        dp = batch_axes(mesh)
        kernel_fn = shard_map(
            kernel_local,
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None), P(dp, None), P(None, "model")),
            out_specs=(P(dp, "model"), P(dp, "model")),
            # pallas_call out_shapes carry no vma metadata; the kernel is
            # elementwise-independent per shard so the check is vacuous here.
            check_vma=False,
        )
    else:
        kernel_fn = kernel_local

    def step(packed, mean2d, inv2d, valid, y_std):
        p_true = y_std.shape[1]
        pad_p = (-p_true) % block_p
        pad_n = packed.shape[1] * 4 - y_std.shape[0]  # packed samples are tile-padded
        if pad_p or pad_n:
            y_std = jnp.pad(y_std, ((0, pad_n), (0, pad_p)))
        r, t = kernel_fn(packed, mean2d, inv2d, y_std)
        if pad_p:
            r = r[:, :p_true]
            t = t[:, :p_true]
        mask = valid[:, None]
        r = jnp.where(mask, r, 0.0)
        t = jnp.where(mask, t, 0.0)
        out = {"r": r, "t": t}
        if sparse is not None:
            out.update(sparse_epilogue_outputs(r, t, dof, sparse))
        else:
            nlp = jnp.where(mask, _stats.neglog10_p_from_t(t, dof), 0.0)
            out["nlp"] = nlp
            out.update(_dense_best_and_hits(nlp, t, hit_threshold))
        return out

    if mesh is None:
        step_j = jax.jit(step)
        if not packed_input:
            return step_j
        from repro.kernels.gwas_dot import ops as kops

        # One-slot memo like the dense/lmm prologs: the device repack runs
        # once per staged batch, then every trait-block cell reuses the
        # tiled bytes through the unchanged kernel step.
        memo: dict[str, Any] = {"g": None, "tiled": None}

        def step_packed(plink_packed, mean2d, inv2d, valid, y_std):
            if memo["g"] is not plink_packed:
                memo["tiled"] = kops.repack_plink_tiled_device(
                    plink_packed,
                    n_samples=n_samples,
                    block_n=block_n,
                    block_m=block_m,
                )
                memo["g"] = plink_packed
            return step_j(memo["tiled"], mean2d, inv2d, valid, y_std)

        step_packed.reset = lambda: memo.update(g=None, tiled=None)
        return step_packed
    sh = gwas_shardings(mesh, mode="mp")
    model_vec = NamedSharding(mesh, P("model"))
    return jax.jit(
        step,
        in_shardings=(sh["packed"], sh["packed"], sh["packed"], sh["marker_vec"], sh["y"]),
        out_shardings={
            "r": sh["out"],
            "t": sh["out"],
            "nlp": sh["out"],
            "batch_best_nlp": model_vec,
            "batch_best_row": model_vec,
            "batch_best_t": model_vec,
            "hit_count": NamedSharding(mesh, P()),
        },
    )


def build_lmm_step(
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions,
    mesh: Mesh | None = None,
    hit_threshold: float = 7.301,
    maf_min: float = 0.0,
    epilogue: str = "dense",
    block_m: int = 256,
    block_p: int = 256,
    sparse_epilogue: bool = False,
    hit_capacity: int = 4096,
    packed_input: bool = False,
) -> Callable[..., dict[str, jax.Array]]:
    """Mixed-model step: standardize -> rotate into the (whitened) GRM
    eigenbasis -> project out the whitened design -> the unchanged
    correlation epilogue (DESIGN.md §9).

    Signature: ``step(g_raw, rotation, qhat, y_std)`` — the rotation matrix
    and panel ride in ``device_args`` because they vary per LOCO scope.
    The GLS dof is structurally ``N - 2 - q`` (the whitened design counts
    its intercept), so the epilogue always runs in exact-dof mode.

    ``epilogue="dense"`` computes t/p in plain XLA; ``"fused"`` routes
    Eq. 3 through the standalone Pallas t-statistic kernel
    (``kernels.tstat``) — identical numbers, exercised by the oracle suite.

    ``block_p`` doubles as the panel-axis GEMM tile (``trait_tile`` of
    ``core.association.correlation``) so blocked and unblocked scans
    compute identical tiles (§10).

    Internally the step is a once-per-marker-batch *prolog* (standardize,
    rotation GEMM, whitened-design projection — everything trait-
    independent, including the dominant (M,N)x(N,N) GEMM) plus a per-cell
    *epilogue* (the panel GEMM + t/p).  The prolog result is memoized on
    the staged batch's array identity, so a blocked scan's inner trait-
    block loop pays the genotype-side work once per marker batch, not once
    per grid cell.  The public signature is unchanged.

    ``sparse_epilogue`` — see ``build_dense_step``.  With
    ``epilogue="fused"`` the t^2 screen additionally fuses into the Pallas
    t-statistic pass (``kernels.tstat.screen_compact``): Eq. 3, the screen
    compare, and the per-block survivor counts run in one kernel; the exact
    CF then touches only the compacted lanes.
    """
    if epilogue not in ("dense", "fused"):
        raise ValueError(f"unknown lmm epilogue {epilogue!r}")
    if packed_input and mesh is not None:
        raise ValueError("packed_input requires mesh=None (see resolve_genotype_staging)")
    if packed_input:
        from repro.kernels.gwas_dot import ops as kops

        decode = functools.partial(kops.decode_packed_device, n_samples=n_samples)
    opts = dataclasses.replace(options, dof_mode="exact")
    dof = opts.dof(n_samples, n_covariates)
    sparse = _resolve_sparse(
        sparse_epilogue, mesh, opts, hit_threshold, dof, hit_capacity
    )

    from repro.core.association import correlation
    from repro.core.residualize import residualize_genotypes

    def prolog(g_raw, rotation, qhat):
        g_std, ms = standardize_genotype_batch(g_raw)
        g_rot = jax.lax.dot_general(
            g_std, rotation, (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32,
        )
        g_fin = residualize_genotypes(g_rot, qhat)
        valid = ms.valid & (ms.maf >= maf_min) if maf_min > 0 else ms.valid
        return g_fin, ms.maf, valid

    cell_opts = (
        dataclasses.replace(opts, sparse_epilogue=True) if sparse is not None
        else opts
    )

    def cell(g_fin, maf, valid, y_std):
        mask = valid[:, None]
        screen = None
        nlp = None
        if epilogue == "fused":
            r = jnp.clip(
                correlation(g_fin, y_std, n_samples, precision=opts.precision,
                            trait_tile=block_p),
                -1.0, 1.0,
            )
            # Mask before the kernel: invalid lanes map to r=0 -> t=0
            # exactly, so masked tiles are identical either way and the
            # fused screen can never admit a masked lane.
            r = jnp.where(mask, r, 0.0)
            if sparse is not None:
                from repro.kernels.tstat import screen_compact

                t, idx, screen_count = screen_compact(
                    r, dof, sparse.t2_screen, sparse.capacity,
                    block_m=block_m, block_p=block_p,
                )
                screen = (idx, screen_count)
            else:
                from repro.kernels.tstat import tstat

                t = tstat(r, dof, block_m=block_m, block_p=block_p)
                nlp = jnp.where(mask, _stats.neglog10_p_from_t(t, dof), 0.0)
        else:
            res = assoc_from_standardized(
                g_fin, y_std, n_samples=n_samples, n_covariates=n_covariates,
                options=cell_opts, trait_tile=block_p,
            )
            r = jnp.where(mask, res.r, 0.0)
            t = jnp.where(mask, res.t, 0.0)
            if sparse is None:
                nlp = jnp.where(mask, res.neglog10p, 0.0)
        out = {"r": r, "t": t, "maf": maf, "valid": valid}
        if sparse is not None:
            out.update(sparse_epilogue_outputs(r, t, dof, sparse, screen=screen))
        else:
            out["nlp"] = nlp
            out.update(_dense_best_and_hits(nlp, t, hit_threshold))
        return out

    if mesh is None:
        prolog_j = jax.jit(prolog)
        cell_j = jax.jit(cell)
    else:
        sh = gwas_shardings(mesh, mode="mp")
        rep = NamedSharding(mesh, P())
        model_vec = NamedSharding(mesh, P("model"))
        prolog_j = jax.jit(
            prolog,
            in_shardings=(sh["g"], rep, rep),
            out_shardings=(sh["g"], sh["marker_vec"], sh["marker_vec"]),
        )
        cell_j = jax.jit(
            cell,
            in_shardings=(sh["g"], sh["marker_vec"], sh["marker_vec"], sh["y"]),
            out_shardings={
                "r": sh["out"],
                "t": sh["out"],
                "nlp": sh["out"],
                "maf": sh["marker_vec"],
                "valid": sh["marker_vec"],
                "batch_best_nlp": model_vec,
                "batch_best_row": model_vec,
                "batch_best_t": model_vec,
                "hit_count": rep,
            },
        )

    # One-slot memo keyed on the staged genotype array's identity: the
    # driver passes the same device array for every trait block of a batch,
    # and a fresh one per batch.  Holding the reference pins the id.
    memo: dict[str, Any] = {"g": None, "out": None}

    def step(g_raw, rotation, qhat, y_std):
        if memo["g"] is not g_raw:
            # See build_dense_step: under packed staging the device decode
            # is its own executable in front of the unchanged prolog.
            g_in = decode(g_raw) if packed_input else g_raw
            memo["out"] = prolog_j(g_in, rotation, qhat)
            memo["g"] = g_raw
        return cell_j(*memo["out"], y_std)

    # See build_dense_step: drop the pinned last batch at executor teardown.
    step.reset = lambda: memo.update(g=None, out=None)
    return step


# ------------------------------------------------------------------- engines


@register_engine("dense")
class DenseEngine(ScanEngine):
    """XLA GEMM over float dosages — the paper-faithful reference engine.
    Supports both 'mp' and 'sample' sharding and the multivariate screen."""

    def build_step(self, ctx: EngineContext) -> Callable[..., dict[str, jax.Array]]:
        return build_dense_step(
            n_samples=ctx.n_samples,
            n_covariates=ctx.n_covariates,
            options=ctx.options,
            mesh=ctx.mesh,
            mode=ctx.mode,
            hit_threshold=ctx.hit_threshold,
            maf_min=ctx.maf_min,
            q_basis=ctx.q_basis,
            multivariate=ctx.multivariate,
            n_traits_eff=ctx.n_traits_eff,
            whitening=ctx.whitening,
            trait_tile=ctx.block_p,
            sparse_epilogue=ctx.sparse_epilogue,
            hit_capacity=ctx.hit_capacity,
            packed_input=ctx.genotype_staging == "packed",
        )

    def prepare_batch(self, source: Any, batch: MarkerBatch, ctx: EngineContext) -> HostBatch:
        if ctx.genotype_staging == "packed":
            # Stage ceil(N/4) bytes/marker through the shared slab cache;
            # the step's device decode front-end expands them (§17).
            from repro.io.packed_cache import read_packed_cached

            return HostBatch(batch, (read_packed_cached(source, batch.lo, batch.hi),))
        dosages = source.read_dosages(batch.lo, batch.hi)
        if ctx.excluded_samples:
            dosages = dosages[:, ctx.keep]
        return HostBatch(batch, (np.asarray(dosages, np.float32),))


@register_engine("fused")
class FusedEngine(ScanEngine):
    """2-bit Pallas engine: packed slabs stay packed until the kernel's
    inner loop; marker stats come from the host repack pass, so the device
    sees N/4 bytes per marker."""

    def validate(self, ctx: EngineContext) -> None:
        if ctx.mode != "mp":
            raise ValueError("fused engine supports marker x phenotype sharding only")

    def build_step(self, ctx: EngineContext) -> Callable[..., dict[str, jax.Array]]:
        return build_fused_step(
            n_samples=ctx.n_samples,
            n_covariates=ctx.n_covariates,
            options=ctx.options,
            mesh=ctx.mesh,
            hit_threshold=ctx.hit_threshold,
            block_m=ctx.block_m,
            block_n=ctx.block_n,
            block_p=ctx.block_p,
            # "bf16" forces the kernel's low-precision GEMM; the default
            # defers to options.precision (the historical plumbing).
            input_dtype="bf16" if ctx.input_dtype == "bf16" else None,
            sparse_epilogue=ctx.sparse_epilogue,
            hit_capacity=ctx.hit_capacity,
            packed_input=ctx.genotype_staging == "packed",
        )

    def prepare_batch(self, source: Any, batch: MarkerBatch, ctx: EngineContext) -> HostBatch:
        from repro.kernels.gwas_dot import ops as kops

        m_batch = batch.n_markers
        if ctx.genotype_staging == "packed":
            # Host prep at memcpy cost: cached raw slab + LUT marker stats.
            # The unpack/re-pack byte shuffle moved onto the device (§17);
            # stat vectors still pad to the block_m geometry the kernel
            # step expects (the device repack pads its rows to match).
            from repro.io.packed_cache import read_packed_cached

            plink_packed = read_packed_cached(source, batch.lo, batch.hi)
            mean, inv_std, valid = kops.marker_stats_from_packed(
                plink_packed, ctx.n_samples
            )
            if ctx.maf_min > 0:
                af = mean / 2.0
                maf = np.minimum(af, 1.0 - af)
                valid &= maf >= ctx.maf_min
                inv_std = np.where(valid, inv_std, 0.0).astype(np.float32)
            pad_m = (-m_batch) % ctx.block_m
            if pad_m:
                mean = np.pad(mean, (0, pad_m))
                inv_std = np.pad(inv_std, (0, pad_m))
                valid = np.pad(valid, (0, pad_m))
            maf = np.minimum(mean / 2.0, 1.0 - mean / 2.0)
            return HostBatch(
                batch,
                (plink_packed, mean.reshape(-1, 1), inv_std.reshape(-1, 1), valid),
                host_maf=maf[:m_batch],
                host_valid=valid[:m_batch],
            )
        n_total = len(ctx.keep) if ctx.keep is not None else ctx.n_samples
        plink_packed = source.read_packed(batch.lo, batch.hi)
        codes = kops.unpack_plink_to_codes(plink_packed, n_total)
        if ctx.excluded_samples:
            codes = codes[:, ctx.keep]
        mean, inv_std, valid = kops.marker_stats_from_codes(codes)
        if ctx.maf_min > 0:
            af = mean / 2.0
            maf = np.minimum(af, 1.0 - af)
            valid &= maf >= ctx.maf_min
            inv_std = np.where(valid, inv_std, 0.0).astype(np.float32)
        packed = kops.pack_tiled(codes, ctx.block_n)
        pad_m = (-packed.shape[0]) % ctx.block_m
        if pad_m:
            packed = np.pad(packed, ((0, pad_m), (0, 0)), constant_values=0b01)
            mean = np.pad(mean, (0, pad_m))
            inv_std = np.pad(inv_std, (0, pad_m))
            valid = np.pad(valid, (0, pad_m))
        maf = np.minimum(mean / 2.0, 1.0 - mean / 2.0)
        return HostBatch(
            batch,
            (packed, mean.reshape(-1, 1), inv_std.reshape(-1, 1), valid),
            host_maf=maf[:m_batch],
            host_valid=valid[:m_batch],
        )


class _LMMDeviceState(EngineDeviceState):
    """One device's share of the lmm engine: the staged per-scope
    (rotation, qhat) pair and the per-(scope, trait-block) rotated panel
    slices, each LRU-bounded *per slot*.  The host float32 panels live on
    the engine (shared across slots); every slot stages its own copies with
    explicit placement, so a multi-device LOCO scan holds at most
    ``_DEV_SCOPES_MAX`` rotations per device, never one shared set on the
    default device."""

    def __init__(self, engine: "LMMEngine", ctx: EngineContext,
                 *, device: Any = None, step: Callable[..., dict] | None = None):
        super().__init__(engine, ctx, device=device, step=step)
        # scope -> staged (rotation, qhat); evicting a scope drops its
        # resident panel blocks with it
        self._dev = DeviceLRU(
            engine._DEV_SCOPES_MAX,
            lambda sid: (
                self.put(engine._scopes[sid].rotation),
                self.put(engine._scopes[sid].qhat),
            ),
            on_evict=lambda sid: self._dev_y.drop_if(lambda k: k[0] == sid),
        )
        # (scope, block) -> staged panel slice
        self._dev_y = DeviceLRU(
            max(1, ctx.panel_resident_blocks), self._load_panel_block
        )

    def _load_panel_block(self, key: tuple[int, int]) -> jax.Array:
        sid, block_index = key
        blk = self.engine._trait_blocks[block_index]
        return self.put(self.engine._scopes[sid].y_block(blk.lo, blk.hi))

    def stage(self, host_batch: HostBatch) -> tuple:
        """(dosages, rotation, qhat) on this slot's device: the dosage copy
        is fresh per batch, the scope pair comes from the slot's LRU —
        staged once and shared by every batch of that scope on this
        device."""
        sid = host_batch.batch.source_id if self.engine._loco else -1
        rotation, qhat = self._dev.get(sid)
        return (self.put(host_batch.device_args[0]), rotation, qhat)

    def panel_block(self, batch: MarkerBatch, block: TraitBlock) -> jax.Array:
        """Rotated-panel slice for one grid cell, LRU-cached on this slot's
        device so a panel that fits stays resident while a paper-scale one
        streams block-by-block.  The slice comes from the scope's host
        float32 panel, which keeps the blocked scan bitwise-identical to
        the unblocked one — the float64 whitening ran panel-wide at setup
        (the global REML fit materializes the rotated panel anyway,
        DESIGN.md §10)."""
        sid = batch.source_id if self.engine._loco else -1
        return self._dev_y.get((sid, block.index))

    def reset(self) -> None:
        """Slot teardown: the step memo (base) plus this slot's staged
        rotation pairs and rotated panel blocks — a closed multi-device
        scan must pin nothing on its devices.  The shared host-side
        float32 panels on the engine are untouched (amortized state)."""
        super().reset()
        if self.device is not None:
            self._dev_y.clear()
            self._dev.clear()


@register_engine("lmm")
class LMMEngine(ScanEngine):
    """Linear mixed model: streamed GRM + one-time rotation (core.grm,
    core.lmm).  ``setup_scan`` amortizes the expensive work — GRM pass,
    eigendecomposition, REML — once per scan (per LOCO chromosome);
    ``prepare_batch`` then only reads dosages, so the per-batch device cost
    is one extra (M, N) x (N, N) GEMM on top of the OLS scan.  All device
    staging — the scope's rotation/basis pair and the per-(scope,
    trait-block) rotated panel slices — lives in ``_LMMDeviceState``, one
    per executor slot (``uses_global_panel = False``), LRU-bounded per
    device."""

    uses_global_panel = False

    # Scopes arrive shard-sequentially (the planner never interleaves
    # shards), but the prefetch window may straddle one boundary — so two
    # resident scopes bound device memory at ~2 (N,N) rotations, not one
    # per chromosome.
    _DEV_SCOPES_MAX = 2

    def __init__(self) -> None:
        self._scopes: dict[int, Any] = {}       # scope -> core.lmm.RotatedPanel
        self._trait_blocks: tuple[TraitBlock, ...] = ()
        self._loco = False
        self._fingerprint: str | None = None
        self._dof: int | None = None
        self._n_cov: int | None = None

    def validate(self, ctx: EngineContext) -> None:
        if ctx.mode != "mp":
            raise ValueError("lmm engine supports marker x phenotype sharding only")
        if ctx.multivariate:
            raise ValueError("lmm engine and the multivariate screen are exclusive")
        if ctx.lmm_epilogue not in ("dense", "fused"):
            raise ValueError(f"unknown lmm epilogue {ctx.lmm_epilogue!r}")

    def setup_scan(self, source, phenotypes, covariates, ctx: EngineContext):
        from repro.core.grm import grm_spectrum, spectrum_fingerprint, stream_grm
        from repro.core.lmm import rotate_panel

        self._trait_blocks = ctx.trait_blocks
        grm = stream_grm(
            source,
            keep=ctx.keep if ctx.excluded_samples else None,
            batch_markers=ctx.grm_batch_markers,
            method=ctx.grm_method,
            maf_min=ctx.maf_min,
            io_workers=ctx.io_workers,
            # Same currency as the scan: packed batches flow through the
            # shared slab cache + device decode, so GRM and scan share one
            # read per batch (satellite of §17).
            staging=ctx.genotype_staging,
        )
        if ctx.loco and grm.n_shards < 2:
            raise ValueError(
                "loco=True needs a per-chromosome fileset (>= 2 genotype shards)"
            )
        scopes = list(range(grm.n_shards)) if ctx.loco else [-1]
        spectra: dict[int, np.ndarray] = {}
        for sid in scopes:
            k = grm.loco(sid) if ctx.loco else grm.full()
            s, u = grm_spectrum(k)
            spectra[sid] = s
            self._scopes[sid] = rotate_panel(
                phenotypes, covariates, s, u, delta=ctx.lmm_delta
            )
        self._loco = ctx.loco
        first = next(iter(self._scopes.values()))
        self._dof = first.dof
        self._n_cov = first.n_covariates
        deltas = {sid: p.delta for sid, p in self._scopes.items()}
        # Deltas enter the fingerprint rounded to the same significant-digit
        # budget as the spectrum hash, so a resume on a different BLAS build
        # (last-bit REML jitter) is not spuriously refused.
        delta_sig = [(sid, f"{d:.6g}") for sid, d in sorted(deltas.items())]
        self._fingerprint = f"{spectrum_fingerprint(spectra)}:{delta_sig}"
        info: dict[str, Any] = {
            "grm_method": grm.method,
            "scopes": len(scopes),
            "loco": ctx.loco,
            "delta": deltas if ctx.loco else first.delta,
            "spectrum_hash": spectrum_fingerprint(spectra),
        }
        if first.reml is not None:
            info["h2"] = first.reml.h2
            info["delta_per_trait"] = first.reml.delta
        return {"dof": self._dof, "info": info}

    def state_fingerprint(self) -> str | None:
        return self._fingerprint

    def build_step(self, ctx: EngineContext) -> Callable[..., dict[str, jax.Array]]:
        if self._dof is None:
            raise RuntimeError("setup_scan must run before build_step")
        return build_lmm_step(
            n_samples=ctx.n_samples,
            n_covariates=self._n_cov,
            options=ctx.options,
            mesh=ctx.mesh,
            hit_threshold=ctx.hit_threshold,
            maf_min=ctx.maf_min,
            epilogue=ctx.lmm_epilogue,
            block_m=ctx.block_m,
            block_p=ctx.block_p,
            sparse_epilogue=ctx.sparse_epilogue,
            hit_capacity=ctx.hit_capacity,
            packed_input=ctx.genotype_staging == "packed",
        )

    def make_device_state(
        self, ctx: EngineContext, *, device: Any = None,
        step: Callable[..., dict] | None = None,
    ) -> EngineDeviceState:
        return _LMMDeviceState(self, ctx, device=device, step=step)

    def prepare_batch(self, source: Any, batch: MarkerBatch, ctx: EngineContext) -> HostBatch:
        """Host side only: read and subset dosages.  The scope's rotation
        pair is attached at staging time by the slot's device state (it is
        device-resident state, not host batch payload)."""
        if ctx.genotype_staging == "packed":
            from repro.io.packed_cache import read_packed_cached

            return HostBatch(batch, (read_packed_cached(source, batch.lo, batch.hi),))
        dosages = source.read_dosages(batch.lo, batch.hi)
        if ctx.excluded_samples:
            dosages = dosages[:, ctx.keep]
        return HostBatch(batch, (np.asarray(dosages, np.float32),))
