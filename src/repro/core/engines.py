"""Pluggable scan engines: device step construction + host batch preparation.

An engine owns both halves of one batch's journey (DESIGN.md §2):

    host side    ``prepare_batch``  — read from the genotype source, decode /
                 repack / compute marker stats on a prefetch worker thread,
                 returning a ``HostBatch`` of device-ready ndarrays
    device side  ``build_step``     — a jit'd (optionally sharded) callable
                 mapping those arrays + the trait panel to summary tiles

``GenomeScan`` resolves engines by name through the registry and never
branches on engine identity — new engines (e.g. an int8 dequant GEMM or a
mixed-precision screen) plug in with ``@register_engine`` and a config
string, touching no driver code.

``build_dense_step`` / ``build_fused_step`` remain importable (also re-
exported from ``core.screening``) for tests and external harnesses.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import stats as _stats
from repro.core.association import AssocOptions, assoc_from_standardized, standardize_genotype_batch
from repro.runtime.compat import shard_map
from repro.runtime.prefetch import MarkerBatch
from repro.runtime.sharding import batch_axes, gwas_shardings

__all__ = [
    "EngineContext",
    "HostBatch",
    "ScanEngine",
    "DenseEngine",
    "FusedEngine",
    "register_engine",
    "get_engine",
    "available_engines",
    "build_dense_step",
    "build_fused_step",
]


@dataclass
class EngineContext:
    """Everything an engine needs, assembled once per scan by the driver."""

    n_samples: int                     # after relatedness exclusion
    n_covariates: int
    options: AssocOptions
    mesh: Mesh | None = None
    mode: str = "mp"
    hit_threshold: float = 7.301
    maf_min: float = 0.0
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256
    q_basis: jax.Array | None = None
    multivariate: bool = False
    n_traits_eff: float = 1.0
    whitening: jax.Array | None = None
    keep: np.ndarray | None = None     # host-side sample mask (None: keep all)
    excluded_samples: int = 0


@dataclass
class HostBatch:
    """Host-prepared batch: positional device args for the engine's step,
    plus any marker stats already known on the host (fused path) so sinks
    need not pull them back from the device."""

    batch: MarkerBatch
    device_args: tuple[np.ndarray, ...]
    host_maf: np.ndarray | None = None     # (m_batch,) observed MAF
    host_valid: np.ndarray | None = None   # (m_batch,) bool


class ScanEngine:
    """Engine interface; subclasses register with ``@register_engine``."""

    name: str = "?"

    def validate(self, ctx: EngineContext) -> None:
        """Raise ValueError for unsupported (engine, context) combinations."""

    def build_step(self, ctx: EngineContext) -> Callable[..., dict[str, jax.Array]]:
        raise NotImplementedError

    def prepare_batch(self, source: Any, batch: MarkerBatch, ctx: EngineContext) -> HostBatch:
        raise NotImplementedError


_REGISTRY: dict[str, type[ScanEngine]] = {}


def register_engine(name: str) -> Callable[[type[ScanEngine]], type[ScanEngine]]:
    def deco(cls: type[ScanEngine]) -> type[ScanEngine]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_engine(name: str) -> ScanEngine:
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scan engine {name!r}; available: {available_engines()}"
        ) from None
    return cls()


def available_engines() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------- steps


def build_dense_step(
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions,
    mesh: Mesh | None = None,
    mode: str = "mp",
    hit_threshold: float = 7.301,
    q_basis: jax.Array | None = None,
    multivariate: bool = False,
    n_traits_eff: float = 1.0,
    whitening: jax.Array | None = None,
) -> Callable[..., dict[str, jax.Array]]:
    """Paper-faithful dense step: float dosages in, summary tiles out."""
    dof = options.dof(n_samples, n_covariates)

    def step(g_raw: jax.Array, y_std: jax.Array) -> dict[str, jax.Array]:
        g_std, ms = standardize_genotype_batch(g_raw)
        if options.dof_mode == "exact":
            from repro.core.residualize import residualize_genotypes

            g_std = residualize_genotypes(g_std, q_basis)
        res = assoc_from_standardized(
            g_std, y_std, n_samples=n_samples, n_covariates=n_covariates, options=options
        )
        mask = ms.valid[:, None]
        nlp = jnp.where(mask, res.neglog10p, 0.0)
        out = {
            "r": jnp.where(mask, res.r, 0.0),
            "t": jnp.where(mask, res.t, 0.0),
            "nlp": nlp,
            "maf": ms.maf,
            "valid": ms.valid,
            "batch_best_nlp": jnp.max(nlp, axis=0),
            "batch_best_row": jnp.argmax(nlp, axis=0).astype(jnp.int32),
            "hit_count": jnp.sum(nlp >= hit_threshold).astype(jnp.int32),
        }
        if multivariate:
            from repro.core import multivariate as mv

            omni, omni_nlp = mv.omnibus_chi2(
                out["r"], n_samples, n_traits_eff, whitening=whitening
            )
            out["omnibus"] = omni
            out["omnibus_nlp"] = omni_nlp
        return out

    if mesh is None:
        return jax.jit(step)

    sh = gwas_shardings(mesh, mode=mode)
    mv_spec = {"omnibus": sh["marker_vec"], "omnibus_nlp": sh["marker_vec"]} if multivariate else {}
    rep = NamedSharding(mesh, P())
    model_vec = NamedSharding(mesh, P("model"))
    out_shardings = {
        "r": sh["out"],
        "t": sh["out"],
        "nlp": sh["out"],
        "maf": sh["marker_vec"],
        "valid": sh["marker_vec"],
        "batch_best_nlp": model_vec,
        "batch_best_row": model_vec,
        "hit_count": rep,
        **mv_spec,
    }
    return jax.jit(step, in_shardings=(sh["g"], sh["y"]), out_shardings=out_shardings)


def build_fused_step(
    *,
    n_samples: int,
    n_covariates: int,
    options: AssocOptions,
    mesh: Mesh | None = None,
    hit_threshold: float = 7.301,
    block_m: int = 256,
    block_n: int = 512,
    block_p: int = 256,
    interpret: bool | None = None,
) -> Callable[..., dict[str, jax.Array]]:
    """Beyond-paper fused step: 2-bit packed slabs in (kernel layout),
    summary tiles out.  'mp' sharding only — the in-kernel epilogue requires
    complete sample contractions per device (DESIGN.md §5)."""
    from repro.kernels.gwas_dot.gwas_dot import build_gwas_dot

    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    dof = options.dof(n_samples, n_covariates)
    input_dtype = jnp.bfloat16 if options.precision == "bf16" else jnp.float32

    def kernel_local(packed, mean2d, inv2d, y):
        m_loc = packed.shape[0]
        n_pad = packed.shape[1] * 4
        p_loc = y.shape[1]
        call = build_gwas_dot(
            m_loc, n_pad, p_loc,
            block_m=block_m, block_n=block_n, block_p=block_p,
            n_samples=n_samples, dof=dof,
            input_dtype=input_dtype, interpret=interpret,
        )
        return tuple(call(packed, mean2d, inv2d, y))

    if mesh is not None:
        dp = batch_axes(mesh)
        kernel_fn = shard_map(
            kernel_local,
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None), P(dp, None), P(None, "model")),
            out_specs=(P(dp, "model"), P(dp, "model")),
            # pallas_call out_shapes carry no vma metadata; the kernel is
            # elementwise-independent per shard so the check is vacuous here.
            check_vma=False,
        )
    else:
        kernel_fn = kernel_local

    def step(packed, mean2d, inv2d, valid, y_std):
        p_true = y_std.shape[1]
        pad_p = (-p_true) % block_p
        pad_n = packed.shape[1] * 4 - y_std.shape[0]  # packed samples are tile-padded
        if pad_p or pad_n:
            y_std = jnp.pad(y_std, ((0, pad_n), (0, pad_p)))
        r, t = kernel_fn(packed, mean2d, inv2d, y_std)
        if pad_p:
            r = r[:, :p_true]
            t = t[:, :p_true]
        mask = valid[:, None]
        r = jnp.where(mask, r, 0.0)
        t = jnp.where(mask, t, 0.0)
        nlp = jnp.where(mask, _stats.neglog10_p_from_t(t, dof), 0.0)
        return {
            "r": r,
            "t": t,
            "nlp": nlp,
            "batch_best_nlp": jnp.max(nlp, axis=0),
            "batch_best_row": jnp.argmax(nlp, axis=0).astype(jnp.int32),
            "hit_count": jnp.sum(nlp >= hit_threshold).astype(jnp.int32),
        }

    if mesh is None:
        return jax.jit(step)
    sh = gwas_shardings(mesh, mode="mp")
    model_vec = NamedSharding(mesh, P("model"))
    return jax.jit(
        step,
        in_shardings=(sh["packed"], sh["packed"], sh["packed"], sh["marker_vec"], sh["y"]),
        out_shardings={
            "r": sh["out"],
            "t": sh["out"],
            "nlp": sh["out"],
            "batch_best_nlp": model_vec,
            "batch_best_row": model_vec,
            "hit_count": NamedSharding(mesh, P()),
        },
    )


# ------------------------------------------------------------------- engines


@register_engine("dense")
class DenseEngine(ScanEngine):
    """XLA GEMM over float dosages — the paper-faithful reference engine.
    Supports both 'mp' and 'sample' sharding and the multivariate screen."""

    def build_step(self, ctx: EngineContext) -> Callable[..., dict[str, jax.Array]]:
        return build_dense_step(
            n_samples=ctx.n_samples,
            n_covariates=ctx.n_covariates,
            options=ctx.options,
            mesh=ctx.mesh,
            mode=ctx.mode,
            hit_threshold=ctx.hit_threshold,
            q_basis=ctx.q_basis,
            multivariate=ctx.multivariate,
            n_traits_eff=ctx.n_traits_eff,
            whitening=ctx.whitening,
        )

    def prepare_batch(self, source: Any, batch: MarkerBatch, ctx: EngineContext) -> HostBatch:
        dosages = source.read_dosages(batch.lo, batch.hi)
        if ctx.excluded_samples:
            dosages = dosages[:, ctx.keep]
        return HostBatch(batch, (np.asarray(dosages, np.float32),))


@register_engine("fused")
class FusedEngine(ScanEngine):
    """2-bit Pallas engine: packed slabs stay packed until the kernel's
    inner loop; marker stats come from the host repack pass, so the device
    sees N/4 bytes per marker."""

    def validate(self, ctx: EngineContext) -> None:
        if ctx.mode != "mp":
            raise ValueError("fused engine supports marker x phenotype sharding only")

    def build_step(self, ctx: EngineContext) -> Callable[..., dict[str, jax.Array]]:
        return build_fused_step(
            n_samples=ctx.n_samples,
            n_covariates=ctx.n_covariates,
            options=ctx.options,
            mesh=ctx.mesh,
            hit_threshold=ctx.hit_threshold,
            block_m=ctx.block_m,
            block_n=ctx.block_n,
            block_p=ctx.block_p,
        )

    def prepare_batch(self, source: Any, batch: MarkerBatch, ctx: EngineContext) -> HostBatch:
        from repro.kernels.gwas_dot import ops as kops

        m_batch = batch.n_markers
        n_total = len(ctx.keep) if ctx.keep is not None else ctx.n_samples
        plink_packed = source.read_packed(batch.lo, batch.hi)
        codes = kops.unpack_plink_to_codes(plink_packed, n_total)
        if ctx.excluded_samples:
            codes = codes[:, ctx.keep]
        mean, inv_std, valid = kops.marker_stats_from_codes(codes)
        if ctx.maf_min > 0:
            af = mean / 2.0
            maf = np.minimum(af, 1.0 - af)
            valid &= maf >= ctx.maf_min
            inv_std = np.where(valid, inv_std, 0.0).astype(np.float32)
        packed = kops.pack_tiled(codes, ctx.block_n)
        pad_m = (-packed.shape[0]) % ctx.block_m
        if pad_m:
            packed = np.pad(packed, ((0, pad_m), (0, 0)), constant_values=0b01)
            mean = np.pad(mean, (0, pad_m))
            inv_std = np.pad(inv_std, (0, pad_m))
            valid = np.pad(valid, (0, pad_m))
        maf = np.minimum(mean / 2.0, 1.0 - mean / 2.0)
        return HostBatch(
            batch,
            (packed, mean.reshape(-1, 1), inv_std.reshape(-1, 1), valid),
            host_maf=maf[:m_batch],
            host_valid=valid[:m_batch],
        )
