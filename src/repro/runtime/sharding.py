"""Sharding vocabulary for both workload wings.

Physical meshes (launch/mesh.py):
    single pod:  (data=16, model=16)          -> axes ("data", "model")
    multi-pod:   (pod=2, data=16, model=16)   -> axes ("pod", "data", "model")

The GWAS scan and the LM zoo never name physical axes directly; they go
through the helpers here so the same model/scan code runs on either mesh.

LM parameters use MaxText-style *logical* axes mapped to physical axes by
``LogicalAxisRules`` — this is what makes FSDP/TP/EP configurable per arch
without touching model code.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "mesh_axes",
    "batch_axes",
    "gwas_shardings",
    "LogicalAxisRules",
    "logical_to_sharding",
    "DEFAULT_RULES",
]


def mesh_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """All axes that act data-parallel: ('pod', 'data') on multi-pod."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def gwas_shardings(mesh: Mesh, *, mode: str = "mp") -> dict[str, NamedSharding]:
    """Sharding contract for the association GEMM ``(M,N)x(N,P)->(M,P)``.

    mode="mp" (default): markers over the data axes, phenotypes over model;
        zero collectives in the hot GEMM — the roofline-optimal layout when
        the panel replica ``Y (N,P/16)`` fits per device.
    mode="sample": samples over the data axes (for biobank-scale N); XLA
        inserts one all-reduce of the (M, P/16) partial products per batch.
    """
    dp = batch_axes(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    if mode == "mp":
        return {
            "packed": ns(P(dp, None)),     # (M, N/4) markers sharded
            "marker_vec": ns(P(dp)),       # per-marker stats
            "g": ns(P(dp, None)),          # dense (M, N)
            "y": ns(P(None, "model")),     # panel: phenotypes sharded
            "out": ns(P(dp, "model")),     # (M, P) fully tiled
        }
    if mode == "sample":
        return {
            "packed": ns(P(None, dp)),
            "marker_vec": ns(P()),
            "g": ns(P(None, dp)),
            "y": ns(P(dp, "model")),
            "out": ns(P(None, "model")),
        }
    raise ValueError(f"unknown GWAS sharding mode: {mode}")


@dataclass(frozen=True)
class LogicalAxisRules:
    """Ordered (logical_axis -> physical axes) mapping, first-fit like
    MaxText: a physical axis is consumed at most once per spec."""

    rules: tuple[tuple[str, tuple[str, ...] | str | None], ...] = ()

    def physical(self, logical: tuple[str | None, ...], mesh: Mesh) -> P:
        available = set(mesh.axis_names)
        used: set[str] = set()
        out: list = []
        table = dict(self.rules)
        for ax in logical:
            if ax is None:
                out.append(None)
                continue
            mapped = table.get(ax)
            if mapped is None:
                out.append(None)
                continue
            cands = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            picked = tuple(c for c in cands if c in available and c not in used)
            used.update(picked)
            if not picked:
                out.append(None)
            elif len(picked) == 1:
                out.append(picked[0])
            else:
                out.append(picked)
        return P(*out)


# Default LM rules: FSDP over the data axes + tensor parallel over "model".
DEFAULT_RULES = LogicalAxisRules(
    rules=(
        ("batch", ("pod", "data")),
        ("seq", None),                  # sequence stays unsharded by default
        ("embed", ("data",)),           # FSDP shard of the embedding dim
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("mlp", ("model",)),
        ("vocab", ("model",)),
        ("experts", ("model",)),
        ("expert_mlp", None),
        ("layers", None),
        # KV-cache sequence dim: fallback target when kv_heads cannot divide
        # the model axis (flash-decoding-style partial softmax).
        ("kv_seq", ("model",)),
        ("state", ("model",)),          # recurrent state width (RWKV/RG-LRU)
    )
)


def logical_to_sharding(
    logical: tuple[str | None, ...], mesh: Mesh, rules: LogicalAxisRules = DEFAULT_RULES
) -> NamedSharding:
    return NamedSharding(mesh, rules.physical(logical, mesh))
