"""Fault-tolerant checkpointing for both wings.

``ScanCheckpoint`` — the GWAS scan is a deterministic stream of
(marker-batch x trait-block) grid cells; each completed cell commits a
result shard plus an atomic manifest update (write-tmp, fsync, rename).
Restart resumes from the manifest — mid-panel if the cut landed between
trait blocks of one batch; the grid decomposition is independent of the
device mesh, so a resume may use a *different* mesh/host count (elastic
scaling) — remaining cells are simply re-partitioned.

``TrainCheckpoint`` — step-granular pytree checkpoints for the LM wing:
flat ``{path: ndarray}`` .npz shards plus a JSON manifest, same atomic
rename discipline.  (No orbax dependency by design: the container is
offline, and the format must stay greppable in production triage.)
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
import threading
import time
from dataclasses import dataclass

import numpy as np

try:
    import fcntl
except ImportError:  # non-POSIX: merge still runs, just without the advisory lock
    fcntl = None

__all__ = ["ScanCheckpoint", "TrainCheckpoint", "config_fingerprint"]


def config_fingerprint(payload: dict) -> str:
    """Stable hash of scan-defining config (mesh EXCLUDED: elastic restarts
    must accept a different topology)."""
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _atomic_write_json(path: str, payload: dict) -> None:
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


class ScanCheckpoint:
    """Grid-cell-granular scan progress under ``root/``:

        manifest.json                    {fingerprint, n_batches, n_blocks,
                                          completed, failed, created, updated}
        batch_<idx>.npz                  committed result shard (n_blocks == 1)
        cell_<idx>_<blk>.npz             committed result shard (blocked scan)

    The unit of progress is one (marker-batch, trait-block) cell of the 2-D
    scan grid (DESIGN.md §10).  Unblocked scans have ``n_blocks == 1`` and
    keep the historical batch-keyed shard layout; blocked scans key every
    shard and manifest entry by cell, so a resume can pick up mid-panel —
    some trait blocks of a marker batch committed, the rest recomputed.
    (Checkpoints written by pre-grid versions are refused by the config
    fingerprint — ``trait_block`` is scan identity, and the grid version
    also changed the step's GEMM tiling — the same strictness as any other
    scan-defining config change.)
    """

    MANIFEST = "manifest.json"

    def __init__(self, root: str, *, fingerprint: str, n_batches: int, n_blocks: int = 1):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.fingerprint = fingerprint
        self.n_batches = n_batches
        self.n_blocks = n_blocks
        self._manifest_path = os.path.join(root, self.MANIFEST)
        # Process-local serialization of manifest state: the distributed
        # executor commits from N worker threads while the scheduler's
        # done-lease verification refreshes from another; the flock below
        # only covers cross-process writers (and not even those on
        # flock-less mounts).
        self._tlock = threading.Lock()
        existing = self._load_manifest()
        if existing is None:
            self._manifest = {
                "fingerprint": fingerprint,
                "n_batches": n_batches,
                "n_blocks": n_blocks,
                "completed": {},
                "failed": {},
                "created": time.time(),
                "updated": time.time(),
            }
            _atomic_write_json(self._manifest_path, self._manifest)
        else:
            if existing["fingerprint"] != fingerprint:
                raise ValueError(
                    f"checkpoint at {root} belongs to a different scan "
                    f"({existing['fingerprint']} != {fingerprint}); refusing to resume"
                )
            if existing["n_batches"] != n_batches:
                raise ValueError(
                    f"batch decomposition changed ({existing['n_batches']} -> {n_batches}); "
                    "keep batch size stable across restarts"
                )
            # Manifests written before the 2-D grid carry no n_blocks: they
            # are unblocked scans by construction.
            if existing.get("n_blocks", 1) != n_blocks:
                raise ValueError(
                    f"trait-block decomposition changed "
                    f"({existing.get('n_blocks', 1)} -> {n_blocks}); "
                    "keep trait_block stable across restarts"
                )
            existing.setdefault("n_blocks", n_blocks)
            self._manifest = existing

    @classmethod
    def open_existing(cls, root: str) -> "ScanCheckpoint":
        """Open a checkpoint directory as-is, trusting its own manifest for
        the fingerprint and grid decomposition.  This is the *read* path
        (``repro.api.session.CheckpointReplay``, the CLI ``merge``
        subcommand): no scan config is available to re-derive the identity,
        and none is needed — nothing is committed through a replay."""
        manifest_path = os.path.join(root, cls.MANIFEST)
        if not os.path.exists(manifest_path):
            raise FileNotFoundError(f"no checkpoint manifest under {root}")
        with open(manifest_path) as f:
            m = json.load(f)
        return cls(
            root,
            fingerprint=m["fingerprint"],
            n_batches=m["n_batches"],
            n_blocks=m.get("n_blocks", 1),
        )

    def _load_manifest(self) -> dict | None:
        if not os.path.exists(self._manifest_path):
            return None
        with open(self._manifest_path) as f:
            return json.load(f)

    # ------------------------------------------------------------- cell keys

    def _key(self, batch: int, block: int) -> str:
        return str(batch) if self.n_blocks == 1 else f"{batch}.{block}"

    def _shard_name(self, batch: int, block: int) -> str:
        if self.n_blocks == 1:
            return f"batch_{batch:06d}.npz"
        return f"cell_{batch:06d}_{block:04d}.npz"

    @property
    def completed(self) -> set[int]:
        """Batch indices with at least one committed cell (all cells, when
        unblocked).  Prefer ``completed_cells`` for grid-aware callers."""
        return {b for b, _ in self.completed_cells()}

    def completed_cells(self) -> set[tuple[int, int]]:
        out: set[tuple[int, int]] = set()
        for k in self._manifest["completed"]:
            if "." in k:
                b, blk = k.split(".", 1)
                out.add((int(b), int(blk)))
            else:
                out.add((int(k), 0))
        return out

    def pending_cells(self) -> list[tuple[int, int]]:
        done = self.completed_cells()
        return [
            (b, k)
            for b in range(self.n_batches)
            for k in range(self.n_blocks)
            if (b, k) not in done
        ]

    def pending_batches(self) -> list[int]:
        """Batches with any pending cell (every pending batch, unblocked)."""
        pending = {b for b, _ in self.pending_cells()}
        return sorted(pending)

    # --------------------------------------------------------------- commits

    @contextlib.contextmanager
    def _commit_lock(self):
        """Advisory flock serializing manifest read-merge-write on one host
        (and across hosts where the shared FS honors flock).  Best-effort:
        where locking is unavailable the atomic-rename merge below still
        converges — concurrent writers can each see the other's entries via
        re-read, and a lost race costs at most a recomputed idempotent cell,
        never a corrupt manifest."""
        if fcntl is None:
            yield
            return
        lock_path = os.path.join(self.root, ".manifest.lock")
        try:
            fd = os.open(lock_path, os.O_CREAT | os.O_RDWR, 0o644)
        except OSError:
            yield
            return
        try:
            try:
                fcntl.flock(fd, fcntl.LOCK_EX)
            except OSError:
                pass  # FS without flock support (some NFS mounts)
            yield
        finally:
            os.close(fd)

    def _locked_manifest_update(self, mutate) -> None:
        """Re-read, merge, mutate, atomically publish the manifest.

        ``commit_cell`` used to rewrite the file from the process-local
        dict, so two processes sharing a checkpoint dir dropped each
        other's ``completed`` entries (classic lost update).  Now every
        manifest write folds the on-disk state in first: ``completed`` is
        the union (shard payloads are deterministic, so colliding keys
        agree), ``failed`` is the union minus anything since completed."""
        with self._tlock, self._commit_lock():
            disk = self._load_manifest()
            if disk is not None:
                merged_completed = {**disk.get("completed", {}), **self._manifest["completed"]}
                merged_failed = {**disk.get("failed", {}), **self._manifest["failed"]}
                self._manifest["completed"] = merged_completed
                self._manifest["failed"] = {
                    k: v for k, v in merged_failed.items() if k not in merged_completed
                }
            mutate(self._manifest)
            self._manifest["updated"] = time.time()
            _atomic_write_json(self._manifest_path, self._manifest)

    def refresh(self) -> None:
        """Fold the on-disk manifest into memory without writing — lets a
        shared-fs host see cells its peers committed (pending computation,
        final replay) without racing a write of its own."""
        with self._tlock:
            disk = self._load_manifest()
            if disk is None:
                return
            completed = {**disk.get("completed", {}), **self._manifest["completed"]}
            failed = {**disk.get("failed", {}), **self._manifest["failed"]}
            self._manifest["completed"] = completed
            self._manifest["failed"] = {k: v for k, v in failed.items() if k not in completed}

    def has_cell(self, batch: int, block: int) -> bool:
        """True iff the cell is in the freshly re-read manifest — the
        shared-fs queue's arbiter for whether a peer's done lease can be
        trusted (DESIGN.md §14): a done marker whose commit lost the
        manifest merge must be recomputed, not skipped forever."""
        self.refresh()
        with self._tlock:
            return self._key(batch, block) in self._manifest["completed"]

    def commit_cell(self, batch: int, block: int, arrays: dict[str, np.ndarray]) -> str:
        """Write the shard, then the manifest — in that order, so a crash
        between the two just re-does one grid cell.  The manifest write is
        a read-merge-write (see ``_locked_manifest_update``), so concurrent
        committers in different processes never drop each other's cells."""
        shard = os.path.join(self.root, self._shard_name(batch, block))
        # Unique tmp (same idiom as _atomic_write_json): double completion
        # of one cell across processes is a SUPPORTED race (lease steal,
        # TTL expiry), and a fixed ``shard + ".tmp"`` path would let one
        # committer truncate the file the other is about to publish —
        # worst case a torn shard recorded completed.
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp.npz")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez_compressed(f, **arrays)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, shard)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        key = self._key(batch, block)
        base = os.path.basename(shard)

        def mutate(m):
            m["completed"][key] = base
            m["failed"].pop(key, None)

        self._locked_manifest_update(mutate)
        return shard

    def commit_batch(self, idx: int, arrays: dict[str, np.ndarray]) -> str:
        return self.commit_cell(idx, 0, arrays)

    def record_failure(self, idx: int, err: str, block: int = 0) -> None:
        key = self._key(idx, block)
        msg = err[:500]

        def mutate(m):
            if key not in m["completed"]:
                m["failed"][key] = msg

        self._locked_manifest_update(mutate)

    def load_cell(self, batch: int, block: int) -> dict[str, np.ndarray]:
        name = self._manifest["completed"][self._key(batch, block)]
        with np.load(os.path.join(self.root, name)) as z:
            return {k: z[k] for k in z.files}

    def load_batch(self, idx: int) -> dict[str, np.ndarray]:
        return self.load_cell(idx, 0)

    def is_complete(self) -> bool:
        return len(self._manifest["completed"]) == self.n_batches * self.n_blocks


class TrainCheckpoint:
    """Step-granular pytree checkpoints: ``step_<n>/arrays.npz`` + manifest."""

    def __init__(self, root: str, *, keep_last: int = 3):
        self.root = root
        self.keep_last = keep_last
        os.makedirs(root, exist_ok=True)
        self._manifest_path = os.path.join(root, "manifest.json")

    def latest_step(self) -> int | None:
        if not os.path.exists(self._manifest_path):
            return None
        with open(self._manifest_path) as f:
            steps = json.load(f).get("steps", [])
        return max(steps) if steps else None

    def save(self, step: int, flat_state: dict[str, np.ndarray], extra: dict | None = None) -> None:
        d = os.path.join(self.root, f"step_{step:08d}")
        os.makedirs(d, exist_ok=True)
        tmp = os.path.join(d, "arrays.tmp.npz")
        np.savez(tmp, **flat_state)
        os.replace(tmp, os.path.join(d, "arrays.npz"))
        if extra:
            _atomic_write_json(os.path.join(d, "extra.json"), extra)
        steps = []
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                steps = json.load(f).get("steps", [])
        steps = sorted(set(steps) | {step})
        _atomic_write_json(self._manifest_path, {"steps": steps})
        # Retention: drop oldest beyond keep_last.
        for old in steps[: -self.keep_last]:
            od = os.path.join(self.root, f"step_{old:08d}")
            if os.path.isdir(od):
                for name in os.listdir(od):
                    os.unlink(os.path.join(od, name))
                os.rmdir(od)
        _atomic_write_json(self._manifest_path, {"steps": steps[-self.keep_last :]})

    def restore(self, step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        with np.load(os.path.join(self.root, f"step_{step:08d}", "arrays.npz")) as z:
            return step, {k: z[k] for k in z.files}
