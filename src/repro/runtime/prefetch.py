"""Host-side pipeline: plan marker batches, decode/repack on worker threads,
overlap with device compute through a bounded queue, and double-buffer the
host->device transfer.

Four cooperating pieces (DESIGN.md §3, §10):

``BatchPlanner``      maps the global marker range onto ``MarkerBatch`` work
                      items.  Batches never cross a shard boundary of a
                      multi-file source, so every item is one contiguous read
                      from one file — items from different files then stream
                      and prefetch concurrently on the worker pool.
``TraitBlockPlanner`` maps the trait (phenotype) axis onto ``TraitBlock``
                      tiles, making the scan a 2-D (marker-batch x
                      trait-block) grid.  The marker stream is the outer
                      loop, so each staged genotype batch is reused across
                      every resident trait block before the next H2D copy.
``Prefetcher``        runs the engine's host-side batch preparation on worker
                      threads, yielding in submission order with a bounded
                      in-flight window.
``double_buffer``     issues the (async) host->device transfer for batch k+1
                      while the device computes on batch k.

The GWAS scan is IO-bound on the genotype stream when the fused kernel path
is active (2-bit slabs are only N/4 bytes per marker), so a shallow queue and
one or two decode workers keep the device saturated; both knobs are config.

Under packed genotype staging (DESIGN.md §17) the currency these workers
carry is the raw 2-bit slab itself: ``prepare_batch`` reads through the
shared ``repro.io.packed_cache`` LRU (one disk read per (source, batch)
across scan, GRM, and serve consumers) and the float decode happens on
device, so a "decode" worker's cost drops to a memcpy plus per-marker stat
LUTs.  The pipeline shape here is unchanged — only the payload shrinks ~16x.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Iterator, TypeVar

T = TypeVar("T")
U = TypeVar("U")
V = TypeVar("V")

__all__ = [
    "MarkerBatch",
    "BatchPlanner",
    "TraitBlock",
    "TraitBlockPlanner",
    "Prefetcher",
    "DecodePool",
    "double_buffer",
]

_SENTINEL = object()


@dataclass(frozen=True)
class MarkerBatch:
    """One schedulable unit of scan work: a contiguous global marker range
    that maps onto a single genotype shard (file)."""

    index: int       # position in the plan == checkpoint batch id
    lo: int          # global marker start (inclusive)
    hi: int          # global marker end (exclusive)
    source_id: int   # shard ordinal (0 for single-file sources)
    local_lo: int    # the same range in the shard's own marker indexing
    local_hi: int

    @property
    def n_markers(self) -> int:
        return self.hi - self.lo


class BatchPlanner:
    """Deterministically decompose a genotype source into ``MarkerBatch``es.

    Sources exposing ``shard_boundaries`` (e.g. ``io.MultiFileSource``) get a
    boundary-respecting plan; plain sources get the classic fixed-stride
    decomposition.  The plan depends only on (source layout, batch_markers),
    never on mesh/host topology, so checkpoints stay elastic across restarts.
    """

    def __init__(self, batch_markers: int):
        if batch_markers <= 0:
            raise ValueError(f"batch_markers must be positive, got {batch_markers}")
        self.batch_markers = batch_markers

    def plan(self, source: Any) -> list[MarkerBatch]:
        boundaries = tuple(
            getattr(source, "shard_boundaries", None) or (0, source.n_markers)
        )
        b = self.batch_markers
        out: list[MarkerBatch] = []
        for sid, (base, end) in enumerate(zip(boundaries[:-1], boundaries[1:])):
            for lo in range(base, end, b):
                hi = min(lo + b, end)
                out.append(
                    MarkerBatch(
                        index=len(out),
                        lo=lo,
                        hi=hi,
                        source_id=sid,
                        local_lo=lo - base,
                        local_hi=hi - base,
                    )
                )
        return out


@dataclass(frozen=True)
class TraitBlock:
    """One tile of the trait (phenotype) axis — the second dimension of the
    2-D scan grid.  ``index`` is the block ordinal; ``lo:hi`` the global
    trait range the block covers."""

    index: int
    lo: int          # global trait start (inclusive)
    hi: int          # global trait end (exclusive)

    @property
    def n_traits(self) -> int:
        return self.hi - self.lo


class TraitBlockPlanner:
    """Deterministically tile the trait axis into ``TraitBlock``s.

    ``trait_block=0`` (the default) means unblocked: one block spanning the
    whole panel, which reproduces the classic 1-D scan exactly.  Like the
    marker plan, the decomposition depends only on (n_traits, trait_block,
    quantum), never on topology, so checkpoint grid cells stay valid across
    restarts.

    ``quantum`` is the panel-axis *compute tile* of the device steps
    (``ScanConfig.block_p``; the fused kernel's p-tile and the dense/lmm
    GEMM's ``trait_tile``).  A non-zero ``trait_block`` is rounded UP to a
    multiple of it, so every block is a union of whole, globally-aligned
    compute tiles: each tile's GEMM is then the *same shape over the same
    columns* no matter how the trait axis is blocked — the mechanism behind
    the blocked == unblocked bitwise contract (DESIGN.md §10).  GEMM
    micro-kernels group accumulators by output width, so unaligned blocks
    would compute last bits differently.
    """

    def __init__(self, trait_block: int = 0, *, quantum: int = 1):
        if trait_block < 0:
            raise ValueError(f"trait_block must be >= 0, got {trait_block}")
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        if trait_block:
            trait_block = ((trait_block + quantum - 1) // quantum) * quantum
        self.trait_block = trait_block
        self.quantum = quantum

    def plan(self, n_traits: int) -> list[TraitBlock]:
        if n_traits <= 0:
            raise ValueError(f"n_traits must be positive, got {n_traits}")
        b = self.trait_block or n_traits
        return [
            TraitBlock(index=i, lo=lo, hi=min(lo + b, n_traits))
            for i, lo in enumerate(range(0, n_traits, b))
        ]


def double_buffer(items: Iterable[T], stage: Callable[[T], V]) -> Iterator[V]:
    """Stage item k+1 (issue its async host->device transfer) before the
    consumer finishes computing on item k — classic two-deep pipelining.

    ``stage`` must only *launch* the transfer (``jnp.asarray`` /
    ``jax.device_put`` are asynchronous on accelerators); the device runtime
    overlaps the copy with whatever the consumer enqueued for item k.
    """
    staged: V | object = _SENTINEL
    for item in items:
        nxt = stage(item)
        if staged is not _SENTINEL:
            yield staged  # type: ignore[misc]
        staged = nxt
    if staged is not _SENTINEL:
        yield staged  # type: ignore[misc]


class DecodePool:
    """Dynamic-submission sibling of ``Prefetcher`` for the pipelined
    multi-device executor (DESIGN.md §15).

    ``Prefetcher`` walks a *static* item list in order — the serial
    executor's shape.  Device workers instead discover their items one
    lease at a time from the scheduler, so they need submit/collect:
    ``submit(key, item)`` enqueues ``fn(item)`` on the shared worker pool
    and ``result(key)`` blocks until that result (re-raising the worker's
    exception, so a decode failure surfaces on the submitting worker's
    claim loop, not in a log).  The pool is shared across every device
    slot: total host decode parallelism is ``num_workers`` —
    ``ScanConfig.io_workers`` means the same thing it means for the serial
    executor's ``Prefetcher``, however many devices drain the grid.

    Keys are caller-chosen and must be unique among in-flight submissions
    (the executor uses ``(slot, batch_index)``).  ``shutdown`` drops
    pending tasks, lets in-flight ones finish, and joins the threads —
    the error-path teardown contract, same as ``Prefetcher``.
    """

    def __init__(self, fn: Callable[[Any], Any], *, num_workers: int = 2,
                 name: str = "slot-decode"):
        self._fn = fn
        self._tasks: list[tuple[Any, Any]] = []       # (key, item) FIFO
        self._results: dict[Any, object] = {}
        self._errors: dict[Any, BaseException] = {}
        self._pending: set[Any] = set()               # submitted, unserved
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._stop = False
        self._workers = [
            threading.Thread(target=self._worker, daemon=True, name=f"{name}-{i}")
            for i in range(max(1, num_workers))
        ]
        for w in self._workers:
            w.start()

    def submit(self, key: Any, item: Any) -> None:
        with self._lock:
            if self._stop:
                return
            if key in self._pending:
                raise ValueError(f"duplicate in-flight decode key {key!r}")
            self._pending.add(key)
            self._tasks.append((key, item))
            self._ready.notify_all()

    def result(self, key: Any) -> Any:
        """Block until ``key``'s decode lands, pop it, re-raise its error."""
        with self._lock:
            while True:
                if key in self._errors:
                    self._pending.discard(key)
                    raise self._errors.pop(key)
                if key in self._results:
                    self._pending.discard(key)
                    return self._results.pop(key)
                if self._stop:
                    raise RuntimeError(f"DecodePool stopped before {key!r} resolved")
                if key not in self._pending:
                    raise KeyError(f"decode key {key!r} was never submitted")
                self._ready.wait()

    def ready(self, key: Any) -> bool:
        """Non-blocking probe: has ``key``'s decode landed (result or
        error)?  Lets a pipelined worker stage early without risking a
        block on an unfinished decode."""
        with self._lock:
            return key in self._results or key in self._errors

    def discard(self, key: Any) -> None:
        """Forget a submission whose result is no longer wanted (teardown
        of a worker's look-ahead).  In-flight work completes and is dropped;
        queued work is cancelled."""
        with self._lock:
            self._tasks = [(k, it) for k, it in self._tasks if k != key]
            self._results.pop(key, None)
            self._errors.pop(key, None)
            self._pending.discard(key)
            self._ready.notify_all()

    def _worker(self) -> None:
        while True:
            with self._lock:
                while not self._stop and not self._tasks:
                    self._ready.wait()
                if self._stop:
                    return
                key, item = self._tasks.pop(0)
            try:
                out = self._fn(item)
                with self._lock:
                    if key in self._pending:
                        self._results[key] = out
                    self._ready.notify_all()
            except BaseException as e:  # noqa: BLE001 — reported to submitter
                with self._lock:
                    if key in self._pending:
                        self._errors[key] = e
                    self._ready.notify_all()

    def shutdown(self, *, join_timeout: float = 5.0) -> None:
        """Stop the pool and join worker threads (idempotent)."""
        with self._lock:
            self._stop = True
            self._tasks.clear()
            self._ready.notify_all()
        for w in self._workers:
            if w.is_alive() and w is not threading.current_thread():
                w.join(timeout=join_timeout)


class Prefetcher:
    """Run ``fn`` over ``items`` on ``num_workers`` threads, yielding results
    in submission order with at most ``depth`` items in flight.

    Ordered delivery matters: scan batches commit in order per shard file,
    and the device stream consumes deterministically.  Workers pull from a
    shared index so a slow item (straggler) never idles the other workers —
    they keep filling the window behind it.
    """

    def __init__(
        self,
        items: Iterable[T],
        fn: Callable[[T], U],
        *,
        depth: int = 3,
        num_workers: int = 2,
    ):
        self._items = list(items)
        self._fn = fn
        self._depth = max(1, depth)
        self._results: dict[int, object] = {}
        self._errors: dict[int, BaseException] = {}
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._next_submit = 0
        self._next_yield = 0
        self._stop = False
        self._workers = [
            threading.Thread(target=self._worker, daemon=True, name=f"prefetch-worker-{i}")
            for i in range(max(1, num_workers))
        ]

    def _claim(self) -> int | None:
        with self._lock:
            while not self._stop:
                if self._next_submit >= len(self._items):
                    return None
                # Window control: stay at most `depth` ahead of the consumer.
                if self._next_submit - self._next_yield < self._depth:
                    idx = self._next_submit
                    self._next_submit += 1
                    return idx
                self._ready.wait(timeout=0.1)
            return None

    def _worker(self) -> None:
        while True:
            idx = self._claim()
            if idx is None:
                return
            try:
                out = self._fn(self._items[idx])
                with self._lock:
                    self._results[idx] = out
                    self._ready.notify_all()
            except BaseException as e:  # noqa: BLE001 — reported to consumer
                with self._lock:
                    self._errors[idx] = e
                    self._ready.notify_all()

    def shutdown(self, *, join_timeout: float = 5.0) -> None:
        """Stop the worker pool and join the threads (idempotent).

        Called by the consumer's error path as well as normal exhaustion:
        a sink or engine step raising mid-scan must not leave decode workers
        alive, still pulling from the genotype source.
        """
        with self._lock:
            self._stop = True
            self._ready.notify_all()
        for w in self._workers:
            if w.is_alive() and w is not threading.current_thread():
                w.join(timeout=join_timeout)

    def __iter__(self) -> Iterator[U]:
        for w in self._workers:
            w.start()
        try:
            while self._next_yield < len(self._items):
                with self._lock:
                    while (
                        self._next_yield not in self._results
                        and self._next_yield not in self._errors
                    ):
                        self._ready.wait()
                    idx = self._next_yield
                    err = self._errors.pop(idx, None)
                    out = self._results.pop(idx, None)
                    self._next_yield += 1
                    self._ready.notify_all()
                if err is not None:
                    raise err
                yield out  # type: ignore[misc]
        finally:
            self.shutdown()
