"""Host-side pipeline: decode/repack on worker threads, overlap with device
compute through a bounded queue (double/triple buffering).

The GWAS scan is IO-bound on the genotype stream when the fused kernel path
is active (2-bit slabs are only N/4 bytes per marker), so a shallow queue and
one or two decode workers keep the device saturated; both knobs are config.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, TypeVar

T = TypeVar("T")
U = TypeVar("U")

__all__ = ["Prefetcher"]

_SENTINEL = object()


class Prefetcher:
    """Run ``fn`` over ``items`` on ``num_workers`` threads, yielding results
    in submission order with at most ``depth`` items in flight.

    Ordered delivery matters: scan batches commit in order per shard file,
    and the device stream consumes deterministically.  Workers pull from a
    shared index so a slow item (straggler) never idles the other workers —
    they keep filling the window behind it.
    """

    def __init__(
        self,
        items: Iterable[T],
        fn: Callable[[T], U],
        *,
        depth: int = 3,
        num_workers: int = 2,
    ):
        self._items = list(items)
        self._fn = fn
        self._depth = max(1, depth)
        self._results: dict[int, object] = {}
        self._errors: dict[int, BaseException] = {}
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._next_submit = 0
        self._next_yield = 0
        self._stop = False
        self._workers = [
            threading.Thread(target=self._worker, daemon=True) for _ in range(max(1, num_workers))
        ]

    def _claim(self) -> int | None:
        with self._lock:
            while not self._stop:
                if self._next_submit >= len(self._items):
                    return None
                # Window control: stay at most `depth` ahead of the consumer.
                if self._next_submit - self._next_yield < self._depth:
                    idx = self._next_submit
                    self._next_submit += 1
                    return idx
                self._ready.wait(timeout=0.1)
            return None

    def _worker(self) -> None:
        while True:
            idx = self._claim()
            if idx is None:
                return
            try:
                out = self._fn(self._items[idx])
                with self._lock:
                    self._results[idx] = out
                    self._ready.notify_all()
            except BaseException as e:  # noqa: BLE001 — reported to consumer
                with self._lock:
                    self._errors[idx] = e
                    self._ready.notify_all()

    def __iter__(self) -> Iterator[U]:
        for w in self._workers:
            w.start()
        try:
            while self._next_yield < len(self._items):
                with self._lock:
                    while (
                        self._next_yield not in self._results
                        and self._next_yield not in self._errors
                    ):
                        self._ready.wait()
                    idx = self._next_yield
                    err = self._errors.pop(idx, None)
                    out = self._results.pop(idx, None)
                    self._next_yield += 1
                    self._ready.notify_all()
                if err is not None:
                    raise err
                yield out  # type: ignore[misc]
        finally:
            with self._lock:
                self._stop = True
                self._ready.notify_all()
            for w in self._workers:
                if w.is_alive():
                    w.join(timeout=1.0)
