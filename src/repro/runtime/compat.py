"""Version shims for the installed jax.

``shard_map`` moved from ``jax.experimental.shard_map`` (0.4.x, kwarg
``check_rep``) to the top-level ``jax.shard_map`` (0.5+, kwarg
``check_vma``).  All in-repo call sites use the new calling convention and
route through :func:`shard_map` here, which translates for old jax.

Importing this module also installs the shim as ``jax.shard_map`` when the
attribute is missing, so subprocess harnesses and user scripts written
against the new API run unchanged on jax 0.4.x.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["shard_map", "token_prefix_sum"]

_JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())


def shard_map(
    f: Callable[..., Any],
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool | None = None,
    **kwargs,
):
    """``jax.shard_map`` with the 0.5+ signature on any supported jax."""
    native = getattr(jax, "shard_map", None)
    if native is not None and native is not _shim:
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as legacy

    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def _shim(f, *, mesh, in_specs, out_specs, **kwargs):
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


if not hasattr(jax, "shard_map"):
    jax.shard_map = _shim


def token_prefix_sum(x: jax.Array, axis: int = 0) -> jax.Array:
    """Inclusive prefix sum along ``axis``, safe under GSPMD partitioning.

    The 0.4.x SPMD partitioner miscompiles ``lax.associative_scan`` when the
    scanned axis ends up sharded (silently wrong values — each shard scans
    locally with no cross-shard carry), which MoE routing hits as soon as an
    output sharding constraint propagates a token-sharded layout into the
    dispatch cumsum.  ``jnp.cumsum`` partitions correctly everywhere, so old
    jax takes that path; newer jax keeps the log-depth associative scan
    (``cumsum``'s reduce-window lowering is costed O(T^2) on some backends).
    """
    if _JAX_VERSION >= (0, 5, 0):
        return jax.lax.associative_scan(jnp.add, x, axis=axis)
    return jnp.cumsum(x, axis=axis)
