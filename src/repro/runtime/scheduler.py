"""Grid-cell scheduling for the multi-device executor (DESIGN.md §12).

The 2-D (marker-batch x trait-block) scan grid is an embarrassingly
schedulable work surface; what distinguishes good from bad placement is
*which staged array a device gets to reuse* (Beyer & Bientinesi: sustained
throughput is bounded by stream locality and IO/compute overlap):

    marker-major   a work item is one marker batch carrying a run of trait
                   blocks: the claiming device stages the genotype batch
                   ONCE and sweeps its blocks before touching the queue
                   again.  Genotype traffic is paid once per batch across
                   the whole fleet; panel blocks re-ship per device.
    trait-major    items are single cells enumerated block-major (all
                   batches of trait block 0, then block 1, ...): contiguous
                   leases keep one panel block resident per device while
                   the genotype stream is re-read per column.  The right
                   trade when the panel block dwarfs the genotype batch.

Distribution itself is the lease/steal discipline of
``runtime.workqueue.WorkQueue`` — contiguous runs of items are leased per
claim (amortizing queue traffic), and a device that drains its lease steals
the largest remaining tail.  Items are never split: stealing therefore
happens at *marker-batch granularity* (a marker-major item is one batch's
whole sweep; a trait-major item is one batch's single cell), so a stolen
cell never tears a staged genotype batch away from the device using it.

Cells are idempotent — the checkpoint manifest deduplicates double
completion — so stealing is always safe; completion order is free, and the
sinks/writers normalize their folds (DESIGN.md §10, §12).

This module is jax-free by design: it schedules *indices*, the executor
owns devices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

from repro.runtime.workqueue import WorkerStats, get_backend

__all__ = ["CellRun", "CellScheduler", "PLACEMENTS"]

PLACEMENTS = ("marker-major", "trait-major")


@dataclass(frozen=True)
class CellRun:
    """One schedulable unit: a marker batch crossed with a run of trait
    blocks — the cells a device computes off a single staged genotype
    batch.  ``blocks`` is every pending block of the batch under
    marker-major placement, exactly one under trait-major."""

    batch: Any                 # runtime.prefetch.MarkerBatch
    blocks: tuple              # runtime.prefetch.TraitBlock, ascending

    @property
    def n_cells(self) -> int:
        return len(self.blocks)


class CellScheduler:
    """Map pending grid cells onto executor slots with work stealing.

    ``batches``/``blocks`` are the planned grid axes; ``pending`` (when
    resuming) restricts the schedule to not-yet-committed cells — a batch
    with some cells committed is swept only over its pending blocks, the
    same mid-panel semantics as the serial executor.  Thread-safe:
    ``claim``/``complete`` are called concurrently from device workers.
    """

    def __init__(
        self,
        batches: Sequence[Any],
        blocks: Sequence[Any],
        pending: set[tuple[int, int]] | None = None,
        *,
        placement: str = "marker-major",
        lease_size: int = 2,
        n_workers: int | None = None,
        backend: str = "threads",
        backend_opts: dict | None = None,
    ):
        if placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {placement!r}; available: {PLACEMENTS}"
            )
        self.placement = placement

        def keep(b, k) -> bool:
            return pending is None or (b.index, k.index) in pending

        items: list[CellRun] = []
        if placement == "marker-major":
            for b in batches:
                blks = tuple(k for k in blocks if keep(b, k))
                if blks:
                    items.append(CellRun(b, blks))
        else:
            for k in blocks:
                items.extend(CellRun(b, (k,)) for b in batches if keep(b, k))
        self.items = items
        # Cap the lease so the initial hand-out spans every slot: with few
        # items and an uncapped lease the first workers would take it all,
        # and a claimed item's immediate pop leaves leases of <= 1 item —
        # unstealable, so late slots would idle for the whole scan.
        if n_workers is not None:
            lease_size = min(lease_size, max(1, len(items) // max(1, n_workers)))
        self.lease_size = max(1, lease_size)
        self.backend = backend
        # ``cell_committed`` in backend_opts is a (batch, block) -> bool
        # manifest probe supplied by the session; distributed backends need
        # it keyed by *item*, and the key->cells mapping is this class's —
        # so the translation happens here: an item's done marker is trusted
        # iff every cell this host would compute for it is in the manifest.
        opts = dict(backend_opts or {})
        cell_committed = opts.pop("cell_committed", None)
        if cell_committed is not None:
            by_key = {self._item_key(run): run for run in items}

            def done_check(key: str) -> bool:
                run = by_key.get(key)
                if run is None:
                    return True   # not an item this host schedules: nothing to verify
                return all(
                    cell_committed(run.batch.index, blk.index) for blk in run.blocks
                )

            opts["done_check"] = done_check
        self._queue = get_backend(backend)(
            len(items),
            keys=[self._item_key(run) for run in items],
            lease_size=self.lease_size,
            **opts,
        )

    def _item_key(self, run: CellRun) -> str:
        """Canonical cross-host identity of a work item.  Distributed
        backends coordinate by key, and hosts resuming with different
        local pending filters must agree on what each key means: under
        marker-major an item is the batch (whatever subset of its blocks
        is pending locally — the checkpoint dedups the overlap); under
        trait-major it is the single (batch, block) cell."""
        if self.placement == "marker-major":
            return f"b{run.batch.index:06d}"
        return f"b{run.batch.index:06d}k{run.blocks[0].index:04d}"

    @property
    def n_items(self) -> int:
        return len(self.items)

    @property
    def n_cells(self) -> int:
        return sum(run.n_cells for run in self.items)

    def claim(self, worker: str, *, block: bool = True) -> tuple[int, CellRun] | None:
        """Next work item for ``worker`` (lease refill / steal inside), or
        None when the grid is drained.  ``block=False`` is the pipelined
        executor's look-ahead probe: distributed backends return
        immediately instead of polling out peers' undone leases, so a
        worker with a cell in flight never parks on the queue."""
        idx = self._queue.claim(worker, block=block)
        if idx is None:
            return None
        return idx, self.items[idx]

    def complete(self, worker: str, idx: int) -> None:
        self._queue.complete(worker, idx)

    def set_lease_size(self, n: int) -> None:
        """Runtime retune of the per-refill lease extent (autotuning hook;
        future refills only)."""
        self.lease_size = max(1, int(n))
        self._queue.set_lease_size(n)

    def remaining(self) -> int:
        return self._queue.remaining()

    def stats(self) -> dict[str, WorkerStats]:
        return self._queue.stats()

    def stop(self) -> None:
        """Unblock any worker parked in a blocking ``claim`` (distributed
        backends poll while peers hold undone leases) — executor teardown
        must call this before joining its worker threads."""
        self._queue.stop()
