"""Straggler-aware distributed work assignment for the scan's batch stream.

At cluster scale the scan is a bag of independent batch indices.  Hosts are
assigned contiguous *leases*; a host that falls behind (straggler) has the
un-started tail of its lease re-assigned to finished hosts (work stealing).
Batches are idempotent — the checkpoint manifest deduplicates double
completion, so stealing is always safe.

The same class drives the single-host thread pool in tests and examples;
at true multi-host scale the lease table would live in the shared filesystem
next to the manifest (same atomic-rename discipline), which is how
``examples/ukb_screening.py`` exercises it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field

__all__ = ["WorkQueue", "WorkerStats"]


@dataclass
class WorkerStats:
    claimed: int = 0
    completed: int = 0
    stolen_from: int = 0
    stolen_by: int = 0
    busy_s: float = 0.0


class WorkQueue:
    """Lease-based batch distribution with work stealing.

    ``lease_size`` batches are claimed at a time (amortizes coordination);
    when a worker exhausts its lease it steals the largest remaining tail
    from the slowest worker.  Thread-safe; deterministic completion set.
    """

    def __init__(self, n_items: int, *, lease_size: int = 8, skip: set[int] | None = None):
        pending = [i for i in range(n_items) if not skip or i not in skip]
        self._pending: list[int] = pending
        self._leases: dict[str, list[int]] = {}
        self._stats: dict[str, WorkerStats] = {}
        self._lease_size = max(1, lease_size)
        self._lock = threading.Lock()
        self._t0: dict[str, float] = {}

    def stats(self) -> dict[str, WorkerStats]:
        """Point-in-time *snapshot* of per-worker accounting.

        Returns copies, not the live ``WorkerStats`` objects: callers hold
        the result across further claims (progress lines, summary.json),
        and handing out the mutable internals would let them corrupt — or
        observe mid-update — the queue's own accounting."""
        with self._lock:
            return {w: dataclasses.replace(st) for w, st in self._stats.items()}

    def remaining(self) -> int:
        with self._lock:
            return len(self._pending) + sum(len(v) for v in self._leases.values())

    def claim(self, worker: str) -> int | None:
        """Next batch index for ``worker``, refilling or stealing as needed."""
        with self._lock:
            st = self._stats.setdefault(worker, WorkerStats())
            now = time.monotonic()
            if worker in self._t0:
                st.busy_s += now - self._t0[worker]
            lease = self._leases.setdefault(worker, [])
            if not lease:
                if self._pending:
                    take = min(self._lease_size, len(self._pending))
                    lease.extend(self._pending[:take])
                    del self._pending[:take]
                else:
                    victim = self._pick_victim(worker)
                    if victim is not None:
                        vlease = self._leases[victim]
                        steal = len(vlease) // 2
                        if steal:
                            lease.extend(vlease[-steal:])
                            del vlease[-steal:]
                            self._stats[victim].stolen_from += steal
                            st.stolen_by += steal
            if not lease:
                return None
            idx = lease.pop(0)
            st.claimed += 1
            self._t0[worker] = time.monotonic()
            return idx

    def _pick_victim(self, thief: str) -> str | None:
        """Largest remaining lease loses half its tail; equal-length leases
        tie-break on the lexicographically greatest worker id, so victim
        choice is deterministic for a given queue state (tested)."""
        candidates = [(len(l), w) for w, l in self._leases.items() if w != thief and len(l) > 1]
        if not candidates:
            return None
        return max(candidates)[1]

    def complete(self, worker: str, idx: int) -> None:
        with self._lock:
            st = self._stats.setdefault(worker, WorkerStats())
            st.completed += 1
            if worker in self._t0:
                st.busy_s += time.monotonic() - self._t0.pop(worker)
