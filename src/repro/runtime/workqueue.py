"""Straggler-aware distributed work assignment for the scan's batch stream.

At cluster scale the scan is a bag of independent batch indices.  Hosts are
assigned contiguous *leases*; a host that falls behind (straggler) has the
un-started tail of its lease re-assigned to finished hosts (work stealing).
Batches are idempotent — the checkpoint manifest deduplicates double
completion, so stealing is always safe.

Two backends implement the same lease/steal discipline (the scheduler
backend is a registry, like engines and writers):

    "threads"    ``WorkQueue`` — the in-process queue that drives one
                 host's device worker threads (DESIGN.md §12).
    "shared-fs"  ``FsWorkQueue`` — the lease table moved to the shared
                 filesystem next to the checkpoint manifest (DESIGN.md
                 §14): one JSON lease file per work item, claimed with
                 the same write-tmp/fsync/atomic-publish discipline the
                 manifest uses, heartbeat timestamps refreshed by a
                 daemon thread, and expiry-based stealing so a
                 SIGKILL'd host's un-started lease tail is reclaimed by
                 the survivors.  N independent processes (on as many
                 hosts as share the filesystem) drain one grid.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import socket
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Callable

__all__ = [
    "WorkQueue",
    "FsWorkQueue",
    "LeasePolicy",
    "WorkerStats",
    "register_backend",
    "get_backend",
    "available_backends",
]


# ------------------------------------------------------------------ registry


_BACKENDS: dict[str, type] = {}


def register_backend(name: str):
    """Register a scheduler backend class under ``name`` (decorator) — the
    same plug-in idiom as ``core.engines.register_engine`` and
    ``api.writers.register_writer``.  Backends share the ``WorkQueue``
    surface: ``claim`` / ``complete`` / ``remaining`` / ``stats`` /
    ``stop``, constructed as ``cls(n_items, keys=..., lease_size=...,
    **backend_opts)``."""

    def deco(cls: type) -> type:
        _BACKENDS[name] = cls
        cls.backend_name = name
        return cls

    return deco


def get_backend(name: str) -> type:
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown scheduler backend {name!r}; available: {available_backends()}"
        )
    return _BACKENDS[name]


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


@dataclass
class WorkerStats:
    claimed: int = 0
    completed: int = 0
    stolen_from: int = 0
    stolen_by: int = 0
    reclaimed: int = 0     # expired foreign leases taken over (shared-fs only)
    busy_s: float = 0.0
    wait_s: float = 0.0    # idle between completing everything and the next item


class _WorkerClock:
    """Busy/wait accounting shared by both queue backends.

    A worker is *busy* while it holds at least one claimed-but-uncompleted
    item and *waiting* otherwise — the pipelined executor claims its next
    item before completing the current one (look-ahead), so intervals are
    attributed by the outstanding count at the time they elapsed, not by
    which call happened to end them.  Every fold advances the worker's
    mark, so no interval is ever counted twice (idle polling folds each
    gap exactly once, into ``wait_s``).  All methods assume the owning
    queue's lock is held.
    """

    def __init__(self) -> None:
        self._mark: dict[str, float] = {}
        self._outstanding: dict[str, int] = {}

    def fold(self, worker: str, st: WorkerStats, now: float) -> None:
        mark = self._mark.get(worker)
        if mark is not None:
            if self._outstanding.get(worker, 0) > 0:
                st.busy_s += now - mark
            else:
                st.wait_s += now - mark
        self._mark[worker] = now

    def claimed(self, worker: str) -> None:
        self._outstanding[worker] = self._outstanding.get(worker, 0) + 1

    def completed(self, worker: str) -> None:
        n = self._outstanding.get(worker, 0)
        self._outstanding[worker] = max(0, n - 1)

    def snapshot_into(self, worker: str, snap: WorkerStats, now: float) -> None:
        """Fold the in-flight interval into a stats *copy* (never the live
        state), so busy/wait stay monotone across snapshots."""
        mark = self._mark.get(worker)
        if mark is not None:
            if self._outstanding.get(worker, 0) > 0:
                snap.busy_s += now - mark
            else:
                snap.wait_s += now - mark


class LeasePolicy:
    """Protocol for pluggable lease-refill order (duck-typed, never
    instantiated): a policy OWNS the pending set and decides which items a
    refilling worker leases next — the fair-share claim path the serve
    layer builds its deficit-round-robin on (``repro.serve.fair``).

    Both methods are invoked with the owning queue's lock held, so
    implementations must be non-blocking and must never call back into the
    queue.  Feeding a policy happens out-of-band (its own ``enroll``-style
    API); after feeding, call ``WorkQueue.kick()`` to wake blocked
    claimers.
    """

    def select(self, k: int) -> list[int]:  # pragma: no cover - protocol
        """Up to ``k`` item indices to lease next, removed from pending."""
        raise NotImplementedError

    def pending_count(self) -> int:  # pragma: no cover - protocol
        raise NotImplementedError


@register_backend("threads")
class WorkQueue:
    """Lease-based batch distribution with work stealing.

    ``lease_size`` batches are claimed at a time (amortizes coordination);
    when a worker exhausts its lease it steals the largest remaining tail
    from the slowest worker.  Thread-safe; deterministic completion set.

    Two optional extensions carry the serve subsystem (both default off,
    leaving the batch executor's behavior byte-identical):

    * ``policy`` — a ``LeasePolicy`` that owns the pending set and decides
      refill order (priority / fair share) instead of the FIFO list.
    * ``persistent`` — a long-lived queue: ``claim(block=True)`` WAITS
      when nothing is available (new items arrive via ``extend``/a policy
      feed + ``kick``) instead of returning ``None``; only ``stop()``
      releases claimers with ``None``.
    """

    def __init__(
        self,
        n_items: int,
        *,
        lease_size: int = 8,
        skip: set[int] | None = None,
        keys: list[str] | None = None,
        done_check: Callable[[str], bool] | None = None,
        policy: "LeasePolicy | None" = None,
        persistent: bool = False,
    ):
        # ``keys`` and ``done_check`` are the cross-host item identity and
        # completion arbiter used by distributed backends; the in-process
        # queue moves plain indices and ignores them (accepted so the
        # scheduler constructs every backend uniformly).
        del keys, done_check
        pending = [i for i in range(n_items) if not skip or i not in skip]
        self._pending: list[int] = pending
        self._leases: dict[str, list[int]] = {}
        self._stats: dict[str, WorkerStats] = {}
        self._lease_size = max(1, lease_size)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._clock = _WorkerClock()
        self._policy = policy
        self._persistent = persistent
        self._stopped = False

    @property
    def lease_size(self) -> int:
        return self._lease_size

    def set_lease_size(self, n: int) -> None:
        """Retune the per-refill lease (runtime autotuning hook).  Only
        future refills are affected — already-leased runs keep their
        extent, so correctness never depends on when this lands."""
        with self._lock:
            self._lease_size = max(1, int(n))

    def stats(self) -> dict[str, WorkerStats]:
        """Point-in-time *snapshot* of per-worker accounting.

        Returns copies, not the live ``WorkerStats`` objects: callers hold
        the result across further claims (progress lines, summary.json),
        and handing out the mutable internals would let them corrupt — or
        observe mid-update — the queue's own accounting.  The in-flight
        interval of a worker is folded into its *copy* (never the live
        state), so ``busy_s``/``wait_s`` are monotone across snapshots and
        a long cell shows up in ``--progress`` utilization while it runs."""
        with self._lock:
            now = time.monotonic()
            out: dict[str, WorkerStats] = {}
            for w, st in self._stats.items():
                snap = dataclasses.replace(st)
                self._clock.snapshot_into(w, snap, now)
                out[w] = snap
            return out

    def remaining(self) -> int:
        with self._lock:
            pend = (
                self._policy.pending_count()
                if self._policy is not None
                else len(self._pending)
            )
            return pend + sum(len(v) for v in self._leases.values())

    def extend(self, items) -> None:
        """Append work items to a live queue (the serve feed path: request
        admission turns grid cells into new indices on the SAME queue the
        workers drain) and wake blocked claimers.  With a ``policy``
        installed, feed the policy instead and call ``kick()``."""
        with self._cv:
            self._pending.extend(int(i) for i in items)
            self._cv.notify_all()

    def kick(self) -> None:
        """Wake blocked claimers after an out-of-band feed (a
        ``LeasePolicy`` enrollment happens outside the queue's lock)."""
        with self._cv:
            self._cv.notify_all()

    def claim(self, worker: str, *, block: bool = True) -> int | None:
        """Next batch index for ``worker``, refilling or stealing as needed.

        On a batch (non-persistent) queue claims never block and ``None``
        means drained.  On a persistent queue ``block=True`` waits for new
        items; ``None`` means ``stop()`` was called.
        """
        with self._cv:
            st = self._stats.setdefault(worker, WorkerStats())
            # Attribute the interval since the worker's last event by its
            # outstanding count THEN: a pipelined worker polling for its
            # look-ahead while a cell is still in flight stays busy; a
            # worker with nothing in hand accrues wait.  Each fold advances
            # the mark, so no interval is ever double-counted.
            self._clock.fold(worker, st, time.monotonic())
            while True:
                idx = self._next_locked(worker, st)
                if idx is not None:
                    st.claimed += 1
                    self._clock.claimed(worker)
                    return idx
                if self._stopped or not (self._persistent and block):
                    return None
                self._cv.wait(timeout=0.25)
                self._clock.fold(worker, st, time.monotonic())

    def _next_locked(self, worker: str, st: WorkerStats) -> int | None:
        """Refill-or-steal under the lock: one attempt, no waiting."""
        lease = self._leases.setdefault(worker, [])
        if not lease:
            if self._policy is not None:
                lease.extend(self._policy.select(self._lease_size))
            elif self._pending:
                take = min(self._lease_size, len(self._pending))
                lease.extend(self._pending[:take])
                del self._pending[:take]
            if not lease:
                victim = self._pick_victim(worker)
                if victim is not None:
                    vlease = self._leases[victim]
                    steal = len(vlease) // 2
                    if steal:
                        lease.extend(vlease[-steal:])
                        del vlease[-steal:]
                        self._stats[victim].stolen_from += steal
                        st.stolen_by += steal
        if not lease:
            return None
        return lease.pop(0)

    def _pick_victim(self, thief: str) -> str | None:
        """Largest remaining lease loses half its tail; equal-length leases
        tie-break on the lexicographically greatest worker id, so victim
        choice is deterministic for a given queue state (tested)."""
        candidates = [(len(l), w) for w, l in self._leases.items() if w != thief and len(l) > 1]
        if not candidates:
            return None
        return max(candidates)[1]

    def complete(self, worker: str, idx: int) -> None:
        with self._lock:
            st = self._stats.setdefault(worker, WorkerStats())
            st.completed += 1
            self._clock.fold(worker, st, time.monotonic())
            self._clock.completed(worker)

    def stop(self) -> None:
        """Teardown: release blocked claimers with ``None``.  (A no-op on
        batch queues, whose claims never block.)"""
        with self._cv:
            self._stopped = True
            self._cv.notify_all()


# -------------------------------------------------------- shared-fs backend


def _publish_exclusive(path: str, payload: dict) -> bool:
    """Atomically publish ``payload`` at ``path`` iff nothing is there.

    write-tmp + fsync (the manifest's discipline), then ``os.link`` —
    which, unlike ``os.replace``, FAILS when the target exists: the
    exclusive-create that makes a fresh lease claim race-free across
    hosts (hard links are atomic on POSIX shared filesystems, NFS
    included)."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        try:
            os.link(tmp, path)
            return True
        except FileExistsError:
            return False
    finally:
        os.unlink(tmp)


def _overwrite_json(path: str, payload: dict) -> None:
    """Atomic clobbering write (heartbeat refresh, steal, done marker) —
    write-tmp/fsync/``os.replace``, byte-for-byte the manifest's idiom."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


@register_backend("shared-fs")
class FsWorkQueue:
    """Shared-filesystem lease table: elastic multi-host work distribution.

    One JSON lease file per work item under ``root/`` (DESIGN.md §14):

        lease_<key>.json   {key, host, worker, claimed, heartbeat,
                            state: "leased" | "done", steals}

    Claim protocol:

    * **fresh claim** — exclusive atomic publish of the lease file
      (``os.link``); losing the race means another host owns the item.
    * **heartbeat** — a daemon thread refreshes the ``heartbeat`` wall
      timestamp of every lease this host holds (every ``lease_ttl / 4``),
      so liveness is observable through the filesystem alone.
    * **expiry steal** — a lease whose heartbeat is older than
      ``lease_ttl`` belongs to a dead (or stalled) host: any survivor
      atomically overwrites it with its own lease and recomputes the item.
      A SIGKILL kills the heartbeat thread with the process, so the
      victim's whole un-started lease tail expires and is reclaimed.
    * **done** — completion overwrites the lease with ``state: "done"``;
      done leases are never stolen and tell late joiners to skip.  When a
      ``done_check`` is installed (the scheduler wires it to the
      checkpoint manifest), a done marker is only trusted if the check
      confirms it: a marker whose commit lost the manifest merge (a
      flock-less mount dropping a concurrent write) names a cell that
      was never durably recorded — nobody heartbeats it and resumes
      would skip it, so it is reclaimed and recomputed instead of
      silently leaving the grid incomplete.

    Safety does NOT depend on mutual exclusion: two hosts that race a
    steal (or a too-small ``lease_ttl`` under a long cell) both compute
    the item, and the checkpoint manifest deduplicates the idempotent,
    bit-identical commits.  ``lease_ttl`` is a liveness/efficiency knob,
    never a correctness one.

    Items are identified by ``keys`` — canonical strings that mean the
    same grid cells on every host regardless of each host's local pending
    filter — and ``claim`` returns the *local* index of the claimed key.
    ``claim`` blocks (polling) while other hosts still hold undone items,
    so a surviving host drains a dead host's tail instead of exiting
    early; pass ``block=False`` to poll once.  Hosts' wall clocks are
    assumed loosely synchronized (well within ``lease_ttl``), the usual
    shared-filesystem-cluster contract.
    """

    def __init__(
        self,
        n_items: int,
        *,
        keys: list[str] | None = None,
        lease_size: int = 8,
        skip: set[int] | None = None,
        root: str | None = None,
        host_id: str | None = None,
        lease_ttl: float = 60.0,
        poll_s: float | None = None,
        done_check: Callable[[str], bool] | None = None,
    ):
        if root is None:
            raise ValueError("FsWorkQueue needs root= (the shared lease directory)")
        if lease_ttl <= 0:
            raise ValueError(f"lease_ttl must be positive, got {lease_ttl}")
        self.root = root
        os.makedirs(root, exist_ok=True)
        all_keys = (
            list(keys) if keys is not None else [f"{i:06d}" for i in range(n_items)]
        )
        if len(all_keys) != n_items:
            raise ValueError(f"{len(all_keys)} keys for {n_items} items")
        if len(set(all_keys)) != len(all_keys):
            raise ValueError("work item keys must be unique")
        self._key_of: dict[int, str] = dict(enumerate(all_keys))
        self._index_of: dict[str, int] = {k: i for i, k in enumerate(all_keys)}
        self._keys: list[str] = [
            k for i, k in enumerate(all_keys) if not skip or i not in skip
        ]
        self.host_id = host_id or f"{socket.gethostname()}-{os.getpid()}"
        self.lease_ttl = float(lease_ttl)
        self.poll_s = (
            float(poll_s)
            if poll_s is not None
            else max(0.05, min(1.0, self.lease_ttl / 10.0))
        )
        self._lease_size = max(1, lease_size)
        self._done_check = done_check
        self._lock = threading.Lock()
        # Serializes per-key lease-file writes between the heartbeat loop
        # and ``complete`` — never held across FS scans, so it cannot
        # starve anything; see ``_heartbeat_loop`` for the ordering it
        # guarantees.
        self._write_lock = threading.Lock()
        self._stop = threading.Event()
        self._stats: dict[str, WorkerStats] = {}
        self._clock = _WorkerClock()
        self._leases: dict[str, list[str]] = {}   # worker -> claimed, unserved
        self._held: set[str] = set()              # our live FS leases
        self._records: dict[str, dict] = {}       # held key -> last lease JSON
        self._not_done: set[str] = set(self._keys)
        # Hosts start their fresh-claim scan at a host-hash offset so a
        # simultaneously-starting fleet mostly claims disjoint regions
        # first (fewer lost races; results are identical regardless).
        n = max(1, len(self._keys))
        self._scan0 = int(hashlib.sha256(self.host_id.encode()).hexdigest(), 16) % n
        self._hb_thread: threading.Thread | None = None

    # ------------------------------------------------------------ lease files

    def _lease_path(self, key: str) -> str:
        return os.path.join(self.root, f"lease_{key}.json")

    def _record(self, key: str, worker: str, state: str, *, steals: int = 0) -> dict:
        now = time.time()
        return {
            "key": key,
            "host": self.host_id,
            "worker": worker,
            "claimed": now,
            "heartbeat": now,
            "state": state,
            "steals": steals,
        }

    def _read_lease(self, key: str) -> dict | None:
        """None: no lease file (unclaimed).  A torn/corrupt file reads as an
        empty record — its heartbeat then falls back to the file mtime, so
        a crashed writer's leftovers still expire and get reclaimed."""
        try:
            with open(self._lease_path(key)) as f:
                return json.load(f)
        except FileNotFoundError:
            return None
        except (json.JSONDecodeError, OSError):
            return {}

    # -------------------------------------------------------------- heartbeat

    def _ensure_heartbeat_locked(self) -> None:
        if self._hb_thread is None and not self._stop.is_set():
            t = threading.Thread(
                target=self._heartbeat_loop,
                daemon=True,
                name=f"fs-lease-heartbeat-{self.host_id}",
            )
            self._hb_thread = t
            t.start()

    def _heartbeat_loop(self) -> None:
        """Refresh held leases' heartbeats.  The FS writes run OUTSIDE
        ``self._lock`` (a slow shared FS must not block claims, and claims
        must not block heartbeats): the held set is snapshotted under the
        lock, then each write re-checks the key under the lock while
        holding ``_write_lock`` — ``complete`` writes its done marker
        under the same ``_write_lock`` *after* releasing the key, so a
        stale "leased" record can never clobber a done marker (either the
        re-check sees the key released and skips, or the done write lands
        after ours)."""
        interval = max(0.05, self.lease_ttl / 4.0)
        while not self._stop.wait(interval):
            with self._lock:
                held = sorted(self._held)
            now = time.time()
            for key in held:
                with self._write_lock:
                    with self._lock:
                        rec = self._records.get(key)
                        if (
                            key not in self._held
                            or rec is None
                            or rec.get("state") == "done"
                        ):
                            continue
                        rec["heartbeat"] = now
                        payload = dict(rec)
                    try:
                        _overwrite_json(self._lease_path(key), payload)
                    except OSError:
                        # A transiently unwritable shared FS must not kill
                        # the heartbeat; worst case the lease expires and a
                        # peer recomputes (idempotent).
                        pass

    # ------------------------------------------------------------------ claim

    def claim(self, worker: str, *, block: bool = True) -> int | None:
        """Local index of the next work item, or None when every item is
        done (all hosts) or ``stop()`` was called.  While peers still hold
        undone leases this polls — waiting out either their completion or
        their expiry — unless ``block=False``.

        All lease-file traffic (the refill ``listdir``, per-key exclusive
        publishes, expiry reads and steals) runs with ``self._lock``
        RELEASED: on a slow shared FS an O(grid) scan held under the lock
        would starve the heartbeat thread past ``lease_ttl``, getting this
        host's own *live* leases stolen and recomputed by peers."""
        while True:
            with self._lock:
                st = self._stats.setdefault(worker, WorkerStats())
                self._clock.fold(worker, st, time.monotonic())
                idx = None if self._stop.is_set() else self._serve_locked(worker, st)
                if idx is not None:
                    st.claimed += 1
                    self._clock.claimed(worker)
                    return idx
                drained = not self._not_done
            if drained or self._stop.is_set():
                return None
            if self._acquire_fs(worker):
                continue                      # fresh keys registered: serve them
            if not block:
                return None
            self._stop.wait(self.poll_s)

    def _serve_locked(self, worker: str, st: WorkerStats) -> int | None:
        """Pop from the worker's lease, rebalancing locally first — no FS
        traffic on this path."""
        lease = self._leases.setdefault(worker, [])
        if not lease:
            self._steal_local_locked(worker, st, lease)
        if not lease:
            return None
        return self._index_of[lease.pop(0)]

    def _rotated_keys(self):
        return self._keys[self._scan0:] + self._keys[: self._scan0]

    def _acquire_fs(self, worker: str) -> bool:
        """Acquire new FS leases for ``worker`` — fresh exclusive publishes
        first, expired-lease steals only when nothing is left to publish —
        and register what was won.  The lease I/O runs on snapshots taken
        under the lock; registration re-checks under the lock, so a key
        two local workers raced lands in exactly one lease (the lease file
        itself carries the same host either way)."""
        with self._lock:
            not_done = set(self._not_done)
            held = set(self._held)
        got = self._publish_fresh(worker, not_done, held)
        reclaimed = False
        retired: list[str] = []
        if not got:
            got = self._steal_expired(worker, not_done, held, retired)
            reclaimed = True
        with self._lock:
            self._not_done.difference_update(retired)
            st = self._stats.setdefault(worker, WorkerStats())
            lease = self._leases.setdefault(worker, [])
            served = False
            for key, rec in got:
                if key in self._held or key not in self._not_done:
                    continue
                self._records[key] = rec
                self._held.add(key)
                lease.append(key)
                served = True
                if reclaimed:
                    st.stolen_by += 1
                    st.reclaimed += 1
            if served:
                self._ensure_heartbeat_locked()
            return served

    def _publish_fresh(
        self, worker: str, not_done: set[str], held: set[str]
    ) -> list[tuple[str, dict]]:
        """Claim up to ``lease_size`` unclaimed items via exclusive publish."""
        try:
            existing = set(os.listdir(self.root))
        except OSError:
            return []
        got: list[tuple[str, dict]] = []
        for key in self._rotated_keys():
            if len(got) >= self._lease_size:
                break
            if key not in not_done or key in held:
                continue
            if os.path.basename(self._lease_path(key)) in existing:
                continue
            rec = self._record(key, worker, "leased")
            try:
                if _publish_exclusive(self._lease_path(key), rec):
                    got.append((key, rec))
            except OSError:
                continue
        return got

    def _steal_local_locked(self, worker: str, st: WorkerStats, lease: list[str]) -> None:
        """Rebalance within this host first (no FS traffic): same
        largest-victim/half-tail/deterministic-tie-break rule as the
        threads backend.  The moved keys stay in ``_held`` — the FS lease
        is per-host, only the serving worker changes."""
        candidates = [
            (len(l), w) for w, l in self._leases.items() if w != worker and len(l) > 1
        ]
        if not candidates:
            return
        victim = max(candidates)[1]
        vlease = self._leases[victim]
        steal = len(vlease) // 2
        if steal:
            lease.extend(vlease[-steal:])
            del vlease[-steal:]
            self._stats[victim].stolen_from += steal
            st.stolen_by += steal

    def _done_confirmed(self, key: str) -> bool | None:
        """Can a done lease for ``key`` be trusted?  True: yes — no checker
        installed, or the cells are in the manifest.  False: a done marker
        whose commit never reached the manifest (lost merge) — recompute.
        None: the check itself failed transiently; recheck next scan."""
        if self._done_check is None:
            return True
        try:
            return bool(self._done_check(key))
        except OSError:
            return None

    def _steal_expired(
        self, worker: str, not_done: set[str], held: set[str], retired: list[str]
    ) -> list[tuple[str, dict]]:
        """Overwrite leases whose heartbeat expired (dead host's tail).
        The scan doubles as done-marker discovery: peers' completed items
        — confirmed against the manifest when a ``done_check`` is
        installed — are appended to ``retired``."""
        now = time.time()
        got: list[tuple[str, dict]] = []
        for key in self._rotated_keys():
            if len(got) >= self._lease_size:
                break
            if key not in not_done or key in held:
                continue
            rec = self._read_lease(key)
            if rec is None:
                continue  # unclaimed: the next refill's exclusive publish wins it
            if rec.get("state") == "done":
                ok = self._done_confirmed(key)
                if ok is None:
                    continue
                if ok:
                    retired.append(key)
                    continue
                # Done marker with no manifest entry: nobody heartbeats a
                # done lease and resumes skip its cell, so without
                # reclaiming it HERE the cell would never be computed —
                # fall through to the overwrite regardless of ttl.
            else:
                hb = rec.get("heartbeat")
                if hb is None:
                    try:
                        hb = os.path.getmtime(self._lease_path(key))
                    except OSError:
                        continue
                if now - float(hb) <= self.lease_ttl:
                    continue
            new = self._record(key, worker, "leased", steals=int(rec.get("steals", 0) or 0) + 1)
            try:
                _overwrite_json(self._lease_path(key), new)
            except OSError:
                continue
            got.append((key, new))
        return got

    # --------------------------------------------------------------- complete

    def complete(self, worker: str, idx: int) -> None:
        key = self._key_of[idx]
        with self._lock:
            st = self._stats.setdefault(worker, WorkerStats())
            st.completed += 1
            self._clock.fold(worker, st, time.monotonic())
            self._clock.completed(worker)
            rec = self._records.pop(key, None) or self._record(key, worker, "done")
            rec["state"] = "done"
            rec["heartbeat"] = time.time()
            self._held.discard(key)
            self._not_done.discard(key)
        # The marker write runs outside self._lock (slow FS must not block
        # claims) but under _write_lock, after the discard above — see
        # _heartbeat_loop for why that ordering keeps the done marker from
        # being clobbered by a stale heartbeat.
        with self._write_lock:
            try:
                _overwrite_json(self._lease_path(key), rec)
            except OSError:
                # The cell is already committed to the manifest (commit-
                # before-done), so the marker is a skip hint, not a
                # correctness requirement: leave the lease to expire —
                # a peer's recompute dedups through the manifest — rather
                # than aborting a scan whose work actually succeeded.
                pass

    # ------------------------------------------------------------- inspection

    def remaining(self) -> int:
        """Undone items across ALL hosts (reads peers' done markers, each
        verified against the manifest when a ``done_check`` is installed —
        an unverifiable done marker still counts as remaining).  Lease
        reads run outside the lock: same heartbeat-liveness reasoning as
        ``claim``."""
        with self._lock:
            candidates = [k for k in sorted(self._not_done) if k not in self._held]
        retired = [
            key
            for key in candidates
            if (rec := self._read_lease(key)) is not None
            and rec.get("state") == "done"
            and self._done_confirmed(key)
        ]
        with self._lock:
            self._not_done.difference_update(retired)
            return len(self._not_done)

    def stats(self) -> dict[str, WorkerStats]:
        """Snapshot copies with the in-flight interval folded in — the same
        contract as the threads backend (this host's workers only; peers
        account for themselves)."""
        with self._lock:
            now = time.monotonic()
            out: dict[str, WorkerStats] = {}
            for w, st in self._stats.items():
                snap = dataclasses.replace(st)
                self._clock.snapshot_into(w, snap, now)
                out[w] = snap
            return out

    @property
    def lease_size(self) -> int:
        return self._lease_size

    def set_lease_size(self, n: int) -> None:
        """Retune future lease refills (host-local; peers tune themselves).
        Already-claimed keys are unaffected, so cross-host correctness
        cannot depend on when — or whether — a retune lands."""
        with self._lock:
            self._lease_size = max(1, int(n))

    def stop(self) -> None:
        """Unblock polling claims and stop the heartbeat thread.  Held
        leases are left to expire — exactly what a crash would do, and how
        survivors are meant to pick the items up."""
        self._stop.set()
