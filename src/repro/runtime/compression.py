"""Gradient compression for the data-parallel all-reduce.

``compressed_psum`` quantizes to int8 against a *globally agreed* scale
(one cheap f32 ``pmax`` first), sums the int32 payload, and dequantizes —
cutting DP gradient traffic 4x vs f32 (2x vs bf16) at ~0.4% RMS error per
tensor (measured in tests/test_compression.py).  Runs inside ``shard_map``;
``build_compressed_grad_sync`` wires it over every gradient leaf.

This is the assignment's "gradient compression" distributed-optimization
trick; the launcher enables it per-arch for bandwidth-bound meshes (the
collective-term column in EXPERIMENTS.md §Roofline shows where it pays).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.compat import shard_map

__all__ = ["compressed_psum", "build_compressed_grad_sync"]


def compressed_psum(x: jax.Array, axis_name, *, bits: int = 8) -> jax.Array:
    """int-quantized ``psum`` over ``axis_name`` (call inside shard_map)."""
    levels = float(2 ** (bits - 1) - 1)
    absmax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(absmax, 1e-12) / levels
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -levels, levels).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def build_compressed_grad_sync(mesh: Mesh, grads_like: Any, *, bits: int = 8, axes=("data",)):
    """Returns ``sync(local_grads) -> mean_grads`` where local grads live
    un-reduced on each data shard (params replicated over data for this
    manual-DP path; model-axis sharding untouched)."""
    axis_names = tuple(a for a in axes if a in mesh.axis_names)
    n = 1
    for a in axis_names:
        n *= mesh.shape[a]

    def local_sync(grads):
        def one(g):
            out = g
            for a in axis_names:
                out = compressed_psum(out, a, bits=bits)
            return out / float(n)

        return jax.tree.map(one, grads)

    spec = P()  # grads replicated over the data axes after the sum
    return shard_map(
        local_sync,
        mesh=mesh,
        in_specs=jax.tree.map(lambda _: spec, grads_like),
        out_specs=jax.tree.map(lambda _: spec, grads_like),
        check_vma=False,
    )
