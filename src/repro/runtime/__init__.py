"""Distributed runtime substrate: sharding rules, checkpoint/restart,
host-side prefetch, straggler-aware work distribution, gradient compression.

Shared by both workload wings (the GWAS scan and the LM model zoo)."""
from repro.runtime.sharding import (
    LogicalAxisRules,
    gwas_shardings,
    logical_to_sharding,
    mesh_axes,
)
from repro.runtime.checkpoint import ScanCheckpoint, TrainCheckpoint
from repro.runtime.prefetch import Prefetcher
from repro.runtime.scheduler import CellRun, CellScheduler
from repro.runtime.workqueue import WorkQueue

__all__ = [
    "LogicalAxisRules",
    "gwas_shardings",
    "logical_to_sharding",
    "mesh_axes",
    "ScanCheckpoint",
    "TrainCheckpoint",
    "Prefetcher",
    "CellRun",
    "CellScheduler",
    "WorkQueue",
]
