"""Distributed runtime substrate: sharding rules, checkpoint/restart,
host-side prefetch, straggler-aware work distribution, gradient compression.

Shared by both workload wings (the GWAS scan and the LM model zoo)."""
from repro.runtime.sharding import (
    LogicalAxisRules,
    gwas_shardings,
    logical_to_sharding,
    mesh_axes,
)
from repro.runtime.checkpoint import ScanCheckpoint, TrainCheckpoint
from repro.runtime.prefetch import Prefetcher
from repro.runtime.scheduler import CellRun, CellScheduler
from repro.runtime.workqueue import (
    FsWorkQueue,
    WorkQueue,
    available_backends,
    get_backend,
    register_backend,
)

__all__ = [
    "LogicalAxisRules",
    "gwas_shardings",
    "logical_to_sharding",
    "mesh_axes",
    "ScanCheckpoint",
    "TrainCheckpoint",
    "Prefetcher",
    "CellRun",
    "CellScheduler",
    "WorkQueue",
    "FsWorkQueue",
    "register_backend",
    "get_backend",
    "available_backends",
]
