"""Session-level per-cell timing and throughput (the ROADMAP
"session progress/metrics" item).

``ScanSession.events()`` is the one loop every consumer drives, so the
metrics hook lives there: each completed grid cell records a
``CellTiming`` — wall time, extent, and which executor slot computed it —
into the session's ``ScanMetrics``.  Three surfaces read it:

    CLI        a live progress line (cells done, markers/s, device count)
    summary    ``summary.json``'s ``metrics`` block via ``summary()``
    BENCH      ``benchmarks/run.py``'s executor section rows

Timing is observational only: recording happens after the cell's arrays
are materialized (the commit/writer path forces that synchronization
anyway), so the hook never adds device syncs of its own.  Replayed
(checkpoint) cells are recorded but excluded from throughput — they cost
one ``np.load``, not a device step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

__all__ = ["CellTiming", "ScanMetrics"]


@dataclass(frozen=True)
class CellTiming:
    """One grid cell's accounting row."""

    batch_index: int
    block_index: int
    n_markers: int
    n_traits: int
    wall_s: float              # compute + payload materialization
    # Executor slot label: "serial", "dev<i>", or — under a distributed
    # scheduler backend — host-qualified "<host_id>/dev<i>", since N
    # processes share one grid and a bare slot index is ambiguous.
    device: str = "-"
    replayed: bool = False     # loaded from a checkpoint shard, not computed
    # wall_s split (DESIGN.md §13): device step (dispatch .. results ready)
    # vs host payload extraction (D2H pulls + hit globalization).  Both 0.0
    # when the executor did not measure the split (checkpoint replay).
    step_s: float = 0.0
    extract_s: float = 0.0
    # Upstream host stages (DESIGN.md §15): genotype decode
    # (``prepare_batch``) and the H2D staging copy.  Attributed to the cell
    # that *first* used the batch/staged arrays; 0.0 for cells reusing a
    # still-staged batch, for replay, and when a pipeline overlapped the
    # stage entirely off the critical path.  These are NOT components of
    # ``wall_s`` — a pipelined executor pays them concurrently with another
    # cell's step, which is exactly what their per-device totals make
    # visible.
    decode_s: float = 0.0
    stage_s: float = 0.0
    # Bytes of host batch payload staged over H2D for this cell, attributed
    # like ``stage_s`` (first cell per fresh staging, 0 on reuse/replay).
    # The observable packed genotype staging (DESIGN.md §17) drives down
    # ~16x: ceil(N/4) packed bytes/marker vs 4N decoded float32.
    h2d_bytes: int = 0


class ScanMetrics:
    """Fold of a session's ``CellTiming`` rows, cheap enough to keep always
    on.  ``wall_s`` is the stream's wall clock (``start()`` .. ``finish()``),
    against which per-device busy time yields utilization."""

    def __init__(self, n_cells_total: int = 0):
        self.n_cells_total = n_cells_total
        self._t0: float | None = None
        self.wall_s = 0.0
        # Running folds only — no per-cell row retention, so the metrics
        # footprint and the per-cell progress hook are both O(1) no matter
        # how many grid cells a paper-scale scan streams.
        self.cells_done = 0
        self._live_cells = 0
        self._live_batches: set[int] = set()
        self._markers = 0
        self._trait_markers = 0
        self._step_s = 0.0
        self._extract_s = 0.0
        self._decode_s = 0.0
        self._stage_s = 0.0
        self._h2d_bytes = 0
        self._per_device: dict[str, dict] = {}     # label -> cells/busy_s/...
        # Serve-mode observability (repro.serve): per-request wall-clock
        # latencies (requests are few relative to cells, so retaining them
        # for exact percentiles is cheap), a queue-depth gauge, and cache
        # counter snapshots (device-state slots, panel blocks).
        self._request_lat: dict[str, list[float]] = {}
        self._queue_depth = 0
        self._caches: dict[str, dict] = {}

    # ------------------------------------------------------------ recording

    def start(self) -> None:
        if self._t0 is None:
            self._t0 = time.perf_counter()

    def record(self, row: CellTiming) -> None:
        self.start()
        self.cells_done += 1
        if not row.replayed:
            self._live_cells += 1
            if row.batch_index not in self._live_batches:
                self._live_batches.add(row.batch_index)
                self._markers += row.n_markers
            self._trait_markers += row.n_markers * row.n_traits
            self._step_s += row.step_s
            self._extract_s += row.extract_s
            self._decode_s += row.decode_s
            self._stage_s += row.stage_s
            self._h2d_bytes += row.h2d_bytes
            d = self._per_device.setdefault(
                row.device,
                {"cells": 0, "busy_s": 0.0, "decode_s": 0.0, "stage_s": 0.0,
                 "h2d_bytes": 0},
            )
            d["cells"] += 1
            d["busy_s"] += row.wall_s
            d["decode_s"] += row.decode_s
            d["stage_s"] += row.stage_s
            d["h2d_bytes"] += row.h2d_bytes

    def finish(self) -> None:
        """Freeze the stream's wall clock — once.  The session calls this
        when the live stream ends and again after checkpoint replay; only
        the first call sticks, so replay (np.load, not compute) never
        dilutes the reported throughput."""
        if self._t0 is not None and self.wall_s == 0.0:
            self.wall_s = time.perf_counter() - self._t0

    # ------------------------------------------------------------ serve mode

    def record_request(self, wall_s: float, *, kind: str = "panel") -> None:
        """One served request's end-to-end latency (admission to final
        result), bucketed by request kind (``panel`` upload vs resident
        ``window`` query — their cost profiles differ by design)."""
        self._request_lat.setdefault(kind, []).append(float(wall_s))

    def set_queue_depth(self, depth: int) -> None:
        """Gauge: work items pending + leased on the serve queue."""
        self._queue_depth = int(depth)

    def set_cache_stats(self, name: str, stats: dict) -> None:
        """Counter snapshot of one warm cache (``device_state`` slots,
        ``panel`` blocks) — taken from ``DeviceLRU.stats()``."""
        self._caches[name] = dict(stats)

    @staticmethod
    def _percentile(xs: list[float], q: float) -> float:
        """Linear-interpolated percentile of a non-empty sample."""
        s = sorted(xs)
        if len(s) == 1:
            return s[0]
        pos = (len(s) - 1) * q
        lo = int(pos)
        frac = pos - lo
        hi = min(lo + 1, len(s) - 1)
        return s[lo] * (1.0 - frac) + s[hi] * frac

    def request_latency(self, kind: str | None = None) -> dict | None:
        """p50/p95/p99/max/mean over recorded request walls (one kind, or
        all kinds pooled); ``None`` until a request completes."""
        if kind is None:
            xs = [x for v in self._request_lat.values() for x in v]
        else:
            xs = list(self._request_lat.get(kind, ()))
        if not xs:
            return None
        return {
            "n": len(xs),
            "p50_s": round(self._percentile(xs, 0.50), 4),
            "p95_s": round(self._percentile(xs, 0.95), 4),
            "p99_s": round(self._percentile(xs, 0.99), 4),
            "max_s": round(max(xs), 4),
            "mean_s": round(sum(xs) / len(xs), 4),
        }

    def serve_summary(self) -> dict | None:
        """The ``summary()`` ``serve`` block; ``None`` when this metrics
        object never saw serve traffic."""
        if not self._request_lat and not self._caches:
            return None
        by_kind = {
            kind: self.request_latency(kind) for kind in sorted(self._request_lat)
        }
        return {
            "requests": sum(len(v) for v in self._request_lat.values()),
            "latency": self.request_latency(),
            "latency_by_kind": by_kind,
            "queue_depth": self._queue_depth,
            "caches": dict(self._caches),
        }

    # -------------------------------------------------------------- reading

    def markers_done(self) -> int:
        """Distinct markers computed live (each batch counted once, however
        many trait blocks it swept)."""
        return self._markers

    def trait_markers_done(self) -> int:
        """Total (marker x trait) statistics computed live — the unit the
        paper's throughput claim is denominated in."""
        return self._trait_markers

    def extract_share(self) -> float | None:
        """Measured fraction of busy time spent in payload extraction
        (D2H + host epilogue work) rather than the device step — the
        observable the sparse epilogue (DESIGN.md §13) drives down.  None
        until an executor that measures the split has recorded a cell."""
        busy = self._step_s + self._extract_s
        if busy <= 0:
            return None
        return self._extract_s / busy

    @property
    def step_s_total(self) -> float:
        return self._step_s

    @property
    def decode_s_total(self) -> float:
        return self._decode_s

    @property
    def h2d_bytes_total(self) -> int:
        return self._h2d_bytes

    def h2d_bytes_per_marker(self) -> float | None:
        """Staged batch-payload bytes per distinct live marker — the §17
        staging-currency observable (~4N dense vs ~N/4 packed)."""
        if self._markers <= 0:
            return None
        return self._h2d_bytes / self._markers

    def _wall(self) -> float:
        if self.wall_s > 0:
            return self.wall_s
        return time.perf_counter() - self._t0 if self._t0 is not None else 0.0

    def summary(self) -> dict:
        """The ``summary.json`` ``metrics`` block."""
        wall = self._wall()
        per_device = {
            label: {
                "cells": d["cells"],
                "busy_s": round(d["busy_s"], 4),
                "utilization": round(d["busy_s"] / wall, 3) if wall > 0 else None,
                "decode_s": round(d.get("decode_s", 0.0), 4),
                "stage_s": round(d.get("stage_s", 0.0), 4),
                "h2d_bytes": d.get("h2d_bytes", 0),
            }
            for label, d in self._per_device.items()
        }
        markers = self.markers_done()
        tm = self.trait_markers_done()
        share = self.extract_share()
        serve = self.serve_summary()
        extra = {"serve": serve} if serve is not None else {}
        return {
            **extra,
            "cells": self.cells_done,
            "cells_total": self.n_cells_total,
            "live_cells": self._live_cells,
            "replayed_cells": self.cells_done - self._live_cells,
            "wall_s": round(wall, 4),
            "markers_per_s": round(markers / wall, 1) if wall > 0 else None,
            "trait_markers_per_s": round(tm / wall, 1) if wall > 0 else None,
            "step_s": round(self._step_s, 4),
            "extract_s": round(self._extract_s, 4),
            "decode_s": round(self._decode_s, 4),
            "stage_s": round(self._stage_s, 4),
            "h2d_bytes": self._h2d_bytes,
            "h2d_bytes_per_marker": (
                round(self._h2d_bytes / markers, 1) if markers > 0 else None
            ),
            "extract_share": round(share, 3) if share is not None else None,
            "per_device": per_device,
        }

    def progress_line(self) -> str:
        """One-line human rendering for the CLI progress hook; O(1) — it
        runs once per cell."""
        wall = time.perf_counter() - self._t0 if self._t0 is not None else 0.0
        rate = self.markers_done() / wall if wall > 0 else 0.0
        total = f"/{self.n_cells_total}" if self.n_cells_total else ""
        share = self.extract_share()
        tail = f"  extract {share:.0%}" if share is not None else ""
        host = self._decode_s + self._stage_s
        if host > 0 and self._step_s > 0:
            tail += f"  decode+stage {host / self._step_s:.0%} of step"
        return (
            f"[scan] {self.cells_done}{total} cells  "
            f"{rate:,.0f} markers/s  {len(self._per_device) or 1} device(s)"
            f"{tail}"
        )
