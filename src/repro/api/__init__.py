"""The composable public API: bind -> plan -> execute -> emit.

    from repro.api import Study, GridSpec

    study = Study.from_files("cohort_chr*.bed", "panel.tsv", covar="covars.tsv")
    plan = study.plan(engine="fused", grid=GridSpec(trait_block=2048),
                      checkpoint_dir="ck/")
    session = plan.run()                       # amortized setup happens here
    summary = session.stream_to(TsvWriter("results/"))

Or stream the grid cells yourself:

    for cell in plan.run().events():
        ...  # cell.hits, cell.best_nlp, cell.maf — one grid cell at a time

The four layers (DESIGN.md §11):

    bind     ``Study``       file opening, table alignment, sample QC
    plan     ``Study.plan``  typed specs (GridSpec/LmmSpec/IOSpec) validated
                             and normalized into the internal ``ScanConfig``
    execute  ``ScanSession`` the streaming grid executor; ``events()``
                             yields per-cell ``CellResult``s, checkpoint/
                             resume included.  ``ExecSpec(devices=N)``
                             drains the grid across N devices with work
                             stealing — bitwise-identical results
                             (DESIGN.md §12)
    emit     ``ResultWriter`` registry; ``"tsv"`` and ``"npz"`` built in,
                             ``"parquet"`` when pyarrow is available

``repro.core.screening.GenomeScan`` remains as a deprecated shim over this
API (it collects events into the historical dense ``ScanResult``).
"""
from repro.api.metrics import CellTiming, ScanMetrics
from repro.api.session import (
    CellResult,
    MultiDeviceExecutor,
    PreparedScan,
    ScanPlan,
    ScanSession,
    SerialExecutor,
)
from repro.api.specs import (
    ExecSpec,
    GridSpec,
    IOSpec,
    LmmSpec,
    ScanConfig,
    ServeSpec,
)
from repro.api.study import Study
from repro.api.writers import (
    NpzShardWriter,
    ResultWriter,
    TsvWriter,
    available_writers,
    get_writer,
    register_writer,
    stream_session,
)

__all__ = [
    "Study",
    "GridSpec",
    "LmmSpec",
    "IOSpec",
    "ExecSpec",
    "ServeSpec",
    "ScanConfig",
    "ScanPlan",
    "ScanSession",
    "SerialExecutor",
    "MultiDeviceExecutor",
    "PreparedScan",
    "CellResult",
    "CellTiming",
    "ScanMetrics",
    "ResultWriter",
    "TsvWriter",
    "NpzShardWriter",
    "register_writer",
    "get_writer",
    "available_writers",
    "stream_session",
]
