"""``Study`` — the *bind* layer of the public API.

A Study owns everything that must be settled before any scan can be
planned: the genotype source, the phenotype/covariate tables aligned to
its samples, missing-phenotype imputation, and sample-level QC
(relatedness exclusion).  Binding is engine- and plan-agnostic: the same
Study can be planned many times with different engines, grids, or
thresholds without re-opening files or re-running QC.

    study = Study.from_files("cohort_chr*.bed", "panel.tsv", covar="covars.tsv")
    plan = study.plan(engine="fused", grid=GridSpec(trait_block=2048))
    session = plan.run()
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import numpy as np

__all__ = ["Study"]


@dataclass
class Study:
    """A bound (genotypes, phenotypes, covariates) triple, QC applied.

    ``phenotypes``/``covariates`` are already row-subset to the kept
    samples; ``keep`` maps kept rows back to the genotype source's sample
    axis (engines subset dosage batches with it).  ``trait_names`` ride
    along for the result writers.
    """

    source: Any                          # GenotypeSource protocol (repro.io)
    phenotypes: np.ndarray               # (N_kept, P) float
    covariates: np.ndarray | None        # (N_kept, C) or None
    keep: np.ndarray                     # (N_source,) bool sample mask
    excluded_samples: int = 0
    exclude_related: bool = False        # QC flag (enters the fingerprint)
    trait_names: Sequence[str] = field(default_factory=tuple)

    # ------------------------------------------------------------------ bind

    @classmethod
    def from_arrays(
        cls,
        source: Any,
        phenotypes: np.ndarray,
        covariates: np.ndarray | None = None,
        *,
        exclude_related: bool = False,
        trait_names: Sequence[str] | None = None,
    ) -> "Study":
        """Bind an already-aligned phenotype panel to a genotype source.

        ``phenotypes`` rows must match the source's sample order (use
        ``Study.from_files`` / ``repro.io.align_tables`` otherwise).
        ``exclude_related=True`` runs the relatedness probe and drops one
        sample of each related pair before anything downstream sees the
        panel.
        """
        n = source.n_samples
        phenotypes = np.asarray(phenotypes)
        if phenotypes.shape[0] != n:
            raise ValueError(
                f"phenotypes rows ({phenotypes.shape[0]}) != genotype samples ({n}); "
                "align tables first (repro.io.align_tables)"
            )
        if covariates is not None:
            covariates = np.asarray(covariates)
            if covariates.shape[0] != n:
                raise ValueError(
                    f"covariates rows ({covariates.shape[0]}) != genotype samples ({n})"
                )

        keep = np.ones(n, bool)
        excluded = 0
        if exclude_related:
            from repro.core.kinship import exclude_related as _exclude

            probe = source.read_dosages(0, min(source.n_markers, 4096)).T
            keep, _, _ = _exclude(probe)
            excluded = int((~keep).sum())
            phenotypes = phenotypes[keep]
            covariates = covariates[keep] if covariates is not None else None

        if trait_names is None:
            trait_names = tuple(f"trait{j}" for j in range(phenotypes.shape[1]))
        return cls(
            source=source,
            phenotypes=phenotypes,
            covariates=covariates,
            keep=keep,
            excluded_samples=excluded,
            exclude_related=exclude_related,
            trait_names=tuple(trait_names),
        )

    @classmethod
    def from_files(
        cls,
        genotypes: str,
        pheno: str,
        covar: str | None = None,
        *,
        exclude_related: bool = False,
        impute_missing: bool = True,
    ) -> "Study":
        """Open a genotype container/fileset and align tables by sample id.

        Alignment is strict: genotype samples missing from the tables raise
        (subset the container first).  NaN phenotype cells are mean-imputed
        per trait when ``impute_missing`` (matching the CLI's historical
        behavior); pass False to keep NaNs and handle them upstream.
        """
        from repro.io import align_tables, open_genotypes, read_table

        source = open_genotypes(genotypes)
        ptable = read_table(pheno)
        ctable = read_table(covar) if covar else None
        y, c, keep = align_tables(source.sample_ids, ptable, ctable)
        if not keep.all():
            raise ValueError(
                f"{(~keep).sum()} genotype samples missing from the tables; "
                "subset the genotype container first (alignment is strict by design)"
            )
        if impute_missing:
            y = np.where(np.isnan(y), np.nanmean(y, axis=0, keepdims=True), y)
        return cls.from_arrays(
            source, y, c,
            exclude_related=exclude_related,
            trait_names=tuple(ptable.names),
        )

    # ---------------------------------------------------------------- shape

    @property
    def n_samples(self) -> int:
        return int(self.keep.sum())

    @property
    def n_traits(self) -> int:
        return int(self.phenotypes.shape[1])

    @property
    def n_markers(self) -> int:
        return int(self.source.n_markers)

    @property
    def marker_ids(self):
        return self.source.marker_ids

    # ----------------------------------------------------------------- plan

    def plan(
        self,
        *,
        engine: str = "dense",
        grid: "GridSpec | None" = None,
        lmm: "LmmSpec | None" = None,
        io: "IOSpec | None" = None,
        executor: "ExecSpec | None" = None,
        options: "AssocOptions | None" = None,
        mode: str = "mp",
        hit_threshold_nlp: float = 7.301,
        maf_min: float = 0.0,
        multivariate: bool = False,
        checkpoint_dir: str | None = None,
        input_dtype: str = "fp32",
        sparse_epilogue: bool = True,
        hit_capacity: int = 4096,
        mesh: Any = None,
    ) -> "ScanPlan":
        """Validate + normalize a spec combination into a ``ScanPlan``.

        This is cheap (no engine setup, no file IO): the expensive amortized
        work — panel residualization, GRM/REML for the lmm engine, step
        compilation — happens in ``plan.run()``.
        """
        from repro.api.session import ScanPlan
        from repro.api.specs import ScanConfig

        config = ScanConfig.from_specs(
            engine=engine,
            grid=grid,
            lmm=lmm,
            io=io,
            executor=executor,
            options=options,
            mode=mode,
            hit_threshold_nlp=hit_threshold_nlp,
            maf_min=maf_min,
            exclude_related=self.exclude_related,
            multivariate=multivariate,
            checkpoint_dir=checkpoint_dir,
            input_dtype=input_dtype,
            sparse_epilogue=sparse_epilogue,
            hit_capacity=hit_capacity,
        )
        return ScanPlan(self, config, mesh=mesh)

    def plan_config(self, config: "ScanConfig", *, mesh: Any = None) -> "ScanPlan":
        """Plan from an already-normalized ``ScanConfig`` (the deprecated
        ``GenomeScan`` shim's path; spec users should call ``plan``)."""
        from repro.api.session import ScanPlan

        if bool(config.exclude_related) != bool(self.exclude_related):
            raise ValueError(
                "config.exclude_related disagrees with the Study's QC binding; "
                "relatedness exclusion is decided at Study construction"
            )
        return ScanPlan(self, config, mesh=mesh)
