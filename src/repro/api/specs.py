"""Typed scan specifications — the *plan* layer of the public API.

``ScanConfig`` grew one flag at a time into a 24-field sprawl where grid
geometry, engine selection, mixed-model knobs, IO tuning, and output policy
all share one namespace.  The public surface groups them into typed specs:

    GridSpec   the 2-D scan-grid geometry (batch/block sizes, compute tiles)
    LmmSpec    mixed-model knobs (engine="lmm" only; rejected elsewhere)
    IOSpec     host pipeline tuning (prefetch depth, decode workers, spill)
    ExecSpec   the executor: device count, cell placement policy, lease size

``Study.plan(...)`` validates a spec combination and *normalizes* it into a
``ScanConfig`` — which remains the single internal currency: the checkpoint
fingerprint is computed from it exactly as before, so sessions planned
through specs resume checkpoints written by the deprecated ``GenomeScan``
shim and vice versa.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.association import AssocOptions
from repro.runtime.scheduler import PLACEMENTS

__all__ = ["GridSpec", "LmmSpec", "IOSpec", "ExecSpec", "ServeSpec", "ScanConfig"]


@dataclass(frozen=True)
class GridSpec:
    """Geometry of the 2-D (marker-batch x trait-block) scan grid.

    ``trait_block=0`` is the unblocked degenerate grid (one block spanning
    the panel).  ``block_m``/``block_n``/``block_p`` are the device compute
    tiles; trait blocks are rounded up to multiples of ``block_p`` so every
    decomposition computes identical GEMM tiles (DESIGN.md §10).
    """

    batch_markers: int = 4096
    trait_block: int = 0
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256
    panel_resident_blocks: int = 4

    def validate(self) -> None:
        for name in ("batch_markers", "block_m", "block_n", "block_p"):
            if getattr(self, name) <= 0:
                raise ValueError(f"GridSpec.{name} must be positive, got {getattr(self, name)}")
        if self.trait_block < 0:
            raise ValueError(f"GridSpec.trait_block must be >= 0, got {self.trait_block}")
        if self.panel_resident_blocks < 1:
            raise ValueError(
                f"GridSpec.panel_resident_blocks must be >= 1, got {self.panel_resident_blocks}"
            )


@dataclass(frozen=True)
class LmmSpec:
    """Mixed-model wing knobs (DESIGN.md §9); only valid with engine="lmm"."""

    loco: bool = False
    grm_method: str = "std"        # "std" (GCTA) | "centered" (EMMAX)
    grm_batch_markers: int = 4096
    delta: float | None = None     # pin se^2/sg^2 (skips the REML fit)
    epilogue: str = "dense"        # "dense" XLA | "fused" Pallas t-stat

    def validate(self) -> None:
        if self.grm_method not in ("std", "centered"):
            raise ValueError(f"unknown grm_method {self.grm_method!r}")
        if self.epilogue not in ("dense", "fused"):
            raise ValueError(f"unknown lmm epilogue {self.epilogue!r}")
        if self.grm_batch_markers <= 0:
            raise ValueError(f"LmmSpec.grm_batch_markers must be positive")


@dataclass(frozen=True)
class IOSpec:
    """Host-side pipeline tuning.  None of these enter the checkpoint
    fingerprint — elastic restarts may retune them freely."""

    prefetch_depth: int = 3
    io_workers: int = 2
    spill_dir: str | None = None       # HitSink spill location (None: in RAM)
    hit_spill_rows: int = 2_000_000
    # H2D staging currency (DESIGN.md §17): "auto" stages raw 2-bit PLINK
    # bytes with device-side decode whenever the source supports it (16x
    # less transfer, bitwise-identical output), "dense" forces decoded
    # float32, "packed" demands the packed path (raises if unavailable).
    genotype_staging: str = "auto"
    packed_cache_mb: int = 256         # shared packed-slab LRU budget

    def validate(self) -> None:
        if self.prefetch_depth < 1 or self.io_workers < 1:
            raise ValueError("IOSpec.prefetch_depth and io_workers must be >= 1")
        if self.hit_spill_rows < 1:
            raise ValueError("IOSpec.hit_spill_rows must be >= 1")
        if self.genotype_staging not in ("auto", "packed", "dense"):
            raise ValueError(
                f"IOSpec.genotype_staging must be auto|packed|dense, "
                f"got {self.genotype_staging!r}"
            )
        if self.packed_cache_mb < 0:
            raise ValueError("IOSpec.packed_cache_mb must be >= 0")


@dataclass(frozen=True)
class ExecSpec:
    """The executor layer (DESIGN.md §12): how many devices drain the scan
    grid and which staged array each one optimizes for reuse.

    Like ``IOSpec``, nothing here enters the checkpoint fingerprint — the
    grid decomposition is device-topology-free, so a scan checkpointed
    under one device count resumes under any other (elastic restarts), and
    results are bitwise-identical either way.
    """

    devices: int = 1               # executor slots; 0 = every visible device
    placement: str = "marker-major"  # lease locality: genotype- vs panel-reuse
    # Work items leased per scheduler claim.  The scheduler caps this at
    # n_items / n_devices so a short scan still spreads over every slot.
    lease_batches: int = 2
    # Scheduler backend (DESIGN.md §14): "threads" keeps the lease table
    # in-process; "shared-fs" puts it on the shared filesystem next to the
    # checkpoint (requires checkpoint_dir), letting N independent processes
    # on as many hosts drain one grid elastically.
    backend: str = "threads"
    host_id: str | None = None     # lease-table identity; None = host-pid
    lease_ttl: float = 60.0        # heartbeat expiry before peers steal (s)
    # Per-slot pipeline depth (DESIGN.md §15): how many work items a device
    # worker claims AHEAD of the one it is computing, so decode + H2D of
    # batch b+1 overlap the step of batch b.  0 disables pipelining (the
    # historical serial claim loop — decode, stage, compute, commit, repeat).
    slot_prefetch: int = 1
    # Runtime lease autotuning (DESIGN.md §15): shrink ``lease_batches``
    # toward the tail of the scan (guided self-scheduling) using the
    # scheduler's live busy/wait accounting.  The initial and final values
    # are reported in summary.json's executor block.
    autotune_lease: bool = True

    def validate(self) -> None:
        from repro.runtime.workqueue import available_backends

        if self.devices < 0:
            raise ValueError(f"ExecSpec.devices must be >= 0, got {self.devices}")
        if self.slot_prefetch < 0:
            raise ValueError(
                f"ExecSpec.slot_prefetch must be >= 0, got {self.slot_prefetch}"
            )
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {self.placement!r}; available: {PLACEMENTS}"
            )
        if self.lease_batches < 1:
            raise ValueError(
                f"ExecSpec.lease_batches must be >= 1, got {self.lease_batches}"
            )
        if self.backend not in available_backends():
            raise ValueError(
                f"unknown scheduler backend {self.backend!r}; "
                f"available: {available_backends()}"
            )
        if self.lease_ttl <= 0:
            raise ValueError(
                f"ExecSpec.lease_ttl must be positive, got {self.lease_ttl}"
            )


@dataclass(frozen=True)
class ServeSpec:
    """The serve subsystem (DESIGN.md §16): a persistent multi-tenant scan
    service over the warm executor stack.

    Nothing here touches the scan math — serve requests run the same grid,
    engines, and sinks as an offline scan, so served results are
    byte-identical to offline outputs by construction.  These knobs size
    the *service*: the shared worker pool, the warm-slot cache, and the
    fair-share scheduler.
    """

    host: str = "127.0.0.1"
    port: int = 0                  # 0 = OS-assigned ephemeral port
    devices: int = 1               # shared pool slots; 0 = every visible device
    # Warm executor-slot cache capacity: (study-state, slot) entries held
    # device-resident across requests; LRU-evicted past this, pinned while
    # a request is mid-cell (DeviceLRU pinning).
    max_resident_slots: int = 8
    # Work items leased per claim on the shared serve queue.  Small leases
    # keep the deficit-round-robin responsive (a big lease would let one
    # request's cells monopolize a worker between scheduling decisions).
    lease_size: int = 1
    # Deficit-round-robin quantum: cells credited to a request queue per
    # scheduling round, scaled by the study's weight (serve/fair.py).
    drr_quantum: float = 2.0
    default_weight: float = 1.0

    def validate(self) -> None:
        if not (0 <= self.port <= 65535):
            raise ValueError(f"ServeSpec.port must be in [0, 65535], got {self.port}")
        if self.devices < 0:
            raise ValueError(f"ServeSpec.devices must be >= 0, got {self.devices}")
        if self.max_resident_slots < 1:
            raise ValueError(
                f"ServeSpec.max_resident_slots must be >= 1, "
                f"got {self.max_resident_slots}"
            )
        if self.lease_size < 1:
            raise ValueError(
                f"ServeSpec.lease_size must be >= 1, got {self.lease_size}"
            )
        if self.drr_quantum <= 0:
            raise ValueError(
                f"ServeSpec.drr_quantum must be positive, got {self.drr_quantum}"
            )
        if self.default_weight <= 0:
            raise ValueError(
                f"ServeSpec.default_weight must be positive, "
                f"got {self.default_weight}"
            )


@dataclass(frozen=True)
class ScanConfig:
    """The normalized internal scan configuration.

    Deprecated as a public construction surface — prefer
    ``Study.plan(engine=..., grid=GridSpec(...), ...)``, which validates and
    produces one of these.  It remains the checkpoint-fingerprint currency
    (``fingerprint_payload``), so its field set and semantics are stable.
    """

    batch_markers: int = 4096
    trait_block: int = 0           # trait-axis tile width; 0 = unblocked (§10)
    options: AssocOptions = AssocOptions()
    engine: str = "dense"          # registry name: core.engines.available_engines()
    mode: str = "mp"               # sharding mode; "sample" implies engine="dense"
    hit_threshold_nlp: float = 7.301  # 5e-8, the GWAS genome-wide line
    # Sparse p-value epilogue (DESIGN.md §13): screen lanes on t^2, run the
    # exact CF only on compacted survivors.  Output is bitwise-identical
    # either way, so neither knob enters the checkpoint fingerprint.
    sparse_epilogue: bool = True
    hit_capacity: int = 4096       # per-cell compacted hit-buffer slots
    maf_min: float = 0.0
    exclude_related: bool = False
    multivariate: bool = False
    checkpoint_dir: str | None = None
    prefetch_depth: int = 3
    io_workers: int = 2
    panel_resident_blocks: int = 4 # device LRU capacity for panel blocks
    spill_dir: str | None = None   # HitSink spill location (None: all in RAM)
    hit_spill_rows: int = 2_000_000  # spill past this many resident hit rows
    block_m: int = 256
    block_n: int = 512
    block_p: int = 256
    input_dtype: str = "fp32"      # fused engine GEMM input: "fp32" | "bf16"
    # mixed-model wing (engine="lmm"; DESIGN.md §9)
    loco: bool = False             # leave-one-chromosome-out GRM per shard
    grm_method: str = "std"        # "std" (GCTA) | "centered" (EMMAX)
    grm_batch_markers: int = 4096  # marker batch of the streamed GRM pass
    lmm_delta: float | None = None # pin se^2/sg^2 (skips the REML fit)
    lmm_epilogue: str = "dense"    # t/p epilogue: "dense" XLA | "fused" Pallas
    # executor (DESIGN.md §12; never fingerprinted — device topology is
    # elastic across restarts, results are bitwise-identical regardless)
    devices: int = 1               # executor slots; 0 = every visible device
    placement: str = "marker-major"  # "marker-major" | "trait-major"
    lease_batches: int = 2         # scheduler lease size (work items/claim)
    exec_backend: str = "threads"  # scheduler backend: "threads" | "shared-fs"
    host_id: str | None = None     # shared-fs lease identity (None: host-pid)
    lease_ttl: float = 60.0        # shared-fs heartbeat expiry (seconds)
    slot_prefetch: int = 1         # per-slot look-ahead depth; 0 = unpipelined
    autotune_lease: bool = True    # runtime lease_batches tuning (§15)
    # H2D staging currency (DESIGN.md §17); bitwise-neutral like the
    # epilogue strategy, so never fingerprinted
    genotype_staging: str = "auto"
    packed_cache_mb: int = 256

    def fingerprint_payload(self) -> dict:
        d = dataclasses.asdict(self)
        d["options"] = dataclasses.asdict(self.options)
        # Mesh topology, host counts, executor shape, and host-memory/spill
        # knobs never enter the fingerprint (elastic restarts may retune
        # them).  trait_block STAYS: it defines the checkpoint grid
        # decomposition.
        for k in ("prefetch_depth", "io_workers", "checkpoint_dir",
                  "panel_resident_blocks", "spill_dir", "hit_spill_rows",
                  "devices", "placement", "lease_batches",
                  "exec_backend", "host_id", "lease_ttl",
                  "slot_prefetch", "autotune_lease",
                  # bitwise-neutral epilogue strategy (§13): a scan
                  # checkpointed sparse resumes dense and vice versa
                  "sparse_epilogue", "hit_capacity",
                  # bitwise-neutral staging currency (§17): a scan
                  # checkpointed packed resumes dense and vice versa
                  "genotype_staging", "packed_cache_mb"):
            d.pop(k)
        d["options"].pop("sparse_epilogue", None)
        return d

    # ------------------------------------------------------ spec round-trip

    @classmethod
    def from_specs(
        cls,
        *,
        engine: str = "dense",
        grid: GridSpec | None = None,
        lmm: LmmSpec | None = None,
        io: IOSpec | None = None,
        executor: ExecSpec | None = None,
        options: AssocOptions | None = None,
        mode: str = "mp",
        hit_threshold_nlp: float = 7.301,
        maf_min: float = 0.0,
        exclude_related: bool = False,
        multivariate: bool = False,
        checkpoint_dir: str | None = None,
        input_dtype: str = "fp32",
        sparse_epilogue: bool = True,
        hit_capacity: int = 4096,
    ) -> "ScanConfig":
        """Validate a spec combination and normalize it (the plan step)."""
        from repro.core.engines import available_engines

        grid = grid or GridSpec()
        io = io or IOSpec()
        executor = executor or ExecSpec()
        options = options or AssocOptions()
        grid.validate()
        io.validate()
        executor.validate()
        if engine not in available_engines():
            raise ValueError(
                f"unknown scan engine {engine!r}; available: {available_engines()}"
            )
        if lmm is not None:
            lmm.validate()
            if engine != "lmm":
                raise ValueError(
                    f"LmmSpec given but engine={engine!r}; mixed-model knobs "
                    "only apply to engine='lmm'"
                )
        if input_dtype not in ("fp32", "bf16"):
            raise ValueError(f"unknown input_dtype {input_dtype!r}")
        if input_dtype == "bf16" and engine != "fused":
            raise ValueError(
                "input_dtype='bf16' selects the fused kernel's GEMM input "
                "dtype; use options=AssocOptions(precision='bf16') for the "
                "dense engine"
            )
        if mode not in ("mp", "sample"):
            raise ValueError(f"unknown sharding mode {mode!r}")
        if hit_capacity < 1:
            raise ValueError(f"hit_capacity must be >= 1, got {hit_capacity}")
        if executor.backend != "threads" and checkpoint_dir is None:
            raise ValueError(
                f"ExecSpec.backend={executor.backend!r} coordinates through "
                "the checkpoint directory; pass checkpoint_dir="
            )
        lmm = lmm or LmmSpec()
        return cls(
            batch_markers=grid.batch_markers,
            trait_block=grid.trait_block,
            options=options,
            engine=engine,
            mode=mode,
            hit_threshold_nlp=hit_threshold_nlp,
            sparse_epilogue=sparse_epilogue,
            hit_capacity=hit_capacity,
            maf_min=maf_min,
            exclude_related=exclude_related,
            multivariate=multivariate,
            checkpoint_dir=checkpoint_dir,
            prefetch_depth=io.prefetch_depth,
            io_workers=io.io_workers,
            panel_resident_blocks=grid.panel_resident_blocks,
            spill_dir=io.spill_dir,
            hit_spill_rows=io.hit_spill_rows,
            block_m=grid.block_m,
            block_n=grid.block_n,
            block_p=grid.block_p,
            input_dtype=input_dtype,
            loco=lmm.loco,
            grm_method=lmm.grm_method,
            grm_batch_markers=lmm.grm_batch_markers,
            lmm_delta=lmm.delta,
            lmm_epilogue=lmm.epilogue,
            devices=executor.devices,
            placement=executor.placement,
            lease_batches=executor.lease_batches,
            exec_backend=executor.backend,
            host_id=executor.host_id,
            lease_ttl=executor.lease_ttl,
            slot_prefetch=executor.slot_prefetch,
            autotune_lease=executor.autotune_lease,
            genotype_staging=io.genotype_staging,
            packed_cache_mb=io.packed_cache_mb,
        )

    def grid_spec(self) -> GridSpec:
        return GridSpec(
            batch_markers=self.batch_markers,
            trait_block=self.trait_block,
            block_m=self.block_m,
            block_n=self.block_n,
            block_p=self.block_p,
            panel_resident_blocks=self.panel_resident_blocks,
        )

    def lmm_spec(self) -> LmmSpec:
        return LmmSpec(
            loco=self.loco,
            grm_method=self.grm_method,
            grm_batch_markers=self.grm_batch_markers,
            delta=self.lmm_delta,
            epilogue=self.lmm_epilogue,
        )

    def io_spec(self) -> IOSpec:
        return IOSpec(
            prefetch_depth=self.prefetch_depth,
            io_workers=self.io_workers,
            spill_dir=self.spill_dir,
            hit_spill_rows=self.hit_spill_rows,
            genotype_staging=self.genotype_staging,
            packed_cache_mb=self.packed_cache_mb,
        )

    def exec_spec(self) -> ExecSpec:
        return ExecSpec(
            devices=self.devices,
            placement=self.placement,
            lease_batches=self.lease_batches,
            backend=self.exec_backend,
            host_id=self.host_id,
            lease_ttl=self.lease_ttl,
            slot_prefetch=self.slot_prefetch,
            autotune_lease=self.autotune_lease,
        )
