"""Plan -> execute: ``ScanPlan`` compiles a Study + specs into a prepared
scan; ``ScanSession.events()`` streams per-grid-cell ``CellResult``s.

This module *is* the scan executor — the loop that used to live inside
``GenomeScan.run``.  The redesign inverts the old shape: instead of one
blocking call that folds every cell into a dense ``ScanResult``, the
session yields each completed (marker-batch x trait-block) cell as a
``CellResult`` the moment it is computed (or replayed from a checkpoint
shard), and *consumers* decide what to keep:

    for cell in session.events():      # streams; never holds (M, P) arrays
        writer.write(cell)

The executor behind ``events()`` is pluggable (DESIGN.md §12):
``SerialExecutor`` is the historical single-device grid walk;
``MultiDeviceExecutor`` drains the same grid across N devices through the
work-stealing ``runtime.scheduler.CellScheduler``, one ``_Slot`` of
explicit per-device state (engine device caches, panel view, compiled
step) per device — results are bitwise-identical, completion order is
free, and the cell-keyed checkpoint is the coordination substrate either
way.  Consumers cannot tell executors apart except by speed:

    for cell in session.events():      # streams; never holds (M, P) arrays
        writer.write(cell)

The deprecated ``GenomeScan`` shim is one such consumer (it folds cells
into the historical sinks to rebuild ``ScanResult``); the streaming result
writers (``repro.api.writers``) are the native one.

Checkpointing rides the session: each live cell's payload is committed to
the cell-keyed manifest before the cell is yielded, and on resume the
committed cells of previous runs are replayed as ``CellResult``s after the
live stream — consumers cannot tell the difference (``cell.replayed`` says,
for the curious).
"""
from __future__ import annotations

import dataclasses
import os
import queue
import socket
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.api.metrics import CellTiming, ScanMetrics
from repro.api.specs import ScanConfig
from repro.api.study import Study
from repro.core.engines import EngineContext, ScanEngine, get_engine
from repro.core.panels import PanelPrefetcher, PanelStore
from repro.core.residualize import covariate_basis
from repro.core import stats as _stats
from repro.core.sinks import BatchView, extract_hits
from repro.runtime.checkpoint import ScanCheckpoint, config_fingerprint
from repro.runtime.prefetch import (
    BatchPlanner,
    DecodePool,
    MarkerBatch,
    Prefetcher,
    TraitBlock,
    TraitBlockPlanner,
    double_buffer,
)
from repro.runtime.scheduler import CellScheduler

__all__ = [
    "CellResult",
    "PreparedScan",
    "ScanPlan",
    "ScanSession",
    "SerialExecutor",
    "MultiDeviceExecutor",
    "CheckpointReplay",
]


LAMBDA_PROBE_ROWS = 64  # rows of the first-trait t probe persisted per batch


class CellResult:
    """One completed grid cell: a marker range crossed with a trait range.

    Live cells wrap the device step's ``BatchView`` and extract their
    summary arrays lazily (the hit-driven-pull invariant holds: the full
    per-cell tiles only cross PCIe when the cell has hits).  Replayed cells
    carry a committed checkpoint shard's arrays.  Either way ``arrays`` is
    the cell's *payload* — the exact dict the checkpoint persists — and the
    accessors below read from it, so consumers never branch on provenance.

    A cell's memory footprint is bounded by its own extent (block-width
    vectors plus its hit rows) — accumulating across cells is the
    consumer's business, which is what keeps ``events()`` streaming.
    """

    def __init__(
        self,
        *,
        batch_index: int,
        block_index: int,
        lo: int,
        hi: int,
        t_lo: int,
        t_hi: int,
        view: BatchView | None = None,
        shard: dict[str, np.ndarray] | None = None,
        hit_threshold: float = 7.301,
    ):
        self.batch_index = batch_index
        self.block_index = block_index
        self.lo = lo
        self.hi = hi
        self.t_lo = t_lo
        self.t_hi = t_hi
        self.view = view
        self._shard = shard
        self._threshold = hit_threshold
        self._arrays: dict[str, np.ndarray] | None = None

    @classmethod
    def from_shard(
        cls, batch_index: int, block_index: int, shard: dict[str, np.ndarray]
    ) -> "CellResult":
        return cls(
            batch_index=batch_index,
            block_index=block_index,
            lo=int(shard["lo"]),
            hi=int(shard["hi"]),
            t_lo=int(shard.get("t_lo", 0)),
            t_hi=int(shard.get("t_hi", shard["best_nlp"].shape[0])),
            shard=shard,
        )

    # ------------------------------------------------------------- geometry

    @property
    def n_markers(self) -> int:
        return self.hi - self.lo

    @property
    def n_traits(self) -> int:
        return self.t_hi - self.t_lo

    @property
    def replayed(self) -> bool:
        return self.view is None

    @property
    def carries_marker_tracks(self) -> bool:
        """Marker-level tracks (maf/valid/omnibus/probe) ride the t_lo==0
        cell of each marker batch — once per batch, not once per cell."""
        return self.t_lo == 0

    # -------------------------------------------------------------- payload

    @property
    def arrays(self) -> dict[str, np.ndarray]:
        """The cell's checkpoint payload (computed once, cached).

        Keys: ``best_nlp``/``best_row`` always; ``hits``/``hit_stats``
        always (possibly empty); ``maf``/``valid`` (+ ``omnibus_nlp`` when
        the multivariate screen ran, + ``t_probe``) on t_lo==0 cells.
        """
        if self._arrays is None:
            if self._shard is not None:
                self._arrays = {
                    k: v for k, v in self._shard.items()
                    if k not in ("lo", "hi", "t_lo", "t_hi")
                }
            else:
                v = self.view
                payload: dict[str, np.ndarray] = {
                    "best_nlp": v.best_nlp,
                    "best_row": v.best_row,
                }
                hits, stats = extract_hits(v, self._threshold)
                payload["hits"] = hits
                payload["hit_stats"] = stats
                if self.carries_marker_tracks:
                    payload["maf"] = v.maf
                    payload["valid"] = v.valid
                    if v.omnibus_nlp is not None:
                        payload["omnibus_nlp"] = v.omnibus_nlp
                    payload["t_probe"] = np.asarray(
                        v.t_probe(LAMBDA_PROBE_ROWS), np.float32
                    )
                self._arrays = payload
        return self._arrays

    def payload(self) -> dict[str, np.ndarray]:
        """The shard the checkpoint commits: payload plus cell extent."""
        return {
            "lo": np.asarray(self.lo),
            "hi": np.asarray(self.hi),
            "t_lo": np.asarray(self.t_lo),
            "t_hi": np.asarray(self.t_hi),
            **self.arrays,
        }

    # ------------------------------------------------------------ accessors

    @property
    def best_nlp(self) -> np.ndarray:
        """(n_traits,) per-trait best -log10 p within this cell's markers."""
        return self.arrays["best_nlp"]

    @property
    def best_row(self) -> np.ndarray:
        """(n_traits,) *batch-local* marker row of the best; globalize with
        ``cell.lo + best_row``."""
        return self.arrays["best_row"]

    @property
    def hits(self) -> np.ndarray:
        """(H, 2) int32 (global marker, global trait) above the threshold."""
        return self.arrays["hits"]

    @property
    def hit_stats(self) -> np.ndarray:
        """(H, 3) float32 (r, t, -log10 p) aligned with ``hits``."""
        return self.arrays["hit_stats"]

    @property
    def maf(self) -> np.ndarray | None:
        return self.arrays.get("maf")

    @property
    def valid(self) -> np.ndarray | None:
        return self.arrays.get("valid")

    @property
    def omnibus_nlp(self) -> np.ndarray | None:
        return self.arrays.get("omnibus_nlp")

    @property
    def t_probe(self) -> np.ndarray | None:
        return self.arrays.get("t_probe")


@dataclass
class PreparedScan:
    """Everything ``ScanPlan.prepare`` amortizes once per scan: the resolved
    engine (setup run — GRM/REML for lmm), the compiled device step, the
    residualized panel store, and the 2-D grid decomposition."""

    study: Study
    config: ScanConfig
    mesh: Mesh | None
    engine: ScanEngine
    ctx: EngineContext
    step: Callable[..., dict]
    trait_blocks: list[TraitBlock]
    panels: PanelStore | None
    batches: list[MarkerBatch]
    dof: int
    lmm_info: dict | None
    n_covariates: int

    @property
    def n_batches(self) -> int:
        return len(self.batches)

    @property
    def n_trait_blocks(self) -> int:
        return len(self.trait_blocks)

    def fingerprint(self) -> str:
        """The checkpoint identity of this scan (mesh/host-topology free)."""
        cfg, study = self.config, self.study
        engine_state = self.engine.state_fingerprint()
        m_total = study.source.n_markers
        return config_fingerprint(
            {
                **cfg.fingerprint_payload(),
                "n_markers": m_total,
                "n_samples": study.n_samples,
                "n_traits": study.n_traits,
                # The plan's index->(lo,hi) mapping depends on the shard
                # layout; resuming against a re-sharded fileset would
                # silently mix two incompatible batch decompositions.
                "shard_boundaries": list(
                    getattr(study.source, "shard_boundaries", (0, m_total))
                ),
                **({"engine_state": engine_state} if engine_state else {}),
            }
        )


class ScanPlan:
    """A validated, normalized scan specification bound to a Study.

    ``prepare()`` runs the amortized setup (residualization, engine setup —
    the lmm engine's streamed GRM + eigh + REML live here — and step
    construction); ``run()`` prepares and returns the executable
    ``ScanSession``.  A plan may be prepared once and run many times.
    """

    def __init__(self, study: Study, config: ScanConfig, *, mesh: Mesh | None = None):
        self.study = study
        self.config = config
        self.mesh = mesh
        self._prepared: PreparedScan | None = None

    # ---------------------------------------------------------------- build

    def prepare(self) -> PreparedScan:
        if self._prepared is not None:
            return self._prepared
        study, config, mesh = self.study, self.config, self.mesh
        engine = get_engine(config.engine)
        n_samples = study.n_samples
        n_traits = study.n_traits
        phenotypes = np.asarray(study.phenotypes)
        covariates = study.covariates

        # The trait axis of the 2-D scan grid (DESIGN.md §10).  block_p is
        # the panel-axis compute tile of every engine's step; aligning the
        # scheduling blocks to it is what makes the blocked scan
        # bitwise-identical to the unblocked one.
        trait_blocks = TraitBlockPlanner(
            config.trait_block, quantum=config.block_p
        ).plan(n_traits)
        if config.multivariate and len(trait_blocks) > 1:
            raise ValueError(
                "the multivariate omnibus screen needs the whole panel per "
                "marker (it combines evidence across every trait); run it "
                "unblocked (trait_block=0)"
            )

        n_traits_eff = float(n_traits)
        whitening = None
        panels: PanelStore | None = None
        q = None
        if engine.uses_global_panel:
            # OLS panel prep (Eq. 1), amortized once per trait block into a
            # host-side store.  Engines that build their own panel (lmm:
            # rotated per LOCO scope in setup_scan) skip this entirely — no
            # (N, P) device array is ever kept alive.
            q = covariate_basis(
                jnp.asarray(covariates) if covariates is not None else None,
                n_samples,
            )
            panels = PanelStore.residualized(
                phenotypes, q, trait_blocks,
                quantum=config.block_p,
                max_resident=config.panel_resident_blocks,
            )
            n_covariates = int(q.shape[1]) - 1
            if config.multivariate:
                from repro.core import multivariate as mv

                # unblocked by the check above: block 0 IS the full panel
                y_full = panels.device_block(trait_blocks[0])
                whitening, eig = mv.whiten_panel(y_full)
                n_traits_eff = float(mv.effective_tests(eig))
        else:
            cov = None if covariates is None else np.asarray(covariates)
            n_covariates = 0 if cov is None else (1 if cov.ndim == 1 else cov.shape[1])

        dof = config.options.dof(n_samples, n_covariates)
        # Negotiate the H2D staging currency per source (DESIGN.md §17) and
        # size the shared packed-slab cache the prepare workers read through.
        from repro.core.engines import resolve_genotype_staging
        from repro.io.packed_cache import configure_default as _configure_packed_cache

        genotype_staging = resolve_genotype_staging(
            config.genotype_staging,
            study.source,
            excluded_samples=study.excluded_samples,
            mesh=mesh,
        )
        if genotype_staging == "packed":
            _configure_packed_cache(config.packed_cache_mb)
        ctx = EngineContext(
            n_samples=n_samples,
            n_covariates=n_covariates,
            options=config.options,
            mesh=mesh,
            mode=config.mode,
            hit_threshold=config.hit_threshold_nlp,
            maf_min=config.maf_min,
            block_m=config.block_m,
            block_n=config.block_n,
            block_p=config.block_p,
            q_basis=q,
            multivariate=config.multivariate,
            n_traits_eff=n_traits_eff,
            whitening=whitening,
            keep=study.keep,
            excluded_samples=study.excluded_samples,
            trait_blocks=tuple(trait_blocks),
            panel_resident_blocks=config.panel_resident_blocks,
            input_dtype=config.input_dtype,
            loco=config.loco,
            grm_method=config.grm_method,
            grm_batch_markers=config.grm_batch_markers,
            lmm_delta=config.lmm_delta,
            lmm_epilogue=config.lmm_epilogue,
            io_workers=config.io_workers,
            sparse_epilogue=config.sparse_epilogue,
            hit_capacity=config.hit_capacity,
            genotype_staging=genotype_staging,
        )
        engine.validate(ctx)
        # Amortized engine setup (LMM: streamed GRM + eigendecomposition +
        # REML + panel rotation).  Engines may override the scan dof and
        # contribute diagnostics to the result.
        lmm_info: dict | None = None
        setup = engine.setup_scan(study.source, phenotypes, covariates, ctx)
        if setup:
            dof = int(setup.get("dof", dof))
            lmm_info = setup.get("info")
        step = engine.build_step(ctx)
        batches = BatchPlanner(config.batch_markers).plan(study.source)
        self._prepared = PreparedScan(
            study=study,
            config=config,
            mesh=mesh,
            engine=engine,
            ctx=ctx,
            step=step,
            trait_blocks=trait_blocks,
            panels=panels,
            batches=batches,
            dof=dof,
            lmm_info=lmm_info,
            n_covariates=n_covariates,
        )
        return self._prepared

    # ----------------------------------------------------------------- run

    def run(
        self,
        *,
        resume: bool = True,
        executor=None,
        marker_window: tuple[int, int] | None = None,
    ) -> "ScanSession":
        """Prepare (if not already) and open an executable session.

        ``executor`` injects a pre-built executor handle (the serve
        subsystem's shared worker pool — DESIGN.md §16) instead of the
        session constructing its own; ``marker_window`` restricts the run
        to the batch-aligned sub-grid covering ``[lo, hi)`` markers.
        """
        return ScanSession(
            self.prepare(), resume=resume, executor=executor,
            marker_window=marker_window,
        )


# ------------------------------------------------------------------ executors


class _Slot:
    """One executor slot: the engine's per-device state plus — for
    global-panel engines — the driver's panel view on the same device.

    This object is the *explicit* home of everything that used to ride
    implicitly on the default device (staged panel blocks, the lmm scope
    caches, the step's prolog memo): one slot per device, no sharing, so a
    multi-device scan never routes two devices through one memo or cache.
    ``device=None`` is the serial slot — placement via ``jnp.asarray`` on
    the implicit default device, bit-for-bit the historical path.
    """

    def __init__(self, prepared: "PreparedScan", *, device=None,
                 step: Callable[..., dict] | None = None, label: str = "serial"):
        self.device = device
        self.label = label
        self.state = prepared.engine.make_device_state(
            prepared.ctx, device=device, step=step
        )
        self.panels = (
            prepared.panels.device_view(device)
            if prepared.panels is not None else None
        )

    def stage(self, host_batch) -> tuple:
        return self.state.stage(host_batch)

    def step(self, *args) -> dict:
        return self.state.step(*args)

    def panel_block(self, batch: MarkerBatch, block: TraitBlock):
        """The trailing step argument for one grid cell: the slot's view of
        the driver's residualized store for OLS engines, the engine device
        state's per-scope rotated panel for the rest."""
        if self.panels is not None:
            return self.panels.device_block(block)
        return self.state.panel_block(batch, block)

    def reset(self) -> None:
        self.state.reset()
        # Per-device panel views die with their slot (their device blocks
        # must not stay pinned after the scan).  The serial slot's view is
        # the store's SHARED default LRU — deliberately left resident, the
        # historical cross-run warm cache.
        if self.panels is not None and self.device is not None:
            self.panels.release()


def _live_cell(
    host_batch, out: dict, blk: TraitBlock, cfg: ScanConfig, dof: float
) -> "CellResult":
    """Wrap one device step output as a materialized live ``CellResult``.

    ``arrays`` is forced here — on the computing slot's thread — so D2H
    pulls parallelize across devices, the per-cell wall time is honest
    (the jitted step dispatches asynchronously; the pull is the sync
    point), and the commit/writer path downstream reads the cache.  The
    hit-driven-pull invariant is untouched: materialization only crosses
    the full tiles when the cell has hits.  ``dof`` plus the scan's screen
    threshold (``t2_screen``) let the view route every emitted -log10 p
    through the canonical refine executables (§13) — in both sparse and
    dense epilogue modes, so the two stay bitwise equal.
    """
    batch = host_batch.batch
    t2_screen = (
        _stats.t2_screen_threshold(float(cfg.hit_threshold_nlp), float(dof))
        if cfg.options.compute_neglog10p
        else None
    )
    view = BatchView(
        host_batch, out, blk.n_traits, t_lo=blk.lo, block_index=blk.index, dof=dof,
        t2_screen=t2_screen,
    )
    cell = CellResult(
        batch_index=batch.index,
        block_index=blk.index,
        lo=batch.lo,
        hi=batch.hi,
        t_lo=blk.lo,
        t_hi=blk.hi,
        view=view,
        hit_threshold=cfg.hit_threshold_nlp,
    )
    cell.arrays
    return cell


class _SlotTail:
    """Per-slot downstream tail (DESIGN.md §15): one FIFO thread that runs
    payload materialization, checkpoint commit, and result delivery OFF the
    compute thread's critical path, so D2H pulls and manifest writes of
    cell k overlap the device step of cell k+1.

    Strict FIFO is the correctness story: the compute thread enqueues each
    cell's emit task followed by its run's ``complete`` task, so a cell is
    always committed before its lease is marked done (the shared-fs
    ordering contract) and per-slot delivery order matches the unpipelined
    path.  A failing task (commit error, D2H error) is reported through
    ``on_error`` and all later tasks are drained unexecuted — in
    particular the run's ``complete`` never fires, so the lease is left to
    expire exactly as a worker crash would.
    """

    def __init__(self, *, stop: threading.Event, on_error: Callable, name: str):
        self._q: queue.Queue = queue.Queue(maxsize=4)
        self._stop = stop
        self._on_error = on_error
        self._failed = False
        self._thread = threading.Thread(target=self._run, daemon=True, name=name)
        self._thread.start()

    def submit(self, task: Callable[[], None]) -> None:
        """Enqueue (bounded: blocks the compute thread when the tail is >4
        cells behind — host-RAM backpressure) unless teardown started."""
        while True:
            try:
                self._q.put(task, timeout=0.1)
                return
            except queue.Full:
                if self._stop.is_set():
                    return

    def _run(self) -> None:
        while True:
            task = self._q.get()
            if task is None:
                return
            if self._failed:
                continue
            try:
                task()
            except BaseException as e:  # noqa: BLE001 — reported to consumer
                self._failed = True
                self._on_error(e)

    def close(self, *, join_timeout: float = 10.0) -> None:
        """Drain queued tasks, then stop and join the thread.  The put may
        block briefly but always lands: the tail consumes unconditionally
        (even after a failure it drains)."""
        self._q.put(None)
        self._thread.join(timeout=join_timeout)


class SerialExecutor:
    """The historical single-device grid walk: marker batches outer
    (decode prefetch + H2D double buffer), trait blocks inner (each staged
    genotype batch sweeps every pending block before the next copy), with
    the trait-axis panel look-ahead staging block b+1 during block b."""

    kind = "serial"

    def __init__(self, prepared: "PreparedScan", *, step: Callable[..., dict] | None = None):
        self.prepared = prepared
        self._step = step

    def info(self) -> dict:
        return {"kind": self.kind, "devices": 1}

    def cells(self, todo, pending) -> Iterator[tuple["CellResult", CellTiming]]:
        prep = self.prepared
        cfg = prep.config
        engine = prep.engine
        blocks = prep.trait_blocks
        slot = _Slot(prep, device=None, step=self._step, label="serial")

        def decode(b):
            t = time.perf_counter()
            hb = engine.prepare_batch(prep.study.source, b, prep.ctx)
            return hb, time.perf_counter() - t

        prefetched = Prefetcher(
            todo,
            decode,
            depth=cfg.prefetch_depth,
            num_workers=cfg.io_workers,
        )
        # Trait-axis look-ahead (DESIGN.md §10): stage the next cell's panel
        # block while the device computes the current cell.
        panel_la = PanelPrefetcher(slot.panel_block)

        def stage(item):
            # Staging launches the copy; on accelerators it completes while
            # the device chews on the previous batch (double buffer).
            host_batch, decode_s = item
            t = time.perf_counter()
            dev_args = slot.stage(host_batch)
            h2d = sum(int(getattr(a, "nbytes", 0)) for a in host_batch.device_args)
            return host_batch, dev_args, decode_s, time.perf_counter() - t, h2d

        stream = double_buffer(prefetched, stage)
        try:
            todo_pos = {b.index: i for i, b in enumerate(todo)}
            for host_batch, dev_args, decode_s, stage_s, h2d_bytes in stream:
                batch = host_batch.batch
                bidx = batch.index
                # Trait blocks are the INNER loop: one staged genotype batch
                # feeds every block before the next H2D copy (DESIGN.md §10).
                cells = [
                    blk for blk in blocks
                    if pending is None or (bidx, blk.index) in pending
                ]
                nxt = todo_pos.get(bidx, len(todo)) + 1
                next_batch = todo[nxt] if nxt < len(todo) else None
                for pos, blk in enumerate(cells):
                    t0 = time.perf_counter()
                    out = slot.step(*dev_args, slot.panel_block(batch, blk))
                    # Look ahead one cell on the trait axis (then wrap to the
                    # next batch's first block, which the LRU may have evicted).
                    # Requested BEFORE the device sync so staging overlaps
                    # the step exactly as it always did.
                    if pos + 1 < len(cells):
                        panel_la.request(batch, cells[pos + 1])
                    elif next_batch is not None and blocks:
                        panel_la.request(next_batch, blocks[0])
                    # Split the cell's wall time at the device fence: the
                    # jitted step dispatches asynchronously, so t1 - t0 is
                    # honest device time and t2 - t1 is the host payload
                    # extraction the sparse epilogue (§13) shrinks.
                    jax.block_until_ready(out)
                    t1 = time.perf_counter()
                    cell = _live_cell(host_batch, out, blk, cfg, prep.dof)
                    t2 = time.perf_counter()
                    yield cell, CellTiming(
                        batch_index=bidx,
                        block_index=blk.index,
                        n_markers=cell.n_markers,
                        n_traits=cell.n_traits,
                        wall_s=t2 - t0,
                        step_s=t1 - t0,
                        extract_s=t2 - t1,
                        # Attributed to the batch's first cell; later cells
                        # of the sweep reuse the staged copy.
                        decode_s=decode_s if pos == 0 else 0.0,
                        stage_s=stage_s if pos == 0 else 0.0,
                        h2d_bytes=h2d_bytes if pos == 0 else 0,
                        device=slot.label,
                    )
        finally:
            # Error path included: a raising consumer or engine step must not
            # leave decode workers alive or the in-flight staged copy pinned.
            stream.close()
            prefetched.shutdown()
            panel_la.shutdown()
            # Drop the step memo's pinned last batch (raw + prolog output)
            # so a cached plan doesn't hold device memory between runs.
            slot.reset()


class MultiDeviceExecutor:
    """Drain the scan grid across N devices with work stealing
    (DESIGN.md §12) and per-slot streaming pipelines (§15).

    One worker thread per device slot; each claims ``CellRun``s from the
    ``CellScheduler`` (lease = runs of cells sharing a marker batch, so a
    claimed genotype batch is staged once per device and swept) and
    computes cells on its own ``_Slot`` — explicit ``jax.device_put``
    placement, per-slot step/prolog memo, per-slot panel and lmm caches.

    With ``slot_prefetch > 0`` each worker runs a three-stage pipeline:

        look-ahead   the worker claims up to ``slot_prefetch`` items BEYOND
                     the one it is computing (non-blocking claims) and
                     submits their genotype decode to a shared
                     ``DecodePool`` of ``io_workers`` threads, then stages
                     the next batch's H2D copy while the device chews on
                     the current one; a per-slot ``PanelPrefetcher``
                     prefetches the next cell's trait-panel block.
        compute      the device step, fenced on the compute thread
                     (``step_s`` stays honest).
        tail         payload materialization (D2H), checkpoint commit, and
                     result delivery run on a per-slot ``_SlotTail`` FIFO
                     thread, overlapping the next cell's step.  FIFO order
                     preserves commit-before-lease-done (the run's
                     ``complete`` is enqueued after its cells).

    ``slot_prefetch=0`` is the historical unpipelined claim loop.  Either
    way the math is untouched: compute order per slot, staged arrays, and
    globally-aligned ``block_p`` tiles are identical — pipelining only
    moves WHEN host work happens — so outputs stay bitwise-identical to
    the serial executor.  Completion order is whatever the fleet produces;
    the session commits each cell before yielding and the sinks/writers
    normalize fold order.

    ``autotune_lease`` closes the loop at runtime: the consumer loop
    watches the scheduler's live ``busy_s``/``wait_s`` accounting and
    shrinks the lease extent toward the tail of the scan (guided
    self-scheduling), so late slots never idle behind one straggler's fat
    lease.  Retunes affect future refills only — which items run where is
    a pure perf question, never a correctness one.
    """

    kind = "multi-device"

    def __init__(self, prepared: "PreparedScan", *, n_devices: int,
                 placement: str = "marker-major", lease_batches: int = 2,
                 backend: str = "threads", backend_opts: dict | None = None,
                 slot_prefetch: int = 1, autotune_lease: bool = True):
        visible = jax.devices()
        if n_devices > len(visible):
            raise ValueError(
                f"devices={n_devices} but only {len(visible)} visible "
                f"({visible[0].platform}); reduce --devices or expose more "
                "devices"
            )
        self.prepared = prepared
        self.devices = visible[:n_devices]
        self.placement = placement
        self.lease_batches = lease_batches
        self.backend = backend
        self.backend_opts = dict(backend_opts or {})
        self.slot_prefetch = max(0, int(slot_prefetch))
        self.autotune_lease = bool(autotune_lease)
        # Under a distributed backend the worker labels are host-qualified
        # (CellTiming.device, summary.json worker stats): N processes share
        # one grid, and "dev0" alone no longer names a unique slot.
        host = self.backend_opts.get("host_id")
        self._label_prefix = f"{host}/" if (backend != "threads" and host) else ""
        self._worker_stats: dict = {}
        self._autotune: dict = {
            "enabled": self.autotune_lease,
            "initial_lease": lease_batches,
            "final_lease": lease_batches,
            "adjustments": 0,
            "wait_share": None,
            "placement_warning": None,
        }
        # Distributed-backend commit hook (set by the session): a cell MUST
        # be committed to the checkpoint BEFORE its lease is marked done —
        # peers treat a done lease as "in the manifest", so the reverse
        # order would let a crash between the two lose the cell for good.
        # Committing on the worker-side pipeline (not the consumer) is what
        # makes the ordering enforceable.
        self.commit: Callable[["CellResult"], object] | None = None

    def info(self) -> dict:
        out = {
            "kind": self.kind,
            "devices": len(self.devices),
            "placement": self.placement,
            "lease_batches": self.lease_batches,
            "slot_prefetch": self.slot_prefetch,
            "backend": self.backend,
            "autotune": dict(self._autotune),
            "workers": {
                w: dataclasses.asdict(st) for w, st in sorted(self._worker_stats.items())
            },
        }
        if self.backend != "threads":
            out["host_id"] = self.backend_opts.get("host_id")
        return out

    def cells(self, todo, pending) -> Iterator[tuple["CellResult", CellTiming]]:
        prep = self.prepared
        cfg = prep.config
        engine = prep.engine
        sched = CellScheduler(
            todo, prep.trait_blocks, pending,
            placement=self.placement, lease_size=self.lease_batches,
            n_workers=len(self.devices),
            backend=self.backend, backend_opts=self.backend_opts,
        )
        self._autotune["initial_lease"] = sched.lease_size
        self._autotune["final_lease"] = sched.lease_size
        # Bounded: in-flight materialized cells are capped per slot, so the
        # fleet cannot outrun a slow consumer into unbounded host RAM.
        results: queue.Queue = queue.Queue(maxsize=4 * len(self.devices))
        stop = threading.Event()
        done = object()
        depth = self.slot_prefetch

        def put(item) -> None:
            # Never blocks forever: once the consumer is gone (stop set) the
            # item is dropped — teardown, nobody is listening.
            while True:
                try:
                    results.put(item, timeout=0.1)
                    return
                except queue.Full:
                    if stop.is_set():
                        return

        def decode(batch):
            t = time.perf_counter()
            hb = engine.prepare_batch(prep.study.source, batch, prep.ctx)
            return hb, time.perf_counter() - t

        # ONE pool across every slot: total host decode parallelism is
        # io_workers — the same meaning the knob has under the serial
        # executor — however many devices drain the grid.
        pool = DecodePool(decode, num_workers=cfg.io_workers) if depth > 0 else None

        def worker(wid: int, device) -> None:
            label = f"{self._label_prefix}dev{wid}"
            slot = _Slot(prep, device=device, label=label)
            panel_la = (
                PanelPrefetcher(slot.panel_block, name=f"panel-prefetch-dev{wid}")
                if depth > 0 else None
            )
            tail = (
                _SlotTail(stop=stop, on_error=put, name=f"slot-tail-{wid}")
                if depth > 0 else None
            )
            # Staged memo, capacity depth+1: the batch being computed plus
            # the look-ahead batches whose H2D copies landed early.  With
            # depth=0 this degenerates to the historical one-slot memo.
            staged: dict[int, tuple] = {}   # idx -> (hb, dev, dec_s, stg_s, h2d)
            inflight: set[int] = set()      # batch idxs pending in the pool
            ahead: deque = deque()          # claimed (idx, run), decode submitted

            def ensure_decode(batch) -> None:
                if batch.index not in staged and batch.index not in inflight:
                    pool.submit((wid, batch.index), batch)
                    inflight.add(batch.index)

            def staged_args(batch) -> tuple:
                if batch.index not in staged:
                    if batch.index in inflight:
                        hb, decode_s = pool.result((wid, batch.index))
                        inflight.discard(batch.index)
                    else:
                        hb, decode_s = decode(batch)
                    t = time.perf_counter()
                    dev_args = slot.stage(hb)
                    h2d = sum(
                        int(getattr(a, "nbytes", 0)) for a in hb.device_args
                    )
                    staged[batch.index] = (
                        hb, dev_args, decode_s, time.perf_counter() - t, h2d
                    )
                    while len(staged) > depth + 1:
                        oldest = next(iter(staged))
                        if oldest == batch.index:
                            break
                        del staged[oldest]
                return staged[batch.index]

            def make_emit(hb, out, blk, batch, step_s, decode_s, stage_s,
                          h2d_bytes):
                def emit() -> None:
                    t = time.perf_counter()
                    cell = _live_cell(hb, out, blk, cfg, prep.dof)
                    if self.commit is not None:
                        self.commit(cell)
                    extract_s = time.perf_counter() - t
                    put((cell, CellTiming(
                        batch_index=batch.index,
                        block_index=blk.index,
                        n_markers=cell.n_markers,
                        n_traits=cell.n_traits,
                        # Not contiguous wall clock under the pipeline: the
                        # extract ran later, overlapped with another cell's
                        # step.  step + extract is the cell's true cost.
                        wall_s=step_s + extract_s,
                        step_s=step_s,
                        extract_s=extract_s,
                        decode_s=decode_s,
                        stage_s=stage_s,
                        h2d_bytes=h2d_bytes,
                        device=label,
                    )))
                return emit

            try:
                while not stop.is_set():
                    # Refill the look-ahead window: the item in hand plus up
                    # to `depth` beyond it, decodes submitted at claim time
                    # so the pool works while this slot computes.  Only the
                    # first claim may block (distributed backends poll out
                    # peers' undone leases): a worker with work in hand
                    # must never park on the queue.
                    while len(ahead) < depth + 1:
                        got = sched.claim(label, block=not ahead)
                        if got is None:
                            break
                        if depth > 0:
                            ensure_decode(got[1].batch)
                        ahead.append(got)
                    if not ahead:
                        break
                    idx, run = ahead.popleft()
                    batch = run.batch
                    hb, dev_args, decode_s, stage_s, h2d_bytes = staged_args(batch)
                    # decode/stage are attributed to the first cell computed
                    # off a fresh staging, once.
                    staged[batch.index] = (hb, dev_args, 0.0, 0.0, 0)
                    for pos, blk in enumerate(run.blocks):
                        if stop.is_set():
                            return
                        t0 = time.perf_counter()
                        out = slot.step(*dev_args, slot.panel_block(batch, blk))
                        # Overlap windows open between dispatch and fence:
                        # the next cell's panel block and (first cell of the
                        # run only) the look-ahead H2D staging.
                        if panel_la is not None:
                            if pos + 1 < len(run.blocks):
                                panel_la.request(batch, run.blocks[pos + 1])
                            elif ahead:
                                nrun = ahead[0][1]
                                panel_la.request(nrun.batch, nrun.blocks[0])
                        if depth > 0 and ahead:
                            # Stage the look-ahead batch's H2D copy as soon
                            # as its decode lands (double buffer) — probed,
                            # never waited on: an unfinished decode is
                            # collected at need instead of blocking here.
                            nxt = ahead[0][1].batch
                            if nxt.index not in staged and pool.ready(
                                (wid, nxt.index)
                            ):
                                staged_args(nxt)
                        jax.block_until_ready(out)
                        step_s = time.perf_counter() - t0
                        emit = make_emit(
                            hb, out, blk, batch, step_s, decode_s, stage_s,
                            h2d_bytes,
                        )
                        if tail is not None:
                            tail.submit(emit)
                        else:
                            emit()
                        decode_s = stage_s = 0.0
                        h2d_bytes = 0
                    if tail is not None:
                        tail.submit(
                            lambda label=label, idx=idx: sched.complete(label, idx)
                        )
                    else:
                        sched.complete(label, idx)
            except BaseException as e:  # noqa: BLE001 — reported to consumer
                put(e)
            finally:
                # Error/teardown path: cancel look-ahead decodes, drain the
                # tail (delivering its finished cells), drop staged copies,
                # and release the slot's device memory.  Unserved claimed
                # items are simply never completed — their leases expire
                # (shared-fs) exactly as a crash would, or die with the
                # scan (threads backend, where the error kills the run).
                if pool is not None:
                    for b in inflight:
                        pool.discard((wid, b))
                if tail is not None:
                    tail.close()
                if panel_la is not None:
                    panel_la.shutdown()
                staged.clear()
                slot.reset()
                put(done)

        threads = [
            threading.Thread(
                target=worker, args=(i, d), daemon=True, name=f"scan-device-{i}"
            )
            for i, d in enumerate(self.devices)
        ]
        for t in threads:
            t.start()
        finished = 0
        decode_total = step_total = 0.0
        last_tune = time.monotonic()
        try:
            while finished < len(threads):
                item = results.get()
                if item is done:
                    finished += 1
                elif isinstance(item, BaseException):
                    raise item
                else:
                    decode_total += item[1].decode_s
                    step_total += item[1].step_s
                    if self.autotune_lease:
                        now = time.monotonic()
                        if now - last_tune >= 0.5:
                            last_tune = now
                            self._tune_lease(sched)
                    yield item
        finally:
            stop.set()
            # Unblock workers parked in a blocking claim (the shared-fs
            # backend polls while peers hold undone leases) and in decode
            # waits ...
            sched.stop()
            if pool is not None:
                pool.shutdown()
            # ... and producers stuck on the bounded queue, then join.
            for t in threads:
                while t.is_alive():
                    try:
                        while True:
                            results.get_nowait()
                    except queue.Empty:
                        pass
                    t.join(timeout=0.1)
            self._worker_stats = sched.stats()
            self._finish_accounting(decode_total, step_total)

    # ------------------------------------------------------------- autotuning

    def _tune_lease(self, sched: CellScheduler) -> None:
        """Guided self-scheduling on live accounting: target half the
        remaining items spread over the fleet (never above the configured
        initial — big early leases amortize queue traffic, small late ones
        balance the tail), and halve once when the fleet's wait share says
        slots are starving behind peers' leases."""
        stats = sched.stats()
        busy = sum(s.busy_s for s in stats.values())
        wait = sum(s.wait_s for s in stats.values())
        share = wait / (busy + wait) if busy + wait > 0 else 0.0
        initial = self._autotune["initial_lease"]
        target = max(1, min(initial, sched.remaining() // (2 * len(self.devices))))
        if share > 0.3:
            target = min(target, max(1, sched.lease_size // 2))
        self._autotune["wait_share"] = round(share, 3)
        if target != sched.lease_size:
            sched.set_lease_size(target)
            self._autotune["adjustments"] += 1
            self._autotune["final_lease"] = target

    def _finish_accounting(self, decode_total: float, step_total: float) -> None:
        stats = self._worker_stats
        busy = sum(s.busy_s for s in stats.values())
        wait = sum(s.wait_s for s in stats.values())
        if busy + wait > 0:
            self._autotune["wait_share"] = round(wait / (busy + wait), 3)
        if (
            self.placement == "trait-major"
            and self.prepared.n_trait_blocks > 1
            and step_total > 0
            and decode_total > step_total
        ):
            msg = (
                "trait-major placement re-decodes each genotype batch once "
                f"per trait block, and this scan spent {decode_total:.1f}s "
                f"decoding vs {step_total:.1f}s computing — marker-major "
                "placement (one decode per batch, swept over every block) "
                "would likely be faster"
            )
            self._autotune["placement_warning"] = msg
            warnings.warn(msg, RuntimeWarning, stacklevel=2)


def _adapt_swapped_step(step, prepared: PreparedScan):
    """A swapped step (the shim's historical ``_step`` hook) speaks the
    decoded staging currency; under packed staging (DESIGN.md §17) the
    staged first argument is raw PLINK bytes.  Interpose the same jitted
    device-side front the engine prologs use — its output is bit-identical
    to the historical host decode, so the caller's patched math sees
    exactly the inputs it always has."""
    ctx = prepared.ctx
    if getattr(ctx, "genotype_staging", "dense") != "packed":
        return step
    import functools

    from repro.kernels.gwas_dot import ops as kops

    if prepared.config.engine == "fused":
        front = functools.partial(
            kops.repack_plink_tiled_device, n_samples=ctx.n_samples,
            block_n=ctx.block_n, block_m=ctx.block_m,
        )
    else:
        front = functools.partial(
            kops.decode_packed_device, n_samples=ctx.n_samples
        )

    def adapted(g_raw, *rest):
        return step(front(g_raw), *rest)

    if hasattr(step, "reset"):
        adapted.reset = step.reset
    return adapted


class ScanSession:
    """One executable pass over the scan grid, streaming ``CellResult``s.

    ``events()`` is a one-shot generator: live cells in grid order (marker
    batches outer, trait blocks inner), then — when resuming — the replayed
    cells committed by previous runs.  All pipeline teardown (prefetch
    workers, the in-flight staged copy, the panel look-ahead thread) happens
    in its ``finally``, so consumers that raise mid-stream must ``close()``
    the generator (or just iterate with a ``for`` loop, which does).
    """

    def __init__(
        self,
        prepared: PreparedScan,
        *,
        resume: bool = True,
        step: Callable[..., dict] | None = None,
        executor=None,
        marker_window: tuple[int, int] | None = None,
    ):
        self.prepared = prepared
        self.study = prepared.study
        self.config = prepared.config
        self.resume = resume
        if step is not None and step is not prepared.step:
            step = _adapt_swapped_step(step, prepared)
        self._step = step if step is not None else prepared.step
        self._consumed = False
        # An injected executor handle (duck-typed: ``cells(todo, pending)``
        # generator + ``info()``) replaces the session-owned executor — the
        # seam that lets N concurrent serve sessions share ONE long-lived
        # worker pool and work queue (each session gets a request-scoped
        # view of the pool, so sinks and writers stay per-session).
        self._executor = executor
        # A batch-aligned sub-grid: only marker batches overlapping
        # [lo, hi) are computed (serve's marker-window queries).  The
        # window is widened to batch boundaries — ``window_covered`` is
        # the exact extent — so every computed cell is bit-identical to
        # the same cell of a full scan.
        self.marker_window = marker_window
        if marker_window is not None:
            lo, hi = int(marker_window[0]), int(marker_window[1])
            if not (0 <= lo < hi <= self.study.n_markers):
                raise ValueError(
                    f"marker_window [{lo}, {hi}) outside "
                    f"[0, {self.study.n_markers})"
                )
            self._batches = [
                b for b in prepared.batches if b.hi > lo and b.lo < hi
            ]
            self.window_covered = (self._batches[0].lo, self._batches[-1].hi)
        else:
            self._batches = list(prepared.batches)
            self.window_covered = None

        # Executor selection (DESIGN.md §12).  devices=0 means every
        # visible device; 1 is the serial walk.  Resolved here, NOT in the
        # fingerprint: a checkpoint cut under one device count resumes
        # under any other.
        self.n_devices = (
            self.config.devices if self.config.devices > 0 else len(jax.devices())
        )
        if self.n_devices > 1 and prepared.mesh is not None:
            raise ValueError(
                "the multi-device grid executor and a sharding mesh are "
                "exclusive parallelism axes; pass devices=1 with a mesh (or "
                "drop the mesh to scale by grid cells)"
            )
        self.metrics = ScanMetrics(
            n_cells_total=len(self._batches) * prepared.n_trait_blocks
        )
        # Optional observer called after every recorded cell — the CLI's
        # progress line; must be cheap, runs on the consumer thread.
        self.progress: Callable[[ScanMetrics], None] | None = None
        self.executor_info: dict | None = None

        if self.config.exec_backend != "threads" and not self.config.checkpoint_dir:
            raise ValueError(
                f"exec_backend={self.config.exec_backend!r} coordinates "
                "through the checkpoint directory (lease table + manifest); "
                "pass checkpoint_dir="
            )
        self.checkpoint: ScanCheckpoint | None = None
        if self.config.checkpoint_dir:
            # Engine state (e.g. the LMM's GRM spectrum hash) is part of the
            # scan identity: resuming against a different GRM or refitted
            # variance components would mix incompatible statistics.
            self.checkpoint = ScanCheckpoint(
                self.config.checkpoint_dir,
                fingerprint=prepared.fingerprint(),
                n_batches=prepared.n_batches,
                n_blocks=prepared.n_trait_blocks,
            )

    # ---------------------------------------------------------------- shape

    @property
    def n_batches(self) -> int:
        return self.prepared.n_batches

    @property
    def n_trait_blocks(self) -> int:
        return self.prepared.n_trait_blocks

    @property
    def n_markers(self) -> int:
        return self.study.n_markers

    @property
    def n_samples(self) -> int:
        return self.study.n_samples

    @property
    def n_traits(self) -> int:
        return self.study.n_traits

    @property
    def dof(self) -> int:
        return self.prepared.dof

    @property
    def lmm_info(self) -> dict | None:
        return self.prepared.lmm_info

    @property
    def hit_threshold(self) -> float:
        return self.config.hit_threshold_nlp

    @property
    def multivariate(self) -> bool:
        return self.config.multivariate

    @property
    def marker_ids(self):
        return self.study.marker_ids

    @property
    def trait_names(self):
        return self.study.trait_names

    # --------------------------------------------------------------- events

    def _backend_opts(self) -> dict:
        """Construction kwargs for a distributed scheduler backend: the
        lease table lives next to the checkpoint it coordinates."""
        if self.config.exec_backend == "threads":
            return {}
        return {
            "root": os.path.join(self.checkpoint.root, "leases"),
            "host_id": self.config.host_id or f"{socket.gethostname()}-{os.getpid()}",
            "lease_ttl": self.config.lease_ttl,
            # A peer's done lease is trusted only if its cells actually
            # reached the manifest: commit-before-done makes that the
            # invariant, but a lost manifest merge (flock-less mount) would
            # otherwise turn a done marker into a cell nobody ever
            # computes or replays.
            "cell_committed": self.checkpoint.has_cell,
        }

    def _make_executor(self):
        if self._executor is not None:
            # Injected handle (the serve pool's request-scoped view): the
            # pool owns workers, devices, and the shared queue; this
            # session only consumes its own cells.
            return self._executor
        # A distributed backend routes through the scheduler even on one
        # device: the lease table is what coordinates this process with its
        # peers, and the serial walk never touches it.
        if self.n_devices > 1 or self.config.exec_backend != "threads":
            if self._step is not self.prepared.step:
                # A swapped step (the shim's historical ``_step`` hook) is a
                # single callable with a single prolog memo — it cannot be
                # shared across worker threads, and silently ignoring it
                # would drop the caller's patched math.
                raise ValueError(
                    "a custom step was supplied but the scan runs on the "
                    "multi-device executor (devices > 1 or a distributed "
                    "exec backend), which builds one step per device slot; "
                    "run with devices=1 on the threads backend to use a "
                    "swapped step"
                )
            return MultiDeviceExecutor(
                self.prepared,
                n_devices=self.n_devices,
                placement=self.config.placement,
                lease_batches=self.config.lease_batches,
                backend=self.config.exec_backend,
                backend_opts=self._backend_opts(),
                slot_prefetch=self.config.slot_prefetch,
                autotune_lease=self.config.autotune_lease,
            )
        return SerialExecutor(self.prepared, step=self._step)

    def events(self) -> Iterator[CellResult]:
        """Stream the grid: compute pending cells on the configured executor
        (serial or multi-device), commit + yield each as a ``CellResult``,
        then replay previously committed cells (resume).  Live cells arrive
        in the executor's completion order — grid order for the serial
        walk, whatever the fleet produces for multi-device; the sinks and
        writers normalize fold order, so consumers see identical results
        either way."""
        if self._consumed:
            raise RuntimeError("ScanSession.events() is one-shot; open a new session")
        self._consumed = True
        ckpt = self.checkpoint

        todo = self._batches
        pending: set[tuple[int, int]] | None = None   # (batch, block) cells
        if ckpt is not None and self.resume:
            # Fold in cells peer processes committed since we opened the
            # manifest (shared-fs hosts join an in-flight grid).
            ckpt.refresh()
            pending = set(ckpt.pending_cells())
            # A marker batch is re-staged iff ANY of its cells is pending;
            # completed cells of a re-staged batch are skipped by the
            # executor and replayed from their shards below.
            batches_pending = {b for b, _ in pending}
            todo = [b for b in self._batches if b.index in batches_pending]

        executor = self._make_executor()
        distributed = getattr(executor, "backend", "threads") != "threads"
        if ckpt is not None and distributed:
            # Shared-fs ordering contract: commit BEFORE the lease-done
            # marker (on the worker thread), so peers that see "done" can
            # trust the manifest.  The consumer loop then must not commit
            # again.
            executor.commit = lambda cell: ckpt.commit_cell(
                cell.batch_index, cell.block_index, cell.payload()
            )
        computed: set[tuple[int, int]] = set()
        self.metrics.start()
        stream = executor.cells(todo, pending)
        try:
            for cell, timing in stream:
                if ckpt is not None and not distributed:
                    # Commit the shard, then the manifest — a crash between
                    # the two just re-does one grid cell.  Commit-before-
                    # yield makes the manifest the multi-device coordination
                    # substrate: double completion (work stealing) is an
                    # idempotent overwrite, and a resume under any device
                    # count skips exactly the committed cells.
                    ckpt.commit_cell(cell.batch_index, cell.block_index, cell.payload())
                computed.add((cell.batch_index, cell.block_index))
                self.metrics.record(timing)
                if self.progress is not None:
                    self.progress(self.metrics)
                yield cell
        finally:
            # Error path included: a raising consumer or engine step must
            # not leave executor workers alive or staged copies pinned.
            stream.close()
            self.executor_info = executor.info()
            self.metrics.finish()

        # Resume path: replay committed-but-not-recomputed cells' shards.
        # Refresh first: under shared-fs the cells this process lost to its
        # peers were committed by them, and every host must still emit the
        # COMPLETE grid (that is what makes N hosts' outputs identical).
        if ckpt is not None:
            ckpt.refresh()
            # A windowed session replays only its own batches: cells other
            # sessions committed outside the window are not its grid.
            window_b = (
                {b.index for b in self._batches}
                if self.marker_window is not None else None
            )
            for bidx, kidx in sorted(ckpt.completed_cells() - computed):
                if window_b is not None and bidx not in window_b:
                    continue
                t0 = time.perf_counter()
                cell = CellResult.from_shard(bidx, kidx, ckpt.load_cell(bidx, kidx))
                self.metrics.record(CellTiming(
                    batch_index=bidx,
                    block_index=kidx,
                    n_markers=cell.n_markers,
                    n_traits=cell.n_traits,
                    wall_s=time.perf_counter() - t0,
                    device="checkpoint",
                    replayed=True,
                ))
                if self.progress is not None:
                    self.progress(self.metrics)
                yield cell
            self.metrics.finish()

    # -------------------------------------------------------------- writers

    def stream_to(self, *writers) -> dict:
        """Drive ``events()`` through result writers: open each, feed every
        cell, close in order; abort them all if anything raises.  Returns
        the merged summary dict of the writers' ``close()`` results."""
        from repro.api.writers import stream_session

        return stream_session(self, writers)


class CheckpointReplay:
    """An offline session over a committed checkpoint directory.

    Replays every committed cell as a ``CellResult`` without touching
    genotypes or recomputing anything — the substrate of the CLI ``merge``
    subcommand (turn a crashed-but-mostly-done scan's shards into final
    outputs) and of any postprocessing that wants the event stream shape.
    Grid extents are inferred from the shards; marker/trait names may be
    supplied when the caller has them (``merge --genotypes/--pheno``).
    """

    def __init__(
        self,
        root: str,
        *,
        marker_ids=None,
        trait_names=None,
    ):
        self.checkpoint = ScanCheckpoint.open_existing(root)
        self.marker_ids = marker_ids
        self.trait_names = trait_names
        cells = sorted(self.checkpoint.completed_cells())
        if not cells:
            raise ValueError(f"checkpoint at {root} has no committed cells")
        self._cells = cells
        # Infer the grid extent from two committed shards: the largest batch
        # index carries the global marker end, the largest block index the
        # trait end.  (Shards store their extents precisely for this.)
        last_batch = max(b for b, _ in cells)
        last_block = max(k for _, k in cells)
        probe_b = self.checkpoint.load_cell(
            last_batch, max(k for b, k in cells if b == last_batch)
        )
        probe_k = self.checkpoint.load_cell(
            max(b for b, k in cells if k == last_block), last_block
        )
        self.n_markers = int(probe_b["hi"])
        self.n_traits = int(probe_k.get("t_hi", probe_k["best_nlp"].shape[0]))
        self.n_trait_blocks = self.checkpoint.n_blocks
        self.n_batches = self.checkpoint.n_batches
        # Marker-level tracks (hence the omnibus) ride block-0 cells only.
        blk0 = next(((b, k) for b, k in cells if k == 0), None)
        self.multivariate = (
            blk0 is not None and "omnibus_nlp" in self.checkpoint.load_cell(*blk0)
        )
        self.dof = None
        self.lmm_info = None
        self.hit_threshold = None

    @property
    def complete(self) -> bool:
        return self.checkpoint.is_complete()

    def events(self) -> Iterator[CellResult]:
        for bidx, kidx in self._cells:
            yield CellResult.from_shard(
                bidx, kidx, self.checkpoint.load_cell(bidx, kidx)
            )

    def stream_to(self, *writers) -> dict:
        from repro.api.writers import stream_session

        return stream_session(self, writers)
