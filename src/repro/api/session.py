"""Plan -> execute: ``ScanPlan`` compiles a Study + specs into a prepared
scan; ``ScanSession.events()`` streams per-grid-cell ``CellResult``s.

This module *is* the scan executor — the loop that used to live inside
``GenomeScan.run``.  The redesign inverts the old shape: instead of one
blocking call that folds every cell into a dense ``ScanResult``, the
session yields each completed (marker-batch x trait-block) cell as a
``CellResult`` the moment it is computed (or replayed from a checkpoint
shard), and *consumers* decide what to keep:

    for cell in session.events():      # streams; never holds (M, P) arrays
        writer.write(cell)

The deprecated ``GenomeScan`` shim is one such consumer (it folds cells
into the historical sinks to rebuild ``ScanResult``); the streaming result
writers (``repro.api.writers``) are the native one.

Checkpointing rides the session: each live cell's payload is committed to
the cell-keyed manifest before the cell is yielded, and on resume the
committed cells of previous runs are replayed as ``CellResult``s after the
live stream — consumers cannot tell the difference (``cell.replayed`` says,
for the curious).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.api.specs import ScanConfig
from repro.api.study import Study
from repro.core.engines import EngineContext, ScanEngine, get_engine
from repro.core.panels import PanelPrefetcher, PanelStore
from repro.core.residualize import covariate_basis
from repro.core.sinks import BatchView, extract_hits
from repro.runtime.checkpoint import ScanCheckpoint, config_fingerprint
from repro.runtime.prefetch import (
    BatchPlanner,
    MarkerBatch,
    Prefetcher,
    TraitBlock,
    TraitBlockPlanner,
    double_buffer,
)

__all__ = ["CellResult", "PreparedScan", "ScanPlan", "ScanSession", "CheckpointReplay"]


LAMBDA_PROBE_ROWS = 64  # rows of the first-trait t probe persisted per batch


class CellResult:
    """One completed grid cell: a marker range crossed with a trait range.

    Live cells wrap the device step's ``BatchView`` and extract their
    summary arrays lazily (the hit-driven-pull invariant holds: the full
    per-cell tiles only cross PCIe when the cell has hits).  Replayed cells
    carry a committed checkpoint shard's arrays.  Either way ``arrays`` is
    the cell's *payload* — the exact dict the checkpoint persists — and the
    accessors below read from it, so consumers never branch on provenance.

    A cell's memory footprint is bounded by its own extent (block-width
    vectors plus its hit rows) — accumulating across cells is the
    consumer's business, which is what keeps ``events()`` streaming.
    """

    def __init__(
        self,
        *,
        batch_index: int,
        block_index: int,
        lo: int,
        hi: int,
        t_lo: int,
        t_hi: int,
        view: BatchView | None = None,
        shard: dict[str, np.ndarray] | None = None,
        hit_threshold: float = 7.301,
    ):
        self.batch_index = batch_index
        self.block_index = block_index
        self.lo = lo
        self.hi = hi
        self.t_lo = t_lo
        self.t_hi = t_hi
        self.view = view
        self._shard = shard
        self._threshold = hit_threshold
        self._arrays: dict[str, np.ndarray] | None = None

    @classmethod
    def from_shard(
        cls, batch_index: int, block_index: int, shard: dict[str, np.ndarray]
    ) -> "CellResult":
        return cls(
            batch_index=batch_index,
            block_index=block_index,
            lo=int(shard["lo"]),
            hi=int(shard["hi"]),
            t_lo=int(shard.get("t_lo", 0)),
            t_hi=int(shard.get("t_hi", shard["best_nlp"].shape[0])),
            shard=shard,
        )

    # ------------------------------------------------------------- geometry

    @property
    def n_markers(self) -> int:
        return self.hi - self.lo

    @property
    def n_traits(self) -> int:
        return self.t_hi - self.t_lo

    @property
    def replayed(self) -> bool:
        return self.view is None

    @property
    def carries_marker_tracks(self) -> bool:
        """Marker-level tracks (maf/valid/omnibus/probe) ride the t_lo==0
        cell of each marker batch — once per batch, not once per cell."""
        return self.t_lo == 0

    # -------------------------------------------------------------- payload

    @property
    def arrays(self) -> dict[str, np.ndarray]:
        """The cell's checkpoint payload (computed once, cached).

        Keys: ``best_nlp``/``best_row`` always; ``hits``/``hit_stats``
        always (possibly empty); ``maf``/``valid`` (+ ``omnibus_nlp`` when
        the multivariate screen ran, + ``t_probe``) on t_lo==0 cells.
        """
        if self._arrays is None:
            if self._shard is not None:
                self._arrays = {
                    k: v for k, v in self._shard.items()
                    if k not in ("lo", "hi", "t_lo", "t_hi")
                }
            else:
                v = self.view
                payload: dict[str, np.ndarray] = {
                    "best_nlp": v.best_nlp,
                    "best_row": v.best_row,
                }
                hits, stats = extract_hits(v, self._threshold)
                payload["hits"] = hits
                payload["hit_stats"] = stats
                if self.carries_marker_tracks:
                    payload["maf"] = v.maf
                    payload["valid"] = v.valid
                    if v.omnibus_nlp is not None:
                        payload["omnibus_nlp"] = v.omnibus_nlp
                    payload["t_probe"] = np.asarray(
                        v.t_probe(LAMBDA_PROBE_ROWS), np.float32
                    )
                self._arrays = payload
        return self._arrays

    def payload(self) -> dict[str, np.ndarray]:
        """The shard the checkpoint commits: payload plus cell extent."""
        return {
            "lo": np.asarray(self.lo),
            "hi": np.asarray(self.hi),
            "t_lo": np.asarray(self.t_lo),
            "t_hi": np.asarray(self.t_hi),
            **self.arrays,
        }

    # ------------------------------------------------------------ accessors

    @property
    def best_nlp(self) -> np.ndarray:
        """(n_traits,) per-trait best -log10 p within this cell's markers."""
        return self.arrays["best_nlp"]

    @property
    def best_row(self) -> np.ndarray:
        """(n_traits,) *batch-local* marker row of the best; globalize with
        ``cell.lo + best_row``."""
        return self.arrays["best_row"]

    @property
    def hits(self) -> np.ndarray:
        """(H, 2) int32 (global marker, global trait) above the threshold."""
        return self.arrays["hits"]

    @property
    def hit_stats(self) -> np.ndarray:
        """(H, 3) float32 (r, t, -log10 p) aligned with ``hits``."""
        return self.arrays["hit_stats"]

    @property
    def maf(self) -> np.ndarray | None:
        return self.arrays.get("maf")

    @property
    def valid(self) -> np.ndarray | None:
        return self.arrays.get("valid")

    @property
    def omnibus_nlp(self) -> np.ndarray | None:
        return self.arrays.get("omnibus_nlp")

    @property
    def t_probe(self) -> np.ndarray | None:
        return self.arrays.get("t_probe")


@dataclass
class PreparedScan:
    """Everything ``ScanPlan.prepare`` amortizes once per scan: the resolved
    engine (setup run — GRM/REML for lmm), the compiled device step, the
    residualized panel store, and the 2-D grid decomposition."""

    study: Study
    config: ScanConfig
    mesh: Mesh | None
    engine: ScanEngine
    ctx: EngineContext
    step: Callable[..., dict]
    trait_blocks: list[TraitBlock]
    panels: PanelStore | None
    batches: list[MarkerBatch]
    dof: int
    lmm_info: dict | None
    n_covariates: int

    @property
    def n_batches(self) -> int:
        return len(self.batches)

    @property
    def n_trait_blocks(self) -> int:
        return len(self.trait_blocks)

    def panel_block(self, batch: MarkerBatch, block: TraitBlock):
        """The trailing step argument for one grid cell: the driver's
        residualized store for OLS engines, the engine's own per-scope
        rotated panel for the rest."""
        if self.engine.uses_global_panel:
            return self.panels.device_block(block)
        return self.engine.panel_block(batch, block)

    def fingerprint(self) -> str:
        """The checkpoint identity of this scan (mesh/host-topology free)."""
        cfg, study = self.config, self.study
        engine_state = self.engine.state_fingerprint()
        m_total = study.source.n_markers
        return config_fingerprint(
            {
                **cfg.fingerprint_payload(),
                "n_markers": m_total,
                "n_samples": study.n_samples,
                "n_traits": study.n_traits,
                # The plan's index->(lo,hi) mapping depends on the shard
                # layout; resuming against a re-sharded fileset would
                # silently mix two incompatible batch decompositions.
                "shard_boundaries": list(
                    getattr(study.source, "shard_boundaries", (0, m_total))
                ),
                **({"engine_state": engine_state} if engine_state else {}),
            }
        )


class ScanPlan:
    """A validated, normalized scan specification bound to a Study.

    ``prepare()`` runs the amortized setup (residualization, engine setup —
    the lmm engine's streamed GRM + eigh + REML live here — and step
    construction); ``run()`` prepares and returns the executable
    ``ScanSession``.  A plan may be prepared once and run many times.
    """

    def __init__(self, study: Study, config: ScanConfig, *, mesh: Mesh | None = None):
        self.study = study
        self.config = config
        self.mesh = mesh
        self._prepared: PreparedScan | None = None

    # ---------------------------------------------------------------- build

    def prepare(self) -> PreparedScan:
        if self._prepared is not None:
            return self._prepared
        study, config, mesh = self.study, self.config, self.mesh
        engine = get_engine(config.engine)
        n_samples = study.n_samples
        n_traits = study.n_traits
        phenotypes = np.asarray(study.phenotypes)
        covariates = study.covariates

        # The trait axis of the 2-D scan grid (DESIGN.md §10).  block_p is
        # the panel-axis compute tile of every engine's step; aligning the
        # scheduling blocks to it is what makes the blocked scan
        # bitwise-identical to the unblocked one.
        trait_blocks = TraitBlockPlanner(
            config.trait_block, quantum=config.block_p
        ).plan(n_traits)
        if config.multivariate and len(trait_blocks) > 1:
            raise ValueError(
                "the multivariate omnibus screen needs the whole panel per "
                "marker (it combines evidence across every trait); run it "
                "unblocked (trait_block=0)"
            )

        n_traits_eff = float(n_traits)
        whitening = None
        panels: PanelStore | None = None
        q = None
        if engine.uses_global_panel:
            # OLS panel prep (Eq. 1), amortized once per trait block into a
            # host-side store.  Engines that build their own panel (lmm:
            # rotated per LOCO scope in setup_scan) skip this entirely — no
            # (N, P) device array is ever kept alive.
            q = covariate_basis(
                jnp.asarray(covariates) if covariates is not None else None,
                n_samples,
            )
            panels = PanelStore.residualized(
                phenotypes, q, trait_blocks,
                quantum=config.block_p,
                max_resident=config.panel_resident_blocks,
            )
            n_covariates = int(q.shape[1]) - 1
            if config.multivariate:
                from repro.core import multivariate as mv

                # unblocked by the check above: block 0 IS the full panel
                y_full = panels.device_block(trait_blocks[0])
                whitening, eig = mv.whiten_panel(y_full)
                n_traits_eff = float(mv.effective_tests(eig))
        else:
            cov = None if covariates is None else np.asarray(covariates)
            n_covariates = 0 if cov is None else (1 if cov.ndim == 1 else cov.shape[1])

        dof = config.options.dof(n_samples, n_covariates)
        ctx = EngineContext(
            n_samples=n_samples,
            n_covariates=n_covariates,
            options=config.options,
            mesh=mesh,
            mode=config.mode,
            hit_threshold=config.hit_threshold_nlp,
            maf_min=config.maf_min,
            block_m=config.block_m,
            block_n=config.block_n,
            block_p=config.block_p,
            q_basis=q,
            multivariate=config.multivariate,
            n_traits_eff=n_traits_eff,
            whitening=whitening,
            keep=study.keep,
            excluded_samples=study.excluded_samples,
            trait_blocks=tuple(trait_blocks),
            panel_resident_blocks=config.panel_resident_blocks,
            input_dtype=config.input_dtype,
            loco=config.loco,
            grm_method=config.grm_method,
            grm_batch_markers=config.grm_batch_markers,
            lmm_delta=config.lmm_delta,
            lmm_epilogue=config.lmm_epilogue,
            io_workers=config.io_workers,
        )
        engine.validate(ctx)
        # Amortized engine setup (LMM: streamed GRM + eigendecomposition +
        # REML + panel rotation).  Engines may override the scan dof and
        # contribute diagnostics to the result.
        lmm_info: dict | None = None
        setup = engine.setup_scan(study.source, phenotypes, covariates, ctx)
        if setup:
            dof = int(setup.get("dof", dof))
            lmm_info = setup.get("info")
        step = engine.build_step(ctx)
        batches = BatchPlanner(config.batch_markers).plan(study.source)
        self._prepared = PreparedScan(
            study=study,
            config=config,
            mesh=mesh,
            engine=engine,
            ctx=ctx,
            step=step,
            trait_blocks=trait_blocks,
            panels=panels,
            batches=batches,
            dof=dof,
            lmm_info=lmm_info,
            n_covariates=n_covariates,
        )
        return self._prepared

    # ----------------------------------------------------------------- run

    def run(self, *, resume: bool = True) -> "ScanSession":
        """Prepare (if not already) and open an executable session."""
        return ScanSession(self.prepare(), resume=resume)


class ScanSession:
    """One executable pass over the scan grid, streaming ``CellResult``s.

    ``events()`` is a one-shot generator: live cells in grid order (marker
    batches outer, trait blocks inner), then — when resuming — the replayed
    cells committed by previous runs.  All pipeline teardown (prefetch
    workers, the in-flight staged copy, the panel look-ahead thread) happens
    in its ``finally``, so consumers that raise mid-stream must ``close()``
    the generator (or just iterate with a ``for`` loop, which does).
    """

    def __init__(
        self,
        prepared: PreparedScan,
        *,
        resume: bool = True,
        step: Callable[..., dict] | None = None,
    ):
        self.prepared = prepared
        self.study = prepared.study
        self.config = prepared.config
        self.resume = resume
        self._step = step if step is not None else prepared.step
        self._consumed = False

        self.checkpoint: ScanCheckpoint | None = None
        if self.config.checkpoint_dir:
            # Engine state (e.g. the LMM's GRM spectrum hash) is part of the
            # scan identity: resuming against a different GRM or refitted
            # variance components would mix incompatible statistics.
            self.checkpoint = ScanCheckpoint(
                self.config.checkpoint_dir,
                fingerprint=prepared.fingerprint(),
                n_batches=prepared.n_batches,
                n_blocks=prepared.n_trait_blocks,
            )

    # ---------------------------------------------------------------- shape

    @property
    def n_batches(self) -> int:
        return self.prepared.n_batches

    @property
    def n_trait_blocks(self) -> int:
        return self.prepared.n_trait_blocks

    @property
    def n_markers(self) -> int:
        return self.study.n_markers

    @property
    def n_samples(self) -> int:
        return self.study.n_samples

    @property
    def n_traits(self) -> int:
        return self.study.n_traits

    @property
    def dof(self) -> int:
        return self.prepared.dof

    @property
    def lmm_info(self) -> dict | None:
        return self.prepared.lmm_info

    @property
    def hit_threshold(self) -> float:
        return self.config.hit_threshold_nlp

    @property
    def multivariate(self) -> bool:
        return self.config.multivariate

    @property
    def marker_ids(self):
        return self.study.marker_ids

    @property
    def trait_names(self):
        return self.study.trait_names

    # --------------------------------------------------------------- events

    def events(self) -> Iterator[CellResult]:
        """Stream the grid: compute pending cells, commit + yield each as a
        ``CellResult``, then replay previously committed cells (resume)."""
        if self._consumed:
            raise RuntimeError("ScanSession.events() is one-shot; open a new session")
        self._consumed = True
        prep = self.prepared
        cfg = self.config
        engine = prep.engine
        blocks = prep.trait_blocks
        ckpt = self.checkpoint

        todo = prep.batches
        pending: set[tuple[int, int]] | None = None   # (batch, block) cells
        if ckpt is not None and self.resume:
            pending = set(ckpt.pending_cells())
            # A marker batch is re-staged iff ANY of its cells is pending;
            # completed cells of a re-staged batch are skipped in the inner
            # loop and replayed from their shards below.
            batches_pending = {b for b, _ in pending}
            todo = [b for b in prep.batches if b.index in batches_pending]

        computed: set[tuple[int, int]] = set()
        prefetched = Prefetcher(
            todo,
            lambda b: engine.prepare_batch(self.study.source, b, prep.ctx),
            depth=cfg.prefetch_depth,
            num_workers=cfg.io_workers,
        )
        # Trait-axis look-ahead (DESIGN.md §10): stage the next cell's panel
        # block while the device computes the current cell.
        panel_la = PanelPrefetcher(prep.panel_block)

        def stage(host_batch):
            # jnp.asarray launches the copy; on accelerators it completes
            # while the device chews on the previous batch (double buffer).
            return host_batch, tuple(jnp.asarray(a) for a in host_batch.device_args)

        stream = double_buffer(prefetched, stage)
        try:
            todo_pos = {b.index: i for i, b in enumerate(todo)}
            for host_batch, dev_args in stream:
                batch = host_batch.batch
                bidx = batch.index
                # Trait blocks are the INNER loop: one staged genotype batch
                # feeds every block before the next H2D copy (DESIGN.md §10).
                cells = [
                    blk for blk in blocks
                    if pending is None or (bidx, blk.index) in pending
                ]
                nxt = todo_pos.get(bidx, len(todo)) + 1
                next_batch = todo[nxt] if nxt < len(todo) else None
                for pos, blk in enumerate(cells):
                    out = self._step(*dev_args, prep.panel_block(batch, blk))
                    # Look ahead one cell on the trait axis (then wrap to the
                    # next batch's first block, which the LRU may have evicted).
                    if pos + 1 < len(cells):
                        panel_la.request(batch, cells[pos + 1])
                    elif next_batch is not None and blocks:
                        panel_la.request(next_batch, blocks[0])
                    view = BatchView(
                        host_batch, out, blk.n_traits,
                        t_lo=blk.lo, block_index=blk.index,
                    )
                    cell = CellResult(
                        batch_index=bidx,
                        block_index=blk.index,
                        lo=batch.lo,
                        hi=batch.hi,
                        t_lo=blk.lo,
                        t_hi=blk.hi,
                        view=view,
                        hit_threshold=cfg.hit_threshold_nlp,
                    )
                    if ckpt is not None:
                        # Commit the shard, then the manifest — a crash
                        # between the two just re-does one grid cell.
                        ckpt.commit_cell(bidx, blk.index, cell.payload())
                    computed.add((bidx, blk.index))
                    yield cell
        finally:
            # Error path included: a raising consumer or engine step must not
            # leave decode workers alive or the in-flight staged copy pinned.
            stream.close()
            prefetched.shutdown()
            panel_la.shutdown()
            # Drop the step memo's pinned last batch (raw + prolog output)
            # so a cached plan doesn't hold device memory between runs.
            getattr(self._step, "reset", lambda: None)()

        # Resume path: replay committed-but-not-recomputed cells' shards.
        if ckpt is not None:
            for bidx, kidx in sorted(ckpt.completed_cells() - computed):
                yield CellResult.from_shard(bidx, kidx, ckpt.load_cell(bidx, kidx))

    # -------------------------------------------------------------- writers

    def stream_to(self, *writers) -> dict:
        """Drive ``events()`` through result writers: open each, feed every
        cell, close in order; abort them all if anything raises.  Returns
        the merged summary dict of the writers' ``close()`` results."""
        from repro.api.writers import stream_session

        return stream_session(self, writers)


class CheckpointReplay:
    """An offline session over a committed checkpoint directory.

    Replays every committed cell as a ``CellResult`` without touching
    genotypes or recomputing anything — the substrate of the CLI ``merge``
    subcommand (turn a crashed-but-mostly-done scan's shards into final
    outputs) and of any postprocessing that wants the event stream shape.
    Grid extents are inferred from the shards; marker/trait names may be
    supplied when the caller has them (``merge --genotypes/--pheno``).
    """

    def __init__(
        self,
        root: str,
        *,
        marker_ids=None,
        trait_names=None,
    ):
        self.checkpoint = ScanCheckpoint.open_existing(root)
        self.marker_ids = marker_ids
        self.trait_names = trait_names
        cells = sorted(self.checkpoint.completed_cells())
        if not cells:
            raise ValueError(f"checkpoint at {root} has no committed cells")
        self._cells = cells
        # Infer the grid extent from two committed shards: the largest batch
        # index carries the global marker end, the largest block index the
        # trait end.  (Shards store their extents precisely for this.)
        last_batch = max(b for b, _ in cells)
        last_block = max(k for _, k in cells)
        probe_b = self.checkpoint.load_cell(
            last_batch, max(k for b, k in cells if b == last_batch)
        )
        probe_k = self.checkpoint.load_cell(
            max(b for b, k in cells if k == last_block), last_block
        )
        self.n_markers = int(probe_b["hi"])
        self.n_traits = int(probe_k.get("t_hi", probe_k["best_nlp"].shape[0]))
        self.n_trait_blocks = self.checkpoint.n_blocks
        self.n_batches = self.checkpoint.n_batches
        # Marker-level tracks (hence the omnibus) ride block-0 cells only.
        blk0 = next(((b, k) for b, k in cells if k == 0), None)
        self.multivariate = (
            blk0 is not None and "omnibus_nlp" in self.checkpoint.load_cell(*blk0)
        )
        self.dof = None
        self.lmm_info = None
        self.hit_threshold = None

    @property
    def complete(self) -> bool:
        return self.checkpoint.is_complete()

    def events(self) -> Iterator[CellResult]:
        for bidx, kidx in self._cells:
            yield CellResult.from_shard(
                bidx, kidx, self.checkpoint.load_cell(bidx, kidx)
            )

    def stream_to(self, *writers) -> dict:
        from repro.api.writers import stream_session

        return stream_session(self, writers)
