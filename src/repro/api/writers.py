"""Streaming result writers — the *emit* layer of the public API.

Writers consume ``ScanSession.events()`` cell by cell and persist results
incrementally, so a paper-scale scan's outputs never exist as dense
(markers x traits) host arrays (the ROADMAP "streaming summary-stat
writers" item).  Host residency is bounded per output class:

    hits      unbounded over a scan  ->  streamed: cells buffer per marker
              batch (sorted runs), flush batch-by-batch in marker order,
              spill to npz parts past ``spill_rows``
    best      (P,)  per-trait accumulators  ->  folded, written at close
    QC        (M,)  per-marker tracks       ->  folded, written at close
    lambda    O(64 x batches) probe samples ->  folded, written at close

The registry makes formats pluggable:

    @register_writer("parquet")
    class ParquetWriter(ResultWriter): ...

    session.stream_to(get_writer("tsv")(out_dir))

Built-ins: ``"tsv"`` (sorted hits.tsv + per_trait_best.tsv + qc.tsv,
matching the CLI's historical column layout) and ``"npz"`` (per-cell hit
shards plus best/qc npz bundles — the machine-readable mirror).
``"parquet"`` registers only when ``pyarrow`` imports (the container has
no hard dependency): a sorted columnar hit table with one row group per
flushed marker batch, so query engines can prune row groups by marker
range.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.sinks import BestTraitSink, LambdaGCSink, QCSink

__all__ = [
    "ResultWriter",
    "TsvWriter",
    "NpzShardWriter",
    "register_writer",
    "get_writer",
    "available_writers",
    "stream_session",
]


class ResultWriter:
    """One output format; consumes cells, never accumulates (M x P) state.

    Lifecycle: ``open(session)`` once, ``write(cell)`` per event,
    ``close()`` exactly once on success (returns a summary dict merged into
    the run summary), ``abort()`` on any failure (must not raise).
    """

    name: str = "?"

    def open(self, session: Any) -> None:
        raise NotImplementedError

    def write(self, cell: Any) -> None:
        raise NotImplementedError

    def close(self) -> dict:
        return {}

    def abort(self) -> None:
        """Best-effort cleanup after a failed stream (never raises)."""


_WRITERS: dict[str, type[ResultWriter]] = {}


def register_writer(name: str) -> Callable[[type[ResultWriter]], type[ResultWriter]]:
    def deco(cls: type[ResultWriter]) -> type[ResultWriter]:
        cls.name = name
        _WRITERS[name] = cls
        return cls

    return deco


def get_writer(name: str) -> type[ResultWriter]:
    try:
        return _WRITERS[name]
    except KeyError:
        raise ValueError(
            f"unknown result writer {name!r}; available: {available_writers()}"
        ) from None


def available_writers() -> list[str]:
    return sorted(_WRITERS)


def stream_session(session: Any, writers: Sequence[ResultWriter]) -> dict:
    """Drive a session's events through writers with clean teardown: the
    generator is closed (tearing down prefetch workers) and every writer
    opened so far is aborted if anything raises — a failing ``open`` of a
    later writer included."""
    opened: list[ResultWriter] = []
    gen = None
    try:
        for w in writers:
            w.open(session)
            opened.append(w)
        gen = session.events()
        for cell in gen:
            for w in writers:
                w.write(cell)
    except BaseException:
        for w in opened:
            w.abort()
        raise
    finally:
        if gen is not None:
            gen.close()
    summary: dict = {}
    for w in writers:
        summary.update(w.close() or {})
    return summary


# ----------------------------------------------------------- hit streaming


class _AsyncFlusher:
    """One background thread running the format-specific hit emission
    (DESIGN.md §15), so writer I/O (TSV ``writelines``, parquet row
    groups, npz shards) overlaps the consumer's next cells instead of
    blocking them.

    Strictly FIFO — submission order IS emission order, so the output
    bytes are identical to the synchronous path.  A failing emission is
    captured and re-raised on the consumer thread at the next
    ``submit``/``finish`` (never swallowed); later queued emissions are
    skipped.  The queue is bounded, so a slow disk backpressures the scan
    instead of buffering unbounded sorted runs.
    """

    def __init__(self, emit: Callable[[np.ndarray, np.ndarray], None],
                 *, name: str = "hit-flush"):
        self._emit = emit
        self._q: queue.Queue = queue.Queue(maxsize=4)
        self._error: BaseException | None = None
        self._aborted = False
        self._thread = threading.Thread(target=self._run, daemon=True, name=name)
        self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            if self._error is not None or self._aborted:
                continue
            try:
                self._emit(*item)
            except BaseException as e:  # noqa: BLE001 — re-raised on consumer
                self._error = e

    def check(self) -> None:
        if self._error is not None:
            err = self._error
            self._aborted = True      # no further emissions after a failure
            raise err

    def submit(self, hits: np.ndarray, stats: np.ndarray) -> None:
        self.check()
        self._q.put((hits, stats))

    def finish(self) -> None:
        """Drain every queued emission, join, re-raise any failure."""
        self._q.put(None)
        self._thread.join()
        self.check()

    def abort(self) -> None:
        """Stop emitting and join (best-effort, never raises: a wedged
        emission leaves a daemon thread behind rather than hanging the
        abort path)."""
        self._aborted = True
        try:
            self._q.put(None, timeout=1.0)
        except queue.Full:
            pass
        self._thread.join(timeout=5.0)


class _BatchedHitStream:
    """Order-restoring, RAM-bounded hit stream.

    Cells arrive marker-batch-major in a fresh scan but may arrive out of
    order when a resumed session replays committed cells after the live
    ones.  Each batch's cell runs are held (or spilled) until all of the
    batch's trait blocks have reported, then complete batches are emitted
    strictly in batch-index order — and batch index order IS global marker
    order (the planner never reorders the marker axis), so concatenated
    emissions are globally sorted by (marker, trait).

    Resident rows are capped: past ``spill_rows`` every pending run is
    flushed to per-batch npz parts and re-read only at emission.  Peak
    *buffered* residency is therefore one cell's rows plus the cap
    (``peak_rows_in_ram``); emission additionally materializes one marker
    batch's rows transiently for the within-batch sort
    (``peak_flush_rows``).  Both bounds are independent of the scan length
    and the panel width — the streaming-writer contract the api tests
    assert.
    """

    def __init__(
        self,
        n_blocks: int,
        emit: Callable[[np.ndarray, np.ndarray], None],
        *,
        spill_dir: str,
        spill_rows: int = 2_000_000,
        async_flush: bool = True,
    ):
        self._expected = max(1, n_blocks)
        self._emit = emit
        # Async flush (DESIGN.md §15): the order-restoring bookkeeping
        # (_pending, spill parts, the within-batch sort) stays on the
        # consumer thread; only the format-specific emission of the
        # already-sorted arrays moves to the flusher, which preserves
        # submission order — so the output bytes are identical, the
        # consumer just stops waiting on the disk.
        self._flusher = _AsyncFlusher(emit) if async_flush else None
        self._spill_dir = spill_dir
        self._spill_rows = max(1, spill_rows)
        # batch -> {"runs": [(hits, stats)], "parts": [paths], "seen": int}
        self._pending: dict[int, dict] = {}
        self._next_emit = 0
        self._max_seen = -1
        self.rows_in_ram = 0
        self.peak_rows_in_ram = 0
        self.peak_flush_rows = 0
        self.total_rows = 0

    def _entry(self, b: int) -> dict:
        return self._pending.setdefault(b, {"runs": [], "parts": [], "seen": 0})

    def add(self, cell: Any) -> None:
        if self._flusher is not None:
            self._flusher.check()     # surface an emission failure promptly
        e = self._entry(cell.batch_index)
        e["runs"].append((cell.hits, cell.hit_stats))
        e["seen"] += 1
        self.rows_in_ram += len(cell.hits)
        self.total_rows += len(cell.hits)
        self.peak_rows_in_ram = max(self.peak_rows_in_ram, self.rows_in_ram)
        self._max_seen = max(self._max_seen, cell.batch_index)
        while self._next_emit in self._pending and (
            self._pending[self._next_emit]["seen"] >= self._expected
        ):
            self._flush(self._next_emit)
            self._next_emit += 1
        if self.rows_in_ram > self._spill_rows:
            self._spill_all()

    def _spill_all(self) -> None:
        os.makedirs(self._spill_dir, exist_ok=True)
        for b, e in self._pending.items():
            if not e["runs"]:
                continue
            hits = np.concatenate([h for h, _ in e["runs"]])
            stats = np.concatenate([s for _, s in e["runs"]])
            part = os.path.join(
                self._spill_dir, f"hits_batch_{b:06d}_{len(e['parts']):04d}.npz"
            )
            tmp = part + ".tmp.npz"
            np.savez(tmp, hits=hits, hit_stats=stats)
            os.replace(tmp, part)
            e["parts"].append(part)
            e["runs"].clear()
        self.rows_in_ram = 0

    def _flush(self, b: int) -> None:
        # The entry stays in _pending until the emit succeeds: a raising
        # emit (disk full mid-write) leaves its spill parts reachable for
        # abort() cleanup instead of orphaning them.
        e = self._pending[b]
        hits_runs = [np.zeros((0, 2), np.int32)]
        stats_runs = [np.zeros((0, 3), np.float32)]
        for part in e["parts"]:
            with np.load(part) as z:
                hits_runs.append(z["hits"])
                stats_runs.append(z["hit_stats"])
        hits_runs.extend(h for h, _ in e["runs"])
        stats_runs.extend(s for _, s in e["runs"])
        hits = np.concatenate(hits_runs)
        stats = np.concatenate(stats_runs)
        self.peak_flush_rows = max(self.peak_flush_rows, len(hits))
        # One batch's rows, sorted (marker, trait) — the within-batch merge.
        order = np.lexsort((hits[:, 1], hits[:, 0]))
        if self._flusher is not None:
            self._flusher.submit(hits[order], stats[order])
        else:
            self._emit(hits[order], stats[order])
        self._pending.pop(b)
        self.rows_in_ram -= sum(len(h) for h, _ in e["runs"])
        for part in e["parts"]:
            if os.path.exists(part):
                os.unlink(part)

    def finish(self) -> None:
        """Emit whatever is pending (partial batches of an interrupted grid
        included) in batch order, then drain the flusher — every emission
        has hit the format layer (and any failure has surfaced) before the
        writer's own close runs."""
        for b in sorted(self._pending):
            self._flush(b)
        if self._flusher is not None:
            self._flusher.finish()

    def abort(self) -> None:
        if self._flusher is not None:
            self._flusher.abort()
        for e in self._pending.values():
            for part in e["parts"]:
                if os.path.exists(part):
                    os.unlink(part)
        self._pending.clear()
        self.rows_in_ram = 0


# ------------------------------------------------------------ base bundler


class _AccumulatingWriter(ResultWriter):
    """Shared skeleton: fold best/QC/lambda through the (P)- and (M)-bounded
    sinks, stream hits through ``_BatchedHitStream``.  Subclasses implement
    the actual emission format."""

    def __init__(self, out_dir: str, *, spill_rows: int = 2_000_000,
                 marker_ids: Sequence[str] | None = None,
                 trait_names: Sequence[str] | None = None,
                 async_flush: bool = True):
        self.out_dir = out_dir
        self.spill_rows = spill_rows
        self.async_flush = async_flush
        self.marker_ids = marker_ids
        self.trait_names = trait_names
        self._session: Any = None
        self._hits: _BatchedHitStream | None = None
        self._best: BestTraitSink | None = None
        self._qc: QCSink | None = None
        self._lam: LambdaGCSink | None = None

    # subclass hooks -------------------------------------------------------

    def _start(self) -> None: ...
    def _emit_hits(self, hits: np.ndarray, stats: np.ndarray) -> None: ...
    def _finish(self, fields: dict) -> dict: ...

    # lifecycle ------------------------------------------------------------

    def open(self, session: Any) -> None:
        self._session = session
        os.makedirs(self.out_dir, exist_ok=True)
        if self.marker_ids is None:
            self.marker_ids = getattr(session, "marker_ids", None)
        if self.trait_names is None:
            self.trait_names = getattr(session, "trait_names", None)
        self._best = BestTraitSink(session.n_traits)
        self._qc = QCSink(
            session.n_markers,
            multivariate=bool(getattr(session, "multivariate", False)),
        )
        self._lam = LambdaGCSink()
        self._hits = _BatchedHitStream(
            session.n_trait_blocks,
            self._emit_hits,
            spill_dir=os.path.join(self.out_dir, ".hit_runs"),
            spill_rows=self.spill_rows,
            async_flush=self.async_flush,
        )
        self._start()

    def write(self, cell: Any) -> None:
        self._best.on_cell(cell)
        self._qc.on_cell(cell)
        self._lam.on_cell(cell)
        self._hits.add(cell)

    def close(self) -> dict:
        self._hits.finish()
        fields: dict = {}
        for sink in (self._best, self._qc, self._lam):
            fields.update(sink.result())
        summary = self._finish(fields)
        runs_dir = os.path.join(self.out_dir, ".hit_runs")
        if os.path.isdir(runs_dir) and not os.listdir(runs_dir):
            os.rmdir(runs_dir)
        return summary

    def abort(self) -> None:
        if self._hits is not None:
            self._hits.abort()

    # naming ---------------------------------------------------------------

    def _marker_name(self, m: int) -> str:
        return str(self.marker_ids[m]) if self.marker_ids is not None else str(m)

    def _trait_name(self, t: int) -> str:
        return str(self.trait_names[t]) if self.trait_names is not None else f"trait{t}"

    @property
    def peak_hit_rows_in_ram(self) -> int:
        return self._hits.peak_rows_in_ram if self._hits else 0


# ---------------------------------------------------------------- builtins


@register_writer("tsv")
class TsvWriter(_AccumulatingWriter):
    """Sorted streaming TSV bundle, column-compatible with the historical
    CLI outputs:

        hits.tsv            marker  trait  r  t  neglog10p   (sorted by
                            (marker, trait); written batch-by-batch)
        per_trait_best.tsv  trait  best_marker  neglog10p
        qc.tsv              marker  maf  valid [omnibus_neglog10p]
    """

    def _start(self) -> None:
        self._hits_path = os.path.join(self.out_dir, "hits.tsv")
        self._f = open(self._hits_path, "w")
        self._f.write("marker\ttrait\tr\tt\tneglog10p\n")

    def _emit_hits(self, hits: np.ndarray, stats: np.ndarray) -> None:
        self._f.writelines(
            f"{self._marker_name(m)}\t{self._trait_name(t)}\t"
            f"{r:.5f}\t{tt:.4f}\t{nlp:.3f}\n"
            for (m, t), (r, tt, nlp) in zip(hits, stats)
        )

    def _finish(self, fields: dict) -> dict:
        self._f.close()
        best_path = os.path.join(self.out_dir, "per_trait_best.tsv")
        with open(best_path, "w") as f:
            f.write("trait\tbest_marker\tneglog10p\n")
            for t in range(self._session.n_traits):
                m = int(fields["best_marker"][t])
                mid = self._marker_name(m) if m >= 0 else "NA"
                f.write(f"{self._trait_name(t)}\t{mid}\t{fields['best_nlp'][t]:.3f}\n")
        qc_path = os.path.join(self.out_dir, "qc.tsv")
        omni = fields.get("omnibus_nlp")
        with open(qc_path, "w") as f:
            cols = "marker\tmaf\tvalid"
            f.write(cols + ("\tomnibus_neglog10p\n" if omni is not None else "\n"))
            for m in range(self._session.n_markers):
                row = (f"{self._marker_name(m)}\t{fields['maf'][m]:.5f}"
                       f"\t{int(fields['valid'][m])}")
                if omni is not None:
                    row += f"\t{omni[m]:.3f}"
                f.write(row + "\n")
        return {
            "hits": self._hits.total_rows,
            "lambda_gc": fields["lambda_gc"],
            "hits_tsv": self._hits_path,
            "per_trait_best_tsv": best_path,
            "qc_tsv": qc_path,
        }

    def abort(self) -> None:
        super().abort()
        f = getattr(self, "_f", None)
        if f is not None and not f.closed:
            f.close()


class ParquetHitWriter(_AccumulatingWriter):
    """Columnar Arrow/Parquet bundle (the ROADMAP "parquet writer" item).

    ``hits.parquet`` streams exactly like the TSV's hit table — the
    order-restoring ``_BatchedHitStream`` emits one sorted run per marker
    batch, and each run becomes ONE ROW GROUP, so the file is globally
    sorted by (marker, trait) and engines prune row groups by marker
    range.  ``per_trait_best.parquet`` and ``qc.parquet`` follow at close.

    The schema is byte-stable by construction: fixed field names/types
    (below), explicit uncompressed pages, no embedded timestamps — two
    scans of the same study produce byte-identical files, which is how the
    executor tests compare columnar output across device counts.  The
    writer registers under ``"parquet"`` only when ``pyarrow`` imports;
    without it the name simply isn't in ``available_writers()`` (tests
    skip, not fail).
    """

    SCHEMA = [            # (name, pyarrow type factory name)
        ("marker", "string"),
        ("trait", "string"),
        ("marker_index", "int32"),
        ("trait_index", "int32"),
        ("r", "float32"),
        ("t", "float32"),
        ("neglog10p", "float32"),
    ]

    def _schema(self):
        import pyarrow as pa

        return pa.schema([(n, getattr(pa, t)()) for n, t in self.SCHEMA])

    def _start(self) -> None:
        import pyarrow.parquet as pq

        self._hits_path = os.path.join(self.out_dir, "hits.parquet")
        self._pq = pq.ParquetWriter(
            self._hits_path, self._schema(), compression="NONE"
        )
        self._row_groups = 0

    def _emit_hits(self, hits: np.ndarray, stats: np.ndarray) -> None:
        if not len(hits):
            return
        import pyarrow as pa

        table = pa.table(
            {
                "marker": [self._marker_name(m) for m in hits[:, 0]],
                "trait": [self._trait_name(t) for t in hits[:, 1]],
                "marker_index": pa.array(hits[:, 0], pa.int32()),
                "trait_index": pa.array(hits[:, 1], pa.int32()),
                "r": pa.array(stats[:, 0], pa.float32()),
                "t": pa.array(stats[:, 1], pa.float32()),
                "neglog10p": pa.array(stats[:, 2], pa.float32()),
            },
            schema=self._schema(),
        )
        self._pq.write_table(table)   # one row group per flushed marker batch
        self._row_groups += 1

    def _finish(self, fields: dict) -> dict:
        import pyarrow as pa
        import pyarrow.parquet as pq

        self._pq.close()
        best_path = os.path.join(self.out_dir, "per_trait_best.parquet")
        n_traits = self._session.n_traits
        best_marker = fields["best_marker"]
        pq.write_table(
            pa.table({
                "trait": [self._trait_name(t) for t in range(n_traits)],
                "best_marker": [
                    self._marker_name(int(m)) if m >= 0 else None
                    for m in best_marker
                ],
                "neglog10p": pa.array(fields["best_nlp"], pa.float32()),
            }),
            best_path, compression="NONE",
        )
        qc_path = os.path.join(self.out_dir, "qc.parquet")
        n_markers = self._session.n_markers
        qc = {
            "marker": [self._marker_name(m) for m in range(n_markers)],
            "maf": pa.array(fields["maf"], pa.float32()),
            "valid": pa.array(fields["valid"].astype(bool)),
        }
        if fields.get("omnibus_nlp") is not None:
            qc["omnibus_neglog10p"] = pa.array(fields["omnibus_nlp"], pa.float32())
        pq.write_table(pa.table(qc), qc_path, compression="NONE")
        return {
            "hits": self._hits.total_rows,
            "lambda_gc": fields["lambda_gc"],
            "hits_parquet": self._hits_path,
            "hit_row_groups": self._row_groups,
            "per_trait_best_parquet": best_path,
            "qc_parquet": qc_path,
        }

    def abort(self) -> None:
        super().abort()
        w = getattr(self, "_pq", None)
        if w is not None:
            try:
                w.close()
            except Exception:  # noqa: BLE001 — abort must not raise
                pass


def _register_parquet() -> bool:
    """Register the parquet writer iff pyarrow is importable.  Optional by
    design: the CI container bakes no Arrow stack, so absence must mean
    "writer not offered", never an import-time crash."""
    try:
        import pyarrow          # noqa: F401
        import pyarrow.parquet  # noqa: F401
    except Exception:
        return False
    register_writer("parquet")(ParquetHitWriter)
    return True


HAVE_PARQUET = _register_parquet()


@register_writer("npz")
class NpzShardWriter(_AccumulatingWriter):
    """Machine-readable npz bundle: sorted hit shards (one per flushed
    marker batch: ``hits_00000.npz`` with ``hits``/``hit_stats``), plus
    ``best.npz`` (best_nlp, best_marker) and ``qc.npz`` (maf, valid
    [, omnibus_nlp]) at close.  Concatenating the hit shards in filename
    order reproduces the sorted hit table exactly."""

    def _start(self) -> None:
        self._shard_paths: list[str] = []

    def _emit_hits(self, hits: np.ndarray, stats: np.ndarray) -> None:
        if not len(hits):
            return
        path = os.path.join(self.out_dir, f"hits_{len(self._shard_paths):05d}.npz")
        tmp = path + ".tmp.npz"
        np.savez(tmp, hits=hits, hit_stats=stats)
        os.replace(tmp, path)
        self._shard_paths.append(path)

    def _finish(self, fields: dict) -> dict:
        best_path = os.path.join(self.out_dir, "best.npz")
        np.savez(best_path, best_nlp=fields["best_nlp"], best_marker=fields["best_marker"])
        qc_path = os.path.join(self.out_dir, "qc.npz")
        qc = {"maf": fields["maf"], "valid": fields["valid"]}
        if fields.get("omnibus_nlp") is not None:
            qc["omnibus_nlp"] = fields["omnibus_nlp"]
        np.savez(qc_path, **qc)
        return {
            "hits": self._hits.total_rows,
            "lambda_gc": fields["lambda_gc"],
            "hit_shards": list(self._shard_paths),
            "best_npz": best_path,
            "qc_npz": qc_path,
        }
