"""Standalone elementwise t-statistic Pallas kernel (paper Eq. 3).

The production scan uses the epilogue fused inside ``gwas_dot``; this kernel
serves the non-fused path (e.g. BGEN float dosages where the GEMM runs in
plain XLA) and doubles as the minimal worked example of the repo's kernel
conventions: kernel body + jit'd wrapper + pure-jnp ``ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["screen_compact", "tstat", "tstat_ref"]


def _tstat_kernel(r_ref, t_ref, *, dof: float, eps: float):
    r = jnp.clip(r_ref[...], -1.0, 1.0)
    denom = jnp.maximum(1.0 - r * r, eps)
    t_ref[...] = r * jax.lax.rsqrt(denom / dof)


def tstat_ref(r: jax.Array, dof: float, *, eps: float = 1e-12) -> jax.Array:
    r = jnp.clip(jnp.asarray(r, jnp.float32), -1.0, 1.0)
    return r * jnp.sqrt(dof / jnp.maximum(1.0 - r * r, eps))


@functools.partial(jax.jit, static_argnames=("dof", "block_m", "block_p", "interpret"))
def _tstat_padded(r, *, dof, block_m, block_p, interpret):
    m, p = r.shape
    return pl.pallas_call(
        functools.partial(_tstat_kernel, dof=float(dof), eps=1e-12),
        grid=(m // block_m, p // block_p),
        in_specs=[pl.BlockSpec((block_m, block_p), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_m, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p), jnp.float32),
        interpret=interpret,
    )(r)


def tstat(
    r: jax.Array,
    dof: float,
    *,
    block_m: int = 256,
    block_p: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Elementwise ``T = R * sqrt(dof / (1 - R^2))`` over an ``(M, P)`` tile."""
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    r = jnp.asarray(r, jnp.float32)
    m_true, p_true = r.shape
    pad_m = (-m_true) % block_m
    pad_p = (-p_true) % block_p
    r_pad = jnp.pad(r, ((0, pad_m), (0, pad_p)))
    t = _tstat_padded(
        r_pad, dof=float(dof), block_m=block_m, block_p=block_p, interpret=bool(interpret)
    )
    return t[:m_true, :p_true]


def _screen_kernel(r_ref, t_ref, mask_ref, count_ref, *, dof: float,
                   t2_screen: float, eps: float):
    # Same arithmetic as _tstat_kernel, op for op: the sparse epilogue's t
    # tile must be bitwise-identical to the dense fused path's.
    r = jnp.clip(r_ref[...], -1.0, 1.0)
    denom = jnp.maximum(1.0 - r * r, eps)
    t = r * jax.lax.rsqrt(denom / dof)
    t_ref[...] = t
    keep = t * t >= t2_screen
    mask_ref[...] = keep.astype(jnp.int8)
    count_ref[0, 0] = jnp.sum(keep).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("dof", "t2_screen", "block_m", "block_p", "interpret")
)
def _screen_padded(r, *, dof, t2_screen, block_m, block_p, interpret):
    m, p = r.shape
    gm, gp = m // block_m, p // block_p
    return pl.pallas_call(
        functools.partial(
            _screen_kernel, dof=float(dof), t2_screen=float(t2_screen), eps=1e-12
        ),
        grid=(gm, gp),
        in_specs=[pl.BlockSpec((block_m, block_p), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_m, block_p), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, block_p), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p), jnp.float32),
            jax.ShapeDtypeStruct((m, p), jnp.int8),
            jax.ShapeDtypeStruct((gm, gp), jnp.int32),
        ],
        interpret=interpret,
    )(r)


def screen_compact(
    r: jax.Array,
    dof: float,
    t2_screen: float,
    capacity: int,
    *,
    block_m: int = 256,
    block_p: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused t-statistic + ``t^2 >= t2_screen`` survivor screen (DESIGN.md 13).

    One Pallas pass emits the t tile, a survivor mask, and per-block survivor
    counts; the wrapper then compacts survivor *flat indices* (row-major over
    the unpadded tile, dense ``np.nonzero`` order) into a fixed ``capacity``
    buffer with XLA's sized ``nonzero`` — true in-kernel compaction would need
    a scatter/sort the TPU lacks a cheap lowering for, so only the screen and
    the reduction fuse into the kernel. Returns ``(t, hit_idx, screen_count)``
    where ``hit_idx`` pads exhausted slots with ``-1`` and ``screen_count`` is
    the exact survivor total (trustworthy even when ``> capacity``).

    ``t2_screen`` must be positive: padding lanes carry ``r = 0 -> t = 0`` and
    must never survive the screen.
    """
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    r = jnp.asarray(r, jnp.float32)
    m_true, p_true = r.shape
    pad_m = (-m_true) % block_m
    pad_p = (-p_true) % block_p
    r_pad = jnp.pad(r, ((0, pad_m), (0, pad_p)))
    t, mask, counts = _screen_padded(
        r_pad, dof=float(dof), t2_screen=float(t2_screen),
        block_m=block_m, block_p=block_p, interpret=bool(interpret),
    )
    keep = mask[:m_true, :p_true].ravel() != 0
    idx = jnp.nonzero(keep, size=int(capacity), fill_value=-1)[0].astype(jnp.int32)
    return t[:m_true, :p_true], idx, jnp.sum(counts).astype(jnp.int32)
