"""Standalone elementwise t-statistic Pallas kernel (paper Eq. 3).

The production scan uses the epilogue fused inside ``gwas_dot``; this kernel
serves the non-fused path (e.g. BGEN float dosages where the GEMM runs in
plain XLA) and doubles as the minimal worked example of the repo's kernel
conventions: kernel body + jit'd wrapper + pure-jnp ``ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["tstat", "tstat_ref"]


def _tstat_kernel(r_ref, t_ref, *, dof: float, eps: float):
    r = jnp.clip(r_ref[...], -1.0, 1.0)
    denom = jnp.maximum(1.0 - r * r, eps)
    t_ref[...] = r * jax.lax.rsqrt(denom / dof)


def tstat_ref(r: jax.Array, dof: float, *, eps: float = 1e-12) -> jax.Array:
    r = jnp.clip(jnp.asarray(r, jnp.float32), -1.0, 1.0)
    return r * jnp.sqrt(dof / jnp.maximum(1.0 - r * r, eps))


@functools.partial(jax.jit, static_argnames=("dof", "block_m", "block_p", "interpret"))
def _tstat_padded(r, *, dof, block_m, block_p, interpret):
    m, p = r.shape
    return pl.pallas_call(
        functools.partial(_tstat_kernel, dof=float(dof), eps=1e-12),
        grid=(m // block_m, p // block_p),
        in_specs=[pl.BlockSpec((block_m, block_p), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_m, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p), jnp.float32),
        interpret=interpret,
    )(r)


def tstat(
    r: jax.Array,
    dof: float,
    *,
    block_m: int = 256,
    block_p: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Elementwise ``T = R * sqrt(dof / (1 - R^2))`` over an ``(M, P)`` tile."""
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    r = jnp.asarray(r, jnp.float32)
    m_true, p_true = r.shape
    pad_m = (-m_true) % block_m
    pad_p = (-p_true) % block_p
    r_pad = jnp.pad(r, ((0, pad_m), (0, pad_p)))
    t = _tstat_padded(
        r_pad, dof=float(dof), block_m=block_m, block_p=block_p, interpret=bool(interpret)
    )
    return t[:m_true, :p_true]
