"""Pure-jnp oracle for the fused gwas_dot kernel.

Implements the identical mathematical contract (decode -> standardize ->
missing->0 -> GEMM/N -> clip -> t) with no tiling, no packing and fp32
everywhere.  Tests assert the kernel matches this to float tolerance across
shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gwas_dot_ref", "decode_standardize_ref"]


def decode_standardize_ref(
    codes: jax.Array,      # (M, N) int32 PLINK 2-bit codes {0,1,2,3}
    mean: jax.Array,       # (M,)
    inv_std: jax.Array,    # (M,)
) -> jax.Array:
    """Code -> standardized dosage; missing (code 1) -> 0."""
    dosage = (2 - codes + (codes >> 1)).astype(jnp.float32)
    g = (dosage - mean[:, None]) * inv_std[:, None]
    return jnp.where(codes == 1, 0.0, g)


def gwas_dot_ref(
    codes: jax.Array,      # (M, N) int32 codes
    mean: jax.Array,
    inv_std: jax.Array,
    y: jax.Array,          # (N, P) f32
    *,
    n_samples: float,
    dof: float,
    eps: float = 1e-12,
) -> tuple[jax.Array, jax.Array]:
    g = decode_standardize_ref(codes, mean, inv_std)
    r = jax.lax.dot(g, y.astype(jnp.float32), preferred_element_type=jnp.float32)
    r = jnp.clip(r / n_samples, -1.0, 1.0)
    t = r * jax.lax.rsqrt(jnp.maximum(1.0 - r * r, eps) / dof)
    return r, t
