from repro.kernels.gwas_dot.ops import (
    gwas_dot,
    marker_stats_from_codes,
    pack_tiled,
    repack_plink_tiled,
    unpack_plink_to_codes,
)
from repro.kernels.gwas_dot.ref import decode_standardize_ref, gwas_dot_ref

__all__ = [
    "gwas_dot",
    "gwas_dot_ref",
    "decode_standardize_ref",
    "marker_stats_from_codes",
    "pack_tiled",
    "repack_plink_tiled",
    "unpack_plink_to_codes",
]
