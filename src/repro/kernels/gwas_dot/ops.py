"""jit'd public wrapper around the fused gwas_dot Pallas kernel.

Owns everything the kernel does not: tile-local packing, marker-stat
computation from raw 2-bit counts, padding to block multiples, un-padding,
and the interpret-mode fallback (CPU containers validate the kernel body in
interpret mode; on TPU the same call lowers to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gwas_dot.gwas_dot import build_gwas_dot

__all__ = [
    "pack_tiled",
    "unpack_plink_to_codes",
    "repack_plink_tiled",
    "marker_stats_from_codes",
    "marker_stats_from_packed",
    "decode_packed_device",
    "repack_plink_tiled_device",
    "gwas_dot",
]


def _pad_to(x: np.ndarray, axis: int, multiple: int, fill) -> np.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


def pack_tiled(codes: np.ndarray, block_n: int) -> np.ndarray:
    """Pack 2-bit codes ``(M, N)`` into the kernel's tile-local interleaved
    layout ``(M, N_pad/4) uint8``.

    Within each ``block_n``-sample tile, byte ``b`` carries the codes of
    samples ``tile_start + s * block_n/4 + b`` at slot ``s``.  Samples are
    padded to a tile multiple with the missing code (0b01), which the kernel
    standardizes to exactly 0, so padding never perturbs the GEMM.
    """
    if block_n % 4:
        raise ValueError("block_n must be a multiple of 4")
    c = _pad_to(np.asarray(codes, np.uint8), 1, block_n, 0b01)
    m, n_pad = c.shape
    quarter = block_n // 4
    tiles = c.reshape(m, n_pad // block_n, 4, quarter)  # (M, T, slot, byte)
    packed = (
        tiles[:, :, 0, :]
        | (tiles[:, :, 1, :] << 2)
        | (tiles[:, :, 2, :] << 4)
        | (tiles[:, :, 3, :] << 6)
    )
    return packed.reshape(m, n_pad // 4).astype(np.uint8)


def unpack_plink_to_codes(plink_packed: np.ndarray, n_samples: int) -> np.ndarray:
    """PLINK byte layout ``(M, ceil(N/4))`` -> raw codes ``(M, N) uint8``."""
    p = np.asarray(plink_packed, np.uint8)
    m = p.shape[0]
    codes = np.empty((m, p.shape[1] * 4), np.uint8)
    for s in range(4):
        codes[:, s::4] = (p >> (2 * s)) & 0b11
    return codes[:, :n_samples]


def repack_plink_tiled(plink_packed: np.ndarray, n_samples: int, block_n: int) -> np.ndarray:
    """Disk layout -> kernel layout in one host-side step (the scan's
    prefetch thread runs this; it is a byte shuffle, ~free next to decode)."""
    return pack_tiled(unpack_plink_to_codes(plink_packed, n_samples), block_n)


def marker_stats_from_codes(codes: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-marker (mean, inv_std, valid) from raw 2-bit codes, using the
    count identities (no float decode needed):

        sum d  = 2*n00 + n10,   sum d^2 = 4*n00 + n10
        var_imputed = (sum d^2 - n_present * mean^2) / N
    """
    c = np.asarray(codes)
    m, n = c.shape
    n00 = (c == 0b00).sum(axis=1).astype(np.float64)
    n10 = (c == 0b10).sum(axis=1).astype(np.float64)
    n11 = (c == 0b11).sum(axis=1).astype(np.float64)
    n_present = n00 + n10 + n11
    sum_d = 2.0 * n00 + n10
    sum_d2 = 4.0 * n00 + n10
    mean = sum_d / np.maximum(n_present, 1.0)
    var = (sum_d2 - n_present * mean**2) / n
    valid = (var > 1e-10) & (n_present > 0)
    inv_std = np.where(valid, 1.0 / np.sqrt(np.maximum(var, 1e-10)), 0.0)
    return mean.astype(np.float32), inv_std.astype(np.float32), valid


_PARTIAL_CODE_COUNTS = np.zeros((5, 256, 3), np.uint8)
for _r in range(1, 5):
    for _b in range(256):
        for _s in range(_r):
            _c = (_b >> (2 * _s)) & 0b11
            if _c == 0b00:
                _PARTIAL_CODE_COUNTS[_r, _b, 0] += 1
            elif _c == 0b10:
                _PARTIAL_CODE_COUNTS[_r, _b, 1] += 1
            elif _c == 0b11:
                _PARTIAL_CODE_COUNTS[_r, _b, 2] += 1


def marker_stats_from_packed(
    plink_packed: np.ndarray, n_samples: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``marker_stats_from_codes`` evaluated straight off PLINK bytes.

    A 256-entry count LUT tallies (n00, n10, n11) per byte — with a partial
    LUT for the tail byte when ``n_samples % 4 != 0`` so pad slots never
    count — then feeds the *identical* float64 count identities.  Bitwise
    equal to ``marker_stats_from_codes(unpack_plink_to_codes(p, n))`` at
    memcpy-level cost: the float decode of the genotype matrix never happens.
    """
    p = np.asarray(plink_packed, np.uint8)
    full, rem = divmod(int(n_samples), 4)
    counts = _PARTIAL_CODE_COUNTS[4][p[:, :full]].sum(axis=1, dtype=np.int64)
    if rem:
        counts = counts + _PARTIAL_CODE_COUNTS[rem][p[:, full]]
    n00 = counts[:, 0].astype(np.float64)
    n10 = counts[:, 1].astype(np.float64)
    n11 = counts[:, 2].astype(np.float64)
    n_present = n00 + n10 + n11
    sum_d = 2.0 * n00 + n10
    sum_d2 = 4.0 * n00 + n10
    mean = sum_d / np.maximum(n_present, 1.0)
    var = (sum_d2 - n_present * mean**2) / n_samples
    valid = (var > 1e-10) & (n_present > 0)
    inv_std = np.where(valid, 1.0 / np.sqrt(np.maximum(var, 1e-10)), 0.0)
    return mean.astype(np.float32), inv_std.astype(np.float32), valid


@functools.partial(jax.jit, static_argnames=("n_samples",))
def decode_packed_device(plink_packed, *, n_samples: int):
    """PLINK bytes ``(M, ceil(N/4)) uint8`` -> dosages ``(M, N) float32`` with
    missing as -9.0, decoded on device by XLA shift/mask ops.

    The code->dosage map matches the host ``_BYTE_LUT`` exactly
    (0b00 -> 2, 0b01 -> -9, 0b10 -> 1, 0b11 -> 0): pure integer arithmetic,
    so the emitted f32 values are bit-identical to the host decode.  Runs as
    its own jitted executable — downstream prolog/step programs stay the
    same compiled artifacts they were under dense staging, which is what
    makes packed staging bitwise-neutral (§17).
    """
    c = (plink_packed[:, :, None].astype(jnp.int32) >> (2 * jnp.arange(4))) & 0b11
    c = c.reshape(plink_packed.shape[0], -1)[:, :n_samples]
    dose = (2 - c + (c >> 1)).astype(jnp.float32)
    return jnp.where(c == 0b01, jnp.float32(-9.0), dose)


@functools.partial(
    jax.jit, static_argnames=("n_samples", "block_n", "block_m")
)
def repack_plink_tiled_device(
    plink_packed, *, n_samples: int, block_n: int, block_m: int
):
    """Disk layout -> kernel tile-local layout, as a device byte shuffle.

    Mirrors host ``repack_plink_tiled`` + the ``block_m`` row padding the
    fused step expects: unpack to codes, slice real samples, re-pad samples
    to a ``block_n`` multiple and rows to a ``block_m`` multiple with the
    missing code 0b01 (standardizes to exactly 0 under the padded
    mean/inv_std of 0), then interleave 4 slot-planes per tile.  Integer
    ops only — output bytes equal the host path's bit-for-bit.
    """
    if block_n % 4:
        raise ValueError("block_n must be a multiple of 4")
    m = plink_packed.shape[0]
    c = (plink_packed[:, :, None].astype(jnp.uint8) >> (2 * jnp.arange(4, dtype=jnp.uint8))) & 0b11
    c = c.reshape(m, -1)[:, :n_samples]
    n_pad = n_samples + (-n_samples) % block_n
    m_pad = m + (-m) % block_m
    c = jnp.pad(
        c,
        ((0, m_pad - m), (0, n_pad - n_samples)),
        constant_values=np.uint8(0b01),
    )
    quarter = block_n // 4
    tiles = c.reshape(m_pad, n_pad // block_n, 4, quarter)
    packed = (
        tiles[:, :, 0, :]
        | (tiles[:, :, 1, :] << 2)
        | (tiles[:, :, 2, :] << 4)
        | (tiles[:, :, 3, :] << 6)
    )
    return packed.reshape(m_pad, n_pad // 4).astype(jnp.uint8)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_samples",
        "dof",
        "block_m",
        "block_n",
        "block_p",
        "input_dtype",
        "interpret",
    ),
)
def _gwas_dot_padded(
    packed, mean2d, inv_std2d, y,
    *, n_samples, dof, block_m, block_n, block_p, input_dtype, interpret,
):
    m = packed.shape[0]
    n = packed.shape[1] * 4
    p = y.shape[1]
    call = build_gwas_dot(
        m, n, p,
        block_m=block_m, block_n=block_n, block_p=block_p,
        n_samples=n_samples, dof=dof,
        input_dtype=input_dtype, interpret=interpret,
    )
    return call(packed, mean2d, inv_std2d, y)


def gwas_dot(
    packed_tiled: np.ndarray | jax.Array,   # (M, N_pad/4) uint8, kernel layout
    mean: np.ndarray | jax.Array,           # (M,)
    inv_std: np.ndarray | jax.Array,        # (M,)
    y: np.ndarray | jax.Array,              # (N_true_or_pad, P)
    *,
    n_samples: int,
    dof: int,
    block_m: int = 256,
    block_n: int = 512,
    block_p: int = 256,
    input_dtype=jnp.float32,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused (R, T) for one genotype batch.  Returns float32 ``(M, P)`` pairs.

    ``y`` rows beyond the packed sample padding are added as zeros; ``M`` and
    ``P`` are padded to block multiples internally and sliced back.
    """
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    m_true = packed_tiled.shape[0]
    p_true = y.shape[1]
    n_pad = packed_tiled.shape[1] * 4

    packed = _pad_to(np.asarray(packed_tiled, np.uint8), 0, block_m, 0b01)
    mean_p = _pad_to(np.asarray(mean, np.float32).reshape(-1, 1), 0, block_m, 0.0)
    inv_p = _pad_to(np.asarray(inv_std, np.float32).reshape(-1, 1), 0, block_m, 0.0)
    y_np = np.asarray(y, np.float32)
    y_np = _pad_to(y_np, 0, n_pad, 0.0)[:n_pad]  # pad samples to match packing
    y_np = _pad_to(y_np, 1, block_p, 0.0)

    r, t = _gwas_dot_padded(
        jnp.asarray(packed),
        jnp.asarray(mean_p),
        jnp.asarray(inv_p),
        jnp.asarray(y_np),
        n_samples=int(n_samples),
        dof=int(dof),
        block_m=block_m,
        block_n=block_n,
        block_p=block_p,
        input_dtype=input_dtype,
        interpret=bool(interpret),
    )
    return r[:m_true, :p_true], t[:m_true, :p_true]
