"""Fused 2-bit-decode + standardize + GEMM Pallas TPU kernel.

The TPU-native reformulation of the paper's cuBLAS fp32 engine (DESIGN.md §5):
genotypes stay 2-bit packed in HBM exactly as they live on disk; each VMEM
tile is unpacked (shift/mask), mapped code->dosage, standardized with the
per-marker (mu, 1/sigma), missing->0, and fed to the MXU — a 16x reduction in
genotype HBM traffic versus the fp32 decode-then-GEMM the GPU release does.

Packed layout contract (produced by ``ops.pack_tiled``): samples are tiled in
groups of ``block_n``; within a tile, byte ``b`` holds the codes of samples
``{tile_start + s*block_n/4 + b : s in 0..3}`` at 2-bit slot ``s`` (LSB
first).  Unpacking is then four shift/mask ops plus one lane-concat — no
in-register transpose, which Mosaic would otherwise have to synthesize.

Grid: ``(M/bm, P/bp, N/bn)`` with the reduction axis minor (innermost), so
each output tile stays resident in VMEM across the whole contraction and the
t-statistic epilogue (paper Eq. 3) is applied in-register on the final step —
the correlation tile never round-trips through HBM between GEMM and epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gwas_dot_kernel", "build_gwas_dot"]

# PLINK 2-bit code -> dosage for codes {0b00, 0b10, 0b11}: v = 2 - c + (c >> 1)
# (code 0b01 = missing is masked to 0 after standardization).


def gwas_dot_kernel(
    packed_ref,    # (bm, bn // 4) uint8, tile-local interleaved layout
    mean_ref,      # (bm, 1) f32
    inv_std_ref,   # (bm, 1) f32
    y_ref,         # (bn, bp) f32
    r_ref,         # (bm, bp) f32 out: correlation
    t_ref,         # (bm, bp) f32 out: t statistic
    acc_ref,       # (bm, bp) f32 scratch accumulator
    *,
    n_samples: float,
    dof: float,
    eps: float,
    n_grid: int,
    input_dtype,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = packed_ref[...].astype(jnp.int32)
    d0 = codes & 3
    d1 = (codes >> 2) & 3
    d2 = (codes >> 4) & 3
    d3 = (codes >> 6) & 3
    c = jnp.concatenate([d0, d1, d2, d3], axis=1)          # (bm, bn)
    dosage = (2 - c + (c >> 1)).astype(jnp.float32)
    g = (dosage - mean_ref[...]) * inv_std_ref[...]
    g = jnp.where(c == 1, 0.0, g)                          # missing -> 0 (post-standardize mean)
    acc_ref[...] += jax.lax.dot(
        g.astype(input_dtype),
        y_ref[...].astype(input_dtype),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_grid - 1)
    def _epilogue():
        r = acc_ref[...] / n_samples
        r = jnp.clip(r, -1.0, 1.0)
        denom = jnp.maximum(1.0 - r * r, eps)
        r_ref[...] = r
        t_ref[...] = r * jax.lax.rsqrt(denom / dof)


def build_gwas_dot(
    m: int,
    n: int,
    p: int,
    *,
    block_m: int = 256,
    block_n: int = 512,
    block_p: int = 256,
    n_samples: float,
    dof: float,
    eps: float = 1e-12,
    input_dtype=jnp.float32,
    interpret: bool = False,
):
    """Construct the pallas_call for padded problem sizes (m, n, p).

    All of (m, n, p) must already be multiples of the block sizes; the ops
    wrapper owns padding.  ``n_samples``/``dof`` are baked in as compile-time
    constants (they are per-scan, not per-batch).
    """
    if m % block_m or n % block_n or p % block_p:
        raise ValueError(f"unpadded dims ({m},{n},{p}) vs blocks ({block_m},{block_n},{block_p})")
    if block_n % 4:
        raise ValueError("block_n must be a multiple of 4 (2-bit packing)")
    grid = (m // block_m, p // block_p, n // block_n)
    kernel = functools.partial(
        gwas_dot_kernel,
        n_samples=float(n_samples),
        dof=float(dof),
        eps=float(eps),
        n_grid=grid[2],
        input_dtype=input_dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n // 4), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_n, block_p), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_p), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_m, block_p), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p), jnp.float32),
            jax.ShapeDtypeStruct((m, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, block_p), jnp.float32)],
        interpret=interpret,
    )
