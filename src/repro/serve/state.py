"""Resident cohort state for the serve subsystem (DESIGN.md §16).

``StudyRegistry`` is the warm half of scan-as-a-service: everything that
does not change across requests stays resident —

    open genotype sources       a ``ResidentStudy`` holds the bound
                                ``Study`` (source stays open, keep mask
                                and covariates stay parsed);
    prepared scan state         the resident panel's ``PreparedScan``
                                (residualized covariate basis, GRM
                                spectrum + REML for the lmm engine,
                                compiled step) built once, lazily, and
                                reused by every marker-window query;
    warm per-slot device state  ``_Slot``s (``EngineDeviceState`` +
                                ``PanelView``) cached in a ``DeviceLRU``
                                keyed by (state, slot), ref-count-pinned
                                while a worker computes a cell, and
                                LRU-evicted (``slot.reset()``) when
                                capacity is exceeded by other studies'
                                traffic.

Eviction rules: a slot is evictable iff no in-flight cell pins it; the
registry allows transient capacity overshoot rather than block a worker
on a fully-pinned cache.  Evicting a slot frees its device arrays but no
host state — the next request on that study pays one re-staging, not a
re-prepare (cache hit/miss/eviction counters are surfaced through serve
metrics so this is observable).
"""
from __future__ import annotations

import threading
import time
from typing import Any

from repro.core.engines import DeviceLRU

__all__ = ["ResidentStudy", "StudyRegistry"]


class ResidentStudy:
    """One admitted cohort: the bound study, its plan kwargs, and the
    lazily-built resident ``PreparedScan`` (the cold cost every later
    window query on this study skips)."""

    def __init__(self, study_id: str, study, *, weight: float = 1.0,
                 plan_kwargs: dict | None = None):
        if weight <= 0:
            raise ValueError(f"study weight must be positive, got {weight}")
        self.study_id = study_id
        self.study = study
        self.weight = float(weight)
        self.plan_kwargs = dict(plan_kwargs or {})
        self.admitted_at = time.time()
        self.state_key = f"study:{study_id}"
        self._plan = None
        self._lock = threading.Lock()

    def plan(self):
        with self._lock:
            if self._plan is None:
                self._plan = self.study.plan(**self.plan_kwargs)
            return self._plan

    def prepared(self):
        """The resident panel's prepared scan (``ScanPlan.prepare`` is
        memoized; concurrent first callers serialize on the plan lock so
        setup cost is paid exactly once)."""
        plan = self.plan()
        with self._lock:
            return plan.prepare()

    def describe(self) -> dict:
        return {
            "study_id": self.study_id,
            "n_samples": self.study.n_samples,
            "n_markers": self.study.n_markers,
            "n_traits": self.study.n_traits,
            "weight": self.weight,
            "admitted_at": self.admitted_at,
            "prepared": self._plan is not None and self._plan._prepared is not None,
        }


class StudyRegistry:
    """Multi-tenant resident state: admitted studies plus the warm
    executor-slot cache shared by every serve worker.

    Slot cache keys are ``(state_key, slot_index)`` where ``state_key``
    names one prepared scan state — ``study:<id>`` for a resident study
    (shared by all its window queries: the warm path) or ``req:<id>`` for
    an uploaded panel (ephemeral; dropped when the request finishes).
    ``acquire_slot``/``release_slot`` bracket one cell's compute with a
    pin, so concurrent requests can never evict a slot mid-step.
    """

    def __init__(self, *, devices: int = 1, max_resident_slots: int = 8):
        import jax

        n = devices if devices > 0 else len(jax.devices())
        # One worker slot per device; n == 1 uses the implicit default
        # device (device=None), byte-for-byte the serial executor's slot.
        self.n_slots = n
        self._devices = [None] if n == 1 else list(jax.devices()[:n])
        self._studies: dict[str, ResidentStudy] = {}
        self._states: dict[str, Any] = {}       # state_key -> PreparedScan
        self._live: dict[Any, Any] = {}          # (state_key, slot) -> _Slot
        self._lock = threading.RLock()
        self._slots = DeviceLRU(
            max_resident_slots, self._load_slot, on_evict=self._evict_slot
        )

    # ------------------------------------------------------------- studies

    def admit(self, study_id: str, study, *, weight: float = 1.0,
              **plan_kwargs) -> ResidentStudy:
        with self._lock:
            if study_id in self._studies:
                raise ValueError(f"study {study_id!r} already admitted")
            res = ResidentStudy(
                study_id, study, weight=weight, plan_kwargs=plan_kwargs
            )
            self._studies[study_id] = res
            return res

    def resident(self, study_id: str) -> ResidentStudy:
        with self._lock:
            if study_id not in self._studies:
                raise KeyError(
                    f"unknown study {study_id!r}; admitted: "
                    f"{sorted(self._studies)}"
                )
            return self._studies[study_id]

    def studies(self) -> list[dict]:
        with self._lock:
            return [s.describe() for s in self._studies.values()]

    # ---------------------------------------------------------- slot cache

    def register_state(self, state_key: str, prepared) -> None:
        """Bind a prepared scan under ``state_key`` so slot loads can find
        it.  Resident studies stay registered for their lifetime; uploaded
        panels register for the request and ``drop_state`` after."""
        with self._lock:
            self._states[state_key] = prepared

    def drop_state(self, state_key: str) -> None:
        """Unbind a state and reset its cached slots (ephemeral panel
        teardown — its device arrays must not outlive the request)."""
        self._slots.drop_if(lambda k: k[0] == state_key)
        with self._lock:
            self._states.pop(state_key, None)
            for key in [k for k in self._live if k[0] == state_key]:
                self._live.pop(key).reset()

    def _load_slot(self, key):
        from repro.api.session import _Slot

        state_key, slot_idx = key
        with self._lock:
            prepared = self._states.get(state_key)
            if prepared is None:
                raise KeyError(f"state {state_key!r} not registered")
        slot = _Slot(
            prepared,
            device=self._devices[slot_idx],
            label=f"serve/dev{slot_idx}",
        )
        with self._lock:
            self._live[key] = slot
        return slot

    def _evict_slot(self, key) -> None:
        with self._lock:
            slot = self._live.pop(key, None)
        if slot is not None:
            slot.reset()

    def acquire_slot(self, state_key: str, slot_idx: int):
        """The warm slot for (state, slot), pinned: the caller MUST pair
        with ``release_slot`` (cell compute bracket)."""
        key = (state_key, slot_idx)
        self._slots.pin(key)
        try:
            return self._slots.get(key)
        except BaseException:
            self._slots.unpin(key)
            raise

    def release_slot(self, state_key: str, slot_idx: int) -> None:
        self._slots.unpin((state_key, slot_idx))

    def device(self, slot_idx: int):
        return self._devices[slot_idx]

    # ------------------------------------------------------------- metrics

    def slot_cache_stats(self) -> dict:
        return self._slots.stats()

    def panel_cache_stats(self) -> dict:
        """Aggregate hit/miss/eviction counters over every live slot's
        panel view plus each registered state's shared default view."""
        agg = {"hits": 0, "misses": 0, "evictions": 0}
        with self._lock:
            views = [
                s.panels for s in self._live.values() if s.panels is not None
            ]
            stores = {
                id(p.panels): p.panels
                for p in self._states.values()
                if getattr(p, "panels", None) is not None
            }
        for view in views:
            st = view.cache_stats()
            for k in agg:
                agg[k] += st[k]
        for store in stores.values():
            st = store.cache_stats()
            for k in agg:
                agg[k] += st[k]
        total = agg["hits"] + agg["misses"]
        agg["hit_rate"] = round(agg["hits"] / total, 4) if total else None
        return agg

    # ------------------------------------------------------------ teardown

    def shutdown(self) -> None:
        """Reset every cached slot and drop all resident state.  Pins are
        ignored (teardown outranks residency — workers are already joined
        when the serve host calls this)."""
        self._slots.clear()
        with self._lock:
            for slot in self._live.values():
                slot.reset()
            self._live.clear()
            self._states.clear()
            self._studies.clear()

    @property
    def n_pinned(self) -> int:
        return self._slots.n_pinned
