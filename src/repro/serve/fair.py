"""Fair-share lease policy for the serve queue (DESIGN.md §16).

One ``WorkQueue`` feeds every serve worker, but its refill order is
delegated to this module's ``DeficitRoundRobin`` — a ``LeasePolicy``
(``runtime.workqueue``) implementing the classic deficit-round-robin
scheduler across per-request item queues:

* every admitted request enrolls its grid-cell indices as one FIFO queue
  with the owning study's *weight*;
* each scheduling round visits active queues in rotation, credits a
  queue ``quantum * weight`` cells of deficit, and leases items while
  deficit lasts;
* a queue's unspent deficit carries to its next turn, so long-run
  throughput shares converge to the weight ratio regardless of when
  requests arrive.

The consequence the serve layer cares about: a 2048-trait panel drain
cannot starve a 3-cell interactive window query — the small request's
queue gets its quantum every round and finishes within a bounded number
of big-request cells (tested in ``tests/test_serve.py``).

``select``/``pending_count`` are called under the owning ``WorkQueue``'s
lock; the policy's own lock only guards its queue table against
concurrent ``enroll``/``retire`` from request driver threads, and no
policy method ever calls back into the work queue (lock order: queue →
policy, never the reverse).
"""
from __future__ import annotations

import threading
from collections import deque

__all__ = ["DeficitRoundRobin"]


class _RequestQueue:
    __slots__ = ("items", "weight", "deficit")

    def __init__(self, weight: float):
        self.items: deque[int] = deque()
        self.weight = weight
        self.deficit = 0.0


class DeficitRoundRobin:
    """Deficit-round-robin over per-request FIFO queues (a ``LeasePolicy``).

    Cost is one unit per grid cell: serve cells of one study share a
    geometry (same batch/block planning), so cell count is an honest
    proxy for work, and weights express *policy* (study priority), not
    size correction.
    """

    def __init__(self, *, quantum: float = 2.0):
        if quantum <= 0:
            raise ValueError(f"quantum must be positive, got {quantum}")
        self.quantum = float(quantum)
        self._queues: dict[str, _RequestQueue] = {}
        self._rotation: deque[str] = deque()
        # True while the head queue is mid-turn: a ``select`` truncated by
        # ``k`` resumes the same queue WITHOUT re-crediting its quantum —
        # otherwise small ``k`` (lease_size=1) would cap every queue at
        # one lease per visit and weights would stop mattering.
        self._head_served = False
        self._lock = threading.Lock()

    # ------------------------------------------------------------- feeding

    def enroll(self, request_id: str, items, *, weight: float = 1.0) -> None:
        """Add ``items`` (work-queue indices) under ``request_id``.  A new
        request joins the BACK of the rotation with zero deficit — it
        cannot pre-empt credit already earned by running requests."""
        if weight <= 0:
            raise ValueError(f"weight must be positive, got {weight}")
        with self._lock:
            q = self._queues.get(request_id)
            if q is None:
                q = self._queues[request_id] = _RequestQueue(float(weight))
                self._rotation.append(request_id)
            q.weight = float(weight)
            q.items.extend(int(i) for i in items)

    def retire(self, request_id: str) -> list[int]:
        """Drop a request's queue (client abort, shutdown); returns the
        item indices that were never leased so the caller can mark them
        cancelled."""
        with self._lock:
            q = self._queues.pop(request_id, None)
            if q is None:
                return []
            if self._rotation and self._rotation[0] == request_id:
                self._head_served = False
            try:
                self._rotation.remove(request_id)
            except ValueError:
                pass
            return list(q.items)

    # ----------------------------------------------------- LeasePolicy API

    def select(self, k: int) -> list[int]:
        """Up to ``k`` items in deficit-round-robin order.  Called under
        the work queue's lock (see module docstring)."""
        out: list[int] = []
        with self._lock:
            if k <= 0 or not self._rotation:
                return out
            # Bounded sweeps: each full rotation with no empty queues
            # grows every deficit by quantum*weight >= quantum*min_weight,
            # so progress is guaranteed; empty queues leave the rotation.
            while len(out) < k and self._rotation:
                rid = self._rotation[0]
                q = self._queues[rid]
                if not q.items:
                    # Drained between enrolls: fall out of the rotation
                    # (and forfeit deficit) until the next enroll.
                    q.deficit = 0.0
                    self._rotation.popleft()
                    self._queues.pop(rid, None)
                    self._head_served = False
                    continue
                if not self._head_served:
                    q.deficit += self.quantum * q.weight
                    self._head_served = True
                while q.items and q.deficit >= 1.0 and len(out) < k:
                    out.append(q.items.popleft())
                    q.deficit -= 1.0
                if not q.items:
                    q.deficit = 0.0
                    self._rotation.popleft()
                    self._queues.pop(rid, None)
                    self._head_served = False
                elif q.deficit < 1.0:
                    # Turn spent: next queue gets the head.
                    self._rotation.rotate(-1)
                    self._head_served = False
                else:
                    # Truncated by k mid-turn: resume this queue on the
                    # next select, no fresh quantum.
                    break
            return out

    def pending_count(self) -> int:
        with self._lock:
            return sum(len(q.items) for q in self._queues.values())

    # ------------------------------------------------------------- reading

    def queue_sizes(self) -> dict[str, int]:
        """Live per-request backlog (serve metrics/debug)."""
        with self._lock:
            return {rid: len(q.items) for rid, q in self._queues.items()}
