"""Stdlib Python client for the serve HTTP API (DESIGN.md §16).

``http.client`` only — usable from any environment that can reach the
server, with numpy as the sole (already-required) dependency for panel
upload packing.
"""
from __future__ import annotations

import http.client
import io
import json
import time
from urllib.parse import urlencode

import numpy as np

__all__ = ["ServeClient", "ServeError"]


class ServeError(RuntimeError):
    """An HTTP-level or request-level failure reported by the server."""

    def __init__(self, status: int, message: str):
        super().__init__(f"HTTP {status}: {message}")
        self.status = status


class ServeClient:
    def __init__(self, host: str, port: int, *, timeout: float = 120.0):
        self.host = host
        self.port = int(port)
        self.timeout = timeout

    # ------------------------------------------------------------ plumbing

    def _request(self, method: str, path: str, body: bytes | None = None,
                 content_type: str = "application/json") -> tuple[int, bytes]:
        conn = http.client.HTTPConnection(
            self.host, self.port, timeout=self.timeout
        )
        try:
            headers = {"Content-Type": content_type} if body else {}
            conn.request(method, path, body=body, headers=headers)
            resp = conn.getresponse()
            return resp.status, resp.read()
        finally:
            conn.close()

    def _json(self, method: str, path: str, body: bytes | None = None,
              content_type: str = "application/json") -> dict:
        status, raw = self._request(method, path, body, content_type)
        try:
            payload = json.loads(raw)
        except ValueError:
            payload = {"error": raw.decode(errors="replace")}
        if status >= 400:
            raise ServeError(status, payload.get("error", "unknown error"))
        return payload

    # ----------------------------------------------------------------- API

    def healthy(self) -> bool:
        try:
            return bool(self._json("GET", "/healthz").get("ok"))
        except (OSError, ServeError):
            return False

    def metrics(self) -> dict:
        return self._json("GET", "/metrics")

    def studies(self) -> list[dict]:
        return self._json("GET", "/studies")["studies"]

    def admit_study(self, study_id: str, *, genotypes: str, phenotypes: str,
                    covariates: str | None = None, weight: float | None = None,
                    plan: dict | None = None, warm: bool = True) -> dict:
        """Admit a study from paths visible to the SERVER."""
        body = json.dumps({
            "study_id": study_id,
            "genotypes": genotypes,
            "phenotypes": phenotypes,
            "covariates": covariates,
            "weight": weight,
            "plan": plan or {},
            "warm": warm,
        }).encode()
        return self._json("POST", "/studies", body)

    def scan_panel(self, study_id: str, phenotypes, trait_names=None, *,
                   hit_threshold_nlp: float | None = None,
                   weight: float | None = None) -> str:
        """Upload a phenotype panel (n_samples x P) for a full scan
        against a resident study's cohort; returns the request id."""
        buf = io.BytesIO()
        arrays = {"phenotypes": np.asarray(phenotypes)}
        if trait_names is not None:
            arrays["trait_names"] = np.asarray(list(trait_names), dtype="U64")
        np.savez(buf, **arrays)
        q = {"study": study_id, "kind": "panel"}
        if hit_threshold_nlp is not None:
            q["threshold"] = hit_threshold_nlp
        if weight is not None:
            q["weight"] = weight
        payload = self._json(
            "POST", f"/scan?{urlencode(q)}", buf.getvalue(),
            content_type="application/octet-stream",
        )
        return payload["request"]

    def scan_window(self, study_id: str, lo: int, hi: int, *,
                    weight: float | None = None) -> str:
        """Queue a marker-window query [lo, hi) against the resident
        panel; returns the request id."""
        q = {"study": study_id, "kind": "window", "lo": int(lo), "hi": int(hi)}
        if weight is not None:
            q["weight"] = weight
        return self._json("POST", f"/scan?{urlencode(q)}")["request"]

    def request_info(self, rid: str) -> dict:
        return self._json("GET", f"/requests/{rid}")

    def wait(self, rid: str, timeout: float = 600.0,
             poll_s: float = 0.2) -> dict:
        """Poll until the request leaves queued/running; raises
        ``ServeError`` if it failed."""
        deadline = time.time() + timeout
        while True:
            info = self.request_info(rid)
            if info["status"] not in ("queued", "running"):
                if info["status"] != "done":
                    raise ServeError(
                        500, f"request {rid} {info['status']}: {info['error']}"
                    )
                return info
            if time.time() >= deadline:
                raise TimeoutError(f"request {rid} still {info['status']}")
            time.sleep(poll_s)

    def fetch(self, rid: str, name: str) -> bytes:
        """Download one result table (hits.tsv, per_trait_best.tsv,
        qc.tsv) as raw bytes — byte-identical to the offline scan's."""
        status, raw = self._request("GET", f"/requests/{rid}/files/{name}")
        if status >= 400:
            raise ServeError(status, raw.decode(errors="replace"))
        return raw

    def fetch_to(self, rid: str, name: str, path: str) -> str:
        data = self.fetch(rid, name)
        with open(path, "wb") as fh:
            fh.write(data)
        return path

    def shutdown(self) -> dict:
        return self._json("POST", "/shutdown")
