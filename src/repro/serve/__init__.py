"""repro.serve — persistent multi-tenant scan service over the warm
executor stack (DESIGN.md §16).

Layers: ``state`` (resident studies + warm slot cache), ``fair``
(deficit-round-robin lease policy), ``requests`` (shared executor +
request admission), ``server``/``client`` (stdlib HTTP front end).
"""
from repro.serve.client import ServeClient, ServeError
from repro.serve.fair import DeficitRoundRobin
from repro.serve.requests import ServeExecutor, ServeHost
from repro.serve.server import ServeServer
from repro.serve.state import ResidentStudy, StudyRegistry

__all__ = [
    "DeficitRoundRobin",
    "ResidentStudy",
    "ServeClient",
    "ServeError",
    "ServeExecutor",
    "ServeHost",
    "ServeServer",
    "StudyRegistry",
]
