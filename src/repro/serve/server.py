"""Dependency-free HTTP front end for ``ServeHost`` (DESIGN.md §16).

Stdlib only (``http.server.ThreadingHTTPServer``): JSON for control,
``.npz`` bytes for phenotype panel upload, TSV bytes for results.

Endpoints
---------
GET  /healthz                       liveness
GET  /metrics                       serve metrics (latency percentiles,
                                    queue depth, cache hit rates)
GET  /studies                       resident studies
POST /studies                       admit a study from server-side paths
                                    (JSON body: study_id, genotypes,
                                    phenotypes, covariates?, plan?,
                                    weight?, warm?)
POST /scan?study=S&kind=panel       body = npz with ``phenotypes``
         [&threshold=..][&weight=..]  (and optional ``trait_names``)
POST /scan?study=S&kind=window&lo=..&hi=..[&weight=..]
                                    -> {"request": rid} (both kinds)
GET  /requests/<rid>                request status/summary
GET  /requests/<rid>/files/<name>   hits.tsv | per_trait_best.tsv | qc.tsv
POST /shutdown                      clean stop (releases slots, joins
                                    workers, then stops the listener)
"""
from __future__ import annotations

import io
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.serve.requests import ServeHost

__all__ = ["ServeServer"]


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-serve/1"

    # The ServeServer instance is attached to the HTTP server object.
    @property
    def host(self) -> ServeHost:
        return self.server.serve_host  # type: ignore[attr-defined]

    def log_message(self, format, *args):  # noqa: A002 — stdlib signature
        if self.server.serve_verbose:  # type: ignore[attr-defined]
            super().log_message(format, *args)

    # ------------------------------------------------------------- plumbing

    def _json(self, payload, status: int = 200) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, status: int, message: str) -> None:
        self._json({"error": message}, status=status)

    def _body(self) -> bytes:
        n = int(self.headers.get("Content-Length") or 0)
        return self.rfile.read(n) if n else b""

    def _send_file(self, path: str) -> None:
        try:
            with open(path, "rb") as fh:
                data = fh.read()
        except OSError as e:
            self._error(404, f"result file unavailable: {e}")
            return
        self.send_response(200)
        self.send_header("Content-Type", "text/tab-separated-values")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    # ------------------------------------------------------------------ GET

    def do_GET(self) -> None:  # noqa: N802 — stdlib naming
        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        try:
            if url.path == "/healthz":
                self._json({"ok": True})
            elif url.path == "/metrics":
                self._json(self.host.metrics_summary())
            elif url.path == "/studies":
                self._json({"studies": self.host.studies()})
            elif len(parts) == 2 and parts[0] == "requests":
                self._json(self.host.request_info(parts[1]))
            elif len(parts) == 4 and parts[0] == "requests" and parts[2] == "files":
                self._send_file(self.host.result_path(parts[1], parts[3]))
            else:
                self._error(404, f"no route for GET {url.path}")
        except KeyError as e:
            self._error(404, str(e))
        except Exception as e:  # noqa: BLE001 — report, don't kill listener
            self._error(500, f"{type(e).__name__}: {e}")

    # ----------------------------------------------------------------- POST

    def do_POST(self) -> None:  # noqa: N802 — stdlib naming
        url = urlparse(self.path)
        try:
            if url.path == "/studies":
                self._post_study()
            elif url.path == "/scan":
                self._post_scan(parse_qs(url.query))
            elif url.path == "/shutdown":
                self._json({"ok": True})
                self.server.serve_shutdown()  # type: ignore[attr-defined]
            else:
                self._error(404, f"no route for POST {url.path}")
        except (KeyError, ValueError) as e:
            self._error(400, str(e))
        except Exception as e:  # noqa: BLE001 — report, don't kill listener
            self._error(500, f"{type(e).__name__}: {e}")

    def _post_study(self) -> None:
        spec = json.loads(self._body() or b"{}")
        from repro.api import GridSpec, IOSpec, LmmSpec, Study

        study = Study.from_files(
            spec["genotypes"],
            spec["phenotypes"],
            spec.get("covariates"),
        )
        # JSON carries nested spec dicts; rebuild the typed specs the plan
        # API takes (unknown keys raise, reported as a 400).
        plan = dict(spec.get("plan") or {})
        for key, cls in (("grid", GridSpec), ("lmm", LmmSpec), ("io", IOSpec)):
            if isinstance(plan.get(key), dict):
                plan[key] = cls(**plan[key])
        info = self.host.admit_study(
            spec["study_id"], study,
            weight=spec.get("weight"),
            **plan,
        )
        if spec.get("warm", True):
            info["warm"] = self.host.warm_study(spec["study_id"])
        self._json(info)

    def _post_scan(self, q: dict) -> None:
        study = q["study"][0]
        kind = (q.get("kind") or ["panel"])[0]
        weight = float(q["weight"][0]) if "weight" in q else None
        if kind == "window":
            rid = self.host.submit_window(
                study, int(q["lo"][0]), int(q["hi"][0]), weight=weight
            )
        elif kind == "panel":
            with np.load(io.BytesIO(self._body()), allow_pickle=False) as z:
                panel = z["phenotypes"]
                names = (
                    [str(t) for t in z["trait_names"]]
                    if "trait_names" in z.files else None
                )
            threshold = (
                float(q["threshold"][0]) if "threshold" in q else None
            )
            rid = self.host.submit_panel(
                study, panel, names,
                hit_threshold_nlp=threshold, weight=weight,
            )
        else:
            raise ValueError(f"unknown scan kind {kind!r}")
        self._json({"request": rid})


class ServeServer:
    """The listener: binds, serves on a background thread, and owns clean
    shutdown ordering (stop accepting -> drain host -> join)."""

    def __init__(self, host: ServeHost, *, bind: str = "127.0.0.1",
                 port: int = 0, verbose: bool = False):
        self.host = host
        self._httpd = ThreadingHTTPServer((bind, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.serve_host = host  # type: ignore[attr-defined]
        self._httpd.serve_verbose = verbose  # type: ignore[attr-defined]
        self._httpd.serve_shutdown = self.shutdown_async  # type: ignore[attr-defined]
        self._thread: threading.Thread | None = None
        self._down = threading.Event()

    @property
    def address(self) -> tuple[str, int]:
        return self._httpd.server_address[:2]

    def start(self) -> "ServeServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.2},
            daemon=True, name="serve-http",
        )
        self._thread.start()
        return self

    def shutdown_async(self) -> None:
        """Trigger shutdown from a handler thread (POST /shutdown) without
        deadlocking on the listener's own join."""
        threading.Thread(target=self.shutdown, daemon=True,
                         name="serve-http-shutdown").start()

    def shutdown(self) -> None:
        if self._down.is_set():
            return
        self._down.set()
        self._httpd.shutdown()          # stop accepting new requests
        self.host.shutdown()            # drain/fail in-flight, free slots
        self._httpd.server_close()
        if self._thread is not None and self._thread is not threading.current_thread():
            self._thread.join(timeout=10.0)

    def wait(self) -> None:
        """Block until shutdown completes (the ``serve`` subcommand's
        foreground loop; interruptible by signals)."""
        while not self._down.wait(timeout=0.5):
            pass
