"""Request admission and the shared serve executor (DESIGN.md §16).

Request → work-item mapping: an admitted request (an uploaded phenotype
panel, or a marker-window query against a resident study) opens a real
``ScanSession`` over its prepared state — so planning, sinks, writers,
and the byte-identity contract are the offline scan's, unchanged — but
the session's executor is a request-scoped view (``_RequestRun``) of ONE
long-lived ``ServeExecutor``: the session's grid cells are enrolled as
work items on the single persistent ``WorkQueue`` every serve worker
drains, ordered across requests by the deficit-round-robin lease policy
(``serve.fair``).  Each request gets its own sinks and writers (its
session owns them), so concurrent clients never share fold state.

Workers compute cells exactly as the offline executors do — decode via
``engine.prepare_batch``, H2D staging through the warm ``_Slot`` from the
``StudyRegistry`` (pinned for the duration of the cell), the slot's
compiled step, ``_live_cell`` materialization — which is what makes every
served table byte-identical to a fresh offline scan of the same
panel/window.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import tempfile
import threading
import time
import uuid
from collections import Counter
from typing import Any

import numpy as np

from repro.api.metrics import CellTiming, ScanMetrics
from repro.api.session import ScanSession, _live_cell
from repro.api.writers import TsvWriter
from repro.runtime.workqueue import WorkQueue
from repro.serve.fair import DeficitRoundRobin
from repro.serve.state import StudyRegistry

__all__ = ["ServeExecutor", "ServeHost"]


_STOPPED = object()


class _Failure:
    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class _Once:
    """A set-once cell (per-(request, batch) decode dedup): the first
    worker to need a batch decodes it; peers block on the event."""

    __slots__ = ("_evt", "_value", "_error")

    def __init__(self) -> None:
        self._evt = threading.Event()
        self._value = None
        self._error: BaseException | None = None

    def set(self, value) -> None:
        self._value = value
        self._evt.set()

    def fail(self, error: BaseException) -> None:
        self._error = error
        self._evt.set()

    def get(self, timeout: float | None = None):
        if not self._evt.wait(timeout):
            raise TimeoutError("decode wait timed out")
        if self._error is not None:
            raise self._error
        return self._value


class _ActiveRequest:
    """Executor-side record of one enrolled session."""

    def __init__(self, request_id: str, prepared, state_key: str,
                 cells: list, weight: float):
        self.request_id = request_id
        self.prepared = prepared
        self.state_key = state_key
        self.cells = cells                      # [(MarkerBatch, TraitBlock)]
        self.weight = weight
        self.out: queue.Queue = queue.Queue(maxsize=16)
        self.cancelled = threading.Event()   # stop computing cells
        self.closed = threading.Event()      # consumer detached (retire)
        self.lock = threading.Lock()
        self.decoded: dict[int, _Once] = {}     # batch index -> host batch
        self.cells_left = Counter(b.index for b, _ in cells)


class _RequestRun:
    """The executor handle a serve ``ScanSession`` runs on: duck-types the
    session executor surface (``cells(todo, pending)`` + ``info()``) while
    the shared pool does the computing.  One per request — its generator
    is where request-scoped delivery order lives; closing it (consumer
    abort) retires the request's unleased items from the fair-share
    policy."""

    kind = "serve"
    backend = "threads"

    def __init__(self, executor: "ServeExecutor", prepared, *,
                 request_id: str, state_key: str, weight: float):
        self._ex = executor
        self._prepared = prepared
        self.request_id = request_id
        self.state_key = state_key
        self.weight = weight
        self._req: _ActiveRequest | None = None

    def info(self) -> dict:
        return {
            "kind": self.kind,
            "devices": self._ex.n_slots,
            "request": self.request_id,
            "shared_queue_remaining": self._ex.queue.remaining(),
        }

    def cells(self, todo, pending):
        prep = self._prepared
        wanted = [
            (b, blk)
            for b in todo
            for blk in prep.trait_blocks
            if pending is None or (b.index, blk.index) in pending
        ]
        req = self._req = self._ex._register(
            self.request_id, prep, self.state_key, wanted, self.weight
        )
        try:
            done = 0
            while done < len(wanted):
                try:
                    item = req.out.get(timeout=0.5)
                except queue.Empty:
                    if self._ex._stop_evt.is_set():
                        item = _STOPPED
                    else:
                        continue
                if item is _STOPPED:
                    raise RuntimeError(
                        "serve executor stopped while request "
                        f"{self.request_id} had cells in flight"
                    )
                if isinstance(item, _Failure):
                    raise item.error
                yield item
                done += 1
        finally:
            self._ex._retire(req)


class ServeExecutor:
    """The long-lived shared worker pool: one thread per device slot, all
    draining ONE persistent ``WorkQueue`` whose refill order is the
    deficit-round-robin policy.  Sessions attach via ``open()`` and detach
    when their generator closes; the pool outlives them all."""

    def __init__(self, registry: StudyRegistry, *, policy=None,
                 lease_size: int = 1):
        self.registry = registry
        self.n_slots = registry.n_slots
        self.policy = policy if policy is not None else DeficitRoundRobin()
        self.queue = WorkQueue(
            0, lease_size=lease_size, policy=self.policy, persistent=True
        )
        self._items: dict[int, tuple[str, Any, Any]] = {}  # idx -> (rid, b, blk)
        self._requests: dict[str, _ActiveRequest] = {}
        self._next_idx = 0
        self._lock = threading.Lock()
        self._stop_evt = threading.Event()
        self._threads = [
            threading.Thread(
                target=self._worker, args=(i,), daemon=True,
                name=f"serve-worker-{i}",
            )
            for i in range(self.n_slots)
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ sessions

    def open(self, prepared, *, request_id: str, state_key: str,
             weight: float = 1.0) -> _RequestRun:
        """A request-scoped executor view for one session.  The caller
        must have ``register_state``d ``state_key`` with the registry."""
        if self._stop_evt.is_set():
            raise RuntimeError("serve executor is stopped")
        return _RequestRun(
            self, prepared, request_id=request_id, state_key=state_key,
            weight=weight,
        )

    def _register(self, rid: str, prepared, state_key: str, cells: list,
                  weight: float) -> _ActiveRequest:
        req = _ActiveRequest(rid, prepared, state_key, cells, weight)
        with self._lock:
            if self._stop_evt.is_set():
                raise RuntimeError("serve executor is stopped")
            if rid in self._requests:
                raise ValueError(f"request {rid!r} already enrolled")
            idxs = []
            for cell in cells:
                idx = self._next_idx
                self._next_idx += 1
                self._items[idx] = (rid, *cell)
                idxs.append(idx)
            self._requests[rid] = req
        self.policy.enroll(rid, idxs, weight=weight)
        self.queue.kick()
        return req

    def _retire(self, req: _ActiveRequest) -> None:
        req.cancelled.set()
        req.closed.set()
        unserved = self.policy.retire(req.request_id)
        with self._lock:
            for idx in unserved:
                self._items.pop(idx, None)
            self._requests.pop(req.request_id, None)

    # ------------------------------------------------------------- workers

    def _worker(self, slot_idx: int) -> None:
        label = f"serve/dev{slot_idx}"
        while True:
            idx = self.queue.claim(label, block=True)
            if idx is None:
                return                      # stop(): queue released us
            try:
                with self._lock:
                    entry = self._items.pop(idx, None)
                if entry is None:
                    continue                # retired while leased
                rid, batch, blk = entry
                with self._lock:
                    req = self._requests.get(rid)
                if req is None or req.cancelled.is_set():
                    continue
                try:
                    result = self._compute(req, slot_idx, label, batch, blk)
                except BaseException as e:  # noqa: BLE001 — to the consumer
                    req.cancelled.set()
                    self._deliver(req, _Failure(e))
                else:
                    self._deliver(req, result)
            finally:
                self.queue.complete(label, idx)

    def _deliver(self, req: _ActiveRequest, item) -> bool:
        """Bounded put that never wedges a shared worker: gives up only
        once the consumer has detached (request retired) — failures set
        ``cancelled`` but must still reach a live consumer."""
        while not req.closed.is_set():
            try:
                req.out.put(item, timeout=0.1)
                return True
            except queue.Full:
                if self._stop_evt.is_set():
                    return False
        return False

    def _host_batch(self, req: _ActiveRequest, batch):
        """Decode one genotype batch exactly once per request (concurrent
        workers on sibling cells share the result)."""
        with req.lock:
            once = req.decoded.get(batch.index)
            owner = once is None
            if owner:
                once = req.decoded[batch.index] = _Once()
        if owner:
            t0 = time.perf_counter()
            try:
                prep = req.prepared
                hb = prep.engine.prepare_batch(
                    prep.study.source, batch, prep.ctx
                )
            except BaseException as e:  # noqa: BLE001 — waiters must wake
                once.fail(e)
                raise
            once.set((hb, time.perf_counter() - t0))
            return once.get()
        hb, _ = once.get(timeout=600.0)
        return hb, 0.0                 # decode cost attributed to the owner

    def _compute(self, req: _ActiveRequest, slot_idx: int, label: str,
                 batch, blk):
        import jax

        prep = req.prepared
        hb, decode_s = self._host_batch(req, batch)
        slot = self.registry.acquire_slot(req.state_key, slot_idx)
        try:
            t0 = time.perf_counter()
            # Per-slot staged memo: consecutive cells of one request's
            # batch reuse the H2D copy (the slot belongs to this worker
            # alone, so the attribute is single-threaded).
            memo = getattr(slot, "_serve_staged", None)
            if memo is not None and memo[0] == (req.request_id, batch.index):
                dev_args, stage_s = memo[1], 0.0
            else:
                ts = time.perf_counter()
                dev_args = slot.stage(hb)
                stage_s = time.perf_counter() - ts
                slot._serve_staged = ((req.request_id, batch.index), dev_args)
            out = slot.step(*dev_args, slot.panel_block(batch, blk))
            jax.block_until_ready(out)
            t1 = time.perf_counter()
            cell = _live_cell(hb, out, blk, prep.config, prep.dof)
            t2 = time.perf_counter()
        finally:
            self.registry.release_slot(req.state_key, slot_idx)
        with req.lock:
            req.cells_left[batch.index] -= 1
            if req.cells_left[batch.index] <= 0:
                req.decoded.pop(batch.index, None)   # free host batch early
        timing = CellTiming(
            batch_index=batch.index,
            block_index=blk.index,
            n_markers=cell.n_markers,
            n_traits=cell.n_traits,
            wall_s=t2 - t0,
            step_s=t1 - t0,
            extract_s=t2 - t1,
            decode_s=decode_s,
            stage_s=stage_s,
            device=label,
        )
        return cell, timing

    # ------------------------------------------------------------ teardown

    def stop(self, *, join_timeout: float = 30.0) -> None:
        """Clean shutdown: release workers, fail in-flight sessions, join.
        Safe to call twice."""
        self._stop_evt.set()
        self.queue.stop()
        for t in self._threads:
            t.join(timeout=join_timeout)
        with self._lock:
            live = list(self._requests.values())
        for req in live:
            # Wake any consumer still blocked on its out queue; its
            # session raises and the driver marks the request failed.
            try:
                req.out.put_nowait(_STOPPED)
            except queue.Full:
                pass

    @property
    def alive(self) -> bool:
        return any(t.is_alive() for t in self._threads)


# ----------------------------------------------------------------- the host


class _Request:
    """Service-side record of one client request's lifecycle."""

    def __init__(self, rid: str, kind: str, study_id: str, out_dir: str):
        self.rid = rid
        self.kind = kind                    # "panel" | "window"
        self.study_id = study_id
        self.out_dir = out_dir
        self.status = "queued"              # running | done | failed
        self.submitted = time.time()
        self.wall_s: float | None = None
        self.covered: tuple[int, int] | None = None
        self.summary: dict | None = None
        self.metrics: dict | None = None
        self.error: str | None = None
        self.thread: threading.Thread | None = None

    def describe(self) -> dict:
        return {
            "request": self.rid,
            "kind": self.kind,
            "study": self.study_id,
            "status": self.status,
            "wall_s": self.wall_s,
            "covered": list(self.covered) if self.covered else None,
            "summary": self.summary,
            "metrics": self.metrics,
            "error": self.error,
        }


class ServeHost:
    """The in-process serve service: registry + shared executor + request
    lifecycle.  ``server.ServeServer`` wraps this with HTTP; tests and
    ``examples/serve_scan.py`` drive it directly.

    Every request writes a full ``TsvWriter`` bundle (hits.tsv,
    per_trait_best.tsv, qc.tsv) into its own directory under
    ``out_root`` — request-scoped writers, byte-identical to an offline
    ``scan`` of the same panel/window.
    """

    RESULT_FILES = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")

    def __init__(self, *, devices: int = 1, max_resident_slots: int = 8,
                 lease_size: int = 1, drr_quantum: float = 2.0,
                 default_weight: float = 1.0, out_root: str | None = None):
        self.registry = StudyRegistry(
            devices=devices, max_resident_slots=max_resident_slots
        )
        self.policy = DeficitRoundRobin(quantum=drr_quantum)
        self.executor = ServeExecutor(
            self.registry, policy=self.policy, lease_size=lease_size
        )
        self.default_weight = default_weight
        self.metrics = ScanMetrics()
        self.out_root = out_root or tempfile.mkdtemp(prefix="repro-serve-")
        self._requests: dict[str, _Request] = {}
        self._lock = threading.Lock()
        self._shutting = False
        self._counter = 0

    # ------------------------------------------------------------- studies

    def admit_study(self, study_id: str, study, *, weight: float | None = None,
                    **plan_kwargs) -> dict:
        """Make a cohort resident.  ``plan_kwargs`` are ``Study.plan``
        keywords fixed for the study's lifetime (grid geometry, engine,
        threshold); serve sessions own their executors and never
        checkpoint, so those knobs are rejected here."""
        for bad in ("executor", "checkpoint_dir"):
            if bad in plan_kwargs:
                raise ValueError(
                    f"plan kwarg {bad!r} is not servable: serve requests "
                    "run on the shared serve executor without checkpoints"
                )
        res = self.registry.admit(
            study_id, study,
            weight=self.default_weight if weight is None else weight,
            **plan_kwargs,
        )
        return res.describe()

    def warm_study(self, study_id: str) -> dict:
        """Eagerly build the resident prepared state (source scan setup,
        GRM/REML for lmm, compiled step) so the first window query is
        warm — the serve boot path calls this."""
        res = self.registry.resident(study_id)
        t0 = time.perf_counter()
        prepared = res.prepared()
        self.registry.register_state(res.state_key, prepared)
        return {"study": study_id, "prepare_s": time.perf_counter() - t0}

    def studies(self) -> list[dict]:
        return self.registry.studies()

    # ------------------------------------------------------------ requests

    def _new_request(self, kind: str, study_id: str) -> _Request:
        with self._lock:
            if self._shutting:
                raise RuntimeError("serve host is shutting down")
            self._counter += 1
            rid = f"{kind[0]}{self._counter:04d}-{uuid.uuid4().hex[:6]}"
            req = _Request(rid, kind, study_id, os.path.join(self.out_root, rid))
            self._requests[rid] = req
            return req

    def submit_panel(self, study_id: str, phenotypes, trait_names=None, *,
                     hit_threshold_nlp: float | None = None,
                     weight: float | None = None) -> str:
        """Admit an uploaded phenotype panel against a resident study's
        cohort: same source, keep mask, and covariates; new traits.
        Returns the request id immediately; the scan runs on the shared
        pool."""
        res = self.registry.resident(study_id)
        panel = np.asarray(phenotypes)
        if panel.ndim != 2 or panel.shape[0] != res.study.n_samples:
            raise ValueError(
                f"panel must be (n_samples={res.study.n_samples}, P), "
                f"got {panel.shape}"
            )
        req = self._new_request("panel", study_id)
        w = res.weight if weight is None else float(weight)

        def drive() -> None:
            state_key = f"req:{req.rid}"
            try:
                req.status = "running"
                t0 = time.perf_counter()
                study = dataclasses.replace(
                    res.study,
                    phenotypes=panel,
                    trait_names=(
                        list(trait_names) if trait_names is not None else None
                    ),
                )
                kwargs = dict(res.plan_kwargs)
                if hit_threshold_nlp is not None:
                    kwargs["hit_threshold_nlp"] = hit_threshold_nlp
                plan = study.plan(**kwargs)
                prepared = plan.prepare()
                self.registry.register_state(state_key, prepared)
                run = self.executor.open(
                    prepared, request_id=req.rid, state_key=state_key,
                    weight=w,
                )
                session = ScanSession(prepared, resume=False, executor=run)
                summary = session.stream_to(TsvWriter(req.out_dir))
                req.wall_s = time.perf_counter() - t0
                req.summary = {
                    k: v for k, v in summary.items() if not k.endswith("_tsv")
                }
                req.metrics = session.metrics.summary()
                req.status = "done"
                self.metrics.record_request(req.wall_s, kind="panel")
            except BaseException as e:  # noqa: BLE001 — reported to client
                req.error = f"{type(e).__name__}: {e}"
                req.status = "failed"
            finally:
                self.registry.drop_state(state_key)

        self._start(req, drive)
        return req.rid

    def submit_window(self, study_id: str, lo: int, hi: int, *,
                      weight: float | None = None) -> str:
        """A marker-window query against the resident panel: reuses the
        study's prepared state (residualized panel, GRM spectrum, compiled
        step, warm slots) — the fast path a persistent service exists
        for.  The window widens to batch boundaries; the response's
        ``covered`` range is the exact extent."""
        res = self.registry.resident(study_id)
        req = self._new_request("window", study_id)
        w = res.weight if weight is None else float(weight)

        def drive() -> None:
            try:
                req.status = "running"
                t0 = time.perf_counter()
                prepared = res.prepared()
                self.registry.register_state(res.state_key, prepared)
                run = self.executor.open(
                    prepared, request_id=req.rid, state_key=res.state_key,
                    weight=w,
                )
                session = ScanSession(
                    prepared, resume=False, executor=run,
                    marker_window=(int(lo), int(hi)),
                )
                req.covered = session.window_covered
                summary = session.stream_to(TsvWriter(req.out_dir))
                req.wall_s = time.perf_counter() - t0
                req.summary = {
                    k: v for k, v in summary.items() if not k.endswith("_tsv")
                }
                req.metrics = session.metrics.summary()
                req.status = "done"
                self.metrics.record_request(req.wall_s, kind="window")
            except BaseException as e:  # noqa: BLE001 — reported to client
                req.error = f"{type(e).__name__}: {e}"
                req.status = "failed"

        self._start(req, drive)
        return req.rid

    def _start(self, req: _Request, drive) -> None:
        req.thread = threading.Thread(
            target=drive, daemon=True, name=f"serve-request-{req.rid}"
        )
        req.thread.start()

    # -------------------------------------------------------------- status

    def request_info(self, rid: str) -> dict:
        with self._lock:
            if rid not in self._requests:
                raise KeyError(f"unknown request {rid!r}")
            return self._requests[rid].describe()

    def wait(self, rid: str, timeout: float | None = None) -> dict:
        with self._lock:
            req = self._requests.get(rid)
        if req is None:
            raise KeyError(f"unknown request {rid!r}")
        if req.thread is not None:
            req.thread.join(timeout)
            if req.thread.is_alive():
                raise TimeoutError(f"request {rid} still running")
        return req.describe()

    def result_path(self, rid: str, name: str) -> str:
        if name not in self.RESULT_FILES:
            raise KeyError(
                f"unknown result file {name!r}; available: {self.RESULT_FILES}"
            )
        with self._lock:
            req = self._requests.get(rid)
        if req is None:
            raise KeyError(f"unknown request {rid!r}")
        if req.status != "done":
            raise RuntimeError(f"request {rid} is {req.status}, not done")
        return os.path.join(req.out_dir, name)

    def metrics_summary(self) -> dict:
        self.metrics.set_queue_depth(self.executor.queue.remaining())
        self.metrics.set_cache_stats(
            "device_state", self.registry.slot_cache_stats()
        )
        self.metrics.set_cache_stats("panel", self.registry.panel_cache_stats())
        with self._lock:
            counts = Counter(r.status for r in self._requests.values())
        return {
            "serve": self.metrics.serve_summary(),
            "requests": dict(counts),
            "queue": {rid: n for rid, n in self.policy.queue_sizes().items()},
            "studies": self.studies(),
        }

    # ------------------------------------------------------------ teardown

    def shutdown(self, *, join_timeout: float = 30.0) -> None:
        """Stop the pool, fail in-flight requests, release every slot.
        Idempotent; leaves no serve threads behind (asserted in tests)."""
        with self._lock:
            self._shutting = True
            live = [r for r in self._requests.values() if r.thread is not None]
        self.executor.stop(join_timeout=join_timeout)
        for req in live:
            req.thread.join(timeout=join_timeout)
        self.registry.shutdown()
