"""Encoder-decoder stack (whisper-small).

The audio conv frontend is a stub per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, encoder_len, d) directly.  The
encoder is bidirectional (no mask, no rope, learned positions); the decoder
is causal self-attention + cross-attention over the encoded memory, with the
standard serve split: cross K/V are computed once at prefill and reused every
decode step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding_ctx import constrain

__all__ = [
    "init_encdec_params",
    "encode",
    "forward_train",
    "prefill",
    "decode",
]


def _maybe_scan(cfg: ModelConfig, body, init, xs):
    """lax.scan over stacked blocks, or an unrolled python loop when
    ``cfg.scan_layers`` is off (dry-run FLOP accounting — see
    configs.base.ModelConfig.scan_layers)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for r in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[r], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *vals: jnp.stack(vals), *ys)
    else:
        ys = None
    return carry, ys


def _pad_mask(cfg: ModelConfig):
    if cfg.padded_vocab == cfg.vocab:
        return None
    return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, L.NEG_INF)


def _head_logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    mask = _pad_mask(cfg)
    if mask is not None:
        logits = logits + mask[None, None, :]
    return logits


def _init_dec_block(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    ones = jnp.ones((cfg.d_model,), jnp.float32)
    return {
        "ln1": ones, "ln2": ones, "ln3": ones,
        "self_attn": L.init_attention_params(cfg, k1, dtype),
        "cross_attn": L.init_attention_params(cfg, k2, dtype),
        "mlp": L.init_mlp_params(cfg, k3, dtype),
    }


def _init_enc_block(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    k1, k2 = jax.random.split(key, 2)
    ones = jnp.ones((cfg.d_model,), jnp.float32)
    return {
        "ln1": ones, "ln2": ones,
        "attn": L.init_attention_params(cfg, k1, dtype),
        "mlp": L.init_mlp_params(cfg, k2, dtype),
    }


def init_encdec_params(cfg: ModelConfig, key: jax.Array, *, max_positions: int) -> dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ks = jax.random.split(key, 8)
    enc_blocks = [_init_enc_block(cfg, k, dtype) for k in jax.random.split(ks[0], cfg.encoder_layers)]
    dec_blocks = [_init_dec_block(cfg, k, dtype) for k in jax.random.split(ks[1], cfg.n_layers)]
    return {
        "embed": jax.random.normal(ks[2], (cfg.padded_vocab, cfg.d_model), dtype) * 0.02,
        "enc_pos": jax.random.normal(ks[3], (cfg.encoder_len, cfg.d_model), dtype) * 0.02,
        "dec_pos": jax.random.normal(ks[4], (max_positions, cfg.d_model), dtype) * 0.02,
        "encoder": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
        "decoder": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_blocks),
        "enc_final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


# ------------------------------------------------------------------ encoder

def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames (B, enc_len, d) from the frontend stub -> memory (B, enc_len, d)."""
    x = frames.astype(params["embed"].dtype) + params["enc_pos"][None, : frames.shape[1]]
    x = constrain(x, ("batch", "seq", "embed"))

    def body(x, p):
        h, _ = L.attention(cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg), angles=None, mask=None, causal=False)
        x = x + h
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg))
        return x, None

    x, _ = _maybe_scan(cfg, body, x, params["encoder"])
    return L.rms_norm(x, params["enc_final_norm"], cfg)


# ------------------------------------------------------------------ decoder

def _cross_kv(cfg: ModelConfig, p_cross: dict, memory: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", memory, p_cross["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p_cross["wv"])
    return k, v


def _dec_block(cfg, p, x, *, self_mask, memory=None, cross_kv=None,
               cache=None, decode_pos=None):
    """One decoder block; cross K/V either fresh from ``memory`` (train /
    prefill) or reused from ``cross_kv`` (decode)."""
    h, new_self = L.attention(
        cfg, p["self_attn"], L.rms_norm(x, p["ln1"], cfg),
        angles=None, mask=self_mask,
        cache=cache["self"] if cache is not None else None,
        decode_pos=decode_pos,
    )
    x = x + h
    kv = cross_kv if cross_kv is not None else _cross_kv(cfg, p["cross_attn"], memory)
    h, _ = L.attention(
        cfg, p["cross_attn"], L.rms_norm(x, p["ln2"], cfg),
        angles=None, mask=None, kv_override=kv,
    )
    x = x + h
    x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln3"], cfg))
    return x, new_self, kv


def apply_head(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    """Chunked-loss head application (tied to the embedding table)."""
    return _head_logits(cfg, params, hidden)


def forward_train(cfg: ModelConfig, params: dict, frames: jax.Array, tokens: jax.Array,
                  *, return_hidden: bool = False):
    """Teacher-forced decoder logits (B, S, V) (or final hidden states)."""
    memory = encode(cfg, params, frames)
    b, s = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][None, :s]
    mask = L.causal_mask(s)

    def body(x, p):
        x, _, _ = _dec_block(cfg, p, x, self_mask=mask, memory=memory)
        return x, None

    x, _ = _maybe_scan(cfg, body, x, params["decoder"])
    x = L.rms_norm(x, params["final_norm"], cfg)
    if return_hidden:
        return x
    logits = _head_logits(cfg, params, x)
    return constrain(logits, ("batch", "seq", "vocab"))


def prefill(cfg: ModelConfig, params: dict, frames: jax.Array, tokens: jax.Array,
            *, cache_capacity: int | None = None):
    """Encode + run the prompt through the decoder, building self caches and
    cross K/V.  Returns (last logits (B, V), caches dict)."""
    memory = encode(cfg, params, frames)
    b, s = tokens.shape
    cap = cache_capacity or s
    x = params["embed"][tokens] + params["dec_pos"][None, :s]
    mask = L.causal_mask(s)
    dtype = x.dtype

    def body(x, p):
        x_out, _, kv = _dec_block(cfg, p, x, self_mask=mask, memory=memory)
        # Self cache from this layer's normed input (same discipline as
        # transformer._fill_cache).
        h = L.rms_norm(x, p["ln1"], cfg)
        k = jnp.einsum("bsd,dhk->bshk", h, p["self_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["self_attn"]["wv"])
        cache = L.init_layer_cache(cfg, b, cap, dtype)
        take = min(s, cap)
        pos = jnp.arange(s - take, s, dtype=jnp.int32)
        slots = pos % cap
        pnew = cache.positions.at[:, slots].set(pos[None, :])
        if cache.k_scale is not None:
            kq, ks = L.quantize_kv(k[:, s - take :])
            vq, vs = L.quantize_kv(v[:, s - take :])
            cache = L.LayerCache(
                cache.k.at[:, slots].set(kq),
                cache.v.at[:, slots].set(vq),
                pnew,
                cache.k_scale.at[:, slots].set(ks),
                cache.v_scale.at[:, slots].set(vs),
            )
        else:
            cache = L.LayerCache(
                cache.k.at[:, slots].set(k[:, s - take :]),
                cache.v.at[:, slots].set(v[:, s - take :]),
                pnew,
            )
        return x_out, {"self": cache, "cross_k": kv[0], "cross_v": kv[1]}

    x, caches = _maybe_scan(cfg, body, x, params["decoder"])
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg)
    logits = _head_logits(cfg, params, x)
    return logits[:, 0], caches


def decode(cfg: ModelConfig, params: dict, token: jax.Array, pos: jax.Array, caches: dict):
    """One decoder token against (self cache, cross K/V)."""
    x = params["embed"][token[:, None]] + params["dec_pos"][pos][:, None]

    def body(x, slices):
        p, cache = slices
        x, new_self, _ = _dec_block(
            cfg, p, x, self_mask=None,
            cross_kv=(cache["cross_k"], cache["cross_v"]),
            cache=cache, decode_pos=pos,
        )
        return x, {"self": new_self, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}

    x, new_caches = _maybe_scan(cfg, body, x, (params["decoder"], caches))
    x = L.rms_norm(x, params["final_norm"], cfg)
    logits = _head_logits(cfg, params, x)
    return logits[:, 0], new_caches
