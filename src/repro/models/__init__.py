"""Architecture zoo: one functional implementation per family, one dispatch
surface (``repro.models.api``) for steps, smoke tests and the dry-run."""
from repro.models import api

__all__ = ["api"]
