"""RWKV-6 "Finch" block (arXiv:2404.05892): time-mix with data-dependent
decay + channel-mix, attention-free.

TPU mapping notes (DESIGN.md §5): the WKV recurrence keeps a per-head
(hd x hd) state; we express one step as rank-1 outer-product updates and run
``lax.scan`` over time.  The per-step einsums batch over (B, H) so the MXU
sees well-shaped contractions; heads shard over the model axis ("state"
logical axis), the state carries no sequence dimension, which is exactly why
this family runs the ``long_500k`` cell (O(1) decode memory).

Token-shift interpolation uses the Finch LoRA form: one fused
``d -> 5*rank`` projection, tanh, and five ``rank -> d`` heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding_ctx import constrain

__all__ = [
    "init_rwkv_params",
    "init_rwkv_cache",
    "rwkv_block",
]

_MIX_RANK = 32
_DECAY_RANK = 64


def _ranks(cfg: ModelConfig) -> tuple[int, int]:
    mix = min(_MIX_RANK, max(4, cfg.d_model // 8))
    dec = min(_DECAY_RANK, max(4, cfg.d_model // 4))
    return mix, dec


def init_rwkv_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    mix_rank, dec_rank = _ranks(cfg)
    ks = jax.random.split(key, 16)
    s = d**-0.5
    return {
        # time-mix
        "mu_x": jnp.zeros((5, d), dtype),            # per-target static mix
        "mix_a": jax.random.normal(ks[0], (d, 5 * mix_rank), dtype) * s,
        "mix_b": jax.random.normal(ks[1], (5, mix_rank, d), dtype) * mix_rank**-0.5,
        "w_r": jax.random.normal(ks[2], (d, h, hd), dtype) * s,
        "w_k": jax.random.normal(ks[3], (d, h, hd), dtype) * s,
        "w_v": jax.random.normal(ks[4], (d, h, hd), dtype) * s,
        "w_g": jax.random.normal(ks[5], (d, h, hd), dtype) * s,
        "w_o": jax.random.normal(ks[6], (h, hd, d), dtype) * s,
        "decay_base": jnp.full((h, hd), -1.0, jnp.float32),   # w0
        "decay_a": jax.random.normal(ks[7], (d, dec_rank), dtype) * s,
        "decay_b": jax.random.normal(ks[8], (dec_rank, h, hd), dtype) * dec_rank**-0.5,
        "bonus": jnp.zeros((h, hd), jnp.float32),             # u ("faaaa")
        "ln_x": jnp.ones((h, hd), jnp.float32),               # per-head groupnorm
        # channel-mix
        "cm_mu_k": jnp.zeros((d,), dtype),
        "cm_mu_r": jnp.zeros((d,), dtype),
        "cm_k": jax.random.normal(ks[9], (d, cfg.d_ff), dtype) * s,
        "cm_v": jax.random.normal(ks[10], (cfg.d_ff, d), dtype) * cfg.d_ff**-0.5,
        "cm_r": jax.random.normal(ks[11], (d, d), dtype) * s,
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), dtype),
        "shift_cm": jnp.zeros((batch, d), dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x (B,S,D) -> x_{t-1} with ``prev`` (B,D) as the t=0 predecessor."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix_targets(p: dict, x: jax.Array, x_prev: jax.Array) -> list[jax.Array]:
    """Finch data-dependent token-shift: five interpolated views of x."""
    xx = x_prev - x
    base = x + xx * p["mu_x"][0][None, None, :]
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["mix_a"]))
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    outs = []
    for i in range(5):
        m = p["mu_x"][i][None, None, :] + jnp.einsum("bsr,rd->bsd", lora[..., i, :], p["mix_b"][i])
        outs.append(x + xx * m)
    return outs  # order: w, k, v, r, g


def _decay(p: dict, x_w: jax.Array) -> jax.Array:
    """Data-dependent per-channel decay in (0, 1): w = exp(-exp(w0 + lora))."""
    t = jnp.tanh(jnp.einsum("bsd,dr->bsr", x_w, p["decay_a"]))
    core = p["decay_base"][None, None] + jnp.einsum("bsr,rhk->bshk", t, p["decay_b"]).astype(jnp.float32)
    return jnp.exp(-jnp.exp(jnp.clip(core, -10.0, 4.0)))


def _wkv_scan(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array, state: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """r/k/v/w: (B, S, H, hd); state (B, H, hd, hd) mapping k-dim -> v-dim.

        y_t   = (S_{t-1} + u*k_t (x) v_t)^T r_t
        S_t   = diag(w_t) S_{t-1} + k_t (x) v_t
    """

    def step(s, inputs):
        rt, kt, vt, wt = inputs        # (B, H, hd)
        outer = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * outer)
        s_new = wt[..., :, None] * s + outer
        return s_new, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state   # (B, S, H, hd)


def _group_norm(y: jax.Array, g: jax.Array, eps: float = 64e-5) -> jax.Array:
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    return (y - mu) * jax.lax.rsqrt(var + eps) * g[None, None]


def _time_mix(cfg: ModelConfig, p: dict, x: jax.Array, shift_prev, wkv_state):
    b, s, d = x.shape
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    x_prev = _token_shift(x, shift_prev)
    x_w, x_k, x_v, x_r, x_g = _mix_targets(p, x, x_prev)
    r = jnp.einsum("bsd,dhk->bshk", x_r, p["w_r"])
    k = jnp.einsum("bsd,dhk->bshk", x_k, p["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x_v, p["w_v"])
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", x_g, p["w_g"]))
    w = _decay(p, x_w)
    if wkv_state is None:
        wkv_state = jnp.zeros((b, h, hd, hd), jnp.float32)
    y, wkv_state = _wkv_scan(r, k, v, w, p["bonus"], wkv_state)
    y = _group_norm(y, p["ln_x"]).astype(x.dtype) * g
    out = jnp.einsum("bshk,hkd->bsd", y, p["w_o"])
    return constrain(out, ("batch", "seq", "embed")), x[:, -1], wkv_state


def _channel_mix(p: dict, x: jax.Array, shift_prev):
    x_prev = _token_shift(x, shift_prev)
    xx = x_prev - x
    x_k = x + xx * p["cm_mu_k"][None, None]
    x_r = x + xx * p["cm_mu_r"][None, None]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", x_k, p["cm_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, p["cm_v"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x_r, p["cm_r"])) * kv
    return out, x[:, -1]


def rwkv_block(
    cfg: ModelConfig,
    p: dict,
    norm1_w: jax.Array,
    norm2_w: jax.Array,
    x: jax.Array,
    cache: dict | None = None,
) -> tuple[jax.Array, dict]:
    """Full RWKV residual block over any sequence length (S=1 is decode).

    ``cache=None`` starts from zero state (training / fresh prefill); the
    returned cache always carries the final state, so train can drop it and
    prefill keeps it.
    """
    from repro.models.layers import rms_norm

    shift_tm = cache["shift_tm"] if cache else None
    shift_cm = cache["shift_cm"] if cache else None
    wkv = cache["wkv"] if cache else None
    h1 = rms_norm(x, norm1_w, cfg)
    tm_out, new_shift_tm, new_wkv = _time_mix(cfg, p, h1, shift_tm, wkv)
    x = x + tm_out
    h2 = rms_norm(x, norm2_w, cfg)
    cm_out, new_shift_cm = _channel_mix(p, h2, shift_cm)
    x = x + cm_out
    return x, {"wkv": new_wkv, "shift_tm": new_shift_tm, "shift_cm": new_shift_cm}
