"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (one "rec" temporal-mix):

    x -> W_branch (d -> 2 * lru_width)       split: [gate | signal]
    signal -> causal depthwise conv1d(width) -> RG-LRU -> * gelu(gate)
    -> W_out (lru_width -> d)

RG-LRU cell (c = 8):

    r_t = sigmoid(W_a u_t + b_a)             recurrence gate
    i_t = sigmoid(W_i u_t + b_i)             input gate
    log a_t = -c * softplus(Lambda) * r_t    (so a_t = sigmoid(Lambda)^(c r_t))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Decode state: h (B, W) plus the conv ring (B, width-1, W) — O(1) in context
length, which is what qualifies this family for the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding_ctx import constrain

__all__ = [
    "init_rglru_params",
    "init_rglru_cache",
    "rglru_mix",
]

_C = 8.0


def init_rglru_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    s = d**-0.5
    return {
        "w_branch": jax.random.normal(ks[0], (d, 2 * w), dtype) * s,
        "conv": jax.random.normal(ks[1], (cfg.conv_width, w), dtype) * 0.1,
        "conv_bias": jnp.zeros((w,), dtype),
        "w_a": jax.random.normal(ks[2], (w, w), dtype) * w**-0.5,
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": jax.random.normal(ks[3], (w, w), dtype) * w**-0.5,
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jax.random.uniform(ks[4], (w,), jnp.float32, 2.0, 4.0),  # softplus -> decay
        "w_out": jax.random.normal(ks[5], (w, d), dtype) * w**-0.5,
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def _conv1d(p: dict, u: jax.Array, conv_state: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """Causal depthwise conv over (B, S, W); ``conv_state`` (B, cw-1, W)
    carries the predecessors (zeros for a fresh sequence).  Works for any S
    including decode's S=1.  Returns (out, new_state)."""
    cw = p["conv"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([conv_state, u], axis=1)            # (B, S+cw-1, W)
    out = sum(ext[:, i : i + u.shape[1]] * p["conv"][i][None, None] for i in range(cw))
    return out + p["conv_bias"][None, None], ext[:, -(cw - 1) :]


def _gates(p: dict, u: jax.Array) -> tuple[jax.Array, jax.Array]:
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, p["w_i"]).astype(jnp.float32) + p["b_i"])
    return r, i


def _lru_coeffs(p: dict, r: jax.Array, i: jax.Array, u: jax.Array):
    log_a = -_C * jax.nn.softplus(p["lam"])[None, None] * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (
        i * u.astype(jnp.float32)
    )
    return a, gated_in


def rglru_mix(
    cfg: ModelConfig, p: dict, x: jax.Array, cache: dict | None = None
) -> tuple[jax.Array, dict]:
    """Temporal mix over any sequence length; ``cache=None`` = fresh state.
    Returns (out (B,S,D), new cache)."""
    b = x.shape[0]
    branch = jnp.einsum("bsd,dw->bsw", x, p["w_branch"])
    gate, signal = jnp.split(branch, 2, axis=-1)
    u, conv_state = _conv1d(p, signal, cache["conv"] if cache else None)
    r, i = _gates(p, u)
    a, gated_in = _lru_coeffs(p, r, i, u)

    def step(h, inputs):
        a_t, in_t = inputs
        h = a_t * h + in_t
        return h, h

    h0 = cache["h"] if cache else jnp.zeros((b, cfg.lru_width), jnp.float32)
    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated_in, 1, 0))
    h_final, hs = jax.lax.scan(step, h0, xs)
    h_seq = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    mixed = h_seq * jax.nn.gelu(gate, approximate=True)
    out = jnp.einsum("bsw,wd->bsd", mixed, p["w_out"])
    out = constrain(out, ("batch", "seq", "embed"))
    return out, {"h": h_final, "conv": conv_state}
