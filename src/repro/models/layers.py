"""Shared transformer layer vocabulary for the architecture zoo.

Pure functions over parameter pytrees — no module framework.  Everything is
written to live inside a ``lax.scan`` over stacked layer parameters and under
GSPMD: activations get explicit sharding constraints at block boundaries via
``sharding_ctx`` so the partitioner never has to guess.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding_ctx import constrain

NEG_INF = -2.0e38


# --------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, w: jax.Array, cfg: ModelConfig, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if cfg.norm_plus_one else w.astype(jnp.float32)
    return (y * scale).astype(dtype)


# ---------------------------------------------------------------------- rope

def rope_freqs(cfg: ModelConfig) -> jax.Array:
    half = cfg.resolved_head_dim // 2
    return cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def rope_angles(cfg: ModelConfig, positions: jax.Array) -> jax.Array:
    """positions (B, S) or (3, B, S) for M-RoPE -> angles (B, S, half).

    M-RoPE (Qwen2-VL): the ``half`` rotary pairs are split into sections
    (t, h, w); each section takes its angle from its own position stream.
    """
    inv = rope_freqs(cfg)
    if positions.ndim == 2:
        return positions[..., None].astype(jnp.float32) * inv
    if cfg.mrope_sections is None:
        raise ValueError("3-D positions require mrope_sections")
    parts = []
    start = 0
    for idx, width in enumerate(cfg.mrope_sections):
        parts.append(positions[idx][..., None].astype(jnp.float32) * inv[start : start + width])
        start += width
    if start != inv.shape[0]:
        raise ValueError(f"mrope sections sum {start} != rotary half {inv.shape[0]}")
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x (B, S, H, hd), angles (B, S, half) -> rotated x (pairs = split halves)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------- masks

def causal_mask(s: int, *, dtype=jnp.float32) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    return jnp.where(j <= i, 0.0, NEG_INF).astype(dtype)


def local_causal_mask(s: int, window: int, *, dtype=jnp.float32) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    ok = (j <= i) & (j > i - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def decode_mask(q_pos: jax.Array, kv_positions: jax.Array, window: int | None) -> jax.Array:
    """One-token decode: q_pos (B,), kv_positions (B, T) absolute (or -1 for
    empty slots) -> (B, 1, T) additive mask."""
    ok = (kv_positions >= 0) & (kv_positions <= q_pos[:, None])
    if window is not None:
        ok &= kv_positions > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)[:, None, :]


# ------------------------------------------------------------------ KV cache

class LayerCache(NamedTuple):
    """Per-layer attention cache.  ``positions`` carries absolute positions
    (-1 = empty), which uniformly handles global caches and local
    ring-buffers.  With ``cfg.kv_cache_dtype == "int8"`` the k/v payloads are
    per-(b, t, kv)-row symmetric-quantized int8 with bf16 scales — half the
    decode HBM traffic and the difference between fitting and not fitting
    qwen1.5-32b's 5.5 TB decode_32k cache (EXPERIMENTS.md §Perf)."""

    k: jax.Array                     # (B, T, KV, hd) bf16 or int8
    v: jax.Array                     # (B, T, KV, hd)
    positions: jax.Array             # (B, T) int32
    k_scale: jax.Array | None = None  # (B, T, KV) bf16, int8 mode only
    v_scale: jax.Array | None = None


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., hd) -> int8 payload + per-row scale."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, s: jax.Array, dtype) -> jax.Array:
    return q.astype(dtype) * s[..., None].astype(dtype)


def init_layer_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> LayerCache:
    kv = cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        return LayerCache(
            k=jnp.zeros((batch, capacity, kv, hd), jnp.int8),
            v=jnp.zeros((batch, capacity, kv, hd), jnp.int8),
            positions=jnp.full((batch, capacity), -1, jnp.int32),
            k_scale=jnp.zeros((batch, capacity, kv), jnp.bfloat16),
            v_scale=jnp.zeros((batch, capacity, kv), jnp.bfloat16),
        )
    return LayerCache(
        k=jnp.zeros((batch, capacity, kv, hd), dtype),
        v=jnp.zeros((batch, capacity, kv, hd), dtype),
        positions=jnp.full((batch, capacity), -1, jnp.int32),
    )


def cache_insert(cache: LayerCache, k: jax.Array, v: jax.Array, pos: jax.Array) -> LayerCache:
    """Insert one decode step (k/v: (B, 1, KV, hd), pos: (B,)) at
    ``pos % capacity`` — a ring for local layers, exact slot for global ones
    (global capacity >= max position, so the ring never wraps)."""
    cap = cache.k.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    b = jnp.arange(cache.k.shape[0])
    pnew = cache.positions.at[b, slot].set(pos.astype(jnp.int32))
    if cache.k_scale is not None:
        kq, ks = quantize_kv(k[:, 0])
        vq, vs = quantize_kv(v[:, 0])
        return LayerCache(
            cache.k.at[b, slot].set(kq),
            cache.v.at[b, slot].set(vq),
            pnew,
            cache.k_scale.at[b, slot].set(ks),
            cache.v_scale.at[b, slot].set(vs),
        )
    knew = cache.k.at[b, slot].set(k[:, 0])
    vnew = cache.v.at[b, slot].set(v[:, 0])
    return LayerCache(knew, vnew, pnew)


def cache_kv_values(cache: LayerCache, dtype) -> tuple[jax.Array, jax.Array]:
    """Materialize dequantized (B, T, KV, hd) k/v for attention."""
    if cache.k_scale is not None:
        return (
            dequantize_kv(cache.k, cache.k_scale, dtype),
            dequantize_kv(cache.v, cache.v_scale, dtype),
        )
    return cache.k, cache.v


# ----------------------------------------------------------------- attention

def init_attention_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, cfg.n_heads, hd), dtype) * scale,
        "wk": jax.random.normal(k2, (d, cfg.n_kv_heads, hd), dtype) * scale,
        "wv": jax.random.normal(k3, (d, cfg.n_kv_heads, hd), dtype) * scale,
        "wo": jax.random.normal(k4, (cfg.n_heads, hd, d), dtype) * scale,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def _softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def _chunked_attention(
    cfg: ModelConfig,
    qg: jax.Array,      # (B, S, KV, G, hd), unscaled
    k: jax.Array,       # (B, T, KV, hd)
    v: jax.Array,       # (B, T, KV, hd)
    *,
    causal: bool,
    window: int | None,
) -> jax.Array:
    """Online-softmax attention over KV chunks (flash-style): the (S, T)
    score tile exists only one ``attn_chunk``-wide slab at a time, in both
    the forward and (via scan) the backward pass.  Masks are built from iota
    per chunk — no (S, T) mask tensor either."""
    b, s, kvh, g, hd = qg.shape
    t = k.shape[1]
    chunk = min(cfg.attn_chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = hd ** -0.5
    q_pos = jnp.arange(s)

    def body(carry, c_idx):
        m, denom, acc = carry
        k_c = jax.lax.dynamic_slice_in_dim(k, c_idx * chunk, chunk, 1)
        v_c = jax.lax.dynamic_slice_in_dim(v, c_idx * chunk, chunk, 1)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, k_c).astype(jnp.float32) * scale
        logits = _softcap(logits, cfg.attn_softcap)
        kv_pos = c_idx * chunk + jnp.arange(chunk)
        ok = kv_pos[None, :] < t  # padding slots
        if causal:
            ok = ok & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        denom = denom * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(v_c.dtype), v_c
        ).astype(jnp.float32)
        return (m_new, denom, acc), None

    init = (
        jnp.full((b, kvh, g, s), -jnp.inf, jnp.float32),
        jnp.zeros((b, kvh, g, s), jnp.float32),
        jnp.zeros((b, kvh, g, s, hd), jnp.float32),
    )
    (m, denom, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    # (B, KV, G, S, hd) -> (B, S, KV*G, hd)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, kvh * g, hd).astype(qg.dtype)


def attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                     # (B, S, D)
    *,
    angles: jax.Array | None,         # rope angles (B, S, half) or None
    mask: jax.Array | None,           # additive (S, T) / (B, 1, T) / None
    cache: LayerCache | None = None,  # decode path when S == 1
    decode_pos: jax.Array | None = None,  # (B,) absolute positions of the new token
    window: int | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attention
    causal: bool = True,
) -> tuple[jax.Array, LayerCache | None]:
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    else:
        k, v = kv_override
    if cfg.qkv_bias and "bq" in p:
        q = q + p["bq"]
        if kv_override is None:
            k = k + p["bk"]
            v = v + p["bv"]
    if angles is not None:
        q = apply_rope(q, angles)
        if kv_override is None:
            k = apply_rope(k, angles)

    new_cache = None
    if cache is not None:
        new_cache = cache_insert(cache, k, v, decode_pos)
        k, v = cache_kv_values(new_cache, x.dtype)  # (B, T, KV, hd)
        mask = decode_mask(decode_pos, new_cache.positions, window)

    q = constrain(q, ("batch", "seq", "heads", None))
    group = h // kvh
    qg = q.reshape(b, s, kvh, group, hd)

    # Flash-style path: full-sequence attention (train/prefill/encoder) with
    # chunking enabled; decode and cross-attention keep the dense path.
    if cfg.attn_chunk and cache is None and s > 1 and kv_override is None:
        ctx = _chunked_attention(cfg, qg, k, v, causal=causal, window=window)
        out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
        return constrain(out, ("batch", "seq", "embed")), None

    scale = hd ** -0.5
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    logits = _softcap(logits, cfg.attn_softcap)
    if mask is not None:
        if mask.ndim == 2:                       # (S, T)
            logits = logits + mask[None, None, None, :, :]
        else:                                    # (B, 1, T) decode
            logits = logits + mask[:, None, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bkgst,btkh->bskgh", probs, v).reshape(b, s, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    return constrain(out, ("batch", "seq", "embed")), new_cache


# ----------------------------------------------------------------------- mlp

def init_mlp_params(cfg: ModelConfig, key: jax.Array, dtype, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": jax.random.normal(k1, (d, f), dtype) * d**-0.5,
        "w_out": jax.random.normal(k2, (f, d), dtype) * f**-0.5,
    }
    if cfg.activation in ("silu", "geglu"):
        p["w_gate"] = jax.random.normal(k3, (d, f), dtype) * d**-0.5
    return p


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    up = constrain(up, ("batch", "seq", "mlp"))
    if cfg.activation == "silu":
        gated = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * up
    elif cfg.activation == "geglu":
        gated = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]), approximate=True) * up
    elif cfg.activation == "gelu":
        gated = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(cfg.activation)
    out = jnp.einsum("bsf,fd->bsd", gated, p["w_out"])
    return constrain(out, ("batch", "seq", "embed"))


# ------------------------------------------------------------------- softcap

def final_softcap(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    return _softcap(logits, cfg.final_softcap)
