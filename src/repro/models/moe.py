"""Mixture-of-experts layer: capacity-based top-k routing with
scatter/gather dispatch.

The classic GShard formulation materializes a (tokens, experts, capacity)
one-hot dispatch tensor; at 1M tokens x 32 experts x 300k capacity that is
~1e13 elements — the dry-run flagged exactly this (granite train_4k at 135x
HBM).  Since the dispatch tensor is a permutation in disguise, we instead
scatter-add tokens into the (experts, capacity, d) buffer and gather them
back: O(T·k·d) data movement, buffer sharded over the model axis (expert
parallelism), positions from a per-round cumsum over the one-hot (O(T·E)).
Under GSPMD the scatter/gather between token-sharded and expert-sharded
layouts lowers to the expected all-to-all exchange.

Top-k routing runs k rounds of top-1 dispatch against a shared capacity
budget; capacity-overflow tokens are dropped (standard GShard semantics),
counted in the aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding_ctx import constrain
from repro.runtime.compat import token_prefix_sum

__all__ = ["init_moe_params", "moe_layer"]


def init_moe_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    assert cfg.moe is not None
    d, e, f = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff_expert
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * d**-0.5,
        "w_in": jax.random.normal(k2, (e, d, f), dtype) * d**-0.5,
        "w_gate": jax.random.normal(k3, (e, d, f), dtype) * d**-0.5,
        "w_out": jax.random.normal(k4, (e, f, d), dtype) * f**-0.5,
    }
    if cfg.moe.dense_d_ff:
        from repro.models.layers import init_mlp_params

        p["dense"] = init_mlp_params(cfg, key, dtype, d_ff=cfg.moe.dense_d_ff)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    moe = cfg.moe
    # k dispatch slots per token spread over E experts.
    cap = int(moe.capacity_factor * n_tokens * moe.top_k / moe.n_experts) + 1
    # Round to a lane-friendly size; tiny smoke configs keep at least 4.
    return max(4, -(-cap // 4) * 4)


def moe_layer(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out, aux_loss).  aux is the standard load-balancing
    loss (mean over experts of fraction_dispatched * mean_gate * E)."""
    moe = cfg.moe
    e = moe.n_experts
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    cap = _capacity(cfg, t)
    remaining = probs
    expert_fill = jnp.zeros((e,), jnp.int32)
    frac_dispatched = jnp.zeros((e,), jnp.float32)
    buf = constrain(jnp.zeros((e, cap, d), xt.dtype), ("experts", None, None))
    routes = []  # per round: (dest_e (T,), dest_c (T,), gate (T,) masked)

    for _ in range(moe.top_k):
        gate = jnp.max(remaining, axis=-1)                      # (T,)
        expert = jnp.argmax(remaining, axis=-1)                 # (T,)
        onehot = jax.nn.one_hot(expert, e, dtype=jnp.float32)   # (T, E)
        # Prefix sum over the token axis.  The token axis may be GSPMD-
        # sharded here, so this must go through the partitioner-safe helper
        # (associative_scan is miscompiled on sharded axes by old jax).
        csum = token_prefix_sum(onehot, axis=0)
        pos = (csum - 1.0) + expert_fill[None, :].astype(jnp.float32)
        pos_tok = jnp.sum(pos * onehot, axis=-1)                # (T,)
        keep = pos_tok < cap
        # Capacity-dropped slots scatter zeros into expert 0 (harmless) and
        # their gates are zeroed, so no dump row is needed and the buffer
        # keeps its clean (E, C, d) expert sharding.
        dest_e = jnp.where(keep, expert, 0).astype(jnp.int32)
        dest_c = jnp.clip(pos_tok, 0, cap - 1).astype(jnp.int32)
        src = jnp.where(keep[:, None], xt, jnp.zeros_like(xt))
        buf = buf.at[dest_e, dest_c].add(src)                   # O(T d) scatter
        routes.append((dest_e, dest_c, jnp.where(keep, gate, 0.0)))
        expert_fill = expert_fill + jnp.sum(
            onehot * keep[:, None].astype(jnp.float32), axis=0
        ).astype(jnp.int32)
        frac_dispatched = frac_dispatched + jnp.mean(onehot, axis=0)
        remaining = remaining * (1.0 - onehot)

    expert_in = constrain(buf, ("experts", None, None))         # (E, C, d)
    hidden = jnp.einsum("ecd,edf->ecf", expert_in, p["w_in"])
    gated = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])) * hidden
    expert_out = jnp.einsum("ecf,efd->ecd", gated, p["w_out"])  # (E, C, d)

    combined = jnp.zeros_like(xt, dtype=jnp.float32)
    for dest_e, dest_c, gate in routes:
        combined = combined + expert_out[dest_e, dest_c].astype(jnp.float32) * gate[:, None]

    aux = jnp.sum(frac_dispatched / moe.top_k * jnp.mean(probs, axis=0)) * e
    out = combined.astype(x.dtype).reshape(b, s, d)
    if "dense" in p:
        from repro.models.layers import mlp

        out = out + mlp(cfg, p["dense"], x)
    return constrain(out, ("batch", "seq", "embed")), aux


def _moe_local(cfg: ModelConfig, p: dict, xt: jax.Array, n_local_experts: int, axis: str):
    """Per-device body of the manual expert-parallel layer (inside
    shard_map over ('pod','data','model')).

    Tokens are local to this data shard (replicated over 'model'); this
    device hosts ``n_local_experts`` consecutive experts.  Routing runs
    against the full router (replicated, tiny); only tokens whose expert
    lives here are scattered into the local buffer; the combined output is
    psum'd over the model axis — wire cost O(T_local * d) instead of the
    O(E*C*d) buffer all-reduce GSPMD chooses for the scatter formulation
    (EXPERIMENTS.md §Perf B4)."""
    moe = cfg.moe
    e = moe.n_experts
    t, d = xt.shape
    shard = jax.lax.axis_index(axis)
    first = shard * n_local_experts

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    # capacity against *local* tokens (each data shard routes independently)
    cap = max(4, int(moe.capacity_factor * t * moe.top_k / e) + 4)

    remaining = probs
    expert_fill = jnp.zeros((e,), jnp.int32)
    frac_dispatched = jnp.zeros((e,), jnp.float32)
    buf = jnp.zeros((n_local_experts, cap, d), xt.dtype)
    routes = []
    for _ in range(moe.top_k):
        gate = jnp.max(remaining, axis=-1)
        expert = jnp.argmax(remaining, axis=-1)
        onehot = jax.nn.one_hot(expert, e, dtype=jnp.float32)
        # Token axis is device-local inside shard_map; the helper still
        # keeps the lowering consistent with the GSPMD path above.
        csum = token_prefix_sum(onehot, axis=0)
        pos_tok = jnp.sum((csum - 1.0 + expert_fill[None].astype(jnp.float32)) * onehot, -1)
        local = (expert >= first) & (expert < first + n_local_experts)
        keep = (pos_tok < cap) & local
        dest_e = jnp.where(keep, expert - first, 0).astype(jnp.int32)
        dest_c = jnp.clip(pos_tok, 0, cap - 1).astype(jnp.int32)
        buf = buf.at[dest_e, dest_c].add(jnp.where(keep[:, None], xt, jnp.zeros_like(xt)))
        routes.append((dest_e, dest_c, jnp.where(keep, gate, 0.0)))
        expert_fill = expert_fill + jnp.sum(
            onehot * (pos_tok < cap)[:, None].astype(jnp.float32), axis=0
        ).astype(jnp.int32)
        frac_dispatched = frac_dispatched + jnp.mean(onehot, axis=0)
        remaining = remaining * (1.0 - onehot)

    w_in, w_gate, w_out = p["w_in"], p["w_gate"], p["w_out"]  # local (E_loc, ...)
    hidden = jnp.einsum("ecd,edf->ecf", buf, w_in)
    gated = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * hidden
    expert_out = jnp.einsum("ecf,efd->ecd", gated, w_out)

    combined = jnp.zeros_like(xt, dtype=jnp.float32)
    for dest_e, dest_c, gate in routes:
        combined = combined + expert_out[dest_e, dest_c].astype(jnp.float32) * gate[:, None]
    # Each token's experts live on exactly the shards that contributed;
    # summing over the model axis assembles the full top-k mixture.
    combined = jax.lax.psum(combined, axis)
    aux = jnp.sum(frac_dispatched / moe.top_k * jnp.mean(probs, axis=0)) * e
    return combined.astype(xt.dtype), aux


def moe_layer_manual(cfg: ModelConfig, p: dict, x: jax.Array, mesh) -> tuple[jax.Array, jax.Array]:
    """Manual expert-parallel MoE via shard_map (moe_impl='manual')."""
    from jax.sharding import PartitionSpec as P

    from repro.runtime.compat import shard_map
    from repro.runtime.sharding import batch_axes

    moe = cfg.moe
    dp = batch_axes(mesh)
    tp = mesh.shape["model"]
    if moe.n_experts % tp:
        # cannot split experts evenly: fall back to the GSPMD path
        return moe_layer(cfg, p, x)
    n_local = moe.n_experts // tp
    b, s, d = x.shape

    def local_fn(p_local, x_local):
        bl, sl, _ = x_local.shape
        out, aux = _moe_local(cfg, p_local, x_local.reshape(bl * sl, d), n_local, "model")
        aux = jax.lax.pmean(aux, dp)  # replicate the load-balance stat
        return out.reshape(bl, sl, d), aux

    p_specs = {
        "router": P(),
        "w_in": P("model", None, None),
        "w_gate": P("model", None, None),
        "w_out": P("model", None, None),
    }
    if "dense" in p:
        p_specs["dense"] = jax.tree.map(lambda _: P(), p["dense"])
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(p_specs, P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )
    out, aux = fn(p, x)
    if "dense" in p:
        from repro.models.layers import mlp

        out = out + mlp(cfg, p["dense"], x)
    return constrain(out, ("batch", "seq", "embed")), aux
