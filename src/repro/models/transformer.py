"""Decoder-only LM orchestrator for every non-enc-dec arch in the zoo.

Heterogeneous layer patterns (gemma2 local/global, recurrentgemma
rec/rec/local, rwkv, dense, moe) are handled by one mechanism: the layer
stack is decomposed into ``repeats`` copies of ``cfg.block_pattern`` plus a
tail (``n_layers = repeats * len(pattern) + len(tail)``).  Parameters (and
caches) are stacked over ``repeats`` and the whole stack runs under one
``lax.scan`` — compile time and HLO size stay O(pattern), not O(n_layers),
which is what keeps 62-layer dry-runs tractable.

Three execution modes share the block code:
    train   — full sequence, no caches
    prefill — full sequence, returns caches (serve step 1)
    decode  — S=1 against caches (serve step N)
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding_ctx import constrain

__all__ = ["init_params", "forward_train", "prefill", "decode", "stack_geometry"]

Params = dict
Cache = Any


# ----------------------------------------------------------------- geometry

def stack_geometry(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    """(repeats, tail_kinds)."""
    k = len(cfg.block_pattern)
    return cfg.n_layers // k, cfg.block_pattern[: cfg.n_layers % k]


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    reps, tail = stack_geometry(cfg)
    return list(cfg.block_pattern) * reps + list(tail)


# --------------------------------------------------------------------- init

def _init_block(cfg: ModelConfig, kind: str, key: jax.Array, dtype) -> Params:
    ks = jax.random.split(key, 4)

    def norm():  # fresh buffer each time: donation forbids aliased leaves
        fill = 0.0 if cfg.norm_plus_one else 1.0
        return jnp.full((cfg.d_model,), fill, jnp.float32)

    p: Params = {"ln1": norm(), "ln2": norm()}
    if kind in ("attn", "local"):
        p["attn"] = L.init_attention_params(cfg, ks[0], dtype)
        if cfg.moe is not None:
            from repro.models.moe import init_moe_params

            p["moe"] = init_moe_params(cfg, ks[1], dtype)
        else:
            p["mlp"] = L.init_mlp_params(cfg, ks[1], dtype)
        if cfg.post_norms:
            p["pn1"] = norm()
            p["pn2"] = norm()
    elif kind == "rwkv":
        from repro.models.rwkv6 import init_rwkv_params

        p["rwkv"] = init_rwkv_params(cfg, ks[0], dtype)
    elif kind == "rec":
        from repro.models.rglru import init_rglru_params

        p["rec"] = init_rglru_params(cfg, ks[0], dtype)
        p["mlp"] = L.init_mlp_params(cfg, ks[1], dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    reps, tail = stack_geometry(cfg)
    keys = jax.random.split(key, reps * len(cfg.block_pattern) + len(tail) + 3)
    ki = iter(range(len(keys)))

    pattern_stacks = []
    for pos, kind in enumerate(cfg.block_pattern):
        per_repeat = [_init_block(cfg, kind, keys[next(ki)], dtype) for _ in range(reps)]
        pattern_stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat))
    tail_blocks = [_init_block(cfg, kind, keys[next(ki)], dtype) for kind in tail]

    params: Params = {
        "embed": jax.random.normal(keys[next(ki)], (cfg.padded_vocab, cfg.d_model), dtype) * 0.02,
        "pattern": pattern_stacks,
        "tail": tail_blocks,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.norm_plus_one
        else jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[next(ki)], (cfg.d_model, cfg.padded_vocab), dtype) * 0.02
        )
    return params


# ------------------------------------------------------------------- caches

def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> list:
    """Per-layer caches, stacked over repeats per pattern position; the tail
    keeps unstacked caches.  Returns [pattern_caches..., tail_caches...]."""

    def one(kind: str) -> Cache:
        if kind == "attn":
            return L.init_layer_cache(cfg, batch, capacity, dtype)
        if kind == "local":
            return L.init_layer_cache(cfg, batch, min(capacity, cfg.local_window), dtype)
        if kind == "rwkv":
            from repro.models.rwkv6 import init_rwkv_cache

            return init_rwkv_cache(cfg, batch, dtype)
        if kind == "rec":
            from repro.models.rglru import init_rglru_cache

            return init_rglru_cache(cfg, batch, dtype)
        raise ValueError(kind)

    reps, tail = stack_geometry(cfg)
    pattern_caches = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *[one(kind) for _ in range(reps)])
        for kind in cfg.block_pattern
    ]
    tail_caches = [one(kind) for kind in tail]
    return [pattern_caches, tail_caches]


# ------------------------------------------------------------------- blocks

def _block(
    cfg: ModelConfig,
    kind: str,
    p: Params,
    x: jax.Array,
    *,
    angles,
    mask,
    cache,
    decode_pos,
    mode: str,
) -> tuple[jax.Array, Cache, jax.Array]:
    """One residual block.  Returns (x, new_cache, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local"):
        window = cfg.local_window if kind == "local" else None
        h = L.rms_norm(x, p["ln1"], cfg)
        attn_cache = cache if mode == "decode" else None
        out, new_cache = L.attention(
            cfg, p["attn"], h,
            angles=angles, mask=mask,
            cache=attn_cache, decode_pos=decode_pos, window=window,
        )
        if mode == "prefill":
            new_cache = _fill_cache(cfg, cache, p, h, angles, window)
        if cfg.post_norms:
            out = L.rms_norm(out, p["pn1"], cfg)
        x = x + out
        h2 = L.rms_norm(x, p["ln2"], cfg)
        if "moe" in p:
            from repro.models.moe import moe_layer, moe_layer_manual
            from repro.models.sharding_ctx import current_mesh

            mesh = current_mesh()
            if cfg.moe_impl == "manual" and mesh is not None:
                ff, aux = moe_layer_manual(cfg, p["moe"], h2, mesh)
            else:
                ff, aux = moe_layer(cfg, p["moe"], h2)
        else:
            ff = L.mlp(cfg, p["mlp"], h2)
        if cfg.post_norms:
            ff = L.rms_norm(ff, p["pn2"], cfg)
        x = x + ff
        return x, new_cache, aux
    if kind == "rwkv":
        from repro.models.rwkv6 import rwkv_block

        # decode continues the carried state; train/prefill start fresh (the
        # returned cache is the final state, which prefill keeps).
        in_cache = cache if mode == "decode" else None
        x, new_cache = rwkv_block(cfg, p["rwkv"], p["ln1"], p["ln2"], x, in_cache)
        return x, new_cache, aux
    if kind == "rec":
        from repro.models.rglru import rglru_mix

        h = L.rms_norm(x, p["ln1"], cfg)
        out, new_cache = rglru_mix(cfg, p["rec"], h, cache if mode == "decode" else None)
        x = x + out
        x = x + L.mlp(cfg, p["mlp"], L.rms_norm(x, p["ln2"], cfg))
        return x, new_cache, aux
    raise ValueError(kind)


def _fill_cache(cfg, cache: L.LayerCache, p, h_normed, angles, window) -> L.LayerCache:
    """Prefill: recompute k/v for the full sequence and lay them into the
    (possibly ring) cache with absolute positions."""
    k = jnp.einsum("bsd,dhk->bshk", h_normed, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h_normed, p["attn"]["wv"])
    if cfg.qkv_bias and "bk" in p["attn"]:
        k = k + p["attn"]["bk"]
        v = v + p["attn"]["bv"]
    if angles is not None:
        k = L.apply_rope(k, angles)
    b, s = k.shape[0], k.shape[1]
    cap = cache.k.shape[1]
    take = min(s, cap)
    src_k = k[:, s - take :]
    src_v = v[:, s - take :]
    pos = jnp.arange(s - take, s, dtype=jnp.int32)
    slots = pos % cap
    pnew = cache.positions.at[:, slots].set(pos[None, :])
    if cache.k_scale is not None:
        kq, ks = L.quantize_kv(src_k)
        vq, vs = L.quantize_kv(src_v)
        return L.LayerCache(
            cache.k.at[:, slots].set(kq),
            cache.v.at[:, slots].set(vq),
            pnew,
            cache.k_scale.at[:, slots].set(ks),
            cache.v_scale.at[:, slots].set(vs),
        )
    knew = cache.k.at[:, slots].set(src_k)
    vnew = cache.v.at[:, slots].set(src_v)
    return L.LayerCache(knew, vnew, pnew)


# ------------------------------------------------------------------ forward

def _embed_inputs(cfg, params, tokens, extra_embeds):
    parts = []
    if extra_embeds is not None:
        parts.append(extra_embeds.astype(params["embed"].dtype))
    if tokens is not None:
        parts.append(params["embed"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return constrain(x, ("batch", "seq", "embed"))


def _vocab_pad_mask(cfg):
    if cfg.padded_vocab == cfg.vocab:
        return None
    return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, L.NEG_INF)


def _logits(cfg, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    logits = L.final_softcap(cfg, logits)
    mask = _vocab_pad_mask(cfg)
    if mask is not None:
        logits = logits + mask[None, None, :]
    return constrain(logits, ("batch", "seq", "vocab"))


def _train_masks(cfg: ModelConfig, s: int) -> dict:
    """Dense additive masks; skipped entirely when chunked attention builds
    its masks from iota per KV slab (a 32k x 32k mask is 4 GB f32)."""
    if cfg.attn_chunk:
        return {}
    return {
        "attn": L.causal_mask(s),
        "local": L.local_causal_mask(s, cfg.local_window),
    }


def _run_stacks(cfg, params, x, *, angles, masks, caches, decode_pos, mode, remat_policy=None):
    """Scan the pattern stacks, then the tail.  Returns (x, new_caches, aux)."""
    reps, tail = stack_geometry(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    pattern_caches, tail_caches = caches if caches is not None else ([None] * len(cfg.block_pattern), [None] * len(tail))

    def repeat_body(x, slices):
        p_slices, c_slices = slices
        aux_acc = jnp.zeros((), jnp.float32)
        new_cs = []
        for pos, kind in enumerate(cfg.block_pattern):
            c = c_slices[pos] if c_slices is not None else None
            x, new_c, aux = _block(
                cfg, kind, p_slices[pos], x,
                angles=angles, mask=masks.get(kind) if masks else None,
                cache=c, decode_pos=decode_pos, mode=mode,
            )
            new_cs.append(new_c)
            aux_acc = aux_acc + aux
        return x, new_cs, aux_acc

    if reps > 0:
        def scan_body(carry, slices):
            x, aux_run = carry
            x, new_cs, aux = repeat_body(x, slices)
            return (x, aux_run + aux), new_cs

        if remat_policy is not None:
            scan_body = jax.checkpoint(scan_body, policy=remat_policy)
        xs = (tuple(params["pattern"]), tuple(pattern_caches) if caches is not None else None)
        if cfg.scan_layers:
            (x, aux_total), new_pattern_caches = jax.lax.scan(scan_body, (x, aux_total), xs)
        else:
            # Unrolled (dry-run accounting mode): same math, every layer in
            # the HLO so cost_analysis counts real FLOPs/bytes.
            collected = []
            for r in range(reps):
                sl = jax.tree.map(lambda a: a[r], xs)
                (x, aux_total), new_cs = scan_body((x, aux_total), sl)
                collected.append(new_cs)
            new_pattern_caches = jax.tree.map(lambda *xs_: jnp.stack(xs_), *collected)
    else:
        new_pattern_caches = pattern_caches

    new_tail_caches = []
    for i, kind in enumerate(tail):
        c = tail_caches[i] if caches is not None else None
        x, new_c, aux = _block(
            cfg, kind, params["tail"][i], x,
            angles=angles, mask=masks.get(kind) if masks else None,
            cache=c, decode_pos=decode_pos, mode=mode,
        )
        new_tail_caches.append(new_c)
        aux_total = aux_total + aux
    new_caches = [list(new_pattern_caches) if reps > 0 else [], new_tail_caches]
    return x, new_caches, aux_total


def apply_head(cfg: ModelConfig, params: Params, hidden: jax.Array) -> jax.Array:
    """Final-normed hidden (B, C, d) -> logits (B, C, V_pad), f32, softcapped,
    pad-masked.  Used by the chunked cross-entropy (never materializes the
    full-sequence logits tensor)."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", hidden, head).astype(jnp.float32)
    logits = L.final_softcap(cfg, logits)
    mask = _vocab_pad_mask(cfg)
    if mask is not None:
        logits = logits + mask[None, None, :]
    return logits


def forward_train(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array | None,
    positions: jax.Array,
    *,
    extra_embeds: jax.Array | None = None,
    remat_policy=None,
    return_hidden: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits (B,S,V), moe_aux); with
    ``return_hidden`` the final-normed hidden states come back instead of
    logits (chunked-loss path)."""
    x = _embed_inputs(cfg, params, tokens, extra_embeds)
    s = x.shape[1]
    angles = L.rope_angles(cfg, positions) if cfg.rope_theta else None
    masks = _train_masks(cfg, s)
    x, _, aux = _run_stacks(
        cfg, params, x, angles=angles, masks=masks, caches=None,
        decode_pos=None, mode="train", remat_policy=remat_policy,
    )
    if return_hidden:
        return L.rms_norm(x, params["final_norm"], cfg), aux
    return _logits(cfg, params, x), aux


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array | None,
    positions: jax.Array,
    *,
    cache_capacity: int | None = None,
    extra_embeds: jax.Array | None = None,
) -> tuple[jax.Array, list]:
    """Serve step 1: full forward building caches.  Returns (last-token
    logits (B,V), caches)."""
    x = _embed_inputs(cfg, params, tokens, extra_embeds)
    b, s = x.shape[0], x.shape[1]
    dtype = x.dtype
    caches = init_cache(cfg, b, cache_capacity or s, dtype)
    angles = L.rope_angles(cfg, positions) if cfg.rope_theta else None
    masks = _train_masks(cfg, s)
    x, caches, _ = _run_stacks(
        cfg, params, x, angles=angles, masks=masks, caches=caches,
        decode_pos=None, mode="prefill",
    )
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def decode(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,          # (B,) int32
    pos: jax.Array,            # (B,) absolute position of this token
    caches: list,
) -> tuple[jax.Array, list]:
    """Serve step N: one token through the caches -> (logits (B,V), caches)."""
    x = _embed_inputs(cfg, params, token[:, None], None)
    angles = L.rope_angles(cfg, pos[:, None]) if cfg.rope_theta else None
    x, caches, _ = _run_stacks(
        cfg, params, x, angles=angles, masks=None, caches=caches,
        decode_pos=pos, mode="decode",
    )
    logits = _logits(cfg, params, x)
    return logits[:, 0], caches
