"""Family dispatch + input specs: the single surface the steps, smoke tests
and the dry-run all build against.

``input_specs(cfg, shape)`` returns ShapeDtypeStructs for every model input
of the (arch x shape) cell — including the modality-stub embeddings for
[vlm]/[audio] per the assignment — so the dry-run lowers with zero
allocation and the smoke tests materialize the same specs at reduced size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = [
    "init_model",
    "abstract_params",
    "train_logits",
    "serve_prefill",
    "serve_decode",
    "input_specs",
    "abstract_caches",
]


def init_model(cfg: ModelConfig, key: jax.Array, *, max_positions: int = 4096):
    if cfg.family == "encdec":
        from repro.models import encdec as E

        return E.init_encdec_params(cfg, key, max_positions=max_positions)
    from repro.models import transformer as T

    return T.init_params(cfg, key)


def abstract_params(cfg: ModelConfig, *, max_positions: int = 4096):
    """Parameter ShapeDtypeStructs without touching device memory."""
    return jax.eval_shape(
        lambda k: init_model(cfg, k, max_positions=max_positions), jax.random.PRNGKey(0)
    )


def train_logits(cfg: ModelConfig, params, batch: dict, *, remat_policy=None):
    """-> (logits (B, S, V), moe_aux)."""
    if cfg.family == "encdec":
        from repro.models import encdec as E

        return E.forward_train(cfg, params, batch["frames"], batch["tokens"]), jnp.zeros((), jnp.float32)
    from repro.models import transformer as T

    return T.forward_train(
        cfg,
        params,
        batch.get("tokens"),
        batch["positions"],
        extra_embeds=batch.get("vision_embeds"),
        remat_policy=remat_policy,
    )


def train_hidden(cfg: ModelConfig, params, batch: dict, *, remat_policy=None):
    """-> (final-normed hidden (B, S, d), moe_aux) for the chunked-loss path."""
    if cfg.family == "encdec":
        from repro.models import encdec as E

        h = E.forward_train(cfg, params, batch["frames"], batch["tokens"], return_hidden=True)
        return h, jnp.zeros((), jnp.float32)
    from repro.models import transformer as T

    return T.forward_train(
        cfg,
        params,
        batch.get("tokens"),
        batch["positions"],
        extra_embeds=batch.get("vision_embeds"),
        remat_policy=remat_policy,
        return_hidden=True,
    )


def apply_head(cfg: ModelConfig, params, hidden):
    """hidden (B, C, d) -> masked f32 logits (B, C, V_pad)."""
    if cfg.family == "encdec":
        from repro.models import encdec as E

        return E.apply_head(cfg, params, hidden)
    from repro.models import transformer as T

    return T.apply_head(cfg, params, hidden)


def serve_prefill(cfg: ModelConfig, params, batch: dict, *, cache_capacity: int):
    if cfg.family == "encdec":
        from repro.models import encdec as E

        return E.prefill(cfg, params, batch["frames"], batch["tokens"], cache_capacity=cache_capacity)
    from repro.models import transformer as T

    return T.prefill(
        cfg,
        params,
        batch.get("tokens"),
        batch["positions"],
        cache_capacity=cache_capacity,
        extra_embeds=batch.get("vision_embeds"),
    )


def serve_decode(cfg: ModelConfig, params, token, pos, caches):
    if cfg.family == "encdec":
        from repro.models import encdec as E

        return E.decode(cfg, params, token, pos, caches)
    from repro.models import transformer as T

    return T.decode(cfg, params, token, pos, caches)


# ------------------------------------------------------------------- specs

def _emb_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell.  decode cells describe the *new-token*
    inputs; the KV/state cache spec comes from ``abstract_caches``."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_len, cfg.d_model), _emb_dtype(cfg))
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    elif cfg.family == "vlm":
        patches = min(cfg.vision_stub_patches, max(s // 2, 1))
        specs["vision_embeds"] = jax.ShapeDtypeStruct((b, patches, cfg.d_model), _emb_dtype(cfg))
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - patches), i32)
        specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["positions"] = jax.ShapeDtypeStruct((b, s), i32)
    if shape.kind == "train":
        label_len = specs["tokens"].shape[1]
        specs["labels"] = jax.ShapeDtypeStruct((b, label_len), i32)
    return specs


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig):
    """Cache ShapeDtypeStructs for a decode cell (capacity = shape.seq_len)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        from repro.models import encdec as E

        def build(key):
            params = E.init_encdec_params(cfg, key, max_positions=s)
            frames = jnp.zeros((b, cfg.encoder_len, cfg.d_model), _emb_dtype(cfg))
            tokens = jnp.zeros((b, 8), jnp.int32)
            _, caches = E.prefill(cfg, params, frames, tokens, cache_capacity=s)
            return caches

        return jax.eval_shape(build, jax.random.PRNGKey(0))
    from repro.models import transformer as T

    return jax.eval_shape(lambda: T.init_cache(cfg, b, s, _emb_dtype(cfg)))
