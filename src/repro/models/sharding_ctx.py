"""Ambient mesh/rules context so layer code can constrain activations by
*logical* axes without threading mesh handles through every function.

Model code calls ``constrain(x, ("batch", "seq", "embed"))``; outside a mesh
context this is the identity, inside it becomes
``lax.with_sharding_constraint`` with the physical spec resolved through the
active ``LogicalAxisRules``.  Step builders install the context.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding

from repro.runtime.sharding import DEFAULT_RULES, LogicalAxisRules

_state = threading.local()

__all__ = ["activation_sharding_scope", "constrain"]


@contextlib.contextmanager
def activation_sharding_scope(mesh: Mesh | None, rules: LogicalAxisRules | None = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules or DEFAULT_RULES) if mesh is not None else None
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x: jax.Array, logical: tuple[str | None, ...]) -> jax.Array:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(logical):
        return x
    spec = rules.physical(logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh():
    """The ambient mesh (None outside a step builder's scope) — used by
    layers that embed manual shard_map regions (e.g. all-to-all MoE)."""
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None
