"""Shared packed-slab host cache (DESIGN.md §17).

One process-wide LRU of raw 2-bit genotype slabs keyed by
``(source identity, marker range)``, so every consumer of the same cohort —
the scan's `prepare_batch`, the streamed GRM pass, `repro.serve` warm
windows, and checkpoint-resume re-preps — performs **one** disk read per
batch instead of one per consumer.  Entries are read-only materialized
copies (a memmap view would pin the page cache but re-fault per consumer;
a materialized slab is ceil(N/4) bytes/marker, 16x smaller than f32, so a
default 256 MiB budget holds ~1M markers of a 4k-sample cohort).

Source identity comes from ``source.packed_cache_key()`` — stable across
source *instances* over the same files (realpath/size/mtime), which is what
makes serve's per-request sources and resumed scans hit.  Sources without a
stable identity (in-memory, synthetic) bypass the cache transparently.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np

__all__ = ["PackedSlabCache", "default_cache", "configure_default", "read_packed_cached"]


class PackedSlabCache:
    """Thread-safe LRU over packed genotype slabs with a bytes budget."""

    def __init__(self, capacity_bytes: int = 256 << 20):
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.capacity_bytes = int(capacity_bytes)
        self._lock = threading.Lock()
        self._slabs: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bypasses = 0

    def read(self, source, lo: int, hi: int) -> np.ndarray:
        """``source.read_packed(lo, hi)`` through the cache.

        Returns a read-only slab; callers must not mutate it (the scan and
        GRM only ever stage it to device).
        """
        key_fn = getattr(source, "packed_cache_key", None)
        if key_fn is None:
            with self._lock:
                self.bypasses += 1
            return np.asarray(source.read_packed(lo, hi))
        key = (key_fn(), int(lo), int(hi))
        with self._lock:
            slab = self._slabs.get(key)
            if slab is not None:
                self._slabs.move_to_end(key)
                self.hits += 1
                return slab
            self.misses += 1
        # Read outside the lock: concurrent DecodePool workers may race on a
        # miss and read twice; both insert the same bytes, which is benign.
        slab = np.array(source.read_packed(lo, hi), dtype=np.uint8, copy=True)
        slab.setflags(write=False)
        with self._lock:
            if key not in self._slabs and slab.nbytes <= self.capacity_bytes:
                self._slabs[key] = slab
                self._bytes += slab.nbytes
                self._evict_locked()
        return slab

    def _evict_locked(self) -> None:
        while self._bytes > self.capacity_bytes and self._slabs:
            _, old = self._slabs.popitem(last=False)
            self._bytes -= old.nbytes
            self.evictions += 1

    def resize(self, capacity_bytes: int) -> None:
        with self._lock:
            self.capacity_bytes = int(capacity_bytes)
            self._evict_locked()

    def clear(self) -> None:
        with self._lock:
            self._slabs.clear()
            self._bytes = 0

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._slabs),
                "bytes": self._bytes,
                "capacity_bytes": self.capacity_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "bypasses": self.bypasses,
            }


_default = PackedSlabCache()


def default_cache() -> PackedSlabCache:
    return _default


def configure_default(capacity_mb: int) -> PackedSlabCache:
    """Resize the shared cache (``--packed-cache-mb``).  Resizing preserves
    resident slabs that still fit, so a serve process re-planning per request
    keeps its warm windows."""
    _default.resize(int(capacity_mb) << 20)
    return _default


def read_packed_cached(source, lo: int, hi: int) -> np.ndarray:
    return _default.read(source, lo, hi)
