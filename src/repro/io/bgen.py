"""Minimal BGEN v1.2 reader/writer (layout 2, biallelic diploid unphased).

This is the subset that imputation pipelines (IMPUTE4/qctool/bgenix) emit for
UK-Biobank-style data: layout-2 blocks, zlib (or uncompressed) probability
payloads, B = 8 or 16 probability bits, diploid unphased samples.  The
reader converts genotype probabilities to expected alt-allele (allele 2)
dosage; hard-called inputs round-trip exactly through the writer.

Reference: www.well.ox.ac.uk/~gav/bgen_format/spec/v1.2.html
"""
from __future__ import annotations

import struct
import threading
import zlib
from dataclasses import dataclass, field

import numpy as np

__all__ = ["BgenFile", "write_bgen"]

_MAGIC = b"bgen"
MISSING = -9.0


@dataclass
class _Variant:
    ident: str
    rsid: str
    chrom: str
    pos: int
    alleles: list[str]
    data_offset: int      # file offset of the genotype data block
    compressed_len: int
    uncompressed_len: int


class BgenFile:
    """Index-on-open streaming reader.

    The variant directory is scanned once at open (cheap: header fields only,
    probability payloads are skipped via their length fields), after which
    ``read_dosages(lo, hi)`` decompresses just the requested marker range.
    """

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "rb")
        # seek+read on the shared handle must be atomic: prefetch workers
        # decode different marker ranges of this file concurrently.
        self._lock = threading.Lock()
        header = self._f.read(4)
        (first_variant_offset,) = struct.unpack("<I", header)
        (h_len, n_variants, n_samples) = struct.unpack("<III", self._f.read(12))
        magic = self._f.read(4)
        if magic != _MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        free_len = h_len - 20
        self._f.seek(free_len, 1)
        (flags,) = struct.unpack("<I", self._f.read(4))
        self.compression = flags & 0x3
        self.layout = (flags >> 2) & 0xF
        has_sample_ids = bool(flags >> 31)
        if self.layout != 2:
            raise NotImplementedError(f"layout {self.layout}; only layout 2 supported")
        if self.compression not in (0, 1):
            raise NotImplementedError("only zlib / uncompressed payloads supported")
        self.n_samples = n_samples
        self.n_markers = n_variants
        self.sample_ids: list[str] = []
        if has_sample_ids:
            (_blk_len, n_ids) = struct.unpack("<II", self._f.read(8))
            for _ in range(n_ids):
                (slen,) = struct.unpack("<H", self._f.read(2))
                self.sample_ids.append(self._f.read(slen).decode())
        else:
            self.sample_ids = [f"S{i:06d}" for i in range(n_samples)]
        # Scan the variant directory.
        self._f.seek(first_variant_offset + 4)
        self.variants: list[_Variant] = []
        for _ in range(n_variants):
            self.variants.append(self._read_variant_header())
        self._f.seek(0)

    def _read_str16(self) -> str:
        (n,) = struct.unpack("<H", self._f.read(2))
        return self._f.read(n).decode()

    def _read_variant_header(self) -> _Variant:
        ident = self._read_str16()
        rsid = self._read_str16()
        chrom = self._read_str16()
        (pos, n_alleles) = struct.unpack("<IH", self._f.read(6))
        alleles = []
        for _ in range(n_alleles):
            (alen,) = struct.unpack("<I", self._f.read(4))
            alleles.append(self._f.read(alen).decode())
        (c_len,) = struct.unpack("<I", self._f.read(4))
        if self.compression:
            (d_len,) = struct.unpack("<I", self._f.read(4))
            payload_len = c_len - 4
        else:
            d_len = c_len
            payload_len = c_len
        data_offset = self._f.tell()
        self._f.seek(payload_len, 1)
        return _Variant(ident, rsid, chrom, pos, alleles, data_offset, payload_len, d_len)

    @property
    def marker_ids(self) -> list[str]:
        return [v.rsid for v in self.variants]

    def read_dosages(self, lo: int, hi: int) -> np.ndarray:
        """Expected allele-2 dosage ``(hi-lo, N) float32``; missing -> -9."""
        out = np.empty((hi - lo, self.n_samples), np.float32)
        for row, idx in enumerate(range(lo, hi)):
            out[row] = self._decode_one(self.variants[idx])
        return out

    def read_packed(self, lo: int, hi: int):
        raise NotImplementedError("BGEN stores probabilities; no 2-bit fast path")

    def _decode_one(self, v: _Variant) -> np.ndarray:
        with self._lock:
            self._f.seek(v.data_offset)
            raw = self._f.read(v.compressed_len)
        if self.compression == 1:
            raw = zlib.decompress(raw, bufsize=v.uncompressed_len)
        (n_samples, n_alleles, min_pl, max_pl) = struct.unpack("<IHBB", raw[:8])
        if n_alleles != 2 or min_pl != 2 or max_pl != 2:
            raise NotImplementedError("only biallelic diploid blocks supported")
        ploidy_missing = np.frombuffer(raw, np.uint8, n_samples, 8)
        off = 8 + n_samples
        phased, bits = raw[off], raw[off + 1]
        if phased != 0:
            raise NotImplementedError("only unphased blocks supported")
        off += 2
        if bits == 8:
            probs = np.frombuffer(raw, np.uint8, 2 * n_samples, off).astype(np.float32)
            scale = 255.0
        elif bits == 16:
            probs = np.frombuffer(raw, np.uint16, 2 * n_samples, off).astype(np.float32)
            scale = 65535.0
        else:
            raise NotImplementedError(f"B={bits} probability bits unsupported")
        p = probs.reshape(n_samples, 2) / scale  # columns: P(11), P(12)
        p11, p12 = p[:, 0], p[:, 1]
        p22 = np.clip(1.0 - p11 - p12, 0.0, 1.0)
        dosage = (p12 + 2.0 * p22).astype(np.float32)
        missing = (ploidy_missing & 0x80) != 0
        dosage[missing] = MISSING
        return dosage

    def close(self) -> None:
        self._f.close()


def write_bgen(
    path: str,
    dosages: np.ndarray,
    *,
    sample_ids: list[str] | None = None,
    rsids: list[str] | None = None,
    bits: int = 8,
    compress: bool = True,
) -> str:
    """Write hard-called ``(M, N)`` dosages (ints in {0,1,2}, -9 missing) as a
    BGEN v1.2 layout-2 file.  Probabilities are one-hot so the reader's
    expected dosage reproduces the input exactly (up to the stated bit depth).
    """
    d = np.asarray(dosages)
    m, n = d.shape
    sample_ids = sample_ids or [f"S{i:06d}" for i in range(n)]
    rsids = rsids or [f"rs{i:08d}" for i in range(m)]

    buf = bytearray()
    sample_block = bytearray()
    for s in sample_ids:
        enc = s.encode()
        sample_block += struct.pack("<H", len(enc)) + enc
    sample_block = struct.pack("<II", len(sample_block) + 8, n) + bytes(sample_block)

    h_len = 20
    flags = (1 if compress else 0) | (2 << 2) | (1 << 31)
    header = struct.pack("<III", h_len, m, n) + _MAGIC + struct.pack("<I", flags)
    # Spec: offset of the first variant block relative to byte 4 of the file.
    first_variant_offset = h_len + len(sample_block)
    buf += struct.pack("<I", first_variant_offset)
    buf += header
    buf += sample_block

    scale = 255 if bits == 8 else 65535
    pack_fmt = np.uint8 if bits == 8 else np.uint16
    for i in range(m):
        for s, text in (("var%d" % i, None), (rsids[i], None), ("1", None)):
            enc = s.encode()
            buf += struct.pack("<H", len(enc)) + enc
        buf += struct.pack("<IH", i + 1, 2)
        for allele in ("A", "G"):
            enc = allele.encode()
            buf += struct.pack("<I", len(enc)) + enc
        row = d[i]
        missing = row == -9
        p11 = np.where(row == 0, scale, 0).astype(pack_fmt)
        p12 = np.where(row == 1, scale, 0).astype(pack_fmt)
        p11[missing] = 0
        p12[missing] = 0
        ploidy = np.full(n, 2, np.uint8)
        ploidy[missing] |= 0x80
        payload = (
            struct.pack("<IHBB", n, 2, 2, 2)
            + ploidy.tobytes()
            + struct.pack("<BB", 0, bits)
            + np.stack([p11, p12], axis=1).tobytes()
        )
        if compress:
            comp = zlib.compress(payload, 6)
            buf += struct.pack("<II", len(comp) + 4, len(payload)) + comp
        else:
            buf += struct.pack("<I", len(payload)) + payload

    with open(path, "wb") as f:
        f.write(bytes(buf))
    return path
