"""Multi-file genotype source: N per-chromosome shards, one global index.

Real cohorts ship split by chromosome (``cohort_chr1.bed .. cohort_chr22.bed``
— the layout UK Biobank, imputation servers, and qctool all emit), so the
scan must treat a fileset as one contiguous marker axis.  ``MultiFileSource``
wraps any mix of backends behind the unchanged ``GenotypeSource`` protocol:

    n_samples, n_markers, sample_ids, marker_ids
    read_dosages(lo, hi) / read_packed(lo, hi)   — global marker indexing

plus ``shard_boundaries``, which ``runtime.prefetch.BatchPlanner`` uses to
keep every scan batch inside one file: each work item is then a single
contiguous read from a single container, and the prefetch worker pool
streams batches from *different* chromosomes concurrently (DESIGN.md §3).

All shards must agree on the sample axis (count and ids, in order) —
per-chromosome filesets of one cohort always do; anything else is a data
bug worth failing loudly on.
"""
from __future__ import annotations

import glob as _glob
import os
import re
from typing import Any, Sequence

import numpy as np

__all__ = ["MultiFileSource", "natural_key", "expand_genotype_paths"]


def natural_key(path: str) -> tuple:
    """Numeric-aware sort key so ``chr2`` orders before ``chr10``."""
    return tuple(
        int(tok) if tok.isdigit() else tok.lower()
        for tok in re.split(r"(\d+)", path)
    )


def expand_genotype_paths(spec: str) -> list[str]:
    """``'a.bed,b.bed'`` or ``'cohort_chr*.bed'`` -> ordered path list."""
    if "," in spec:
        return [p.strip() for p in spec.split(",") if p.strip()]
    # A literal file whose name contains glob metacharacters wins over
    # pattern interpretation (e.g. 'data[2024].bed').
    if any(ch in spec for ch in "*?[") and not os.path.exists(spec):
        matches = sorted(_glob.glob(spec), key=natural_key)
        if not matches:
            raise FileNotFoundError(f"genotype glob matched nothing: {spec}")
        return matches
    return [spec]


def _describe(source: Any) -> str:
    """Short identity for error messages (dataclass reprs embed whole
    sample/marker tables)."""
    for attr in ("path", "bed_path"):
        p = getattr(source, attr, None)
        if p:
            return str(p)
    return type(source).__name__


class MultiFileSource:
    """Concatenate genotype shards along the marker axis (samples shared)."""

    def __init__(self, sources: Sequence[Any]):
        if not sources:
            raise ValueError("MultiFileSource needs at least one shard")
        self.sources = list(sources)
        first = self.sources[0]
        for s in self.sources[1:]:
            if s.n_samples != first.n_samples:
                raise ValueError(
                    f"shard sample counts differ: {first.n_samples} vs {s.n_samples} "
                    f"({_describe(s)})"
                )
            if list(s.sample_ids) != list(first.sample_ids):
                raise ValueError(
                    "shard sample ids differ or are reordered; per-chromosome "
                    "filesets of one cohort must share the sample axis"
                )
        self.n_samples = first.n_samples
        self.sample_ids = list(first.sample_ids)
        counts = [s.n_markers for s in self.sources]
        self.shard_boundaries: tuple[int, ...] = tuple(np.cumsum([0] + counts).tolist())
        self.n_markers = self.shard_boundaries[-1]
        self.marker_ids: list[str] = []
        for s in self.sources:
            self.marker_ids.extend(s.marker_ids)

    @property
    def n_shards(self) -> int:
        return len(self.sources)

    @property
    def supports_packed(self) -> bool:
        """Packed staging needs every shard to speak native 2-bit bytes
        (rows are ceil(N/4) bytes for all shards, so slabs concatenate)."""
        return all(getattr(s, "supports_packed", False) for s in self.sources)

    def packed_cache_key(self) -> tuple:
        keys = []
        for s in self.sources:
            fn = getattr(s, "packed_cache_key", None)
            if fn is None:
                raise ValueError(f"{_describe(s)} has no stable packed identity")
            keys.append(fn())
        return ("multi", tuple(keys))

    def _segments(self, lo: int, hi: int) -> list[tuple[int, int, int]]:
        """Split global [lo, hi) into (shard_id, local_lo, local_hi) runs."""
        if not (0 <= lo <= hi <= self.n_markers):
            raise IndexError(f"marker range [{lo}, {hi}) outside [0, {self.n_markers})")
        bounds = self.shard_boundaries
        segs: list[tuple[int, int, int]] = []
        sid = int(np.searchsorted(bounds, lo, side="right")) - 1
        while lo < hi:
            base, end = bounds[sid], bounds[sid + 1]
            take = min(hi, end)
            segs.append((sid, lo - base, take - base))
            lo = take
            sid += 1
        return segs

    def read_dosages(self, lo: int, hi: int) -> np.ndarray:
        parts = [self.sources[sid].read_dosages(a, b) for sid, a, b in self._segments(lo, hi)]
        return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)

    def read_packed(self, lo: int, hi: int) -> np.ndarray:
        # Rows are ceil(N/4) bytes for every shard (same N), so slabs concat.
        parts = [self.sources[sid].read_packed(a, b) for sid, a, b in self._segments(lo, hi)]
        return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
