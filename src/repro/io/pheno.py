"""Phenotype / covariate tables and sample alignment.

Paper §2.1: "aligns phenotype and covariate tables by sample identifier, and
performs covariate adjustment internally".  Tables are whitespace- or
comma-delimited text with a header row; the sample-id column is ``IID``
(PLINK convention), ``id``, or the first column.  Missing values: ``NA``,
``nan``, ``-9``, empty.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["PhenotypeTable", "align_tables", "read_table"]

_MISSING_TOKENS = {"na", "nan", "-9", "", "."}
_ID_COLUMNS = ("iid", "id", "sample", "sample_id", "eid")


@dataclass
class PhenotypeTable:
    sample_ids: list[str]
    names: list[str]          # column (trait / covariate) names
    values: np.ndarray        # (n_samples, n_columns) float32, NaN missing

    @property
    def n_samples(self) -> int:
        return len(self.sample_ids)

    @property
    def n_columns(self) -> int:
        return len(self.names)

    def column(self, name: str) -> np.ndarray:
        return self.values[:, self.names.index(name)]


def _sniff_delimiter(header: str) -> str | None:
    return "," if ("," in header and "\t" not in header) else None


def read_table(path: str) -> PhenotypeTable:
    """Parse a phenotype/covariate table; drops the FID column if present."""
    with open(path) as f:
        header_line = f.readline().rstrip("\n")
        delim = _sniff_delimiter(header_line)
        header = [h.strip() for h in (header_line.split(delim) if delim else header_line.split())]
        lower = [h.lower() for h in header]
        id_col = next((lower.index(c) for c in _ID_COLUMNS if c in lower), 0)
        skip_cols = {id_col}
        if "fid" in lower:
            skip_cols.add(lower.index("fid"))
        value_cols = [i for i in range(len(header)) if i not in skip_cols]
        names = [header[i] for i in value_cols]
        sample_ids: list[str] = []
        rows: list[list[float]] = []
        for line in f:
            parts = line.split(delim) if delim else line.split()
            if not parts or not "".join(parts).strip():
                continue
            sample_ids.append(parts[id_col].strip())
            row = []
            for i in value_cols:
                tok = parts[i].strip().lower() if i < len(parts) else ""
                row.append(np.nan if tok in _MISSING_TOKENS else float(parts[i]))
            rows.append(row)
    values = np.asarray(rows, np.float32).reshape(len(rows), len(names))
    return PhenotypeTable(sample_ids=sample_ids, names=names, values=values)


def align_tables(
    genotype_sample_ids: list[str],
    phenotypes: PhenotypeTable,
    covariates: PhenotypeTable | None = None,
    *,
    require_complete: bool = False,
) -> tuple[np.ndarray, np.ndarray | None, np.ndarray]:
    """Intersect sample sets and order table rows to match the genotype file.

    Returns ``(Y, C, keep_mask)``:
      Y (n_kept, P) phenotypes, C (n_kept, q) covariates or None, and a
      boolean mask over genotype samples marking the kept rows.  Samples
      missing from either table are dropped.  With ``require_complete`` any
      sample with a missing covariate is dropped too (phenotype NaNs are
      allowed and handled by per-trait masking downstream).
    """
    pheno_index = {s: i for i, s in enumerate(phenotypes.sample_ids)}
    cov_index = {s: i for i, s in enumerate(covariates.sample_ids)} if covariates else None

    keep = np.zeros(len(genotype_sample_ids), bool)
    p_rows: list[int] = []
    c_rows: list[int] = []
    for g_idx, sid in enumerate(genotype_sample_ids):
        p_i = pheno_index.get(sid)
        if p_i is None:
            continue
        if cov_index is not None:
            c_i = cov_index.get(sid)
            if c_i is None:
                continue
            if require_complete and np.isnan(covariates.values[c_i]).any():
                continue
            c_rows.append(c_i)
        keep[g_idx] = True
        p_rows.append(p_i)
    y = phenotypes.values[p_rows]
    c = covariates.values[c_rows] if cov_index is not None else None
    if c is not None and np.isnan(c).any():
        # Mean-impute remaining covariate gaps (standard screening practice).
        col_mean = np.nanmean(c, axis=0)
        c = np.where(np.isnan(c), col_mean[None, :], c)
    return y, c, keep
