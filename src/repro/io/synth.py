"""Synthetic cohort generation: the test/benchmark substrate.

Everything the paper's benchmark needs, scaled down or up:
  * genotypes with a realistic MAF spectrum (beta-shaped), missingness,
    optional related pairs (for the kinship/exclusion tests),
  * a covariate matrix (age/sex/PC-like columns),
  * a quantitative phenotype panel with *planted* marker effects so power
    and calibration are checkable, plus pure-null columns for lambda_GC.

Returned effects are ground truth for tests: every planted (marker, trait,
beta) triple should surface in the scan's top hits.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "SyntheticCohort",
    "make_cohort",
    "make_structured_cohort",
    "write_cohort_files",
    "write_split_plink",
]


@dataclass
class SyntheticCohort:
    dosages: np.ndarray             # (M, N) int8, -9 missing
    covariates: np.ndarray          # (N, q) float32
    phenotypes: np.ndarray          # (N, P) float32
    sample_ids: list[str]
    marker_ids: list[str]
    maf: np.ndarray                 # (M,)
    effects: list[tuple[int, int, float]]  # (marker, trait, beta)
    related_pairs: list[tuple[int, int]] = field(default_factory=list)
    populations: np.ndarray | None = None  # (N,) int subpopulation labels
    h2: float | None = None                # planted polygenic heritability

    @property
    def shape(self) -> tuple[int, int, int]:
        m, n = self.dosages.shape
        return m, n, self.phenotypes.shape[1]


def make_cohort(
    *,
    n_samples: int = 512,
    n_markers: int = 256,
    n_traits: int = 8,
    n_covariates: int = 3,
    n_causal: int = 6,
    effect_size: float = 0.5,
    missing_rate: float = 0.01,
    n_related_pairs: int = 0,
    maf_range: tuple[float, float] = (0.05, 0.5),
    seed: int = 0,
) -> SyntheticCohort:
    rng = np.random.default_rng(seed)
    maf = rng.uniform(*maf_range, size=n_markers).astype(np.float32)
    dosages = rng.binomial(2, maf[:, None], size=(n_markers, n_samples)).astype(np.int8)

    # Related pairs: copy a sample's genome with per-marker "mendelian" noise,
    # overwriting the tail of the cohort (kinship ~ 0.35-0.45, i.e. 1st degree).
    related_pairs: list[tuple[int, int]] = []
    for k in range(n_related_pairs):
        src = k
        dst = n_samples - 1 - k
        if dst <= src:
            break
        copy = dosages[:, src].copy()
        flip = rng.random(n_markers) < 0.12
        copy[flip] = rng.binomial(2, maf[flip]).astype(np.int8)
        dosages[:, dst] = copy
        related_pairs.append((src, dst))

    covariates = rng.normal(size=(n_samples, n_covariates)).astype(np.float32)

    g_float = dosages.astype(np.float32)
    g_std = (g_float - g_float.mean(axis=1, keepdims=True))
    g_std /= np.maximum(g_std.std(axis=1, keepdims=True), 1e-6)

    phenotypes = rng.normal(size=(n_samples, n_traits)).astype(np.float32)
    cov_load = rng.normal(scale=0.5, size=(n_covariates, n_traits)).astype(np.float32)
    phenotypes += covariates @ cov_load

    effects: list[tuple[int, int, float]] = []
    causal_markers = rng.choice(n_markers, size=min(n_causal, n_markers), replace=False)
    for i, m in enumerate(causal_markers):
        trait = int(i % n_traits)
        beta = float(effect_size * (1.0 if i % 2 == 0 else -1.0))
        phenotypes[:, trait] += beta * g_std[m]
        effects.append((int(m), trait, beta))

    if missing_rate > 0:
        miss = rng.random(dosages.shape) < missing_rate
        dosages[miss] = -9

    return SyntheticCohort(
        dosages=dosages,
        covariates=covariates,
        phenotypes=phenotypes,
        sample_ids=[f"S{i:06d}" for i in range(n_samples)],
        marker_ids=[f"rs{i:08d}" for i in range(n_markers)],
        maf=maf,
        effects=effects,
        related_pairs=related_pairs,
    )


def make_structured_cohort(
    *,
    n_samples: int = 160,
    n_markers: int = 120,
    n_traits: int = 4,
    n_covariates: int = 2,
    n_pops: int = 2,
    fst: float = 0.1,
    h2: float = 0.4,
    n_causal: int = 3,
    effect_size: float = 0.5,
    maf_range: tuple[float, float] = (0.1, 0.5),
    seed: int = 0,
) -> SyntheticCohort:
    """A cohort with *population structure* and a *polygenic background* —
    the confounded workload the mixed model exists for.

    Genotypes follow the Balding-Nichols model: each marker has an
    ancestral frequency, and each of ``n_pops`` subpopulations draws its
    own frequency from ``Beta`` with divergence ``fst``.  Phenotypes carry
    a polygenic term ``Z a`` built from ALL markers (variance ``h2``) plus
    ``N(0, 1 - h2)`` noise, so the genotype-derived GRM is the true trait
    covariance — an OLS scan inflates (lambda_GC >> 1) while the LMM scan
    calibrates.  Planted fixed effects ride on top for power checks.

    No missingness by design: the oracle tests compare against exact GLS,
    and imputation semantics would blur the comparison.
    """
    rng = np.random.default_rng(seed)
    p_anc = rng.uniform(*maf_range, size=n_markers)
    a = p_anc * (1.0 - fst) / fst
    b = (1.0 - p_anc) * (1.0 - fst) / fst
    p_pop = rng.beta(a[None, :], b[None, :], size=(n_pops, n_markers))
    p_pop = np.clip(p_pop, 0.01, 0.99)
    pops = rng.integers(0, n_pops, size=n_samples)
    dosages = rng.binomial(2, p_pop[pops].T).astype(np.int8)  # (M, N)

    g_float = dosages.astype(np.float64)
    g_std = g_float - g_float.mean(axis=1, keepdims=True)
    g_std /= np.maximum(g_float.std(axis=1), 1e-9)[:, None]

    covariates = rng.normal(size=(n_samples, n_covariates)).astype(np.float32)
    # Polygenic background: u = Z^T a with Var(u_i) ~ h2 across samples.
    poly = g_std.T @ rng.normal(scale=np.sqrt(h2 / n_markers), size=(n_markers, n_traits))
    noise = rng.normal(scale=np.sqrt(max(1.0 - h2, 1e-6)), size=(n_samples, n_traits))
    cov_load = rng.normal(scale=0.3, size=(n_covariates, n_traits))
    phenotypes = (poly + noise + covariates.astype(np.float64) @ cov_load).astype(np.float32)

    effects: list[tuple[int, int, float]] = []
    causal = rng.choice(n_markers, size=min(n_causal, n_markers), replace=False)
    for i, m in enumerate(causal):
        trait = int(i % n_traits)
        beta = float(effect_size * (1.0 if i % 2 == 0 else -1.0))
        phenotypes[:, trait] += (beta * g_std[m]).astype(np.float32)
        effects.append((int(m), trait, beta))

    af = g_float.mean(axis=1) / 2.0
    return SyntheticCohort(
        dosages=dosages,
        covariates=covariates,
        phenotypes=phenotypes,
        sample_ids=[f"S{i:06d}" for i in range(n_samples)],
        marker_ids=[f"rs{i:08d}" for i in range(n_markers)],
        maf=np.minimum(af, 1.0 - af).astype(np.float32),
        effects=effects,
        populations=pops,
        h2=h2,
    )


def write_cohort_files(cohort: SyntheticCohort, stem: str) -> dict[str, str]:
    """Materialize a cohort as on-disk PLINK + BGEN + tables (for IO tests
    and the quickstart example).  Returns the path map."""
    from repro.io.bgen import write_bgen
    from repro.io.plink import write_plink

    paths: dict[str, str] = {}
    paths["bed"] = write_plink(stem, cohort.dosages, sample_ids=cohort.sample_ids)
    paths["bgen"] = write_bgen(
        stem + ".bgen",
        cohort.dosages,
        sample_ids=cohort.sample_ids,
        rsids=cohort.marker_ids,
    )
    pheno_path = stem + ".pheno.tsv"
    with open(pheno_path, "w") as f:
        f.write("FID\tIID\t" + "\t".join(f"trait{j}" for j in range(cohort.phenotypes.shape[1])) + "\n")
        for i, sid in enumerate(cohort.sample_ids):
            vals = "\t".join(f"{v:.6g}" for v in cohort.phenotypes[i])
            f.write(f"{sid}\t{sid}\t{vals}\n")
    paths["pheno"] = pheno_path
    cov_path = stem + ".cov.tsv"
    with open(cov_path, "w") as f:
        f.write("FID\tIID\t" + "\t".join(f"cov{j}" for j in range(cohort.covariates.shape[1])) + "\n")
        for i, sid in enumerate(cohort.sample_ids):
            vals = "\t".join(f"{v:.6g}" for v in cohort.covariates[i])
            f.write(f"{sid}\t{sid}\t{vals}\n")
    paths["cov"] = cov_path
    return paths


def write_split_plink(
    cohort: SyntheticCohort, stem: str, n_shards: int = 3
) -> list[str]:
    """Write the cohort as a per-chromosome PLINK fileset
    (``<stem>_chr1.bed`` .. ``<stem>_chr<n>.bed``) — the multi-file layout
    real cohorts ship in.  Shard sizes are deliberately uneven so tests
    exercise batch planning against ragged boundaries; returns bed paths
    in chromosome order."""
    from repro.io.plink import Marker, write_plink

    m = cohort.dosages.shape[0]
    if not 1 <= n_shards <= m:
        raise ValueError(f"cannot split {m} markers into {n_shards} shards")
    # Ragged but deterministic: proportions 1x, 2x, 1x, 2x, ... with every
    # shard guaranteed >= 1 marker (an empty .bed is unreadable).
    weights = np.array([1 + (i % 2) for i in range(n_shards)], np.float64)
    extra = m - n_shards
    alloc = np.floor(extra * weights / weights.sum()).astype(int)
    alloc[: extra - alloc.sum()] += 1
    bounds = np.concatenate([[0], np.cumsum(1 + alloc)])
    paths: list[str] = []
    for sid, (a, b) in enumerate(zip(bounds[:-1], bounds[1:])):
        markers = [
            Marker(str(sid + 1), cohort.marker_ids[i], 0.0, i - a + 1, "A", "G")
            for i in range(a, b)
        ]
        paths.append(
            write_plink(
                f"{stem}_chr{sid + 1}",
                cohort.dosages[a:b],
                sample_ids=cohort.sample_ids,
                markers=markers,
            )
        )
    return paths
