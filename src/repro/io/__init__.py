"""Genotype / phenotype IO substrate.

Three genotype backends (paper §2.1: "supports NumPy, PLINK, and BGEN
genotype inputs") behind one streaming interface, plus phenotype/covariate
table alignment and synthetic-cohort generation for tests and examples.

All backends expose the same protocol (``GenotypeSource``):

    n_samples, n_markers, sample_ids, marker_ids
    read_dosages(lo, hi)  -> int8 (markers, samples), -9 missing
    read_packed(lo, hi)   -> uint8 2-bit packed slab (PLINK native; numpy
                             re-packs hardcalls; BGEN raises)
    supports_packed       -> True when 2-bit bytes are the *native* layout,
                             enabling packed H2D staging (DESIGN.md §17)

Packed slabs flow through the shared ``PackedSlabCache`` so scan, GRM, and
serve warm windows share one read per (source, batch).
"""
from repro.io.plink import PlinkBed, write_plink
from repro.io.bgen import BgenFile, write_bgen
from repro.io.numpy_io import NumpyGenotypes
from repro.io.multifile import MultiFileSource, expand_genotype_paths
from repro.io.packed_cache import PackedSlabCache, default_cache, read_packed_cached
from repro.io.pheno import PhenotypeTable, align_tables, read_table
from repro.io.synth import SyntheticCohort, make_cohort

__all__ = [
    "PackedSlabCache",
    "default_cache",
    "read_packed_cached",
    "PlinkBed",
    "write_plink",
    "BgenFile",
    "write_bgen",
    "NumpyGenotypes",
    "MultiFileSource",
    "PhenotypeTable",
    "align_tables",
    "read_table",
    "SyntheticCohort",
    "make_cohort",
    "open_genotypes",
]


def _open_one(path: str):
    if path.endswith(".bed"):
        return PlinkBed(path)
    if path.endswith(".bgen"):
        return BgenFile(path)
    if path.endswith((".npy", ".npz")):
        return NumpyGenotypes(path)
    raise ValueError(f"unrecognized genotype container: {path}")


def open_genotypes(path: str):
    """Open one container or a per-chromosome fileset.

    Dispatch on file suffix: ``.bed`` -> PLINK, ``.bgen`` -> BGEN,
    ``.npy``/``.npz`` -> NumPy.  A glob pattern (``cohort_chr*.bed``,
    numeric-aware ordering so chr2 < chr10) or a comma-separated list
    (``chr1.bed,chr2.bed``) opens every match as one ``MultiFileSource``
    with contiguous global marker indexing.
    """
    paths = expand_genotype_paths(str(path))
    if len(paths) == 1:
        return _open_one(paths[0])
    return MultiFileSource([_open_one(p) for p in paths])
