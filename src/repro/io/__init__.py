"""Genotype / phenotype IO substrate.

Three genotype backends (paper §2.1: "supports NumPy, PLINK, and BGEN
genotype inputs") behind one streaming interface, plus phenotype/covariate
table alignment and synthetic-cohort generation for tests and examples.

All backends expose the same protocol (``GenotypeSource``):

    n_samples, n_markers, sample_ids, marker_ids
    read_dosages(lo, hi)  -> int8 (markers, samples), -9 missing
    read_packed(lo, hi)   -> uint8 2-bit packed slab for the fused kernel
                             (PLINK only; others raise)
"""
from repro.io.plink import PlinkBed, write_plink
from repro.io.bgen import BgenFile, write_bgen
from repro.io.numpy_io import NumpyGenotypes
from repro.io.pheno import PhenotypeTable, align_tables
from repro.io.synth import SyntheticCohort, make_cohort

__all__ = [
    "PlinkBed",
    "write_plink",
    "BgenFile",
    "write_bgen",
    "NumpyGenotypes",
    "PhenotypeTable",
    "align_tables",
    "SyntheticCohort",
    "make_cohort",
    "open_genotypes",
]


def open_genotypes(path: str):
    """Dispatch on file suffix: ``.bed`` -> PLINK, ``.bgen`` -> BGEN,
    ``.npy``/``.npz`` -> NumPy."""
    p = str(path)
    if p.endswith(".bed"):
        return PlinkBed(p)
    if p.endswith(".bgen"):
        return BgenFile(p)
    if p.endswith((".npy", ".npz")):
        return NumpyGenotypes(p)
    raise ValueError(f"unrecognized genotype container: {p}")
