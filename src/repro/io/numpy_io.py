"""NumPy genotype backend: ``.npy``/``.npz`` dosage matrices.

This is the entry point the paper highlights for representation-learning
workflows where dosages were already extracted upstream.  Accepts

    .npy  — (M, N) int8/float dosage matrix (markers x samples), -9/NaN missing
    .npz  — keys: ``dosages`` (required), ``sample_ids``, ``marker_ids``

Memory-mapped where possible so genome-scale matrices stream.
"""
from __future__ import annotations

import numpy as np

__all__ = ["NumpyGenotypes"]


class NumpyGenotypes:
    # ``read_packed`` re-packs decoded hardcalls on host (and raises on true
    # dosages), so packed *staging* would cost more than it saves — staging
    # negotiation (DESIGN.md §17) keeps numpy sources on the decoded path.
    supports_packed = False

    def __init__(self, path: str):
        self.path = path
        if path.endswith(".npz"):
            archive = np.load(path, allow_pickle=False)
            self._data = archive["dosages"]
            sample_ids = archive.get("sample_ids")
            marker_ids = archive.get("marker_ids")
        else:
            self._data = np.load(path, mmap_mode="r", allow_pickle=False)
            sample_ids = marker_ids = None
        if self._data.ndim != 2:
            raise ValueError(f"{path}: expected (markers, samples) matrix")
        self.n_markers, self.n_samples = self._data.shape
        self.sample_ids = (
            [str(s) for s in sample_ids]
            if sample_ids is not None
            else [f"S{i:06d}" for i in range(self.n_samples)]
        )
        self.marker_ids = (
            [str(s) for s in marker_ids]
            if marker_ids is not None
            else [f"rs{i:08d}" for i in range(self.n_markers)]
        )

    def read_dosages(self, lo: int, hi: int) -> np.ndarray:
        return np.asarray(self._data[lo:hi])

    def read_packed(self, lo: int, hi: int):
        from repro.io.plink import pack_dosages

        block = np.asarray(self._data[lo:hi])
        if not np.issubdtype(block.dtype, np.integer):
            rounded = np.where(np.isnan(block), -9, np.rint(block)).astype(np.int8)
            if not np.isin(rounded, (-9, 0, 1, 2)).all():
                raise ValueError("non-hardcall dosages have no 2-bit packing")
            block = rounded
        return pack_dosages(block)
