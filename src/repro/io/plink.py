"""PLINK 1 binary (.bed/.bim/.fam) reader and writer.

Format (SNP-major .bed, the only variant PLINK 1.9 writes):

    bytes 0-2: magic 0x6C 0x1B 0x01
    per marker: ceil(N/4) bytes; sample i lives in byte i//4 at bit
    offset 2*(i%4) (LSB first).  2-bit codes:

        0b00  hom A1      -> dosage 2   (A1 allele count)
        0b01  missing     -> -9
        0b10  het         -> dosage 1
        0b11  hom A2      -> dosage 0

The reader is a zero-copy ``np.memmap`` over the marker-major slab so a
genome-scale file (8.9M x 23k ~ 51 GB packed) is streamed, never resident.
``read_packed`` hands slabs straight to the fused Pallas kernel without
decoding; ``read_dosages`` decodes on the host via a 256x4 lookup table
(vectorized ``np.take``) for the reference path.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

__all__ = ["PlinkBed", "write_plink", "decode_packed", "pack_dosages", "BED_MAGIC"]

BED_MAGIC = b"\x6c\x1b\x01"
MISSING = -9

# 256 x 4 lookup: byte value -> 4 dosages (sample order LSB-first).
_CODE_TO_DOSAGE = np.array([2, MISSING, 1, 0], dtype=np.int8)
_BYTE_LUT = np.zeros((256, 4), dtype=np.int8)
for _b in range(256):
    for _k in range(4):
        _BYTE_LUT[_b, _k] = _CODE_TO_DOSAGE[(_b >> (2 * _k)) & 0b11]

# Inverse: dosage -> 2-bit code.
_DOSAGE_TO_CODE = {2: 0b00, MISSING: 0b01, 1: 0b10, 0: 0b11}


def decode_packed(packed: np.ndarray, n_samples: int) -> np.ndarray:
    """``(M, ceil(N/4)) uint8 -> (M, N) int8`` dosages with -9 missing."""
    out = _BYTE_LUT[packed]  # (M, bytes, 4)
    return out.reshape(packed.shape[0], -1)[:, :n_samples]


def pack_dosages(dosages: np.ndarray) -> np.ndarray:
    """``(M, N) int dosages (-9 missing) -> (M, ceil(N/4)) uint8`` packed."""
    d = np.asarray(dosages)
    m, n = d.shape
    n_pad = (-n) % 4
    if n_pad:
        # Pad with hom A2 (code 0b11 -> dosage 0) like PLINK does.
        d = np.concatenate([d, np.zeros((m, n_pad), d.dtype)], axis=1)
    code = np.empty(d.shape, np.uint8)
    code[d == 2] = 0b00
    code[d == MISSING] = 0b01
    code[d == 1] = 0b10
    code[d == 0] = 0b11
    code = code.reshape(m, -1, 4)
    packed = (
        code[:, :, 0]
        | (code[:, :, 1] << 2)
        | (code[:, :, 2] << 4)
        | (code[:, :, 3] << 6)
    )
    return packed.astype(np.uint8)


@dataclass
class Marker:
    chrom: str
    snp_id: str
    cm: float
    pos: int
    a1: str
    a2: str


@dataclass
class PlinkBed:
    """Streaming reader over a .bed/.bim/.fam fileset."""

    # PLINK bytes are the native layout: ``read_packed`` is a memmap view,
    # so packed staging (DESIGN.md §17) can make 2-bit bytes the H2D currency.
    supports_packed = True

    bed_path: str
    n_samples: int = field(init=False)
    n_markers: int = field(init=False)
    sample_ids: list[str] = field(init=False)
    markers: list[Marker] = field(init=False)

    def __post_init__(self) -> None:
        stem = self.bed_path[: -len(".bed")]
        self.sample_ids = []
        with open(stem + ".fam") as f:
            for line in f:
                parts = line.split()
                if parts:
                    self.sample_ids.append(parts[1])
        self.markers = []
        with open(stem + ".bim") as f:
            for line in f:
                parts = line.split()
                if parts:
                    self.markers.append(
                        Marker(parts[0], parts[1], float(parts[2]), int(parts[3]), parts[4], parts[5])
                    )
        self.n_samples = len(self.sample_ids)
        self.n_markers = len(self.markers)
        self._bytes_per_marker = (self.n_samples + 3) // 4
        with open(self.bed_path, "rb") as f:
            magic = f.read(3)
        if magic != BED_MAGIC:
            raise ValueError(
                f"{self.bed_path}: bad magic {magic!r} (need SNP-major PLINK 1 bed)"
            )
        expected = 3 + self._bytes_per_marker * self.n_markers
        actual = os.path.getsize(self.bed_path)
        if actual != expected:
            raise ValueError(
                f"{self.bed_path}: size {actual} != expected {expected} "
                f"for {self.n_markers} markers x {self.n_samples} samples"
            )
        self._mmap = np.memmap(self.bed_path, dtype=np.uint8, mode="r", offset=3)

    @property
    def marker_ids(self) -> list[str]:
        return [m.snp_id for m in self.markers]

    def read_packed(self, lo: int, hi: int) -> np.ndarray:
        """Raw 2-bit slab ``(hi-lo, ceil(N/4)) uint8`` — the fused-kernel path."""
        bpm = self._bytes_per_marker
        slab = self._mmap[lo * bpm : hi * bpm]
        return np.asarray(slab).reshape(hi - lo, bpm)

    def packed_cache_key(self) -> tuple:
        """Stable identity for the shared packed-slab cache: same fileset on
        disk (by realpath/size/mtime) -> same cached slabs across source
        instances, which is what lets serve warm windows and resumed scans
        reuse reads."""
        st = os.stat(self.bed_path)
        return ("plink", os.path.realpath(self.bed_path), st.st_size, st.st_mtime_ns)

    def read_dosages(self, lo: int, hi: int) -> np.ndarray:
        """Decoded ``(hi-lo, N) int8`` dosages, -9 missing — the reference path."""
        return decode_packed(self.read_packed(lo, hi), self.n_samples)


def write_plink(
    stem: str,
    dosages: np.ndarray,
    *,
    sample_ids: list[str] | None = None,
    markers: list[Marker] | None = None,
) -> str:
    """Write ``(M, N)`` dosages as a .bed/.bim/.fam fileset; returns bed path.

    Used by tests (round-trip oracle) and by the synthetic-cohort generator;
    also handy for exporting filtered cohorts.
    """
    d = np.asarray(dosages)
    m, n = d.shape
    sample_ids = sample_ids or [f"S{i:06d}" for i in range(n)]
    markers = markers or [
        Marker("1", f"rs{i:08d}", 0.0, i + 1, "A", "G") for i in range(m)
    ]
    if len(sample_ids) != n or len(markers) != m:
        raise ValueError("sample/marker metadata does not match dosage shape")
    with open(stem + ".fam", "w") as f:
        for s in sample_ids:
            f.write(f"{s} {s} 0 0 0 -9\n")
    with open(stem + ".bim", "w") as f:
        for mk in markers:
            f.write(f"{mk.chrom}\t{mk.snp_id}\t{mk.cm}\t{mk.pos}\t{mk.a1}\t{mk.a2}\n")
    packed = pack_dosages(d)
    with open(stem + ".bed", "wb") as f:
        f.write(BED_MAGIC)
        f.write(packed.tobytes())
    return stem + ".bed"
