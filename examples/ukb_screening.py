"""End-to-end phenotype-rich screening workflow (the paper's production
scenario, scaled to run on CPU): BGEN input, covariate adjustment,
relatedness-aware exclusion, fault-tolerant batched scan with a simulated
mid-scan crash + restart, multivariate omnibus, BH q-values, TSV report.

    PYTHONPATH=src python examples/ukb_screening.py [--traits 256]
"""
import argparse
import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import stats as S
from repro.core.screening import GenomeScan, ScanConfig
from repro.io import bgen, pheno, synth

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--traits", type=int, default=128)
    ap.add_argument("--markers", type=int, default=4_000)
    ap.add_argument("--samples", type=int, default=800)
    args = ap.parse_args()

    workdir = tempfile.mkdtemp(prefix="ukb_screening_")
    cohort = synth.make_cohort(
        n_samples=args.samples, n_markers=args.markers, n_traits=args.traits,
        n_causal=12, effect_size=0.45, missing_rate=0.015,
        n_related_pairs=6, seed=7,
    )
    paths = synth.write_cohort_files(cohort, os.path.join(workdir, "ukb"))
    print(f"[1/4] cohort: {args.markers} markers x {args.samples} samples x "
          f"{args.traits} traits (BGEN: {paths['bgen']})")

    # Align tables by sample id (the BGEN reader carries ids).
    source = bgen.BgenFile(paths["bgen"])
    pt = pheno.read_table(paths["pheno"])
    ct = pheno.read_table(paths["cov"])
    y, c, keep = pheno.align_tables(source.sample_ids, pt, ct)
    assert keep.all()

    ckdir = os.path.join(workdir, "checkpoints")
    config = ScanConfig(
        batch_markers=512, engine="dense", exclude_related=True,
        multivariate=True, checkpoint_dir=ckdir,
        block_m=64, block_n=128, block_p=64,
    )

    # [2/4] First pass; then simulate a node crash losing two batches.
    scan = GenomeScan(source, y, c, config=config)
    print(f"[2/4] relatedness exclusion dropped {scan.excluded_samples} samples; "
          f"{scan.n_batches} batches")
    scan.run()
    mani_path = os.path.join(ckdir, "manifest.json")
    mani = json.load(open(mani_path))
    for k in list(mani["completed"])[1:3]:
        mani["completed"].pop(k)
    json.dump(mani, open(mani_path, "w"))
    print("[3/4] simulated crash: dropped 2 committed batches; restarting...")
    result = GenomeScan(source, y, c, config=config).run(resume=True)

    # [4/4] Report with BH q-values.
    out_tsv = os.path.join(workdir, "hits.tsv")
    with open(out_tsv, "w") as f:
        f.write("marker\ttrait\tr\tt\tneglog10p\tneglog10q\n")
        if len(result.hits):
            nlq = np.asarray(S.bh_qvalues(jnp.asarray(result.hit_stats[:, 2])))
            for (m, t), (r, tt, nlp), q in zip(result.hits, result.hit_stats, nlq):
                f.write(f"{source.marker_ids[m]}\t{t}\t{r:.4f}\t{tt:.3f}\t{nlp:.2f}\t{q:.2f}\n")
    planted = {(m, t) for m, t, _ in cohort.effects}
    found = {(int(m), int(t)) for m, t in result.hits}
    print(f"[4/4] lambda_GC={result.lambda_gc:.3f}  hits={len(result.hits)}  "
          f"recovered {len(planted & found)}/{len(planted)} planted effects")
    print(f"      report: {out_tsv}")

if __name__ == "__main__":
    main()
