"""End-to-end phenotype-rich screening workflow (the paper's production
scenario, scaled to run on CPU): BGEN input, covariate adjustment,
relatedness-aware exclusion at Study binding, fault-tolerant streamed scan
with a simulated mid-scan crash + resume through the event stream,
multivariate omnibus, BH q-values, TSV report.

    PYTHONPATH=src python examples/ukb_screening.py [--traits 256]
"""
import argparse
import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.api import GridSpec, Study, TsvWriter
from repro.core import stats as S

from repro.io import synth

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--traits", type=int, default=128)
    ap.add_argument("--markers", type=int, default=4_000)
    ap.add_argument("--samples", type=int, default=800)
    args = ap.parse_args()

    workdir = tempfile.mkdtemp(prefix="ukb_screening_")
    cohort = synth.make_cohort(
        n_samples=args.samples, n_markers=args.markers, n_traits=args.traits,
        n_causal=12, effect_size=0.45, missing_rate=0.015,
        n_related_pairs=6, seed=7,
    )
    paths = synth.write_cohort_files(cohort, os.path.join(workdir, "ukb"))
    print(f"[1/4] cohort: {args.markers} markers x {args.samples} samples x "
          f"{args.traits} traits (BGEN: {paths['bgen']})")

    # Bind: open the BGEN, align tables by sample id, run the relatedness
    # probe — all before any plan exists.
    study = Study.from_files(paths["bgen"], paths["pheno"], paths["cov"],
                             exclude_related=True)
    ckdir = os.path.join(workdir, "checkpoints")
    plan = study.plan(
        engine="dense", multivariate=True, checkpoint_dir=ckdir,
        grid=GridSpec(batch_markers=512, block_m=64, block_n=128, block_p=64),
    )

    # [2/4] First pass; then simulate a node crash losing two batches.
    session = plan.run()
    print(f"[2/4] relatedness exclusion dropped {study.excluded_samples} "
          f"samples; {session.n_batches} batches")
    for _ in session.events():
        pass  # stream to nowhere: the checkpoint commits every cell anyway
    mani_path = os.path.join(ckdir, "manifest.json")
    mani = json.load(open(mani_path))
    for k in list(mani["completed"])[1:3]:
        mani["completed"].pop(k)
    json.dump(mani, open(mani_path, "w"))
    print("[3/4] simulated crash: dropped 2 committed batches; resuming...")

    # [3/4] Resume: only the lost cells recompute, the rest replay from
    # shards; the TSV writer cannot tell the difference.
    out_dir = os.path.join(workdir, "results")
    resumed = plan.run(resume=True)
    hits = []
    stats = []
    writer = TsvWriter(out_dir)
    writer.open(resumed)
    n_recomputed = 0
    for cell in resumed.events():
        n_recomputed += not cell.replayed
        writer.write(cell)
        hits.append(cell.hits)
        stats.append(cell.hit_stats)
    summary = writer.close()
    hits = np.concatenate(hits)
    stats = np.concatenate(stats)
    print(f"      resumed: {n_recomputed} cells recomputed, "
          f"{resumed.n_batches * resumed.n_trait_blocks - n_recomputed} replayed")

    # [4/4] Report with BH q-values over the streamed hit set.
    out_tsv = os.path.join(out_dir, "hits_q.tsv")
    with open(out_tsv, "w") as f:
        f.write("marker\ttrait\tr\tt\tneglog10p\tneglog10q\n")
        if len(hits):
            nlq = np.asarray(S.bh_qvalues(jnp.asarray(stats[:, 2])))
            for (m, t), (r, tt, nlp), q in zip(hits, stats, nlq):
                f.write(f"{study.marker_ids[m]}\t{t}\t{r:.4f}\t{tt:.3f}"
                        f"\t{nlp:.2f}\t{q:.2f}\n")
    planted = {(m, t) for m, t, _ in cohort.effects}
    found = {(int(m), int(t)) for m, t in hits}
    print(f"[4/4] lambda_GC={summary['lambda_gc']:.3f}  hits={summary['hits']}  "
          f"recovered {len(planted & found)}/{len(planted)} planted effects")
    print(f"      report: {out_tsv}  (sorted hits: {summary['hits_tsv']})")

if __name__ == "__main__":
    main()
