"""Batched serving with the zoo: prefill a prompt batch, decode greedily.

    PYTHONPATH=src python examples/serve_batch.py [--arch gemma2-9b] [--tokens 24]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import api as M
from repro.train.serve_step import build_decode_step, build_prefill_step

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cap = args.prompt_len + args.tokens + 1
    shape = ShapeConfig("serve", seq_len=cap, global_batch=args.batch, kind="prefill")
    params = M.init_model(cfg, jax.random.PRNGKey(0), max_positions=cap)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32)),
        "positions": jnp.broadcast_to(jnp.arange(args.prompt_len), (args.batch, args.prompt_len)),
    }
    if cfg.family == "vlm":
        patches = 4
        batch["vision_embeds"] = jnp.asarray(rng.normal(0, .02, (args.batch, patches, cfg.d_model)).astype(np.float32))
        batch["positions"] = jnp.broadcast_to(jnp.arange(args.prompt_len + patches), (3, args.batch, args.prompt_len + patches))
    if cfg.family == "encdec":
        batch = {"frames": jnp.asarray(rng.normal(0, .02, (args.batch, cfg.encoder_len, cfg.d_model)).astype(np.float32)),
                 "tokens": batch["tokens"]}

    prefill = build_prefill_step(cfg, shape)
    decode = build_decode_step(cfg, shape)

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    logits = jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    start = args.prompt_len + (4 if cfg.family == "vlm" else 0)
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.full((args.batch,), start + i, jnp.int32)
        logits, caches = decode(params, tok, pos, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)
    print(f"arch={args.arch} (reduced)  batch={args.batch}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode/max(args.tokens-1,1)*1e3:.2f} ms/token")
    for b in range(min(args.batch, 2)):
        print(f"  stream {b}: {gen[b][:16].tolist()} ...")

if __name__ == "__main__":
    main()
