"""Quickstart: simulate a cohort, bind a Study, stream a scan, write TSVs.

    PYTHONPATH=src python examples/quickstart.py [--trait-block 32]

Demonstrates the layered public API (DESIGN.md §11):

    bind     Study.from_files        — open genotypes, align tables
    plan     study.plan(...)         — typed specs, validated
    execute  plan.run().events()     — per-grid-cell streaming results
    emit     TsvWriter               — sorted hits.tsv, never dense in RAM

``--trait-block`` also runs the scan as a 2-D (marker-batch x trait-block)
grid (DESIGN.md §10) and asserts it is bitwise-identical to the unblocked
scan — CI exercises the blocked path this way on every push.  The final
section checks the deprecated ``GenomeScan`` shim agrees with the API
bitwise.
"""
import argparse
import os
import tempfile

import numpy as np

from repro.api import GridSpec, Study, TsvWriter
from repro.io import synth

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trait-block", type=int, default=16,
                    help="trait-axis tile width for the blocked-scan check")
    args = ap.parse_args()

    # 1. A small synthetic cohort with six planted marker->trait effects,
    #    shipped the way real cohorts are: PLINK files + TSV tables.
    cohort = synth.make_cohort(
        n_samples=600, n_markers=2_000, n_traits=48,
        n_causal=6, effect_size=0.5, missing_rate=0.01, seed=42,
    )
    workdir = tempfile.mkdtemp(prefix="torchgwas_quickstart_")
    paths = synth.write_cohort_files(cohort, os.path.join(workdir, "cohort"))
    print(f"cohort on disk: {paths['bed']}  ({cohort.shape[0]} markers x "
          f"{cohort.shape[1]} samples x {cohort.shape[2]} traits)")

    # 2. Bind -> plan -> execute -> emit.
    study = Study.from_files(paths["bed"], paths["pheno"], paths["cov"])
    grid = GridSpec(batch_markers=512, block_m=64, block_n=128, block_p=64)
    plan = study.plan(engine="dense", grid=grid, multivariate=True)
    session = plan.run()
    out_dir = os.path.join(workdir, "results")
    summary = session.stream_to(TsvWriter(out_dir))
    print(f"\nlambda_GC = {summary['lambda_gc']:.3f}   "
          f"hits(p<5e-8) = {summary['hits']}   dof = {session.dof}")
    print(f"results: {summary['hits_tsv']}")

    # 3. Streaming consumption: walk the event stream yourself.  Each cell
    #    is one (marker-batch x trait-block) tile; nothing dense is kept.
    session2 = study.plan(engine="dense", grid=grid).run()
    found = set()
    for cell in session2.events():
        found.update(map(tuple, cell.hits))
    planted = {(m, t) for m, t, _ in cohort.effects}
    print(f"planted effects recovered from the event stream: "
          f"{len(planted & found)}/{len(planted)}")
    assert planted <= found

    # 4. The same cohort as a per-chromosome fileset (how real cohorts ship):
    #    a glob opens all shards as one source; best-hit results identical.
    synth.write_split_plink(cohort, os.path.join(workdir, "cohort"), n_shards=4)
    multi = Study.from_files(os.path.join(workdir, "cohort_chr*.bed"),
                             paths["pheno"], paths["cov"])
    multi_out = os.path.join(workdir, "results_multi")
    multi.plan(engine="dense", grid=grid).run().stream_to(TsvWriter(multi_out))
    single_best = open(os.path.join(out_dir, "per_trait_best.tsv")).read()
    multi_best = open(os.path.join(multi_out, "per_trait_best.tsv")).read()
    print(f"per-chromosome fileset: {multi.source.n_shards} shards, "
          f"{multi.n_markers} markers; best-hit match vs single file: "
          f"{single_best == multi_best}")
    assert single_best == multi_best

    # 5. The blocked 2-D scan grid: tile the trait axis so peak device
    #    memory scales with the block, not the panel — bitwise-identical.
    #    (block_p is the panel compute tile; trait blocks align to it.)
    small = GridSpec(batch_markers=512, block_m=64, block_n=128, block_p=16)
    blocked_grid = GridSpec(batch_markers=512, block_m=64, block_n=128,
                            block_p=16, trait_block=args.trait_block)
    ref_out, blk_out = (os.path.join(workdir, d) for d in ("ref", "blk"))
    study.plan(engine="dense", grid=small).run().stream_to(TsvWriter(ref_out))
    blk_session = study.plan(engine="dense", grid=blocked_grid).run()
    blk_session.stream_to(TsvWriter(blk_out))
    same_blk = all(
        open(os.path.join(ref_out, f)).read() == open(os.path.join(blk_out, f)).read()
        for f in ("hits.tsv", "per_trait_best.tsv", "qc.tsv")
    )
    print(f"blocked scan grid: {blk_session.n_batches} marker batches x "
          f"{blk_session.n_trait_blocks} trait blocks "
          f"(trait_block={args.trait_block}); bitwise match: {same_blk}")
    assert same_blk

    # 6. The deprecated shim still agrees with the API, bitwise.
    from repro.core.screening import GenomeScan, ScanConfig
    from repro.io import plink

    res = GenomeScan(
        plink.PlinkBed(paths["bed"]), cohort.phenotypes, cohort.covariates,
        config=ScanConfig(batch_markers=512, engine="dense",
                          block_m=64, block_n=128, block_p=16),
    ).run()
    order = np.lexsort((res.hits[:, 1], res.hits[:, 0]))
    shim_rows = {tuple(map(int, r)) for r in res.hits[order]}
    api_rows = set()
    with open(os.path.join(ref_out, "hits.tsv")) as f:
        next(f)
        for line in f:
            mid, tname = line.split("\t")[:2]
            api_rows.add((int(mid.lstrip("rs")), int(tname.lstrip("trait"))))
    print(f"deprecated GenomeScan shim hit set == API hit set: "
          f"{shim_rows == api_rows}")
    assert shim_rows == api_rows

if __name__ == "__main__":
    main()
