"""Quickstart: simulate a cohort, write PLINK files, run the scan, print hits.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.core.screening import GenomeScan, ScanConfig
from repro.io import plink, synth

def main() -> None:
    # 1. A small synthetic cohort with six planted marker->trait effects.
    cohort = synth.make_cohort(
        n_samples=600, n_markers=2_000, n_traits=16,
        n_causal=6, effect_size=0.5, missing_rate=0.01, seed=42,
    )
    workdir = tempfile.mkdtemp(prefix="torchgwas_quickstart_")
    paths = synth.write_cohort_files(cohort, os.path.join(workdir, "cohort"))
    print(f"cohort on disk: {paths['bed']}  ({cohort.shape[0]} markers x "
          f"{cohort.shape[1]} samples x {cohort.shape[2]} traits)")

    # 2. Scan: phenotype panel residualized once, genome streamed in batches.
    source = plink.PlinkBed(paths["bed"])
    config = ScanConfig(batch_markers=512, engine="dense", multivariate=True,
                        block_m=64, block_n=128, block_p=64)
    scan = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=config)
    result = scan.run()

    # 3. Report.
    print(f"\nlambda_GC = {result.lambda_gc:.3f}   "
          f"hits(p<5e-8) = {len(result.hits)}   dof = {result.dof}")
    print("\n marker      trait   r        t        -log10p")
    order = np.argsort(-result.hit_stats[:, 2])
    for (m, t), (r, tstat, nlp) in zip(result.hits[order], result.hit_stats[order]):
        print(f" {source.marker_ids[m]:<10s} trait{t:<3d} {r:+.3f}  {tstat:+8.2f}  {nlp:8.2f}")
    planted = {(m, t) for m, t, _ in cohort.effects}
    found = {(int(m), int(t)) for m, t in result.hits}
    print(f"\nplanted effects recovered: {len(planted & found)}/{len(planted)}")

    # 4. The same cohort as a per-chromosome fileset (how real cohorts ship):
    #    a glob opens all shards as one source; hits/best are identical.
    from repro.io import open_genotypes

    synth.write_split_plink(cohort, os.path.join(workdir, "cohort"), n_shards=4)
    multi = open_genotypes(os.path.join(workdir, "cohort_chr*.bed"))
    multi_result = GenomeScan(multi, cohort.phenotypes, cohort.covariates, config=config).run()
    same = np.array_equal(result.best_nlp, multi_result.best_nlp)
    print(f"\nper-chromosome fileset: {multi.n_shards} shards, "
          f"{multi.n_markers} markers; best-hit match vs single file: {same}")
    assert same

if __name__ == "__main__":
    main()
