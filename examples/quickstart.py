"""Quickstart: simulate a cohort, write PLINK files, run the scan, print hits.

    PYTHONPATH=src python examples/quickstart.py [--trait-block 32]

``--trait-block`` also runs the scan as a 2-D (marker-batch x trait-block)
grid (DESIGN.md §10) and asserts it is bitwise-identical to the unblocked
scan — CI exercises the blocked path this way on every push.
"""
import argparse
import os
import tempfile

import numpy as np

from repro.core.screening import GenomeScan, ScanConfig
from repro.io import plink, synth

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trait-block", type=int, default=16,
                    help="trait-axis tile width for the blocked-scan check")
    args = ap.parse_args()

    # 1. A small synthetic cohort with six planted marker->trait effects.
    cohort = synth.make_cohort(
        n_samples=600, n_markers=2_000, n_traits=48,
        n_causal=6, effect_size=0.5, missing_rate=0.01, seed=42,
    )
    workdir = tempfile.mkdtemp(prefix="torchgwas_quickstart_")
    paths = synth.write_cohort_files(cohort, os.path.join(workdir, "cohort"))
    print(f"cohort on disk: {paths['bed']}  ({cohort.shape[0]} markers x "
          f"{cohort.shape[1]} samples x {cohort.shape[2]} traits)")

    # 2. Scan: phenotype panel residualized once, genome streamed in batches.
    source = plink.PlinkBed(paths["bed"])
    config = ScanConfig(batch_markers=512, engine="dense", multivariate=True,
                        block_m=64, block_n=128, block_p=64)
    scan = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=config)
    result = scan.run()

    # 3. Report.
    print(f"\nlambda_GC = {result.lambda_gc:.3f}   "
          f"hits(p<5e-8) = {len(result.hits)}   dof = {result.dof}")
    print("\n marker      trait   r        t        -log10p")
    order = np.argsort(-result.hit_stats[:, 2])
    for (m, t), (r, tstat, nlp) in zip(result.hits[order], result.hit_stats[order]):
        print(f" {source.marker_ids[m]:<10s} trait{t:<3d} {r:+.3f}  {tstat:+8.2f}  {nlp:8.2f}")
    planted = {(m, t) for m, t, _ in cohort.effects}
    found = {(int(m), int(t)) for m, t in result.hits}
    print(f"\nplanted effects recovered: {len(planted & found)}/{len(planted)}")

    # 4. The same cohort as a per-chromosome fileset (how real cohorts ship):
    #    a glob opens all shards as one source; hits/best are identical.
    from repro.io import open_genotypes

    synth.write_split_plink(cohort, os.path.join(workdir, "cohort"), n_shards=4)
    multi = open_genotypes(os.path.join(workdir, "cohort_chr*.bed"))
    multi_result = GenomeScan(multi, cohort.phenotypes, cohort.covariates, config=config).run()
    same = np.array_equal(result.best_nlp, multi_result.best_nlp)
    print(f"\nper-chromosome fileset: {multi.n_shards} shards, "
          f"{multi.n_markers} markers; best-hit match vs single file: {same}")
    assert same

    # 5. The blocked 2-D scan grid: tile the trait axis so peak device
    #    memory scales with the block, not the panel — bitwise-identical.
    #    (block_p is the panel compute tile; trait blocks align to it.)
    blocked_cfg = ScanConfig(batch_markers=512, engine="dense",
                             trait_block=args.trait_block,
                             block_m=64, block_n=128, block_p=16)
    ref = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                     config=ScanConfig(batch_markers=512, engine="dense",
                                       block_m=64, block_n=128, block_p=16)).run()
    blk_scan = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=blocked_cfg)
    blocked = blk_scan.run()
    same_blk = (np.array_equal(ref.best_nlp, blocked.best_nlp)
                and np.array_equal(ref.best_marker, blocked.best_marker)
                and ref.lambda_gc == blocked.lambda_gc)
    print(f"blocked scan grid: {blk_scan.n_batches} marker batches x "
          f"{blk_scan.n_trait_blocks} trait blocks "
          f"(trait_block={args.trait_block}); bitwise match: {same_blk}")
    assert same_blk

if __name__ == "__main__":
    main()
