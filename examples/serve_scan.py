"""Scan as a service: a resident cohort serving concurrent clients
(DESIGN.md §16).

    PYTHONPATH=src python examples/serve_scan.py [--devices 1]

The paper's core amortization — one genotype matrix reused across a huge
phenotype panel — taken to serving: a ``ServeHost`` keeps the cohort
resident (open source, residualized covariate basis, warm per-device
engine states) behind a stdlib HTTP server, and TWO concurrent clients
submit work against it:

    client A   uploads a fresh 32-trait phenotype panel (a full scan);
    client B   fires marker-window queries against the resident panel
               (the warm path: no re-prepare, no re-staging on cache hit).

Both run as real scan sessions on ONE shared worker pool, interleaved by
the deficit-round-robin fair-share policy — and the demo's point is the
correctness contract: every served table is byte-identical to a fresh
offline scan of the same panel/window, asserted with ``filecmp`` below.
"""
import argparse
import filecmp
import os
import tempfile
import threading

import numpy as np

from repro.api import GridSpec, Study, TsvWriter
from repro.io import synth
from repro.serve import ServeClient, ServeHost, ServeServer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=1,
                    help="serve worker slots (0 = every visible device)")
    args = ap.parse_args()

    # 1. A cohort on disk, as real studies arrive: PLINK + TSV tables.
    cohort = synth.make_cohort(
        n_samples=500, n_markers=1_200, n_traits=16,
        n_causal=6, effect_size=0.5, missing_rate=0.01, seed=11,
    )
    workdir = tempfile.mkdtemp(prefix="torchgwas_serve_")
    paths = synth.write_cohort_files(cohort, os.path.join(workdir, "cohort"))
    study = Study.from_files(paths["bed"], paths["pheno"], paths["cov"])
    grid = GridSpec(batch_markers=256, trait_block=8,
                    block_m=64, block_n=128, block_p=8)

    # 2. Boot the service: admit the study, warm it, start the listener.
    host = ServeHost(devices=args.devices, out_root=os.path.join(workdir, "serve"))
    host.admit_study("cohort", study, grid=grid)
    warm = host.warm_study("cohort")
    server = ServeServer(host).start()
    addr = server.address
    print(f"serving on {addr[0]}:{addr[1]}  "
          f"(resident prepare: {warm['prepare_s']:.2f}s)")

    # 3. Two concurrent clients.
    rng = np.random.default_rng(5)
    panel = rng.standard_normal((study.n_samples, 32)).astype(np.float32)
    # Mix four resident traits (planted effects) into the upload so the
    # served hits table is non-empty — the byte-compare has teeth.
    panel[:, :4] += np.asarray(study.phenotypes)[:, :4]
    panel_names = [f"derived_{i}" for i in range(panel.shape[1])]
    windows = [(0, 300), (300, 700), (700, 1_200)]
    results: dict = {}

    def client_a() -> None:
        cli = ServeClient(*addr)
        rid = cli.scan_panel("cohort", panel, panel_names)
        results["panel"] = (rid, cli.wait(rid))

    def client_b() -> None:
        cli = ServeClient(*addr)
        for lo, hi in windows:
            rid = cli.scan_window("cohort", lo, hi)
            results[(lo, hi)] = (rid, cli.wait(rid))

    threads = [threading.Thread(target=client_a), threading.Thread(target=client_b)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    # 4. The contract: served bytes == a fresh offline scan's bytes.
    cli = ServeClient(*addr)
    tables = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")

    # 4a. The uploaded panel vs an offline scan of the same panel.
    import dataclasses
    off = dataclasses.replace(study, phenotypes=panel, trait_names=panel_names)
    off_dir = os.path.join(workdir, "offline_panel")
    off.plan(grid=grid).run(resume=False).stream_to(TsvWriter(off_dir))
    rid, info = results["panel"]
    for name in tables:
        served = os.path.join(workdir, f"served_{name}")
        cli.fetch_to(rid, name, served)
        assert filecmp.cmp(os.path.join(off_dir, name), served, shallow=False), \
            f"served panel {name} differs from the offline scan"
    print(f"panel scan: {info['summary']['hits']} hits, "
          f"{info['wall_s']:.2f}s — byte-identical to offline")

    # 4b. Each window vs an offline windowed session on the resident panel.
    for lo, hi in windows:
        rid, info = results[(lo, hi)]
        ref_dir = os.path.join(workdir, f"offline_w{lo}")
        sess = study.plan(grid=grid).run(resume=False, marker_window=(lo, hi))
        sess.stream_to(TsvWriter(ref_dir))
        assert tuple(info["covered"]) == sess.window_covered
        for name in tables:
            served = os.path.join(workdir, f"served_w{lo}_{name}")
            cli.fetch_to(rid, name, served)
            assert filecmp.cmp(os.path.join(ref_dir, name), served,
                               shallow=False), \
                f"served window [{lo},{hi}) {name} differs"
    print(f"{len(windows)} window queries — byte-identical to offline "
          "windowed sessions")

    # 5. Warm-path observability, then a clean stop.
    m = cli.metrics()["serve"]
    lat = m["latency"]
    print(f"requests: {m['requests']}  "
          f"latency p50/p95/p99 = {lat['p50_s']:.3f}/{lat['p95_s']:.3f}/"
          f"{lat['p99_s']:.3f}s  "
          f"device-state cache hit rate: {m['caches']['device_state']['hit_rate']}")
    server.shutdown()
    print("clean shutdown — no serve threads left:",
          [t.name for t in threading.enumerate()
           if t.name.startswith("serve")] == [])


if __name__ == "__main__":
    main()
