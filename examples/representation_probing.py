"""The paper's motivating use case (§1): representation-learning phenotypes.

A zoo model (reduced rwkv6) embeds token sequences per "individual"; its
hidden-state features become a quantitative phenotype panel screened against
genotypes with the GWAS engine — thousands of derived traits, one shared
genotype matrix, exactly the workload TorchGWAS amortizes.  A planted
genotype->sequence coupling validates that the screen finds real structure.

    PYTHONPATH=src python examples/representation_probing.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.screening import GenomeScan, ScanConfig
from repro.models import transformer as T

def main() -> None:
    rng = np.random.default_rng(0)
    n_samples, n_markers, seq = 400, 1_500, 32

    # 1. Genotypes, with marker 7 coupled to the "expression" sequences below.
    maf = rng.uniform(0.1, 0.5, n_markers).astype(np.float32)
    dosages = rng.binomial(2, maf[:, None], size=(n_markers, n_samples)).astype(np.int8)
    causal = dosages[7].astype(np.int32)

    # 2. Per-individual token sequences whose composition depends on the
    #    causal dosage (a crude stand-in for genotype-driven biology).
    cfg = get_config("rwkv6-3b").reduced()
    tokens = rng.integers(0, cfg.vocab, size=(n_samples, seq), dtype=np.int32)
    biased = 11 + causal  # dosage shifts a marker token's identity
    tokens[:, ::4] = biased[:, None]

    # 3. Embed with the LM; mean-pooled hidden features = phenotype panel.
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    positions = jnp.broadcast_to(jnp.arange(seq), (n_samples, seq))

    @jax.jit
    def embed(tok):
        logits, _ = T.forward_train(cfg, params, tok, positions)
        return logits.mean(axis=1)  # (N, vocab) features

    feats = np.asarray(embed(jnp.asarray(tokens)))[:, :256]  # panel: 256 traits
    print(f"embedded {n_samples} individuals -> {feats.shape[1]}-trait panel")

    # 4. GWAS screen of the derived panel.
    class ArraySource:
        def __init__(self, d):
            self._d = d
            self.n_markers, self.n_samples = d.shape
            self.sample_ids = [f"S{i}" for i in range(self.n_samples)]
            self.marker_ids = [f"rs{i}" for i in range(self.n_markers)]
        def read_dosages(self, lo, hi):
            return self._d[lo:hi]

    config = ScanConfig(batch_markers=512, engine="dense", multivariate=True,
                        block_m=64, block_n=128, block_p=64)
    res = GenomeScan(ArraySource(dosages), feats, None, config=config).run()
    best = int(np.argmax(res.omnibus_nlp))
    print(f"omnibus peak at marker {best} (-log10p={res.omnibus_nlp[best]:.1f}); "
          f"planted causal marker = 7")
    top5 = np.argsort(-res.omnibus_nlp)[:5]
    for m in top5:
        print(f"  marker {m:5d} omnibus -log10p = {res.omnibus_nlp[m]:7.2f}")
    assert best == 7, "screen failed to localize the planted coupling"
    print("representation screen localized the planted signal.")

if __name__ == "__main__":
    main()
