"""End-to-end genome scan: engines agree, planted effects surface,
crash/restart resumes, multivariate screen calibrated."""
import json
import os

import numpy as np
import pytest

from repro.core.association import AssocOptions
from repro.core.screening import GenomeScan, ScanConfig
from repro.io import plink


@pytest.fixture(scope="module")
def source(cohort_files):
    return plink.PlinkBed(cohort_files["bed"])


def _cfg(**kw):
    base = dict(batch_markers=128, block_m=64, block_n=128, block_p=64)
    base.update(kw)
    return ScanConfig(**base)


def test_dense_engine_recovers_planted(source, cohort, tmp_path):
    cfg = _cfg(engine="dense", multivariate=True, checkpoint_dir=str(tmp_path / "ck"))
    res = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    found = {(m, t) for m, t in res.hits}
    planted = {(m, t) for m, t, _ in cohort.effects}
    assert planted <= found
    assert 0.7 < res.lambda_gc < 1.4
    # multivariate omnibus: signal at planted markers, calibrated null
    planted_m = sorted({m for m, _, _ in cohort.effects})
    assert np.median(res.omnibus_nlp[planted_m]) > 5.0
    null_m = [m for m in range(res.n_markers) if m not in set(planted_m)]
    assert np.median(res.omnibus_nlp[null_m]) < 1.0


def test_fused_engine_matches_dense(source, cohort):
    dense = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=_cfg(engine="dense")).run()
    fused = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=_cfg(engine="fused")).run()
    np.testing.assert_allclose(dense.best_nlp, fused.best_nlp, atol=2e-3)
    assert set(map(tuple, dense.hits)) == set(map(tuple, fused.hits))


def test_exact_mode_scan(source, cohort):
    cfg = _cfg(engine="dense", options=AssocOptions(dof_mode="exact"))
    res = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    planted = {(m, t) for m, t, _ in cohort.effects}
    assert planted <= {(m, t) for m, t in res.hits}


def test_crash_resume_identical(source, cohort, tmp_path):
    ckdir = str(tmp_path / "ck")
    cfg = _cfg(engine="dense", checkpoint_dir=ckdir)
    full = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    # simulate a crash that lost two batches
    mpath = os.path.join(ckdir, "manifest.json")
    mani = json.load(open(mpath))
    for k in ["1", "3"]:
        mani["completed"].pop(k)
    json.dump(mani, open(mpath, "w"))
    res = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    np.testing.assert_allclose(res.best_nlp, full.best_nlp, atol=1e-5)
    assert res.hits.shape == full.hits.shape


def test_resume_preserves_lambda_gc(source, cohort, tmp_path):
    ckdir = str(tmp_path / "ck")
    cfg = _cfg(engine="dense", checkpoint_dir=ckdir)
    full = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    # lose two batches: lambda must come from persisted probes + recompute
    mpath = os.path.join(ckdir, "manifest.json")
    mani = json.load(open(mpath))
    for k in ["0", "2"]:
        mani["completed"].pop(k)
    json.dump(mani, open(mpath, "w"))
    partial = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    assert abs(partial.lambda_gc - full.lambda_gc) < 1e-6
    # fully-resumed scan (zero recomputed batches) must not degrade either
    resumed = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    assert abs(resumed.lambda_gc - full.lambda_gc) < 1e-6
    np.testing.assert_allclose(resumed.best_nlp, full.best_nlp, atol=1e-6)
    assert set(map(tuple, resumed.hits)) == set(map(tuple, full.hits))


def test_checkpoint_refuses_foreign_scan(source, cohort, tmp_path):
    ckdir = str(tmp_path / "ck")
    GenomeScan(source, cohort.phenotypes, cohort.covariates,
               config=_cfg(engine="dense", checkpoint_dir=ckdir)).run()
    other = _cfg(engine="dense", checkpoint_dir=ckdir, maf_min=0.1)
    with pytest.raises(ValueError, match="different scan"):
        GenomeScan(source, cohort.phenotypes, cohort.covariates, config=other).run()


def test_sample_sharded_mode_matches(source, cohort):
    a = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="dense", mode="mp")).run()
    b = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="dense", mode="sample")).run()
    np.testing.assert_allclose(a.best_nlp, b.best_nlp, atol=1e-4)


def test_maf_filter(source, cohort):
    res = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                     config=_cfg(engine="fused", maf_min=0.2)).run()
    # filter applies to the OBSERVED frequency (what a scan can know)
    assert (~res.valid[res.maf < 0.199]).all()
    assert res.valid[res.maf > 0.21].all()


def test_phenotype_row_mismatch_raises(source, cohort):
    with pytest.raises(ValueError, match="align"):
        GenomeScan(source, cohort.phenotypes[:-5], cohort.covariates, config=_cfg())


def test_dense_prolog_split_bitwise(cohort, rng):
    """The dense step's once-per-marker-batch prolog fold (standardize +
    exact-mode FWL residualization memoized on the staged batch) must be
    bitwise-identical to the historical single-jit step — the cell GEMM
    consumes the identical float32 g_std either way."""
    import jax.numpy as jnp

    from repro.core.engines import build_dense_step
    from repro.core.residualize import covariate_basis

    n, m, p = 150, 48, 12
    g = rng.binomial(2, 0.3, size=(m, n)).astype(np.float32)
    g[rng.random(g.shape) < 0.02] = -9.0
    y = rng.normal(size=(n, p)).astype(np.float32)
    q = covariate_basis(jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32)), n)
    for dof_mode in ("paper", "exact"):
        kw = dict(
            n_samples=n, n_covariates=2,
            options=AssocOptions(dof_mode=dof_mode), q_basis=q,
            trait_tile=4, maf_min=0.05, multivariate=(dof_mode == "paper"),
        )
        split = build_dense_step(split_prolog=True, **kw)
        mono = build_dense_step(split_prolog=False, **kw)
        gd, yd = jnp.asarray(g), jnp.asarray(y)
        out_split = split(gd, yd)
        out_mono = mono(gd, yd)
        for key in out_mono:
            np.testing.assert_array_equal(
                np.asarray(out_split[key]), np.asarray(out_mono[key]),
                err_msg=f"{dof_mode}:{key}",
            )
        # the memo pays the prolog once per staged batch: a second trait
        # block on the SAME staged array reuses the cached prolog output
        y2 = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
        out2 = split(gd, y2)
        ref2 = mono(gd, y2)
        np.testing.assert_array_equal(np.asarray(out2["nlp"]), np.asarray(ref2["nlp"]))


def test_dense_blocked_scan_equals_monolithic_step_scan(source, cohort):
    """End-to-end guard for the prolog fold: a full blocked scan driven by
    the split step equals one driven by the monolithic step bitwise."""
    from repro.core.engines import build_dense_step

    cfg = _cfg(engine="dense", trait_block=4, block_p=4, hit_threshold_nlp=2.0)
    scan_a = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg)
    a = scan_a.run()
    scan_b = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg)
    scan_b._step = build_dense_step(
        n_samples=scan_b.n_samples,
        n_covariates=scan_b.n_covariates,
        options=cfg.options,
        hit_threshold=cfg.hit_threshold_nlp,
        trait_tile=cfg.block_p,
        split_prolog=False,
    )
    b = scan_b.run()
    np.testing.assert_array_equal(a.best_nlp, b.best_nlp)
    np.testing.assert_array_equal(a.best_marker, b.best_marker)
    np.testing.assert_array_equal(a.hits, b.hits)
    np.testing.assert_array_equal(a.hit_stats, b.hit_stats)
    assert a.lambda_gc == b.lambda_gc
