"""Association engine vs per-trait OLS oracles — the paper's Fig. 2 left
(r = 0.999 concordance with PLINK) reproduced against scipy.linregress."""
import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats as sps

from repro.core import association as A
from repro.core import residualize as Rz


@pytest.fixture(scope="module")
def small_problem(rng=np.random.default_rng(0)):
    n, m, p, q = 500, 40, 16, 3
    g = rng.integers(0, 3, size=(m, n)).astype(np.float32)
    g[rng.random((m, n)) < 0.02] = -9.0
    c = rng.normal(size=(n, q)).astype(np.float32)
    y = rng.normal(size=(n, p)).astype(np.float32) + c @ rng.normal(size=(q, p)).astype(np.float32)
    return g, c, y


def test_concordance_with_per_trait_ols(small_problem):
    g, c, y = small_problem
    n, q = y.shape[0], c.shape[1]
    qb = Rz.covariate_basis(jnp.asarray(c), n)
    panel = Rz.residualize_and_standardize(jnp.asarray(y), qb)
    res, _ = A.assoc_batch(jnp.asarray(g), panel.y, n_samples=n, n_covariates=q)

    g_std, _ = A.standardize_genotype_batch(jnp.asarray(g))
    g_std = np.asarray(g_std)
    yr = np.asarray(panel.y)
    r_ours = np.asarray(res.r)
    t_ours = np.asarray(res.t)
    checked = 0
    for m in range(0, g.shape[0], 7):
        for p in range(0, y.shape[1], 5):
            lr = sps.linregress(g_std[m], yr[:, p])
            t_ref = lr.rvalue * np.sqrt((n - 2) / max(1 - lr.rvalue**2, 1e-12))
            assert abs(r_ours[m, p] - lr.rvalue) < 1e-5
            assert abs(t_ours[m, p] - t_ref) < 1e-4 * max(1.0, abs(t_ref))
            checked += 1
    assert checked > 10
    # the paper's headline: near-perfect correlation of estimates
    flat_ref = []
    for m in range(g.shape[0]):
        flat_ref.append([sps.linregress(g_std[m], yr[:, p]).rvalue for p in range(y.shape[1])])
    concord = np.corrcoef(r_ours.ravel(), np.asarray(flat_ref).ravel())[0, 1]
    assert concord > 0.999


def test_exact_mode_equals_full_covariate_ols(small_problem):
    g, c, y = small_problem
    n, q = y.shape[0], c.shape[1]
    qb = Rz.covariate_basis(jnp.asarray(c), n)
    panel = Rz.residualize_and_standardize(jnp.asarray(y), qb)
    opts = A.AssocOptions(dof_mode="exact")
    res, _ = A.assoc_batch(
        jnp.asarray(g), panel.y, n_samples=n, n_covariates=q, options=opts, q_basis=qb
    )
    g_std, _ = A.standardize_genotype_batch(jnp.asarray(g))
    g_std = np.asarray(g_std)
    for m, p in [(3, 5), (11, 0), (25, 9)]:
        x = np.column_stack([np.ones(n), g_std[m], c])
        beta, *_ = np.linalg.lstsq(x, y[:, p], rcond=None)
        resid = y[:, p] - x @ beta
        dof = n - x.shape[1]
        sigma2 = resid @ resid / dof
        se = np.sqrt(sigma2 * np.linalg.inv(x.T @ x)[1, 1])
        t_ols = beta[1] / se
        assert abs(float(res.t[m, p]) - t_ols) < 1e-3 * max(1.0, abs(t_ols))


def test_paper_vs_exact_mode_differ_but_agree_in_rank(small_problem):
    """The paper's Y-only residualization is close to, but not identical to,
    exact covariate-adjusted OLS (DESIGN.md §2)."""
    g, c, y = small_problem
    n, q = y.shape[0], c.shape[1]
    qb = Rz.covariate_basis(jnp.asarray(c), n)
    panel = Rz.residualize_and_standardize(jnp.asarray(y), qb)
    paper, _ = A.assoc_batch(jnp.asarray(g), panel.y, n_samples=n, n_covariates=q)
    exact, _ = A.assoc_batch(
        jnp.asarray(g), panel.y, n_samples=n, n_covariates=q,
        options=A.AssocOptions(dof_mode="exact"), q_basis=qb,
    )
    corr = np.corrcoef(np.asarray(paper.t).ravel(), np.asarray(exact.t).ravel())[0, 1]
    assert corr > 0.99


def test_bf16_precision_ladder(small_problem):
    g, c, y = small_problem
    n, q = y.shape[0], c.shape[1]
    qb = Rz.covariate_basis(jnp.asarray(c), n)
    panel = Rz.residualize_and_standardize(jnp.asarray(y), qb)
    fp32, _ = A.assoc_batch(jnp.asarray(g), panel.y, n_samples=n, n_covariates=q)
    bf16, _ = A.assoc_batch(
        jnp.asarray(g), panel.y, n_samples=n, n_covariates=q,
        options=A.AssocOptions(precision="bf16"),
    )
    err = np.abs(np.asarray(fp32.r) - np.asarray(bf16.r)).max()
    assert err < 5e-3  # bounded, quantified degradation (EXPERIMENTS.md §Perf)


def test_monomorphic_markers_masked():
    n = 100
    g = np.zeros((3, n), np.float32)
    g[1] = 1.0                      # constant non-zero
    g[2] = np.arange(n) % 3
    y = np.random.default_rng(0).normal(size=(n, 4)).astype(np.float32)
    qb = Rz.covariate_basis(None, n)
    panel = Rz.residualize_and_standardize(jnp.asarray(y), qb)
    res, ms = A.assoc_batch(jnp.asarray(g), panel.y, n_samples=n, n_covariates=0)
    assert not bool(ms.valid[0]) and not bool(ms.valid[1]) and bool(ms.valid[2])
    assert np.all(np.asarray(res.t)[:2] == 0.0)
    assert np.all(np.asarray(res.neglog10p)[:2] == 0.0)


def test_missing_imputation_matches_explicit(rng):
    n = 200
    g = rng.integers(0, 3, size=(5, n)).astype(np.float32)
    g_miss = g.copy()
    miss = rng.random(g.shape) < 0.1
    g_miss[miss] = -9.0
    # explicit mean imputation
    g_imp = g.copy()
    for i in range(g.shape[0]):
        mean = g_miss[i][g_miss[i] != -9].mean()
        g_imp[i] = np.where(miss[i], mean, g[i])
    a, _ = A.standardize_genotype_batch(jnp.asarray(g_miss))
    mu = g_imp.mean(axis=1, keepdims=True)
    sd = g_imp.std(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(a), (g_imp - mu) / sd, atol=1e-5)
