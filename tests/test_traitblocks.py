"""The 2-D (marker-batch x trait-block) scan grid (DESIGN.md §10).

The contract under test: a blocked scan is *bitwise-identical* to the
unblocked scan for every engine (hit rows compared up to ordering — the
grid emits them block-major), resume works from a checkpoint cut
mid-trait-block, the checkpoint refuses a changed grid decomposition, and
the error path tears the prefetch pool down.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.core.screening import GenomeScan, PanelStore, ScanConfig
from repro.core.sinks import ResultSink
from repro.io import open_genotypes, plink, synth
from repro.runtime.prefetch import TraitBlockPlanner


@pytest.fixture(scope="module")
def source(cohort_files):
    return plink.PlinkBed(cohort_files["bed"])


@pytest.fixture(scope="module")
def split_beds(cohort, tmp_path_factory):
    stem = str(tmp_path_factory.mktemp("tb_multifile") / "cohort")
    return synth.write_split_plink(cohort, stem, n_shards=3)


def _cfg(**kw):
    # block_p=4 keeps the compute tile narrower than the 12-trait fixture
    # panel, so small trait_block values yield real multi-block grids
    base = dict(batch_markers=128, block_m=64, block_n=128, block_p=4)
    base.update(kw)
    return ScanConfig(**base)


def _assert_same_scan(a, b):
    """Bitwise equality of two ScanResults, hits canonicalized by sort."""
    np.testing.assert_array_equal(a.best_nlp, b.best_nlp)
    np.testing.assert_array_equal(a.best_marker, b.best_marker)
    np.testing.assert_array_equal(a.maf, b.maf)
    np.testing.assert_array_equal(a.valid, b.valid)
    assert a.lambda_gc == b.lambda_gc
    oa, ob = np.lexsort(a.hits.T), np.lexsort(b.hits.T)
    np.testing.assert_array_equal(a.hits[oa], b.hits[ob])
    np.testing.assert_array_equal(a.hit_stats[oa], b.hit_stats[ob])


# ------------------------------------------------------------------- planner


def test_trait_block_planner_unblocked_is_single_block():
    plan = TraitBlockPlanner(0).plan(17)
    assert len(plan) == 1 and (plan[0].lo, plan[0].hi) == (0, 17)


def test_trait_block_planner_covers_axis_in_order():
    for p, k in [(13, 4), (5, 2), (12, 5), (16, 16), (100, 7), (2, 2)]:
        plan = TraitBlockPlanner(k).plan(p)
        assert plan[0].lo == 0 and plan[-1].hi == p
        assert all(a.hi == b.lo for a, b in zip(plan[:-1], plan[1:]))
        assert [b.index for b in plan] == list(range(len(plan)))
        assert all(b.n_traits <= k for b in plan)


def test_trait_block_planner_rounds_to_quantum():
    # trait_block is rounded UP to a multiple of the compute tile, so every
    # block is a union of whole, globally-aligned GEMM tiles (the bitwise
    # contract's mechanism)
    pl = TraitBlockPlanner(5, quantum=4)
    assert pl.trait_block == 8
    plan = pl.plan(19)
    assert [(b.lo, b.hi) for b in plan] == [(0, 8), (8, 16), (16, 19)]
    assert all(b.lo % 4 == 0 for b in plan)
    # already-aligned widths pass through; 0 stays unblocked
    assert TraitBlockPlanner(8, quantum=4).trait_block == 8
    assert TraitBlockPlanner(0, quantum=4).plan(19)[0].n_traits == 19


def test_trait_block_planner_rejects_degenerate():
    with pytest.raises(ValueError, match=">= 0"):
        TraitBlockPlanner(-3)
    with pytest.raises(ValueError, match=">= 1"):
        TraitBlockPlanner(4, quantum=0)
    with pytest.raises(ValueError, match="positive"):
        TraitBlockPlanner(4).plan(0)


# --------------------------------------------------- blocked == unblocked


@pytest.mark.parametrize("trait_block", [4, 8, 5, 12])
def test_blocked_dense_bitwise_identical(source, cohort, trait_block):
    # 5 rounds up to the tile multiple 8; 4/8/12 are aligned already
    a = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=_cfg()).run()
    b = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(trait_block=trait_block)).run()
    _assert_same_scan(a, b)


def test_blocked_dense_bitwise_identical_ragged_tile(source, cohort):
    # block_p=5 over 12 traits: tiles (and tail blocks) of width 5, 5, 2 —
    # the ragged tail tile is computed identically in both decompositions
    a = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(block_p=5)).run()
    b = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(block_p=5, trait_block=5)).run()
    _assert_same_scan(a, b)


def test_blocked_fused_bitwise_identical(source, cohort):
    a = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="fused")).run()
    b = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="fused", trait_block=4)).run()
    _assert_same_scan(a, b)


@pytest.mark.parametrize("loco", [False, True])
def test_blocked_lmm_bitwise_identical(cohort, split_beds, loco):
    src = open_genotypes(",".join(split_beds))
    a = GenomeScan(src, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="lmm", loco=loco)).run()
    b = GenomeScan(src, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="lmm", loco=loco, trait_block=4)).run()
    _assert_same_scan(a, b)


def test_blocked_exact_dof_mode(source, cohort):
    from repro.core.association import AssocOptions

    opt = AssocOptions(dof_mode="exact")
    a = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(options=opt)).run()
    b = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(options=opt, trait_block=4)).run()
    _assert_same_scan(a, b)


def test_blocked_with_tiny_lru_still_identical(source, cohort):
    """Thrashing the device LRU (capacity 1, 3 blocks) re-stages every
    block per batch but must not change a single bit."""
    a = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=_cfg()).run()
    b = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(trait_block=4, panel_resident_blocks=1)).run()
    _assert_same_scan(a, b)


def test_multivariate_requires_unblocked(source, cohort):
    with pytest.raises(ValueError, match="unblocked"):
        GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(multivariate=True, trait_block=4))


# ------------------------------------------------------- checkpoint + resume


def test_resume_from_mid_block_cut(source, cohort, tmp_path):
    """Cut the checkpoint mid-panel — one whole batch plus a strict subset
    of another batch's trait blocks — and resume: bitwise-identical."""
    ckdir = str(tmp_path / "ck")
    cfg = _cfg(trait_block=5, checkpoint_dir=ckdir)
    full = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()

    mpath = os.path.join(ckdir, "manifest.json")
    mani = json.load(open(mpath))
    assert any("." in k for k in mani["completed"])  # cell-keyed manifest
    lost = [k for k in mani["completed"] if k.startswith("1.")]  # whole batch
    lost += ["2.1"]                                              # mid-panel cut
    for k in lost:
        mani["completed"].pop(k)
    json.dump(mani, open(mpath, "w"))

    res = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    _assert_same_scan(full, res)
    # and a fully-resumed scan (zero recomputed cells) matches too
    res2 = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    _assert_same_scan(full, res2)


def test_blocked_checkpoint_equals_unblocked_scan(source, cohort, tmp_path):
    unblocked = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                           config=_cfg()).run()
    blocked = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                         config=_cfg(trait_block=4,
                                     checkpoint_dir=str(tmp_path / "ck"))).run()
    _assert_same_scan(unblocked, blocked)


def test_checkpoint_refuses_changed_trait_block(source, cohort, tmp_path):
    ckdir = str(tmp_path / "ck")
    GenomeScan(source, cohort.phenotypes, cohort.covariates,
               config=_cfg(trait_block=5, checkpoint_dir=ckdir)).run()
    with pytest.raises(ValueError, match="different scan"):
        GenomeScan(source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(trait_block=4, checkpoint_dir=ckdir)).run()


# ------------------------------------------------------------ panel store


def test_panel_store_lru_bounds_residency(cohort):
    import jax.numpy as jnp

    from repro.core.residualize import covariate_basis

    q = covariate_basis(jnp.asarray(cohort.covariates), cohort.phenotypes.shape[0])
    blocks = TraitBlockPlanner(4, quantum=4).plan(cohort.phenotypes.shape[1])
    store = PanelStore.residualized(cohort.phenotypes, q, blocks, quantum=4,
                                    max_resident=2)
    assert store.n_blocks == len(blocks)
    for blk in blocks:
        dev = store.device_block(blk)
        assert dev.shape == (cohort.phenotypes.shape[0], blk.n_traits)
        np.testing.assert_array_equal(np.asarray(dev), store.host_block(blk))
        assert len(store._dev) <= 2
    # re-touching a resident block must not grow residency
    store.device_block(blocks[-1])
    assert len(store._dev) <= 2


# ------------------------------------------------------- error-path teardown


class _ExplodingSink(ResultSink):
    def __init__(self, after: int):
        self.after = after
        self.calls = 0

    def on_batch(self, view, payload):
        self.calls += 1
        if self.calls > self.after:
            raise RuntimeError("sink exploded mid-scan")


def _prefetch_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("prefetch-worker") and t.is_alive()]


def test_raising_sink_tears_down_prefetch_pool(source, cohort):
    """A sink raising mid-scan must propagate AND shut the prefetch worker
    pool down (no orphan decode threads, no wedged in-flight staging)."""
    assert _prefetch_threads() == []

    class Scan(GenomeScan):
        def _make_sinks(self, ckpt):
            return [*super()._make_sinks(ckpt), _ExplodingSink(after=1)]

    scan = Scan(source, cohort.phenotypes, cohort.covariates,
                config=_cfg(io_workers=3, prefetch_depth=3))
    with pytest.raises(RuntimeError, match="sink exploded"):
        scan.run()
    assert _prefetch_threads() == []
    # the machinery is not poisoned: a fresh scan on the same source works
    res = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=_cfg()).run()
    assert res.n_markers == source.n_markers


def test_raising_engine_step_tears_down_prefetch_pool(source, cohort):
    scan = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                      config=_cfg(io_workers=2))

    def boom(*a, **k):
        raise RuntimeError("step exploded")

    scan._step = boom
    with pytest.raises(RuntimeError, match="step exploded"):
        scan.run()
    assert _prefetch_threads() == []


# ------------------------------------------------------------- hit spilling


def test_hit_sink_spills_past_cap_without_changing_result(tmp_path):
    from repro.core.sinks import HitSink

    spill = str(tmp_path / "spill")
    rng = np.random.default_rng(0)
    chunks = [
        (rng.integers(0, 500, size=(n, 2)).astype(np.int32),
         rng.normal(size=(n, 3)).astype(np.float32))
        for n in (20, 1, 40, 0, 33, 17)
    ]
    plain = HitSink(5.0)
    spilling = HitSink(5.0, spill_dir=spill, spill_rows=32)
    for hits, stats in chunks:
        for sink in (plain, spilling):
            sink._append(hits, stats)
    parts = sorted(p for p in os.listdir(spill) if p.startswith("hits_spill_"))
    assert parts and spilling.spilled_rows >= 32, "cap must force parts to disk"
    a, b = plain.result(), spilling.result()
    np.testing.assert_array_equal(a["hits"], b["hits"])          # order kept
    np.testing.assert_array_equal(a["hit_stats"], b["hit_stats"])
    # consumed parts are intermediate state, removed once result() folds them
    assert not [p for p in os.listdir(spill) if p.startswith("hits_spill_")]
    # result() is repeatable: spilled rows were folded back, not lost
    again = spilling.result()
    np.testing.assert_array_equal(a["hits"], again["hits"])
    np.testing.assert_array_equal(a["hit_stats"], again["hit_stats"])
    # a crashed run's leftover parts are cleared by the next run's sink
    stale = os.path.join(spill, "hits_spill_00042.npz")
    np.savez(stale, hits=np.zeros((3, 2), np.int32), hit_stats=np.zeros((3, 3), np.float32))
    HitSink(5.0, spill_dir=spill, spill_rows=32)
    assert not os.path.exists(stale)


def test_hit_spill_through_the_scan(source, cohort, tmp_path):
    spill = str(tmp_path / "spill")
    ref = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                     config=_cfg(hit_threshold_nlp=1.0)).run()
    assert len(ref.hits) > 64  # the loose threshold floods the sink
    res = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                     config=_cfg(hit_threshold_nlp=1.0, spill_dir=spill,
                                 hit_spill_rows=32)).run()
    np.testing.assert_array_equal(ref.hits, res.hits)
    np.testing.assert_array_equal(ref.hit_stats, res.hit_stats)
    assert not [p for p in os.listdir(spill) if p.startswith("hits_spill_")]


def test_hit_sink_spill_composes_with_blocking_and_resume(source, cohort, tmp_path):
    ckdir, spill = str(tmp_path / "ck"), str(tmp_path / "spill")
    cfg = _cfg(hit_threshold_nlp=2.0, trait_block=5, checkpoint_dir=ckdir,
               spill_dir=spill, hit_spill_rows=16)
    full = GenomeScan(source, cohort.phenotypes, cohort.covariates, config=cfg).run()
    ref = GenomeScan(source, cohort.phenotypes, cohort.covariates,
                     config=_cfg(hit_threshold_nlp=2.0)).run()
    _assert_same_scan(ref, full)
