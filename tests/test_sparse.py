"""Sparse threshold-compacted p-value epilogue (DESIGN.md §13).

The contract under test: with ``sparse_epilogue=True`` the scan screens
every lane on t^2 against the host-inverted per-dof threshold, compacts
survivors into a fixed-capacity device buffer, and runs the exact 128-trip
CF only there — and the hit set, hit stats, best-trait tables, lambda-GC,
and checkpoint shards are all *bitwise-identical* to the dense full-tile
CF path, across dense/fused/lmm engines, blocked grids, overflowing
buffers, and the multi-device executor.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.specs import ScanConfig
from repro.core import association as A
from repro.core import stats as S
from repro.core.screening import GenomeScan
from repro.io import plink


@pytest.fixture(scope="module")
def source(cohort_files):
    return plink.PlinkBed(cohort_files["bed"])


def _run(source, cohort, **kw):
    base = dict(
        batch_markers=128, block_m=64, block_n=128, block_p=64,
        hit_threshold_nlp=3.0,
    )
    base.update(kw)
    return GenomeScan(
        source, cohort.phenotypes, cohort.covariates, config=ScanConfig(**base)
    ).run()


def _sorted(hits, stats):
    order = np.lexsort((hits[:, 1], hits[:, 0]))
    return hits[order], stats[order]


def _assert_identical(dense, sparse, label=""):
    np.testing.assert_array_equal(dense.best_nlp, sparse.best_nlp, err_msg=label)
    np.testing.assert_array_equal(
        dense.best_marker, sparse.best_marker, err_msg=label
    )
    dh, ds = _sorted(dense.hits, dense.hit_stats)
    sh, ss = _sorted(sparse.hits, sparse.hit_stats)
    np.testing.assert_array_equal(dh, sh, err_msg=label)
    np.testing.assert_array_equal(ds, ss, err_msg=label)
    assert dense.lambda_gc == sparse.lambda_gc, label
    np.testing.assert_array_equal(dense.maf, sparse.maf, err_msg=label)
    np.testing.assert_array_equal(dense.valid, sparse.valid, err_msg=label)


# ------------------------------------------------------------ plan building


def test_plan_refuses_degenerate_thresholds():
    assert A.plan_sparse_epilogue(0.0, 100.0) is None
    assert A.plan_sparse_epilogue(-2.0, 100.0) is None
    plan = A.plan_sparse_epilogue(7.301, 998.0)
    assert plan.t2_screen > 0 and plan.capacity >= 1


def test_plan_capacity_clamped_to_cell_area():
    plan = A.plan_sparse_epilogue(7.301, 998.0, capacity=4096, cell_area=128)
    assert plan.capacity == 128


def test_plan_capacity_rounds_to_simd_multiple():
    """Capacities round up to a multiple of 64 so the (capacity,) refine
    executable has no scalar remainder lanes (lane position must not be
    able to change a bit)."""
    assert A.plan_sparse_epilogue(7.301, 998.0, capacity=2).capacity == 64
    assert A.plan_sparse_epilogue(7.301, 998.0, capacity=65).capacity == 128
    assert A.plan_sparse_epilogue(7.301, 998.0, capacity=4096).capacity == 4096


def test_tie_breaks_match_dense_argmax_rule():
    """Exact t^2 ties (plus nlp plateaus) resolve to the first index in
    both paths — the redefined winner rule both share.  The step emits the
    winner *t*, not its nlp: every emitted p-value is refined host-side
    through the canonical executable."""
    dof = 998.0
    t = np.zeros((6, 3), np.float32)
    t[1, 0], t[4, 0] = 5.0, -5.0        # equal t^2, opposite sign
    t[2, 1], t[3, 1] = 3.0, 3.0         # exact duplicate
    r = (t / 40.0).astype(np.float32)
    plan = A.plan_sparse_epilogue(1.0, dof, capacity=t.size)
    out = {
        k: np.asarray(v)
        for k, v in A.sparse_epilogue_outputs(
            jnp.asarray(r), jnp.asarray(t), dof, plan
        ).items()
    }
    assert "batch_best_nlp" not in out and "hit_nlp" not in out  # no in-step CF
    np.testing.assert_array_equal(out["batch_best_row"], [1, 2, 0])
    np.testing.assert_array_equal(
        out["batch_best_t"], t[[1, 2, 0], np.arange(3)]
    )
    nlp = S.refine_neglog10p(out["batch_best_t"], dof)
    np.testing.assert_array_equal(nlp, S.refine_neglog10p(t[[1, 2, 0], np.arange(3)], dof))


# ----------------------------------------------------- scan-level identity


@pytest.mark.parametrize(
    "kw",
    [
        {"engine": "dense"},
        {"engine": "dense", "options": A.AssocOptions(dof_mode="exact")},
        {"engine": "fused"},
        {"engine": "lmm", "lmm_delta": 1.0},
        {"engine": "lmm", "lmm_delta": 1.0, "lmm_epilogue": "fused"},
    ],
    ids=["dense", "dense_exact", "fused", "lmm", "lmm_fused"],
)
def test_sparse_scan_bitwise_identical(source, cohort, kw):
    dense = _run(source, cohort, sparse_epilogue=False, **kw)
    sparse = _run(source, cohort, sparse_epilogue=True, **kw)
    _assert_identical(dense, sparse, str(kw))
    assert len(sparse.hits) > 0  # the comparison must not be vacuous


def test_sparse_blocked_grid_identical(source, cohort):
    dense = _run(source, cohort, sparse_epilogue=False, trait_block=64)
    sparse = _run(source, cohort, sparse_epilogue=True, trait_block=64)
    _assert_identical(dense, sparse, "blocked")


def test_sparse_overflow_falls_back_bitwise(source, cohort):
    """A permissive threshold with the minimum (64-lane) buffer overflows;
    the host fallback screens the pulled t tile and refines survivors
    through the same (capacity,) executable — identical results."""
    dense = _run(source, cohort, sparse_epilogue=False, hit_threshold_nlp=1.0)
    tiny = _run(source, cohort, sparse_epilogue=True, hit_capacity=2,
                hit_threshold_nlp=1.0)
    _assert_identical(dense, tiny, "overflow")
    assert len(dense.hits) > 64  # far beyond the rounded-up capacity


def test_sparse_checkpoint_shards_identical(source, cohort, tmp_path):
    """Committed shard *contents* match array-for-array: a scan
    checkpointed sparse resumes dense and vice versa (the flag is not
    fingerprinted)."""
    from repro.runtime.checkpoint import ScanCheckpoint

    dirs = {}
    for tag, flag in (("dense", False), ("sparse", True)):
        ck = str(tmp_path / tag)
        _run(source, cohort, sparse_epilogue=flag, trait_block=64,
             checkpoint_dir=ck)
        dirs[tag] = ScanCheckpoint.open_existing(ck)
    a, b = dirs["dense"], dirs["sparse"]
    cells = sorted(a.completed_cells())
    assert cells == sorted(b.completed_cells()) and cells
    for bi, ki in cells:
        sa, sb = a.load_cell(bi, ki), b.load_cell(bi, ki)
        assert sorted(sa) == sorted(sb), (bi, ki)
        for k in sa:
            np.testing.assert_array_equal(sa[k], sb[k], err_msg=f"{bi}.{ki}.{k}")


# -------------------------------------------------------- view-level sparse


def test_batchview_sparse_accessors(source, cohort):
    """A live sparse session serves hits from the compacted buffers (refined
    host-side through the canonical executable) and can still reconstruct
    the dense nlp tile for report/QC paths."""
    from repro.api import GridSpec, Study

    study = Study.from_arrays(source, cohort.phenotypes, cohort.covariates)
    plan = study.plan(
        grid=GridSpec(batch_markers=128, block_m=64, block_n=128, block_p=64),
        hit_threshold_nlp=3.0,
        sparse_epilogue=True,
    )
    session = plan.run()
    seen_hits = False
    for cell in session.events():
        v = cell.view
        assert v.is_sparse and not v.overflowed
        assert v.hit_capacity % 64 == 0
        if v.screen_count:
            keep = (v.hit_idx >= 0) & (v.hit_nlp >= 3.0)
            if keep.any():
                seen_hits = True
                flat = v.hit_idx[keep].astype(np.int64)
                # the cell's extracted rows come straight from the buffers
                np.testing.assert_array_equal(
                    cell.hits[:, 0] - cell.lo, flat // v.n_traits
                )
                np.testing.assert_array_equal(cell.hit_stats[:, 2], v.hit_nlp[keep])
                # the reconstructed tile agrees to CF accuracy (lane
                # positions differ, so bit-equality is not promised there)
                np.testing.assert_allclose(
                    v.nlp[flat // v.n_traits, flat % v.n_traits],
                    v.hit_nlp[keep], rtol=1e-5, atol=1e-5,
                )
    assert seen_hits


def test_batchview_overflow_flag(source, cohort):
    """screen_count past capacity raises the overflow flag; extraction
    still lands on the same rows via the host fallback."""
    from repro.api import GridSpec, Study

    study = Study.from_arrays(source, cohort.phenotypes, cohort.covariates)
    session = study.plan(
        grid=GridSpec(batch_markers=128, block_m=64, block_n=128, block_p=64),
        hit_threshold_nlp=1.0,
        sparse_epilogue=True,
        hit_capacity=2,
    ).run()
    flags = [cell.view.overflowed for cell in session.events()]
    assert any(flags)


# ------------------------------------------------------ multi-device (§12)


_CHILD = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import os.path as osp
    from repro.api import ExecSpec, GridSpec, LmmSpec, Study, TsvWriter
    from repro.io import open_genotypes, synth

    co = synth.make_cohort(n_samples=200, n_markers=400, n_traits=12,
                           n_causal=4, seed=5)
    d = tempfile.mkdtemp()
    beds = synth.write_split_plink(co, osp.join(d, "toy"), n_shards=3)
    src = open_genotypes(",".join(beds))
    study = Study.from_arrays(src, co.phenotypes, co.covariates)
    grid = GridSpec(batch_markers=128, block_m=64, block_n=128, block_p=4,
                    trait_block=4)
    FILES = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")

    def scan(tag, sparse, devices, **plan_kw):
        session = study.plan(
            grid=grid, hit_threshold_nlp=2.0, sparse_epilogue=sparse,
            executor=ExecSpec(devices=devices), **plan_kw,
        ).run()
        out = osp.join(d, tag)
        session.stream_to(TsvWriter(out))
        return {f: open(osp.join(out, f)).read() for f in FILES}

    out = {}
    for name, kw in {
        "dense": {},
        "lmm_loco": {"engine": "lmm",
                     "lmm": LmmSpec(loco=True, delta=1.0, epilogue="fused")},
    }.items():
        ref = scan(f"{name}_ref", False, 1, **kw)
        md = scan(f"{name}_md", True, 4, **kw)
        out[f"{name}_identical"] = md == ref
        out[f"{name}_hits"] = ref["hits.tsv"].count("\\n")
    print(json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def sparse_md_results():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        timeout=900, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("engine", ["dense", "lmm_loco"])
def test_sparse_multi_device_matches_dense_serial(sparse_md_results, engine):
    """sparse epilogue on 4 fake devices == dense epilogue on the serial
    walk — the §13 contract composed with the §12 executor contract."""
    assert sparse_md_results[f"{engine}_identical"] is True
    assert sparse_md_results[f"{engine}_hits"] > 1
