"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — unit/smoke tests
must see the real single-CPU device; only launch/dryrun.py forces the
512-device placeholder topology (in a subprocess)."""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.io import synth

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# Tooling byproducts that may legitimately appear in the checkout.
_TREE_IGNORED = {".pytest_cache", "__pycache__", ".hypothesis"}


@pytest.fixture(autouse=True)
def _no_repo_tree_dirt():
    """Fail any test that leaves new entries in the repo root (e.g. a
    subprocess child running with a repo cwd and writing relative paths —
    the historical ``hostB/`` leak).  Write under ``tmp_path`` instead."""
    before = set(os.listdir(_REPO_ROOT)) - _TREE_IGNORED
    yield
    new = (set(os.listdir(_REPO_ROOT)) - _TREE_IGNORED) - before
    assert not new, (
        f"test dirtied the repo root with {sorted(new)}; tests and their "
        "subprocesses must write under tmp_path"
    )


@pytest.fixture(scope="session")
def cohort():
    return synth.make_cohort(
        n_samples=400,
        n_markers=600,
        n_traits=12,
        n_causal=8,
        effect_size=0.6,
        missing_rate=0.02,
        seed=7,
    )


@pytest.fixture(scope="session")
def cohort_files(cohort, tmp_path_factory):
    stem = str(tmp_path_factory.mktemp("cohort") / "toy")
    return synth.write_cohort_files(cohort, stem)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
