"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — unit/smoke tests
must see the real single-CPU device; only launch/dryrun.py forces the
512-device placeholder topology (in a subprocess)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.io import synth


@pytest.fixture(scope="session")
def cohort():
    return synth.make_cohort(
        n_samples=400,
        n_markers=600,
        n_traits=12,
        n_causal=8,
        effect_size=0.6,
        missing_rate=0.02,
        seed=7,
    )


@pytest.fixture(scope="session")
def cohort_files(cohort, tmp_path_factory):
    stem = str(tmp_path_factory.mktemp("cohort") / "toy")
    return synth.write_cohort_files(cohort, stem)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
