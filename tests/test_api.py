"""The layered public API (repro.api): bind/plan/execute/emit.

Covers the Study -> plan -> ScanSession.events() -> writers pipeline:
spec validation, event-stream completeness, streaming-writer outputs
identical to the deprecated ScanResult shim's, the bounded-memory contract
of the sorted hit stream, checkpoint interop between the shim and the API
(same fingerprints, mid-grid resume through writers), the CLI subcommand
shell, and teardown of the trait-axis prefetch worker on error paths.
"""
from __future__ import annotations

import json
import os
import threading

import numpy as np
import pytest

from repro.api import (
    GridSpec,
    IOSpec,
    LmmSpec,
    NpzShardWriter,
    ResultWriter,
    Study,
    TsvWriter,
    available_writers,
    get_writer,
    register_writer,
)
from repro.api.session import CheckpointReplay
from repro.api.specs import ScanConfig
from repro.core.screening import GenomeScan
from repro.io import plink


@pytest.fixture(scope="module")
def source(cohort_files):
    return plink.PlinkBed(cohort_files["bed"])


@pytest.fixture(scope="module")
def study(source, cohort):
    return Study.from_arrays(source, cohort.phenotypes, cohort.covariates)


def _grid(**kw):
    base = dict(batch_markers=128, block_m=64, block_n=128, block_p=64)
    base.update(kw)
    return GridSpec(**base)


def _scan_result(source, cohort, **cfg_kw):
    base = dict(batch_markers=128, block_m=64, block_n=128, block_p=64)
    base.update(cfg_kw)
    return GenomeScan(
        source, cohort.phenotypes, cohort.covariates, config=ScanConfig(**base)
    ).run()


def _sorted_hits(res):
    order = np.lexsort((res.hits[:, 1], res.hits[:, 0]))
    return res.hits[order], res.hit_stats[order]


# ------------------------------------------------------------ bind + plan


def test_study_binds_files(cohort, cohort_files):
    study = Study.from_files(
        cohort_files["bed"], cohort_files["pheno"], cohort_files["cov"]
    )
    assert study.n_samples == cohort.phenotypes.shape[0]
    assert study.n_traits == cohort.phenotypes.shape[1]
    assert list(study.trait_names)[:2] == ["trait0", "trait1"]
    np.testing.assert_allclose(study.phenotypes, cohort.phenotypes, atol=2e-5)


def test_study_rejects_misaligned_arrays(source, cohort):
    with pytest.raises(ValueError, match="align"):
        Study.from_arrays(source, cohort.phenotypes[:-3])


def test_plan_validates_specs(study):
    with pytest.raises(ValueError, match="unknown scan engine"):
        study.plan(engine="nope")
    with pytest.raises(ValueError, match="engine='lmm'"):
        study.plan(engine="dense", lmm=LmmSpec(loco=True))
    with pytest.raises(ValueError, match="batch_markers"):
        study.plan(grid=GridSpec(batch_markers=0))
    with pytest.raises(ValueError, match="input_dtype"):
        study.plan(engine="dense", input_dtype="bf16")
    with pytest.raises(ValueError, match="epilogue"):
        study.plan(engine="lmm", lmm=LmmSpec(epilogue="nope"))
    with pytest.raises(ValueError, match="sharding mode"):
        study.plan(mode="diag")


def test_config_spec_roundtrip():
    cfg = ScanConfig.from_specs(
        engine="lmm",
        grid=GridSpec(batch_markers=64, trait_block=8, block_p=8),
        lmm=LmmSpec(loco=True, delta=1.5),
        io=IOSpec(io_workers=3, hit_spill_rows=77),
        maf_min=0.01,
    )
    assert cfg.engine == "lmm" and cfg.loco and cfg.lmm_delta == 1.5
    assert cfg.batch_markers == 64 and cfg.trait_block == 8
    assert cfg.io_workers == 3 and cfg.hit_spill_rows == 77
    assert cfg.grid_spec() == GridSpec(batch_markers=64, trait_block=8, block_p=8)
    assert cfg.lmm_spec() == LmmSpec(loco=True, delta=1.5)
    assert cfg.io_spec().io_workers == 3


# ---------------------------------------------------------------- execute


def test_events_cover_the_grid(study):
    session = study.plan(grid=_grid(trait_block=4, block_p=4)).run()
    seen = set()
    n_live = 0
    for cell in session.events():
        seen.add((cell.batch_index, cell.block_index))
        assert cell.n_markers == cell.hi - cell.lo
        assert cell.best_nlp.shape == (cell.n_traits,)
        assert not cell.replayed
        n_live += 1
        if cell.carries_marker_tracks:
            assert cell.maf is not None and cell.maf.shape == (cell.n_markers,)
        else:
            assert cell.maf is None
    assert len(seen) == session.n_batches * session.n_trait_blocks == n_live


def test_session_events_one_shot(study):
    session = study.plan(grid=_grid()).run()
    list(session.events())
    with pytest.raises(RuntimeError, match="one-shot"):
        next(session.events())


# ------------------------------------------------------------------- emit


def test_writer_registry():
    assert {"tsv", "npz"} <= set(available_writers())
    assert get_writer("tsv") is TsvWriter
    with pytest.raises(ValueError, match="unknown result writer"):
        get_writer("parquetish")

    calls = []

    @register_writer("_counting")
    class CountingWriter(ResultWriter):
        def open(self, session):
            calls.append("open")

        def write(self, cell):
            calls.append("write")

        def close(self):
            calls.append("close")
            return {"counted": calls.count("write")}

    try:
        assert get_writer("_counting") is CountingWriter
    finally:
        from repro.api import writers as W

        del W._WRITERS["_counting"]


def test_tsv_writer_matches_shim(study, source, cohort, tmp_path):
    """The acceptance contract: streaming TSV outputs == the deprecated
    ScanResult shim's hits/best/QC/lambda, on a blocked grid."""
    kw = dict(trait_block=4, block_p=4)
    res = _scan_result(source, cohort, hit_threshold_nlp=2.0, **kw)
    session = study.plan(grid=_grid(**kw), hit_threshold_nlp=2.0).run()
    out = tmp_path / "tsv"
    summary = session.stream_to(TsvWriter(str(out)))
    assert summary["hits"] == len(res.hits)
    assert summary["lambda_gc"] == res.lambda_gc

    hits, stats = _sorted_hits(res)
    expected = [
        f"{source.marker_ids[m]}\ttrait{t}\t{r:.5f}\t{tt:.4f}\t{nlp:.3f}"
        for (m, t), (r, tt, nlp) in zip(hits, stats)
    ]
    lines = (out / "hits.tsv").read_text().strip().splitlines()
    assert lines[0] == "marker\ttrait\tr\tt\tneglog10p"
    assert lines[1:] == expected

    best = (out / "per_trait_best.tsv").read_text().strip().splitlines()[1:]
    assert len(best) == res.n_traits
    for t, line in enumerate(best):
        name, mid, nlp = line.split("\t")
        assert name == f"trait{t}"
        want = source.marker_ids[int(res.best_marker[t])] if res.best_marker[t] >= 0 else "NA"
        assert mid == want
        assert float(nlp) == pytest.approx(float(res.best_nlp[t]), abs=5e-4)

    qc = (out / "qc.tsv").read_text().strip().splitlines()[1:]
    assert len(qc) == res.n_markers
    m0 = qc[0].split("\t")
    assert m0[0] == source.marker_ids[0]
    assert float(m0[1]) == pytest.approx(float(res.maf[0]), abs=5e-6)


def test_npz_writer_matches_shim(study, source, cohort, tmp_path):
    res = _scan_result(source, cohort, hit_threshold_nlp=2.0)
    session = study.plan(grid=_grid(), hit_threshold_nlp=2.0).run()
    out = tmp_path / "npz"
    summary = session.stream_to(NpzShardWriter(str(out)))
    hits, stats = _sorted_hits(res)
    got_h, got_s = [], []
    for p in summary["hit_shards"]:
        with np.load(p) as z:
            got_h.append(z["hits"])
            got_s.append(z["hit_stats"])
    np.testing.assert_array_equal(np.concatenate(got_h), hits)
    np.testing.assert_array_equal(np.concatenate(got_s), stats)
    with np.load(summary["best_npz"]) as z:
        np.testing.assert_array_equal(z["best_nlp"], res.best_nlp)
        np.testing.assert_array_equal(z["best_marker"], res.best_marker)
    with np.load(summary["qc_npz"]) as z:
        np.testing.assert_array_equal(z["maf"], res.maf)
        np.testing.assert_array_equal(z["valid"], res.valid)


def test_parquet_writer_registered_only_with_pyarrow():
    """The registry gate: 'parquet' is offered iff pyarrow imports — its
    absence means skip-not-fail everywhere (tests included)."""
    from repro.api.writers import HAVE_PARQUET

    assert ("parquet" in available_writers()) == HAVE_PARQUET


def test_parquet_writer_matches_tsv(study, source, cohort, tmp_path):
    """One row group per flushed marker batch, globally (marker, trait)
    sorted, same rows as the TSV writer, byte-stable across identical
    runs."""
    pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq

    kw = dict(grid=_grid(trait_block=4, block_p=4), hit_threshold_nlp=2.0)
    tsv_out, pq_out = tmp_path / "tsv", tmp_path / "pq"
    study.plan(**kw).run().stream_to(TsvWriter(str(tsv_out)))
    summary = study.plan(**kw).run().stream_to(get_writer("parquet")(str(pq_out)))

    table = pq.read_table(summary["hits_parquet"])
    assert [f.name for f in table.schema] == [
        "marker", "trait", "marker_index", "trait_index", "r", "t", "neglog10p"
    ]
    tsv_rows = (tsv_out / "hits.tsv").read_text().strip().splitlines()[1:]
    assert table.num_rows == len(tsv_rows) == summary["hits"]
    got = [
        f"{m}\t{t}\t{r:.5f}\t{tt:.4f}\t{nlp:.3f}"
        for m, t, r, tt, nlp in zip(
            table["marker"].to_pylist(), table["trait"].to_pylist(),
            table["r"].to_pylist(), table["t"].to_pylist(),
            table["neglog10p"].to_pylist(),
        )
    ]
    assert got == tsv_rows                      # same rows, same global order
    pf = pq.ParquetFile(summary["hits_parquet"])
    assert pf.num_row_groups == summary["hit_row_groups"]
    # one row group per flushed marker batch (batches with hits only)
    hit_batches = {int(i) // 128 for i in table["marker_index"].to_pylist()}
    assert pf.num_row_groups == len(hit_batches)

    best = pq.read_table(summary["per_trait_best_parquet"])
    assert best.num_rows == study.n_traits
    qc = pq.read_table(summary["qc_parquet"])
    assert qc.num_rows == source.n_markers

    # byte-stable: an identical scan writes identical bytes
    pq_out2 = tmp_path / "pq2"
    study.plan(**kw).run().stream_to(get_writer("parquet")(str(pq_out2)))
    assert (pq_out / "hits.parquet").read_bytes() == (pq_out2 / "hits.parquet").read_bytes()
    assert (pq_out / "qc.parquet").read_bytes() == (pq_out2 / "qc.parquet").read_bytes()


def test_streaming_hit_memory_is_bounded(study, source, cohort, tmp_path):
    """The streaming-writer contract: with a flood of hits (threshold 0,
    every cell full) and a small spill cap, peak resident hit rows never
    exceed one grid cell plus the cap — the writer path cannot materialize
    the dense (markers x traits) hit table."""
    kw = dict(trait_block=4, block_p=4)
    cap = 256
    session = study.plan(grid=_grid(**kw), hit_threshold_nlp=0.0).run()
    w = TsvWriter(str(tmp_path / "bounded"), spill_rows=cap)
    summary = session.stream_to(w)
    m, p = source.n_markers, cohort.phenotypes.shape[1]
    assert summary["hits"] == m * p              # every cell is a hit
    max_cell_rows = 128 * 4                      # batch_markers x trait_block
    assert w.peak_hit_rows_in_ram > 0
    assert w.peak_hit_rows_in_ram <= cap + max_cell_rows
    # emission transiently materializes at most one marker batch (the
    # within-batch sort unit), never the scan's full hit table
    assert w._hits.peak_flush_rows <= 128 * p
    assert summary["hits"] > cap + max_cell_rows  # the bound actually bit
    # spill parts are consumed and removed
    assert not os.path.isdir(os.path.join(str(tmp_path / "bounded"), ".hit_runs"))
    # ... and the flood is still emitted exactly (count + sortedness)
    lines = (tmp_path / "bounded" / "hits.tsv").read_text().strip().splitlines()[1:]
    assert len(lines) == m * p


def test_writers_identical_across_spill(study, tmp_path):
    """Spilling must never change emitted bytes."""
    a = tmp_path / "nospill"
    b = tmp_path / "spill"
    s1 = study.plan(grid=_grid(trait_block=4, block_p=4), hit_threshold_nlp=1.0).run()
    s1.stream_to(TsvWriter(str(a)))
    s2 = study.plan(grid=_grid(trait_block=4, block_p=4), hit_threshold_nlp=1.0).run()
    s2.stream_to(TsvWriter(str(b), spill_rows=16))
    assert (a / "hits.tsv").read_text() == (b / "hits.tsv").read_text()
    assert (a / "per_trait_best.tsv").read_text() == (b / "per_trait_best.tsv").read_text()


# ------------------------------------------------- checkpoint + resume


def test_api_resumes_shim_checkpoint_and_vice_versa(study, source, cohort, tmp_path):
    """The shim and the API compute identical fingerprints: a checkpoint
    written by one is resumed by the other (cells all replayed)."""
    ck = str(tmp_path / "ck")
    cfg_kw = dict(trait_block=4, block_p=4)
    res = _scan_result(source, cohort, checkpoint_dir=ck, **cfg_kw)
    session = study.plan(grid=_grid(**cfg_kw), checkpoint_dir=ck).run()
    cells = list(session.events())
    assert all(c.replayed for c in cells)
    assert len(cells) == session.n_batches * session.n_trait_blocks
    best = np.zeros(res.n_traits, np.float32)
    marker = np.full(res.n_traits, -1, np.int64)
    for c in sorted(cells, key=lambda c: (c.batch_index, c.block_index)):
        sl = slice(c.t_lo, c.t_hi)
        better = c.best_nlp > best[sl]
        best[sl] = np.where(better, c.best_nlp, best[sl])
        marker[sl] = np.where(better, c.lo + c.best_row.astype(np.int64), marker[sl])
    np.testing.assert_array_equal(best, res.best_nlp)
    np.testing.assert_array_equal(marker, res.best_marker)


def test_writer_output_identical_across_mid_grid_resume(study, tmp_path):
    """Cut the checkpoint mid-panel, resume through writers: the replayed
    (out-of-order) cells must restore exact sorted output."""
    ck = str(tmp_path / "ck")
    plan_kw = dict(grid=_grid(trait_block=4, block_p=4), hit_threshold_nlp=1.0)
    full = study.plan(checkpoint_dir=ck, **plan_kw).run()
    out_full = tmp_path / "full"
    full.stream_to(TsvWriter(str(out_full)))

    mpath = os.path.join(ck, "manifest.json")
    mani = json.load(open(mpath))
    lost = [k for k in mani["completed"] if k.startswith("1.")] + ["2.1"]
    for k in lost:
        mani["completed"].pop(k)
    json.dump(mani, open(mpath, "w"))

    resumed = study.plan(checkpoint_dir=ck, **plan_kw).run()
    out_res = tmp_path / "resumed"
    resumed.stream_to(TsvWriter(str(out_res)))
    for name in ("hits.tsv", "per_trait_best.tsv", "qc.tsv"):
        assert (out_full / name).read_text() == (out_res / name).read_text(), name


def test_checkpoint_replay_merges_offline(study, source, tmp_path):
    ck = str(tmp_path / "ck")
    plan_kw = dict(grid=_grid(trait_block=4, block_p=4), hit_threshold_nlp=1.0)
    session = study.plan(checkpoint_dir=ck, **plan_kw).run()
    out_live = tmp_path / "live"
    session.stream_to(TsvWriter(str(out_live)))

    replay = CheckpointReplay(ck, marker_ids=source.marker_ids)
    assert replay.complete
    assert replay.n_markers == source.n_markers
    assert replay.n_traits == study.n_traits
    out_merged = tmp_path / "merged"
    replay.stream_to(TsvWriter(str(out_merged)))
    assert (out_live / "hits.tsv").read_text() == (out_merged / "hits.tsv").read_text()
    assert (out_live / "per_trait_best.tsv").read_text() == (
        out_merged / "per_trait_best.tsv"
    ).read_text()


# ------------------------------------------------------- error teardown


def _scan_threads():
    return [
        t for t in threading.enumerate()
        if t.is_alive() and (
            t.name.startswith("prefetch-worker") or t.name.startswith("panel-prefetch")
        )
    ]


def test_shim_sinks_share_one_payload_dict(source, cohort):
    """The historical sink contract through the shim: live cells flow
    through ``on_batch`` with one payload dict shared along the chain, so
    a custom sink appended via ``_make_sinks`` sees its predecessors'
    contributions (best/hits/QC keys)."""
    from repro.core.sinks import ResultSink as Sink

    seen_keys = []

    class Observer(Sink):
        def on_batch(self, view, payload):
            seen_keys.append(set(payload))

        def merge_shard(self, shard, lo, hi):
            pass

    class Scan(GenomeScan):
        def _make_sinks(self, ckpt):
            return [*super()._make_sinks(ckpt), Observer()]

    Scan(source, cohort.phenotypes, cohort.covariates,
         config=ScanConfig(batch_markers=128, block_m=64, block_n=128,
                           block_p=64)).run()
    assert seen_keys and all(
        {"best_nlp", "best_row", "hits", "hit_stats", "maf", "valid",
         "t_probe"} <= keys
        for keys in seen_keys
    )


def test_failing_writer_open_aborts_earlier_writers(study, tmp_path):
    """A later writer failing to open must abort the already-opened ones
    (no leaked half-written hits.tsv handles)."""

    class FailsToOpen(ResultWriter):
        def open(self, session):
            raise PermissionError("cannot create output dir")

    tsv = TsvWriter(str(tmp_path / "o"))
    session = study.plan(grid=_grid()).run()
    with pytest.raises(PermissionError):
        session.stream_to(tsv, FailsToOpen())
    assert tsv._f.closed
    assert _scan_threads() == []


def test_raising_writer_tears_down_pipeline(study, tmp_path):
    assert _scan_threads() == []

    class Exploding(ResultWriter):
        def open(self, session):
            self.calls = 0

        def write(self, cell):
            self.calls += 1
            if self.calls > 1:
                raise RuntimeError("writer exploded mid-stream")

    session = study.plan(grid=_grid(trait_block=4, block_p=4)).run()
    with pytest.raises(RuntimeError, match="writer exploded"):
        session.stream_to(TsvWriter(str(tmp_path / "o")), Exploding())
    assert _scan_threads() == []


def test_clean_scan_leaves_no_threads(study):
    list(study.plan(grid=_grid(trait_block=4, block_p=4)).run().events())
    assert _scan_threads() == []


def test_panel_prefetcher_stages_ahead_and_shuts_down():
    """The trait-axis look-ahead: requests reach the stage callable off the
    caller's thread, staging errors are swallowed (the consumer's own
    synchronous call surfaces them), and shutdown joins the worker."""
    import time

    from repro.core.panels import PanelPrefetcher
    from repro.runtime.prefetch import TraitBlock

    staged, done = [], threading.Event()

    def stage(batch, block):
        staged.append((batch, block.index))
        if block.index == 13:
            raise RuntimeError("staging failed (must be swallowed)")
        done.set()

    pf = PanelPrefetcher(stage, name="panel-prefetch-test")
    pf.request("batch0", TraitBlock(index=13, lo=0, hi=4))   # raises inside
    pf.request("batch0", TraitBlock(index=1, lo=4, hi=8))
    assert done.wait(timeout=5.0)
    deadline = time.time() + 5.0
    while len(staged) < 2 and time.time() < deadline:
        time.sleep(0.01)
    assert ("batch0", 1) in staged and ("batch0", 13) in staged
    pf.shutdown()
    assert not any(t.name == "panel-prefetch-test" and t.is_alive()
                   for t in threading.enumerate())
    pf.request("batch1", TraitBlock(index=2, lo=8, hi=12))   # no-op after stop
    pf.shutdown()                                            # idempotent


def test_panel_blocks_resident_after_lookahead(study):
    """During a blocked scan the look-ahead keeps the next block staged: by
    the end of any batch the panel LRU holds up to its capacity of blocks
    without the consumer having had to stage them synchronously (the LRU is
    shared, so we assert residency post-scan)."""
    plan = study.plan(grid=_grid(trait_block=4, block_p=4))
    session = plan.run()
    list(session.events())
    store = plan.prepare().panels
    assert len(store._dev) >= min(store.n_blocks, 2)


# ------------------------------------------------------------------- CLI


def test_cli_scan_subcommand(cohort, cohort_files, tmp_path):
    from repro.launch.gwas import main

    out = tmp_path / "results"
    main([
        "scan",
        "--genotypes", cohort_files["bed"],
        "--pheno", cohort_files["pheno"],
        "--covar", cohort_files["cov"],
        "--out", str(out),
        "--batch-markers", "128",
        "--trait-block", "4", "--block-p", "4",
        "--writer", "tsv,npz",
    ])
    summary = json.loads((out / "summary.json").read_text())
    assert summary["markers"] == cohort.dosages.shape[0]
    assert summary["traits"] == cohort.phenotypes.shape[1]
    assert summary["writers"] == ["tsv", "npz"]
    assert summary["trait_blocks"] == 3
    lines = (out / "hits.tsv").read_text().strip().splitlines()
    assert lines[0].split("\t") == ["marker", "trait", "r", "t", "neglog10p"]
    found = {(r.split("\t")[0], r.split("\t")[1]) for r in lines[1:]}
    for m, t, _ in cohort.effects:
        assert (cohort.marker_ids[m], f"trait{t}") in found
    assert (out / "best.npz").exists() and (out / "qc.tsv").exists()


def test_cli_merge_and_report(cohort, cohort_files, tmp_path, capsys):
    from repro.launch.gwas import main

    ck, out1, out2 = str(tmp_path / "ck"), tmp_path / "r1", tmp_path / "r2"
    main([
        "scan",
        "--genotypes", cohort_files["bed"],
        "--pheno", cohort_files["pheno"],
        "--out", str(out1),
        "--batch-markers", "128",
        "--hit-threshold", "2.0",
        "--checkpoint-dir", ck,
    ])
    main([
        "merge",
        "--checkpoint-dir", ck,
        "--out", str(out2),
        "--genotypes", cohort_files["bed"],
        "--pheno", cohort_files["pheno"],
    ])
    assert (out1 / "hits.tsv").read_text() == (out2 / "hits.tsv").read_text()
    merged = json.loads((out2 / "summary.json").read_text())
    assert merged["complete"] is True

    capsys.readouterr()
    main(["report", "--out", str(out1), "--top", "5"])
    rep = capsys.readouterr().out
    assert "scan summary" in rep and "top 5" in rep


def test_cli_grm_subcommand(cohort, cohort_files, tmp_path):
    from repro.core.grm import stream_grm
    from repro.launch.gwas import main

    out = str(tmp_path / "grm.npz")
    main(["grm", "--genotypes", cohort_files["bed"], "--out", out,
          "--batch-markers", "128", "--spectrum"])
    with np.load(out) as z:
        k = z["k"]
        assert "s" in z and "u" in z
    ref = stream_grm(plink.PlinkBed(cohort_files["bed"]), batch_markers=128)
    np.testing.assert_allclose(k, ref.full(), atol=1e-6)
