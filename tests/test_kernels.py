"""Pallas kernel validation: interpret-mode sweep over shapes/dtypes vs the
pure-jnp oracle (``ref.py``), per the assignment's per-kernel contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tstat as TS
from repro.kernels.gwas_dot import ops, ref


def _mk(m, n, seed=0, missing=0.02):
    rng = np.random.default_rng(seed)
    codes = rng.choice(
        [0, 1, 2, 3], p=[0.3, missing, 0.4 - missing, 0.3], size=(m, n)
    ).astype(np.uint8)
    return codes, rng


@pytest.mark.parametrize(
    "m,n,p,bm,bn,bp",
    [
        (64, 256, 32, 32, 128, 16),     # aligned
        (70, 1000, 40, 32, 128, 16),    # all dims ragged
        (8, 128, 8, 8, 128, 8),         # single tile
        (256, 512, 128, 128, 256, 64),  # production-like ratios
        (33, 131, 17, 16, 64, 16),      # prime-ish everything
    ],
)
def test_gwas_dot_shape_sweep(m, n, p, bm, bn, bp):
    codes, rng = _mk(m, n)
    mean, inv_std, _ = ops.marker_stats_from_codes(codes)
    y = rng.normal(size=(n, p)).astype(np.float32)
    r_ref, t_ref = ref.gwas_dot_ref(
        jnp.asarray(codes.astype(np.int32)), jnp.asarray(mean), jnp.asarray(inv_std),
        jnp.asarray(y), n_samples=n, dof=n - 2,
    )
    packed = ops.pack_tiled(codes, bn)
    r, t = ops.gwas_dot(
        packed, mean, inv_std, y,
        n_samples=n, dof=n - 2, block_m=bm, block_n=bn, block_p=bp,
    )
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref), atol=2e-6)
    np.testing.assert_allclose(np.asarray(t), np.asarray(t_ref), atol=2e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-6), (jnp.bfloat16, 5e-3)])
def test_gwas_dot_dtype_sweep(dtype, atol):
    codes, rng = _mk(48, 512, seed=3)
    mean, inv_std, _ = ops.marker_stats_from_codes(codes)
    y = rng.normal(size=(512, 24)).astype(np.float32)
    r_ref, _ = ref.gwas_dot_ref(
        jnp.asarray(codes.astype(np.int32)), jnp.asarray(mean), jnp.asarray(inv_std),
        jnp.asarray(y), n_samples=512, dof=510,
    )
    packed = ops.pack_tiled(codes, 128)
    r, _ = ops.gwas_dot(
        packed, mean, inv_std, y,
        n_samples=512, dof=510, block_m=16, block_n=128, block_p=8, input_dtype=dtype,
    )
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref), atol=atol)


def test_gwas_dot_all_missing_and_monomorphic():
    codes = np.zeros((8, 128), np.uint8)
    codes[0, :] = 1          # all missing
    codes[1, :] = 3          # monomorphic (all dosage 0)
    codes[2, ::2] = 2        # polymorphic het pattern
    mean, inv_std, valid = ops.marker_stats_from_codes(codes)
    assert not valid[0] and not valid[1] and valid[2]
    y = np.random.default_rng(0).normal(size=(128, 8)).astype(np.float32)
    packed = ops.pack_tiled(codes, 128)
    r, t = ops.gwas_dot(packed, mean, inv_std, y, n_samples=128, dof=126,
                        block_m=8, block_n=128, block_p=8)
    assert np.all(np.asarray(r)[0] == 0.0) and np.all(np.asarray(r)[1] == 0.0)
    assert np.all(np.isfinite(np.asarray(t)))


def test_pack_tiled_roundtrip_through_plink_layout():
    codes, _ = _mk(20, 333, seed=9)
    from repro.io.plink import pack_dosages

    dosage = np.where(codes == 1, -9, 2 - codes.astype(np.int32) + (codes.astype(np.int32) >> 1)).astype(np.int8)
    plink_packed = pack_dosages(dosage)
    recodes = ops.unpack_plink_to_codes(plink_packed, 333)
    np.testing.assert_array_equal(recodes, codes)
    tiled = ops.repack_plink_tiled(plink_packed, 333, 128)
    np.testing.assert_array_equal(tiled, ops.pack_tiled(codes, 128))


def test_marker_stats_match_float_path():
    codes, _ = _mk(31, 517, seed=5)
    from repro.core.association import standardize_genotype_batch

    c32 = codes.astype(np.int32)
    dosage = np.where(c32 == 1, -9, 2 - c32 + (c32 >> 1)).astype(np.float32)
    _, ms = standardize_genotype_batch(jnp.asarray(dosage))
    mean, inv_std, valid = ops.marker_stats_from_codes(codes)
    np.testing.assert_allclose(mean, np.asarray(ms.mean), atol=1e-5)
    np.testing.assert_allclose(inv_std, np.asarray(ms.inv_std), atol=1e-4)


@pytest.mark.parametrize("m,p", [(64, 64), (100, 37), (16, 256)])
def test_tstat_kernel(m, p, rng):
    r = (rng.random((m, p)).astype(np.float32) - 0.5) * 1.8
    out = TS.tstat(jnp.asarray(r), 998, block_m=32, block_p=32)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(TS.tstat_ref(jnp.asarray(r), 998)), atol=1e-4
    )
