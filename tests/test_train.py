"""Training substrate: overfit, microbatch equivalence, optimizer math,
checkpoint round trip, residualize/multivariate units."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import multivariate as MV
from repro.core.residualize import covariate_basis, residualize_and_standardize
from repro.train.data import make_batch
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.train.train_step import TrainStepConfig, build_train_step, init_train_state

KEY = jax.random.PRNGKey(0)
SHAPE = ShapeConfig("t", 32, 4, "train")


def test_overfit_fixed_batch():
    cfg = get_config("deepseek-coder-33b").reduced()
    tcfg = TrainStepConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=1))
    params, opt = init_train_state(cfg, tcfg, KEY, max_positions=64)
    step = build_train_step(cfg, tcfg=tcfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    first = None
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first - 1.0


def test_microbatch_equivalence():
    """Same total batch through 1 vs 4 microbatches gives the same update
    (up to accumulation rounding)."""
    cfg = get_config("gemma-7b").reduced()
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    outs = {}
    for n_micro in (1, 4):
        tcfg = TrainStepConfig(n_microbatches=n_micro,
                               optimizer=AdamWConfig(lr=1e-3, warmup_steps=1))
        params, opt = init_train_state(cfg, tcfg, KEY, max_positions=64)
        step = build_train_step(cfg, tcfg=tcfg, donate=False)
        p2, _, m = step(params, opt, batch)
        outs[n_micro] = (p2, float(m["loss"]))
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        outs[1][0], outs[4][0],
    )
    assert max(jax.tree.leaves(d)) < 2e-2
    assert abs(outs[1][1] - outs[4][1]) < 5e-2


def test_remat_policies_same_loss():
    cfg = get_config("gemma2-9b").reduced()
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    losses = {}
    for remat in ("none", "dots", "full"):
        tcfg = TrainStepConfig(remat=remat)
        params, opt = init_train_state(cfg, tcfg, KEY, max_positions=64)
        step = build_train_step(cfg, tcfg=tcfg, donate=False)
        _, _, m = step(params, opt, batch)
        losses[remat] = float(m["loss"])
    assert abs(losses["none"] - losses["full"]) < 1e-4
    assert abs(losses["none"] - losses["dots"]) < 1e-4


def test_adamw_against_reference():
    cfg = AdamWConfig(lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                      weight_decay=0.0, clip_norm=1e9, warmup_steps=1, total_steps=10**9)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3], jnp.float32)}
    state = adamw_init(cfg, params)
    new, state, metrics = adamw_update(cfg, grads, state, params)
    g = np.asarray([0.1, 0.2, -0.3])
    m = 0.1 * g
    v = 0.001 * g**2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    # warmup factor at count=1 with warmup_steps=1 -> full lr; cosine ~ 1.
    expected = np.asarray([1.0, -2.0, 3.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]), expected, rtol=1e-4)
    assert float(metrics["grad_norm"]) == pytest.approx(np.linalg.norm(g), rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    lr5 = float(cosine_schedule(cfg, jnp.asarray(5)))
    lr10 = float(cosine_schedule(cfg, jnp.asarray(10)))
    lr110 = float(cosine_schedule(cfg, jnp.asarray(110)))
    assert lr5 == pytest.approx(0.5, abs=1e-6)
    assert lr10 == pytest.approx(1.0, abs=1e-6)
    assert lr110 < 1e-6


def test_bf16_optimizer_state_dtype():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    tcfg = TrainStepConfig(optimizer=AdamWConfig(state_dtype="bfloat16"))
    params, opt = init_train_state(cfg, tcfg, KEY, max_positions=64)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(opt.m))
    step = build_train_step(cfg, tcfg=tcfg, donate=False)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    _, _, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))


def test_chunked_loss_matches_full():
    """The chunked cross-entropy path (never materializing full logits) must
    reproduce the dense loss bit-for-bit up to f32 reduction order."""
    for arch in ("gemma-7b", "whisper-small"):
        cfg = get_config(arch).reduced()
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
        params, opt = init_train_state(cfg, TrainStepConfig(), KEY, max_positions=64)
        losses = {}
        for chunk in (0, 8):
            tcfg = TrainStepConfig(loss_chunk=chunk)
            step = build_train_step(cfg, tcfg=tcfg, donate=False)
            _, _, m = step(params, opt, batch)
            losses[chunk] = float(m["loss"])
        assert abs(losses[0] - losses[8]) < 1e-3, (arch, losses)


def test_vocab_padding_masked_in_logits():
    """Padded vocab slots must never win an argmax or alter the loss."""
    from repro.models import api as M

    cfg = get_config("granite-moe-1b-a400m").reduced()  # vocab 512 -> pad 512
    import dataclasses

    cfg = dataclasses.replace(cfg, vocab=500)  # force padding (500 -> 512)
    params = M.init_model(cfg, KEY, max_positions=64)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, 0).items()}
    logits, _ = M.train_logits(cfg, params, batch)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool((logits[..., cfg.vocab :] < -1e30).all())
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab


def test_residualize_removes_covariates(rng):
    n, q = 300, 4
    c = rng.normal(size=(n, q)).astype(np.float32)
    y = (c @ rng.normal(size=(q, 5)) + 0.1 * rng.normal(size=(n, 5))).astype(np.float32)
    qb = covariate_basis(jnp.asarray(c), n)
    panel = residualize_and_standardize(jnp.asarray(y), qb)
    resid = np.asarray(panel.y)
    # residuals orthogonal to covariates and mean-zero, unit variance
    assert np.abs(resid.mean(0)).max() < 1e-4
    assert np.abs(resid.std(0) - 1).max() < 1e-3
    assert np.abs(c.T @ resid / n).max() < 1e-4


def test_covariate_basis_rank_deficient(rng):
    n = 100
    c = rng.normal(size=(n, 2)).astype(np.float32)
    c = np.concatenate([c, c[:, :1] * 2.0], axis=1)  # exact collinearity
    qb = np.asarray(covariate_basis(jnp.asarray(c), n))
    # basis columns orthonormal-or-zero; rank = 3 (intercept + 2)
    gram = qb.T @ qb
    rank = np.sum(np.abs(np.diag(gram)) > 0.5)
    assert rank == 3


def test_whitening_identity(rng):
    n, p = 500, 6
    y = rng.normal(size=(n, p)).astype(np.float32)
    y[:, 3] = y[:, 0] * 0.9 + 0.1 * y[:, 3]  # correlated traits
    qb = covariate_basis(None, n)
    panel = residualize_and_standardize(jnp.asarray(y), qb)
    w, eig = MV.whiten_panel(panel.y)
    yw = np.asarray(panel.y) @ np.asarray(w)
    corr = yw.T @ yw / n
    keep = np.diag(corr) > 0.5
    np.testing.assert_allclose(corr[np.ix_(keep, keep)], np.eye(keep.sum()), atol=5e-2)
    meff = float(MV.effective_tests(eig))
    assert 1.0 <= meff <= p
