"""Statistical epilogue vs scipy oracles across the full (dof, t) envelope."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from scipy import stats as sps

from repro.core import stats as S


@pytest.mark.parametrize("nu", [2, 5, 18, 100, 1000, 4095, 4097, 21000, 499000, 2000000])
def test_neglog10_p_vs_scipy(nu):
    worst = 0.0
    for t in [0.0, 0.3, 1.0, 2.0, 2.44, 2.46, 3.2, 5.0, 10.0, 12.1, 30.0, 100.0]:
        ours = float(S.neglog10_p_from_t(jnp.float32(t), float(nu)))
        if t == 0.0:
            assert ours == 0.0
            continue
        ref = -(sps.t.logsf(t, nu) + math.log(2)) / math.log(10)
        if math.isinf(ref):
            assert ours > 300  # beyond float64, ours keeps going
            continue
        worst = max(worst, abs(ours - ref) / max(abs(ref), 1e-2))
    assert worst < 5e-3, worst


@pytest.mark.parametrize("nu", [2, 5, 18, 100, 1000, 4095, 4097, 21000, 499000, 2000000])
def test_neglog10_p_audit_full_envelope(nu):
    """Exactness audit for the sparse-epilogue contract (DESIGN.md §13):
    the same <5e-3 relative envelope as the spot-check above, but over a
    dense t grid out to 1e3 — the range the compacted refine actually
    evaluates (screened survivors are arbitrarily deep in the tail)."""
    ts = np.concatenate(
        [np.linspace(0.01, 30.0, 40), np.geomspace(30.0, 1000.0, 25)]
    )
    nlp = np.asarray(S.neglog10_p_from_t(jnp.asarray(ts, jnp.float32), float(nu)))
    worst = 0.0
    for t, ours in zip(ts, nlp):
        ref = -(sps.t.logsf(t, nu) + math.log(2)) / math.log(10)
        if math.isinf(ref) or math.isnan(ref):
            assert ours > 300
            continue
        worst = max(worst, abs(float(ours) - ref) / max(abs(ref), 1e-2))
    assert worst < 5e-3, (nu, worst)


@pytest.mark.parametrize("nu", [10.0, 998.0, 4097.0, 21000.0])
def test_refine_is_canonical_and_deterministic(nu, rng):
    """XLA's CF codegen is fusion-context-sensitive: the same t evaluated
    at a different buffer shape can differ in the last f32 bit.  The §13
    bitwise contract therefore rests on ``refine_neglog10p``: one cached
    executable per (shape, dof), so (a) repeated calls are bit-identical,
    (b) a chunked width=W call over k <= W values equals the direct (W,)
    call on the zero-padded buffer — exactly how the compact-buffer and
    host-fallback paths line up — and (c) values stay within the CF's
    accuracy envelope of the tile evaluation."""
    t = rng.normal(0, 8, 10).astype(np.float32)
    a = S.refine_neglog10p(t, nu, width=64)
    b = S.refine_neglog10p(np.pad(t, (0, 54)), nu)
    np.testing.assert_array_equal(a, b[:10])
    np.testing.assert_array_equal(a, S.refine_neglog10p(t, nu, width=64))
    # multi-chunk: 100 values through width=64 -> two chunks, same exe
    big = rng.normal(0, 8, 100).astype(np.float32)
    c = S.refine_neglog10p(big, nu, width=64)
    assert c.shape == (100,)
    np.testing.assert_array_equal(c[:64], S.refine_neglog10p(big[:64], nu))
    # tolerance cross-check vs the in-step tile evaluation
    tile = np.asarray(S.neglog10_p_from_t(jnp.asarray(big.reshape(10, 10)), nu))
    np.testing.assert_allclose(c, tile.ravel(), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("thr", [1.0, 3.0, 7.301, 20.0])
@pytest.mark.parametrize("nu", [5.0, 100.0, 998.0, 4097.0, 21000.0, 2000000.0])
def test_t2_screen_threshold_conservative(thr, nu):
    """The inverted screen threshold must never reject a true hit: every t
    with nlp(t) >= thr must satisfy t^2 >= t2*.  Checked on a dense t grid
    bracketing the threshold plus the deep tail."""
    t2s = S.t2_screen_threshold(thr, nu)
    assert t2s is not None and t2s > 0
    tstar = math.sqrt(t2s)
    ts = np.concatenate(
        [
            np.linspace(0.0, 3 * tstar, 400),
            np.geomspace(max(tstar, 1.0), 1000.0, 50),
        ]
    ).astype(np.float32)
    nlp = np.asarray(S.neglog10_p_from_t(jnp.asarray(ts), float(nu)))
    hits = nlp >= thr
    assert np.all(ts[hits] ** 2 >= t2s), (thr, nu, t2s)
    # ... and it is tight: the screen admits only a thin sub-threshold
    # margin, not half the tile.
    assert float(S.neglog10_p_from_t(jnp.float32(tstar), float(nu))) > 0.5 * thr


def test_t2_screen_threshold_degenerate():
    # Unreachable target: the cap is returned and rejects everything real.
    cap = S.t2_screen_threshold(1e6, 3.0)
    assert cap is not None and cap >= 1e36
    # No meaningful target (threshold margin swallows it): refuse to plan.
    assert S.t2_screen_threshold(0.0, 100.0) is None
    assert S.t2_screen_threshold(-1.0, 100.0) is None


def test_neglog10_p_deep_tail_monotone():
    ts = jnp.asarray(np.linspace(0, 2000, 4001), jnp.float32)
    nlp = np.asarray(S.neglog10_p_from_t(ts, 21000.0))
    assert np.all(np.isfinite(nlp))
    assert np.all(np.diff(nlp) >= -1e-3)  # monotone in |t|
    assert nlp[-1] > 10_000  # p ~ 1e-10000 territory without overflow


@pytest.mark.parametrize("k", [1, 2, 10, 100, 2048, 20480])
def test_chi2_sf_vs_scipy(k):
    for mult in [0.1, 0.5, 1.0, 1.5, 3.0, 10.0, 50.0]:
        s = k * mult
        ours = float(S.neglog10_sf_chi2(jnp.float32(s), float(k)))
        ref = -sps.chi2.logsf(s, k) / math.log(10)
        if math.isinf(ref) or math.isnan(ref):
            continue
        assert abs(ours - ref) / max(ref, 1e-2) < 6e-3, (k, s, ours, ref)


def test_t_from_r_matches_paper_eq3():
    r = jnp.asarray([0.0, 0.1, -0.5, 0.99], jnp.float32)
    n = 1000
    t = np.asarray(S.t_from_r(r, n - 2))
    expected = np.asarray(r) * np.sqrt((n - 2) / (1 - np.asarray(r) ** 2))
    np.testing.assert_allclose(t, expected, rtol=1e-6)


def test_t_from_r_degenerate_clamped():
    t = float(S.t_from_r(jnp.float32(1.0), 100))
    assert np.isfinite(t) and t > 1e4


def test_bh_qvalues_match_reference(rng):
    nlp = np.abs(rng.normal(2, 3, 500)).astype(np.float32)
    p = 10.0 ** -nlp

    def bh_ref(p):
        m = len(p)
        order = np.argsort(p)
        q = np.empty(m)
        prev = 1.0
        for i in range(m - 1, -1, -1):
            prev = min(prev, p[order[i]] * m / (i + 1))
            q[order[i]] = prev
        return q

    ours = 10.0 ** -np.asarray(S.bh_qvalues(jnp.asarray(nlp)))
    np.testing.assert_allclose(ours, bh_ref(p), rtol=1e-4)


def test_lambda_gc_calibrated_on_null(rng):
    t = rng.standard_t(200, 100_000).astype(np.float32)
    lam = float(S.genomic_control_lambda(jnp.asarray(t)))
    assert 0.97 < lam < 1.03
