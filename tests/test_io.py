"""Genotype/phenotype IO: round trips, alignment, malformed input."""
import numpy as np
import pytest

from repro.io import bgen, open_genotypes, pheno, plink


def test_plink_roundtrip(cohort, cohort_files):
    pb = plink.PlinkBed(cohort_files["bed"])
    assert pb.n_samples == len(cohort.sample_ids)
    assert pb.n_markers == len(cohort.marker_ids)
    got = pb.read_dosages(0, pb.n_markers)
    np.testing.assert_array_equal(got, cohort.dosages)
    mid = pb.read_dosages(10, 20)
    np.testing.assert_array_equal(mid, cohort.dosages[10:20])


def test_plink_packed_path(cohort, cohort_files):
    pb = plink.PlinkBed(cohort_files["bed"])
    packed = pb.read_packed(5, 17)
    np.testing.assert_array_equal(
        plink.decode_packed(packed, pb.n_samples), cohort.dosages[5:17]
    )


def test_plink_bad_magic(tmp_path):
    p = tmp_path / "bad.bed"
    p.write_bytes(b"\x00\x00\x00")
    (tmp_path / "bad.bim").write_text("1\trs1\t0\t1\tA\tG\n")
    (tmp_path / "bad.fam").write_text("s1 s1 0 0 0 -9\n")
    with pytest.raises(ValueError, match="magic"):
        plink.PlinkBed(str(p))


def test_plink_truncated(tmp_path, cohort):
    stem = str(tmp_path / "trunc")
    plink.write_plink(stem, cohort.dosages)
    with open(stem + ".bed", "r+b") as f:
        f.truncate(100)
    with pytest.raises(ValueError, match="size"):
        plink.PlinkBed(stem + ".bed")


def test_bgen_roundtrip(cohort, cohort_files):
    bg = bgen.BgenFile(cohort_files["bgen"])
    assert bg.n_samples == len(cohort.sample_ids)
    assert bg.sample_ids == cohort.sample_ids
    got = bg.read_dosages(0, bg.n_markers)
    miss = cohort.dosages == -9
    assert (got[miss] == -9).all()
    np.testing.assert_allclose(got[~miss], cohort.dosages[~miss], atol=1e-2)


def test_bgen_16bit_and_uncompressed(tmp_path, cohort):
    for bits, compress in [(16, True), (8, False)]:
        path = str(tmp_path / f"b{bits}{compress}.bgen")
        bgen.write_bgen(path, cohort.dosages[:50], bits=bits, compress=compress)
        bg = bgen.BgenFile(path)
        got = bg.read_dosages(0, 50)
        miss = cohort.dosages[:50] == -9
        np.testing.assert_allclose(got[~miss], cohort.dosages[:50][~miss], atol=1e-3)


def test_open_genotypes_dispatch(cohort_files, tmp_path, cohort):
    assert isinstance(open_genotypes(cohort_files["bed"]), plink.PlinkBed)
    assert isinstance(open_genotypes(cohort_files["bgen"]), bgen.BgenFile)
    npy = str(tmp_path / "g.npy")
    np.save(npy, cohort.dosages)
    src = open_genotypes(npy)
    np.testing.assert_array_equal(src.read_dosages(3, 9), cohort.dosages[3:9])
    with pytest.raises(ValueError):
        open_genotypes("genotypes.vcf")


def test_table_alignment_shuffled_subset(cohort, cohort_files):
    pt = pheno.read_table(cohort_files["pheno"])
    ct = pheno.read_table(cohort_files["cov"])
    rng = np.random.default_rng(1)
    idx = rng.permutation(len(pt.sample_ids))[:300]
    pt2 = pheno.PhenotypeTable(
        [pt.sample_ids[i] for i in idx], pt.names, pt.values[idx]
    )
    y, c, keep = pheno.align_tables(cohort.sample_ids, pt2, ct)
    assert keep.sum() == 300
    kept = [s for s, k in zip(cohort.sample_ids, keep) if k]
    ref = np.stack([pt.values[pt.sample_ids.index(s)] for s in kept])
    np.testing.assert_allclose(y, ref, atol=1e-5)


def test_table_missing_tokens(tmp_path):
    p = tmp_path / "t.tsv"
    p.write_text("FID\tIID\ttrait\na\ta\t1.5\nb\tb\tNA\nc\tc\t-9\n")
    t = pheno.read_table(str(p))
    assert np.isnan(t.values[1, 0]) and np.isnan(t.values[2, 0])
    assert t.values[0, 0] == pytest.approx(1.5)


def test_table_csv_sniff(tmp_path):
    p = tmp_path / "t.csv"
    p.write_text("id,x,y\ns1,1.0,2.0\ns2,3.0,4.0\n")
    t = pheno.read_table(str(p))
    assert t.names == ["x", "y"]
    assert t.sample_ids == ["s1", "s2"]
