"""Multi-device semantics, validated on 8 fake host devices in a subprocess
(unit tests must keep seeing 1 device, so the flag is set only in the child
process).  Covers: sharded GWAS step vs single-device reference, logical-axis
rules, compressed psum accuracy, collective parsing calibration."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_CHILD = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    out = {}
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # ---- sharded dense GWAS step equals single-device reference
    from repro.core.screening import build_dense_step
    from repro.core.association import AssocOptions
    rng = np.random.default_rng(0)
    M, N, Pn = 16, 64, 8
    g = rng.integers(0, 3, size=(M, N)).astype(np.float32)
    y = rng.normal(size=(N, Pn)).astype(np.float32)
    y = (y - y.mean(0)) / y.std(0)
    ref_step = build_dense_step(n_samples=N, n_covariates=0, options=AssocOptions())
    ref = ref_step(jnp.asarray(g), jnp.asarray(y))
    for mode in ("mp", "sample"):
        step = build_dense_step(n_samples=N, n_covariates=0, options=AssocOptions(),
                                mesh=mesh, mode=mode)
        got = step(jnp.asarray(g), jnp.asarray(y))
        out[f"dense_{mode}_err"] = float(jnp.abs(got["t"] - ref["t"]).max())

    # ---- fused engine under shard_map
    from repro.core.screening import build_fused_step
    from repro.kernels.gwas_dot import ops as kops
    codes = rng.choice([0,1,2,3], p=[.3,.02,.38,.3], size=(M*4, N)).astype(np.uint8)
    mean, inv, valid = kops.marker_stats_from_codes(codes)
    packed = kops.pack_tiled(codes, 32)
    fstep = build_fused_step(n_samples=N, n_covariates=0, options=AssocOptions(),
                             mesh=mesh, block_m=16, block_n=32, block_p=4)
    fref = build_fused_step(n_samples=N, n_covariates=0, options=AssocOptions(),
                            block_m=16, block_n=32, block_p=4)
    a = fstep(jnp.asarray(packed), jnp.asarray(mean.reshape(-1,1)),
              jnp.asarray(inv.reshape(-1,1)), jnp.asarray(valid), jnp.asarray(y))
    b = fref(jnp.asarray(packed), jnp.asarray(mean.reshape(-1,1)),
             jnp.asarray(inv.reshape(-1,1)), jnp.asarray(valid), jnp.asarray(y))
    out["fused_err"] = float(jnp.abs(a["t"] - b["t"]).max())

    # ---- lmm step (rotation + whitened projection + epilogue) under pjit
    from repro.core.screening import build_lmm_step
    a_rot = np.linalg.qr(rng.normal(size=(N, N)))[0].astype(np.float32)
    qhat = np.linalg.qr(rng.normal(size=(N, 3)))[0].astype(np.float32)
    lref = build_lmm_step(n_samples=N, n_covariates=2, options=AssocOptions())
    lsh = build_lmm_step(n_samples=N, n_covariates=2, options=AssocOptions(), mesh=mesh)
    la = lref(jnp.asarray(g), jnp.asarray(a_rot), jnp.asarray(qhat), jnp.asarray(y))
    lb = lsh(jnp.asarray(g), jnp.asarray(a_rot), jnp.asarray(qhat), jnp.asarray(y))
    out["lmm_err"] = float(jnp.abs(la["t"] - lb["t"]).max())

    # ---- compressed psum
    from repro.runtime.compression import compressed_psum
    vals = rng.normal(size=(8, 256)).astype(np.float32)
    def local(x):
        return compressed_psum(x, "data", bits=8)
    f = jax.shard_map(local, mesh=mesh, in_specs=P("data", None),
                      out_specs=P("data", None), check_vma=False)
    got = np.asarray(f(jnp.asarray(vals)))
    # psum over 'data' sums groups of rows {0,2,4,6} and {1,3,5,7}? No:
    # data axis has 4 shards of 2 rows; each shard's psum = sum over shards.
    expect = vals.reshape(4, 2, 256).sum(0)
    expect = np.tile(expect, (4, 1)).reshape(8, 256)
    rms = float(np.sqrt(np.mean((got - expect) ** 2)) / np.sqrt(np.mean(expect ** 2)))
    out["psum_rms"] = rms

    # ---- logical rules + divisibility degrade
    from repro.runtime.sharding import DEFAULT_RULES
    spec = DEFAULT_RULES.physical(("batch", None, "heads"), mesh)
    out["spec"] = str(spec)
    from repro.train.partition import divisible_sharding
    s = divisible_sharding(mesh, P("data", "model"), (3, 64))
    out["degraded"] = str(s.spec)

    # ---- manual all-to-all MoE == GSPMD MoE under the same scope
    import dataclasses
    from repro.configs import get_config
    from repro.models import transformer as TR
    from repro.models.sharding_ctx import activation_sharding_scope
    cfg0 = get_config("granite-moe-1b-a400m").reduced()
    cfg0 = dataclasses.replace(
        cfg0, moe=dataclasses.replace(cfg0.moe, capacity_factor=float(cfg0.moe.n_experts))
    )
    tr_params = TR.init_params(cfg0, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg0.vocab)
    tr_pos = jnp.broadcast_to(jnp.arange(16), (4, 16))
    impl_outs = {}
    for impl in ("gspmd", "manual"):
        cfg_i = dataclasses.replace(cfg0, moe_impl=impl)
        def fwd(p_, t_, po_, cfg_i=cfg_i):
            with activation_sharding_scope(mesh, None):
                return TR.forward_train(cfg_i, p_, t_, po_)
        o, _ = jax.jit(fwd)(tr_params, toks, tr_pos)
        impl_outs[impl] = o
    out["moe_manual_err"] = float(jnp.abs(impl_outs["gspmd"] - impl_outs["manual"]).max())

    # ---- train step on mesh: loss finite, params sharded
    from repro.train.train_step import TrainStepConfig, build_train_step, init_train_state
    from repro.train.data import make_batch
    from repro.configs.base import ShapeConfig
    cfg = get_config("granite-moe-1b-a400m").reduced()
    tcfg = TrainStepConfig(n_microbatches=2)
    params, opt = init_train_state(cfg, tcfg, jax.random.PRNGKey(0), max_positions=64)
    step = build_train_step(cfg, tcfg=tcfg, mesh=mesh, donate=False)
    shape = ShapeConfig("t", 32, 8, "train")
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, 0).items()}
    p2, o2, m = step(params, opt, batch)
    out["mesh_train_loss"] = float(m["loss"])

    print(json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def child_results():
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True, timeout=900
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_dense_modes_match_reference(child_results):
    assert child_results["dense_mp_err"] < 1e-3
    assert child_results["dense_sample_err"] < 1e-3


def test_sharded_fused_matches_reference(child_results):
    assert child_results["fused_err"] < 1e-3


def test_sharded_lmm_matches_reference(child_results):
    assert child_results["lmm_err"] < 1e-3


def test_compressed_psum_error_budget(child_results):
    assert child_results["psum_rms"] < 0.01  # ~0.4% typical for int8


def test_logical_rules_first_fit(child_results):
    assert "data" in child_results["spec"] and "model" in child_results["spec"]


def test_divisibility_degrade(child_results):
    # dim of size 3 cannot shard 4 ways -> replicated; 64 shards 2-way fine
    assert child_results["degraded"] == "PartitionSpec(None, 'model')"


def test_train_step_on_mesh(child_results):
    assert np.isfinite(child_results["mesh_train_loss"])


def test_manual_moe_matches_gspmd(child_results):
    assert child_results["moe_manual_err"] < 1e-3


def test_collective_parser_formulas():
    from repro.launch.roofline import parse_collectives

    hlo = """
      %ar = f32[1024,256] all-reduce(f32[1024,256] %x), replica_groups={{0,1,2,3}}
      %ag = bf16[512] all-gather(bf16[128] %y), replica_groups=[2,4]<=[8]
      %cp = f32[64,64] collective-permute(f32[64,64] %z)
    """
    colls = parse_collectives(hlo)
    kinds = {c.kind: c for c in colls}
    ar = kinds["all-reduce"]
    assert ar.group_size == 4 and ar.out_bytes == 1024 * 256 * 4
    assert abs(ar.wire_bytes - 2 * ar.out_bytes * 3 / 4) < 1
    ag = kinds["all-gather"]
    assert ag.group_size == 4
    cp = kinds["collective-permute"]
    assert cp.wire_bytes == 64 * 64 * 4
