"""Multi-file genotype sources, batch planning, the engine registry, and
the packaged CLI end to end."""
import json
import os

import numpy as np
import pytest

from repro.core import engines as E
from repro.core.screening import GenomeScan, ScanConfig
from repro.io import MultiFileSource, open_genotypes, plink
from repro.io.multifile import expand_genotype_paths, natural_key
from repro.io.synth import write_split_plink
from repro.runtime.prefetch import BatchPlanner


@pytest.fixture(scope="module")
def split_beds(cohort, tmp_path_factory):
    stem = str(tmp_path_factory.mktemp("multifile") / "cohort")
    return write_split_plink(cohort, stem, n_shards=3)


@pytest.fixture(scope="module")
def single_source(cohort_files):
    return plink.PlinkBed(cohort_files["bed"])


def _cfg(**kw):
    base = dict(batch_markers=128, block_m=64, block_n=128, block_p=64)
    base.update(kw)
    return ScanConfig(**base)


# ------------------------------------------------------------------- sources


def test_open_genotypes_glob_builds_multifile(split_beds):
    pattern = split_beds[0].replace("chr1", "chr*")
    src = open_genotypes(pattern)
    assert isinstance(src, MultiFileSource)
    assert src.n_shards == 3
    assert src.n_markers == sum(plink.PlinkBed(p).n_markers for p in split_beds)


def test_open_genotypes_comma_list(split_beds):
    src = open_genotypes(",".join(split_beds))
    assert isinstance(src, MultiFileSource)
    assert [s.bed_path for s in src.sources] == split_beds


def test_open_genotypes_single_path_unchanged(cohort_files):
    assert isinstance(open_genotypes(cohort_files["bed"]), plink.PlinkBed)


def test_natural_sort_orders_chromosomes():
    paths = [f"c_chr{i}.bed" for i in (10, 2, 1, 22, 11)]
    assert sorted(paths, key=natural_key) == [
        "c_chr1.bed", "c_chr2.bed", "c_chr10.bed", "c_chr11.bed", "c_chr22.bed"
    ]


def test_glob_matching_nothing_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="matched nothing"):
        expand_genotype_paths(str(tmp_path / "nope_chr*.bed"))


def test_mismatched_shards_rejected(cohort, split_beds, tmp_path):
    odd = plink.write_plink(
        str(tmp_path / "odd"), cohort.dosages[:10, :-3],
        sample_ids=cohort.sample_ids[:-3],
    )
    with pytest.raises(ValueError, match="sample counts differ"):
        MultiFileSource([plink.PlinkBed(split_beds[0]), plink.PlinkBed(odd)])


def test_reads_match_across_boundaries(cohort, split_beds):
    src = open_genotypes(",".join(split_beds))
    assert src.n_markers == cohort.dosages.shape[0]
    # a range spanning all three shards
    got = src.read_dosages(100, src.n_markers - 50)
    np.testing.assert_array_equal(got, cohort.dosages[100 : src.n_markers - 50])
    packed = src.read_packed(100, src.n_markers - 50)
    np.testing.assert_array_equal(
        plink.decode_packed(packed, src.n_samples), cohort.dosages[100 : src.n_markers - 50]
    )
    assert src.marker_ids == cohort.marker_ids


# ------------------------------------------------------------------- planner


def test_planner_respects_shard_boundaries(split_beds):
    src = open_genotypes(",".join(split_beds))
    plan = BatchPlanner(100).plan(src)
    bounds = src.shard_boundaries
    covered = []
    for b in plan:
        assert b.hi - b.lo <= 100
        assert bounds[b.source_id] <= b.lo and b.hi <= bounds[b.source_id + 1]
        assert b.local_lo == b.lo - bounds[b.source_id]
        assert b.local_hi == b.hi - bounds[b.source_id]
        covered.append((b.lo, b.hi))
    # full coverage, in order, no overlap
    assert covered[0][0] == 0 and covered[-1][1] == src.n_markers
    assert all(a[1] == b[0] for a, b in zip(covered[:-1], covered[1:]))
    assert [b.index for b in plan] == list(range(len(plan)))


def test_planner_plain_source_fixed_stride(single_source):
    plan = BatchPlanner(128).plan(single_source)
    assert len(plan) == (single_source.n_markers + 127) // 128
    assert all(b.source_id == 0 and b.local_lo == b.lo for b in plan)


# ---------------------------------------------------------- scan equivalence


def test_multifile_scan_identical_to_single_dense(cohort, single_source, split_beds):
    multi = open_genotypes(split_beds[0].replace("chr1", "chr*"))
    a = GenomeScan(single_source, cohort.phenotypes, cohort.covariates, config=_cfg()).run()
    b = GenomeScan(multi, cohort.phenotypes, cohort.covariates, config=_cfg()).run()
    np.testing.assert_array_equal(a.best_nlp, b.best_nlp)
    np.testing.assert_array_equal(a.best_marker, b.best_marker)
    assert set(map(tuple, a.hits)) == set(map(tuple, b.hits))
    np.testing.assert_array_equal(a.valid, b.valid)
    np.testing.assert_allclose(a.maf, b.maf)
    planted = {(m, t) for m, t, _ in cohort.effects}
    assert planted <= set(map(tuple, b.hits))


def test_multifile_scan_identical_to_single_fused(cohort, single_source, split_beds):
    multi = open_genotypes(",".join(split_beds))
    a = GenomeScan(single_source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="fused")).run()
    b = GenomeScan(multi, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="fused")).run()
    np.testing.assert_array_equal(a.best_nlp, b.best_nlp)
    np.testing.assert_array_equal(a.best_marker, b.best_marker)
    assert set(map(tuple, a.hits)) == set(map(tuple, b.hits))


# ------------------------------------------------------------------ registry


def test_engine_registry_roundtrip():
    assert set(E.available_engines()) >= {"dense", "fused"}
    assert isinstance(E.get_engine("dense"), E.DenseEngine)
    assert isinstance(E.get_engine("fused"), E.FusedEngine)


def test_engine_registry_unknown_lists_available():
    with pytest.raises(ValueError, match="dense"):
        E.get_engine("warp-drive")


def test_engine_registry_custom_engine_drives_scan(cohort, single_source):
    @E.register_engine("dense-test-alias")
    class AliasEngine(E.DenseEngine):
        pass

    try:
        res = GenomeScan(
            single_source, cohort.phenotypes, cohort.covariates,
            config=_cfg(engine="dense-test-alias"),
        ).run()
        planted = {(m, t) for m, t, _ in cohort.effects}
        assert planted <= {(m, t) for m, t in res.hits}
    finally:
        E._REGISTRY.pop("dense-test-alias")


def test_fused_engine_rejects_sample_mode(cohort, single_source):
    with pytest.raises(ValueError, match="marker x phenotype"):
        GenomeScan(single_source, cohort.phenotypes, cohort.covariates,
                   config=_cfg(engine="fused", mode="sample"))


# ----------------------------------------------------------------------- CLI


def test_cli_end_to_end_multifile(cohort, cohort_files, split_beds, tmp_path):
    from repro.launch.gwas import main

    out = tmp_path / "results"
    main([
        "--genotypes", split_beds[0].replace("chr1", "chr*"),
        "--pheno", cohort_files["pheno"],
        "--covar", cohort_files["cov"],
        "--out", str(out),
        "--batch-markers", "128",
    ])
    summary = json.loads((out / "summary.json").read_text())
    assert summary["markers"] == cohort.dosages.shape[0]
    assert summary["traits"] == cohort.phenotypes.shape[1]
    assert summary["genotype_shards"] == 3
    assert summary["hits"] >= len(cohort.effects)

    lines = (out / "hits.tsv").read_text().strip().splitlines()
    assert lines[0].split("\t") == ["marker", "trait", "r", "t", "neglog10p"]
    found = {(row.split("\t")[0], row.split("\t")[1]) for row in lines[1:]}
    for m, t, _ in cohort.effects:
        assert (cohort.marker_ids[m], f"trait{t}") in found

    best = (out / "per_trait_best.tsv").read_text().strip().splitlines()
    assert len(best) == 1 + cohort.phenotypes.shape[1]
