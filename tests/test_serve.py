"""repro.serve: the persistent multi-tenant scan service (DESIGN.md §16).

Correctness contract under test: every table a serve request produces is
byte-identical to a fresh offline scan of the same panel/window — under
concurrent interleaved clients, warm-cache eviction, and fair-share
scheduling; plus the policy/queue/cache mechanics unit-tested directly.
"""
from __future__ import annotations

import dataclasses
import filecmp
import os
import threading
import time

import numpy as np
import pytest

TABLES = ("hits.tsv", "per_trait_best.tsv", "qc.tsv")
GRID = dict(batch_markers=128, block_m=64, block_n=128, block_p=4,
            trait_block=4)


# --------------------------------------------------------------- fixtures


@pytest.fixture(scope="module")
def study(cohort_files):
    from repro.api import Study

    return Study.from_files(
        cohort_files["bed"], cohort_files["pheno"], cohort_files["cov"]
    )


@pytest.fixture(scope="module")
def plan_kwargs():
    from repro.api import GridSpec

    return dict(grid=GridSpec(**GRID), hit_threshold_nlp=2.0)


def _offline(study, plan_kwargs, out_dir, **run_kwargs):
    from repro.api import TsvWriter

    session = study.plan(**plan_kwargs).run(resume=False, **run_kwargs)
    session.stream_to(TsvWriter(str(out_dir)))
    return session


def _same_tables(dir_a, dir_b):
    for name in TABLES:
        assert filecmp.cmp(
            os.path.join(str(dir_a), name), os.path.join(str(dir_b), name),
            shallow=False,
        ), f"{name} differs between {dir_a} and {dir_b}"


# ------------------------------------------------- deficit round robin


class TestDeficitRoundRobin:
    def test_weighted_shares(self):
        from repro.serve import DeficitRoundRobin

        drr = DeficitRoundRobin(quantum=1.0)
        drr.enroll("a", range(0, 100), weight=1.0)
        drr.enroll("b", range(100, 200), weight=3.0)
        leased = [drr.select(1)[0] for _ in range(40)]
        from_b = sum(1 for i in leased if i >= 100)
        # 3:1 weights -> b gets ~3/4 of the leases
        assert 24 <= from_b <= 36

    def test_small_request_bounded_by_rounds(self):
        from repro.serve import DeficitRoundRobin

        drr = DeficitRoundRobin(quantum=2.0)
        drr.enroll("big", range(1000), weight=1.0)
        drr.enroll("small", range(1000, 1003), weight=1.0)
        order = [drr.select(1)[0] for _ in range(20)]
        # all three small items leased within the first few rounds
        assert {i for i in order if i >= 1000} == {1000, 1001, 1002}
        assert max(order.index(i) for i in (1000, 1001, 1002)) < 10

    def test_retire_returns_unleased(self):
        from repro.serve import DeficitRoundRobin

        drr = DeficitRoundRobin(quantum=1.0)
        drr.enroll("r", [1, 2, 3, 4])
        got = drr.select(2)
        assert sorted(got + drr.retire("r")) == [1, 2, 3, 4]
        assert drr.pending_count() == 0
        assert drr.retire("r") == []            # idempotent

    def test_drained_queue_leaves_rotation(self):
        from repro.serve import DeficitRoundRobin

        drr = DeficitRoundRobin(quantum=10.0)
        drr.enroll("a", [1, 2])
        assert drr.select(8) == [1, 2]
        assert drr.queue_sizes() == {}
        drr.enroll("b", [5])
        assert drr.select(1) == [5]

    def test_validation(self):
        from repro.serve import DeficitRoundRobin

        with pytest.raises(ValueError, match="quantum"):
            DeficitRoundRobin(quantum=0.0)
        with pytest.raises(ValueError, match="weight"):
            DeficitRoundRobin().enroll("r", [1], weight=-1.0)


# ------------------------------------------------- persistent work queue


class TestPersistentWorkQueue:
    def test_claim_blocks_until_extend(self):
        from repro.runtime.workqueue import WorkQueue

        wq = WorkQueue(0, persistent=True)
        got = []

        def worker():
            while (idx := wq.claim("w", block=True)) is not None:
                got.append(idx)
                wq.complete("w", idx)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        time.sleep(0.1)
        assert got == []                        # parked on the empty queue
        wq.extend([7, 8])
        deadline = time.time() + 5.0
        while len(got) < 2 and time.time() < deadline:
            time.sleep(0.01)
        assert sorted(got) == [7, 8]
        wq.stop()                               # releases the blocked claim
        t.join(timeout=5.0)
        assert not t.is_alive()

    def test_policy_orders_leases(self):
        from repro.runtime.workqueue import WorkQueue
        from repro.serve import DeficitRoundRobin

        drr = DeficitRoundRobin(quantum=1.0)
        wq = WorkQueue(0, policy=drr, persistent=True)
        drr.enroll("a", [0, 1], weight=1.0)
        drr.enroll("b", [10, 11], weight=1.0)
        wq.kick()
        got = []
        while (idx := wq.claim("w", block=False)) is not None:
            got.append(idx)
            wq.complete("w", idx)
        assert sorted(got) == [0, 1, 10, 11]
        # round-robin: the two requests interleave rather than run back-to-back
        assert got[0] // 10 != got[1] // 10
        assert wq.remaining() == 0


# ----------------------------------------------------- cache mechanics


class TestDeviceLRUPinning:
    def test_pins_block_eviction_and_stats(self):
        from repro.core.engines import DeviceLRU

        made, lru = [], DeviceLRU(2, lambda k: made.append(k) or f"v{k}")
        lru.pin("a")
        lru.get("a")
        lru.get("b")
        lru.get("c")                            # capacity 2: evicts b, not a
        assert lru.get("a") == "va"             # still resident (pinned)
        st = lru.stats()
        assert st["evictions"] >= 1 and st["pinned"] == 1
        assert "b" not in [k for k in made if lru.stats()["resident"]] or True
        lru.unpin("a")
        lru.get("d")
        lru.get("e")                            # now a can go
        assert lru.n_pinned == 0
        assert lru.stats()["resident"] <= 2

    def test_unpin_underflow_raises(self):
        from repro.core.engines import DeviceLRU

        lru = DeviceLRU(2, lambda k: k)
        with pytest.raises(KeyError):
            lru.unpin("never-pinned")


# ------------------------------------------------------ serve metrics


def test_metrics_request_latency_percentiles():
    from repro.api.metrics import ScanMetrics

    m = ScanMetrics()
    assert m.serve_summary() is None            # no serve traffic: absent
    for w in (0.1, 0.2, 0.3, 0.4, 1.0):
        m.record_request(w, kind="window")
    m.record_request(5.0, kind="panel")
    m.set_queue_depth(3)
    m.set_cache_stats("device_state", {"hits": 9, "misses": 1})
    s = m.serve_summary()
    assert s["requests"] == 6
    assert s["latency"]["p50_s"] == pytest.approx(0.35, abs=1e-6)
    assert s["latency"]["max_s"] == 5.0
    assert s["latency_by_kind"]["window"]["n"] == 5
    assert s["queue_depth"] == 3
    assert s["caches"]["device_state"]["hits"] == 9
    assert "serve" in m.summary()


def test_marker_window_validation(study, plan_kwargs):
    plan = study.plan(**plan_kwargs)
    with pytest.raises(ValueError, match="marker_window"):
        plan.run(resume=False, marker_window=(50, 50))
    with pytest.raises(ValueError, match="marker_window"):
        plan.run(resume=False, marker_window=(-1, 50))
    with pytest.raises(ValueError, match="marker_window"):
        plan.run(resume=False, marker_window=(0, 601))
    session = plan.run(resume=False, marker_window=(130, 140))
    # widened outward to batch boundaries (batch_markers=128)
    assert session.window_covered == (128, 256)


# --------------------------------------------------------- the service


@pytest.fixture()
def host(study, plan_kwargs, tmp_path):
    from repro.serve import ServeHost

    h = ServeHost(devices=1, max_resident_slots=4,
                  out_root=str(tmp_path / "serve"))
    h.admit_study("toy", study, **plan_kwargs)
    yield h
    h.shutdown()
    assert h.registry.n_pinned == 0


def test_interleaved_clients_byte_identical(host, study, plan_kwargs, tmp_path):
    """Concurrent clients on one study — an uploaded panel and window
    queries interleaving on the shared pool — each byte-identical to its
    sequential offline scan."""
    rng = np.random.default_rng(3)
    panel = rng.standard_normal((study.n_samples, 8)).astype(np.float32)
    panel[:, :2] += np.asarray(study.phenotypes)[:, :2]   # planted hits
    names = [f"p{i}" for i in range(8)]
    rids: dict = {}

    def upload():
        rids["panel"] = host.submit_panel("toy", panel, names)
        host.wait(rids["panel"], timeout=300)

    def windows():
        for lo, hi in ((0, 128), (200, 500)):
            rids[(lo, hi)] = host.submit_window("toy", lo, hi)
            host.wait(rids[(lo, hi)], timeout=300)

    threads = [threading.Thread(target=upload), threading.Thread(target=windows)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    info = host.request_info(rids["panel"])
    assert info["status"] == "done", info["error"]
    ref = tmp_path / "offline_panel"
    _offline(
        dataclasses.replace(study, phenotypes=panel, trait_names=names),
        plan_kwargs, ref,
    )
    _same_tables(ref, os.path.dirname(host.result_path(rids["panel"], TABLES[0])))

    for lo, hi in ((0, 128), (200, 500)):
        info = host.request_info(rids[(lo, hi)])
        assert info["status"] == "done", info["error"]
        ref = tmp_path / f"offline_w{lo}"
        sess = _offline(study, plan_kwargs, ref, marker_window=(lo, hi))
        assert tuple(info["covered"]) == sess.window_covered
        _same_tables(
            ref, os.path.dirname(host.result_path(rids[(lo, hi)], TABLES[0]))
        )

    served = host.metrics_summary()["serve"]
    assert served["requests"] == 3
    assert served["latency"]["p95_s"] >= served["latency"]["p50_s"]
    assert served["caches"]["device_state"]["hits"] >= 1


def test_eviction_and_readmission(study, plan_kwargs, tmp_path):
    """A second state forcing ``DeviceLRU`` eviction of the resident
    study's slot, then re-admission on the next query — still
    byte-identical, with the churn visible in the cache counters."""
    from repro.serve import ServeHost

    host = ServeHost(devices=1, max_resident_slots=1,
                     out_root=str(tmp_path / "serve"))
    try:
        host.admit_study("toy", study, **plan_kwargs)
        rid1 = host.submit_window("toy", 0, 128)
        host.wait(rid1, timeout=300)
        # An uploaded panel's ephemeral req:<rid> state takes the single
        # slot, evicting the resident study's warm slot.
        rng = np.random.default_rng(4)
        panel = rng.standard_normal((study.n_samples, 4)).astype(np.float32)
        pid = host.submit_panel("toy", panel)
        host.wait(pid, timeout=300)
        st = host.registry.slot_cache_stats()
        assert st["evictions"] >= 1
        # Re-admission: the study's slot is rebuilt (a miss, not an
        # error), and the served bytes are unchanged.
        rid2 = host.submit_window("toy", 0, 128)
        host.wait(rid2, timeout=300)
        _same_tables(
            os.path.dirname(host.result_path(rid1, TABLES[0])),
            os.path.dirname(host.result_path(rid2, TABLES[0])),
        )
        ref = tmp_path / "offline_w0"
        _offline(study, plan_kwargs, ref, marker_window=(0, 128))
        _same_tables(ref, os.path.dirname(host.result_path(rid2, TABLES[0])))
        assert host.registry.slot_cache_stats()["misses"] >= 3
    finally:
        host.shutdown()


def test_fair_share_no_starvation(host, study):
    """A large panel drain must not starve a small interactive query: the
    window query completes while the big request is still running."""
    rng = np.random.default_rng(6)
    big = rng.standard_normal((study.n_samples, 512)).astype(np.float32)
    big_rid = host.submit_panel("toy", big)
    # Wait until the big request is actually draining on the pool.
    deadline = time.time() + 120.0
    while time.time() < deadline:
        if (host.request_info(big_rid)["status"] == "running"
                and host.executor.queue.remaining() > 0):
            break
        time.sleep(0.02)
    else:
        pytest.fail("big panel request never started draining")

    t0 = time.perf_counter()
    small_rid = host.submit_window("toy", 0, 128)
    small = host.wait(small_rid, timeout=300)
    small_wall = time.perf_counter() - t0
    big_status = host.request_info(big_rid)["status"]
    assert small["status"] == "done", small["error"]
    # The regression being guarded: FIFO would park the 3-cell query
    # behind ~640 big cells.  Under DRR it completes while the big panel
    # is still draining.
    assert big_status == "running", (
        f"big request already {big_status}; small wall {small_wall:.3f}s — "
        "queue too fast to exercise fairness, enlarge the big panel"
    )
    big_info = host.wait(big_rid, timeout=600)
    assert big_info["status"] == "done", big_info["error"]
    lat = host.metrics_summary()["serve"]["latency_by_kind"]
    assert lat["window"]["p95_s"] < lat["panel"]["max_s"]


def test_clean_shutdown_mid_request_releases_everything(study, plan_kwargs,
                                                        tmp_path):
    """Shutdown with a request in flight: the request fails (not hangs),
    no serve worker threads survive, and no slot stays pinned."""
    from repro.serve import ServeHost

    host = ServeHost(devices=1, out_root=str(tmp_path / "serve"))
    host.admit_study("toy", study, **plan_kwargs)
    rng = np.random.default_rng(8)
    panel = rng.standard_normal((study.n_samples, 256)).astype(np.float32)
    rid = host.submit_panel("toy", panel)
    deadline = time.time() + 120.0
    while (host.request_info(rid)["status"] == "queued"
           and time.time() < deadline):
        time.sleep(0.02)
    host.shutdown()
    info = host.wait(rid, timeout=60)
    assert info["status"] in ("failed", "done")
    assert not host.executor.alive
    leftovers = [
        t.name for t in threading.enumerate()
        if t.name.startswith(("serve-worker", "serve-request"))
    ]
    assert leftovers == []
    assert host.registry.n_pinned == 0
    # idempotent
    host.shutdown()


def test_admit_validation(study, host):
    with pytest.raises(ValueError, match="already admitted"):
        host.admit_study("toy", study)
    with pytest.raises(ValueError, match="not servable"):
        host.admit_study("toy2", study, checkpoint_dir="/tmp/x")
    with pytest.raises(KeyError, match="unknown study"):
        host.submit_window("nope", 0, 10)
    with pytest.raises(ValueError, match="panel must be"):
        host.submit_panel("toy", np.zeros((3, 2), np.float32))
    with pytest.raises(KeyError, match="unknown request"):
        host.request_info("r0000-nope")
    with pytest.raises(KeyError, match="unknown result file"):
        host.result_path("any", "etc/passwd")


# ----------------------------------------------------------- CLI surface


def test_exec_backend_help_lists_registry():
    from repro.launch.gwas import build_scan_parser
    from repro.runtime.workqueue import available_backends

    help_text = build_scan_parser().format_help()
    for backend in available_backends():
        assert backend in help_text
    with pytest.raises(SystemExit):
        build_scan_parser().parse_args([
            "--genotypes", "x.bed", "--pheno", "p.tsv", "--out", "o",
            "--exec-backend", "smoke-signals",
        ])


def test_serve_spec_validation():
    from repro.api import ServeSpec

    ServeSpec().validate()
    with pytest.raises(ValueError, match="port"):
        ServeSpec(port=70000).validate()
    with pytest.raises(ValueError, match="max_resident_slots"):
        ServeSpec(max_resident_slots=0).validate()
    with pytest.raises(ValueError, match="drr_quantum"):
        ServeSpec(drr_quantum=0.0).validate()
